"""Per-architecture smoke tests: reduced configs, one forward/train step
on CPU, asserting output shapes and no NaNs (assignment requirement f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.configs.base import shape_supported
from repro.models import build_model
from repro.models.sharding import init_params

ARCHS = list(list_archs())


def make_batch(cfg, key, B=2, S=32):
    kt, kl, kp = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab),
    }
    if cfg.frontend != "none":
        batch["prefix"] = jax.random.normal(
            kp, (B, cfg.n_prefix, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(model.specs, key)
    batch = make_batch(cfg, key)
    loss, metrics = model.loss_fn(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step_no_nans(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = init_params(model.specs, key)
    batch = make_batch(cfg, key, B=2, S=16)

    @jax.jit
    def step(p, b):
        (loss, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(p, b)
        return loss, grads

    loss, grads = step(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert flat, "no grads produced"
    for g in flat:
        assert np.isfinite(np.asarray(g, dtype=np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_and_decode(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = init_params(model.specs, key)
    B, S, max_seq = 2, 8, 24
    batch = make_batch(cfg, key, B=B, S=S)
    logits, cache = model.prefill_fn(params, batch, max_seq)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: prefill NaN"
    # greedy-decode two tokens
    pos0 = S + (cfg.n_prefix if cfg.family in () else 0)
    tok = jnp.argmax(logits, axis=-1)[:, None]
    position = jnp.full((B,), S, dtype=jnp.int32)
    for step in range(2):
        logits, cache = model.decode_fn(params, cache, tok, position + step)
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all(), f"{arch}: decode NaN"
        tok = jnp.argmax(logits, axis=-1)[:, None]


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_counts(arch):
    """The published configs must roughly match their nameplate sizes."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "qwen3-moe-30b-a3b": (25e9, 36e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
        "qwen1.5-4b": (3.0e9, 5.0e9),
        "glm4-9b": (8e9, 12e9),
        "llama3.2-3b": (2.6e9, 4.0e9),
        "gemma-7b": (7e9, 10e9),
        "llava-next-34b": (30e9, 40e9),
        "whisper-small": (0.2e9, 0.35e9),
        "mamba2-1.3b": (1.0e9, 1.7e9),
    }[cfg.name]
    assert expected[0] <= n <= expected[1], f"{cfg.name}: {n:.3e}"


def test_moe_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    active = cfg.active_param_count()
    # "a32b": ~32B active (embeddings included here, so allow slack)
    assert 25e9 <= active <= 45e9, active


def test_long_context_support_flags():
    for arch in ARCHS:
        cfg = get_config(arch)
        skip = shape_supported(cfg, "long_500k")
        if cfg.family in ("ssm", "hybrid"):
            assert skip is None, arch
        else:
            assert skip is not None, arch


def test_decode_matches_prefill_logits():
    """Decode step at position S must reproduce the prefill's next-token
    logits when fed the same context (dense reference arch)."""
    cfg = get_smoke_config("llama3.2-3b")
    model = build_model(cfg)
    key = jax.random.PRNGKey(3)
    params = init_params(model.specs, key)
    B, S = 1, 8
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    # prefill on S+1 tokens
    logits_full, _ = model.prefill_fn(params, {"tokens": toks}, 16)
    # prefill on S tokens, then decode token S
    logits_s, cache = model.prefill_fn(params, {"tokens": toks[:, :S]}, 16)
    logits_dec, _ = model.decode_fn(
        params, cache, toks[:, S:], jnp.full((B,), S, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full), rtol=2e-2, atol=2e-2
    )
