"""Filter pruning (paper Sec. 3): soundness, paper examples, fast path."""

import numpy as np
from hypothesis import given, settings

from repro.core import expr as E
from repro.core.metadata import FULL_MATCH, NO_MATCH, PARTIAL_MATCH
from repro.core.prune_filter import (eval_ranges_tv, eval_tv, extract_ranges,
                                     fully_matching_two_pass)
from repro.core.rowval import matches
from repro.data.table import Table

from helpers import arith_pred, predicates, small_tables


def fig5_table() -> Table:
    """The paper's Figure 5: 4 micro-partitions of tracking data."""
    species = (
        ["Duck", "Eagle", "Frog", "Pike"] * 2              # p0: no Alpine
        + ["Alpine Ibex", "Alpine Marmot"] * 4             # p1: all Alpine, s>=50
        + ["Alpine Ibex", "Duck", "Alpine Marmot", "Pike"] * 2   # p2: mixed
        + ["Alpine Ibex", "Bear", "Alpine Chough", "Wolf"] * 2   # p3: mixed
    )
    s = ([40, 75, 8, 60] * 2
         + [85, 50, 86, 51, 87, 52, 88, 53]
         + [90, 18, 55, 12] * 2
         + [95, 170, 58, 120] * 2)
    return Table.build(
        "tracking_data",
        {"species": np.array(species), "s": np.array(s, dtype=np.int64)},
        rows_per_partition=8,
    )


PRED_FIG5 = E.like(E.col("species"), "Alpine%") & (E.col("s") >= 50)


class TestPaperExamples:
    def test_fig5_three_classes(self):
        tbl = fig5_table()
        tv = eval_tv(PRED_FIG5, tbl.stats)
        assert tv[0] == NO_MATCH          # pruned: no Alpine species
        assert tv[1] == FULL_MATCH        # fully matching (Fig. 5's p3)
        assert tv[2] == PARTIAL_MATCH
        assert tv[3] == PARTIAL_MATCH

    def test_fig5_two_pass_equivalence(self):
        tbl = fig5_table()
        tv = eval_tv(PRED_FIG5, tbl.stats)
        fm = fully_matching_two_pass(PRED_FIG5, tbl.stats)
        np.testing.assert_array_equal(fm, tv == FULL_MATCH)

    def test_sec31_if_expression_not_pruned(self):
        """The guiding query's partition must be retained (paper metadata:
        unit in [feet, meters], altit in [934, 7674])."""
        tbl = Table.build(
            "trails",
            {
                "unit": np.array(["feet", "meters"] * 50),
                "altit": np.linspace(934, 7674, 100),
                "name": np.array(["Marked-A-Ridge", "Basecamp"] * 50),
            },
            rows_per_partition=100,
        )
        pred = (
            E.if_(E.col("unit") == E.lit("feet"),
                  E.col("altit") * 0.3048, E.col("altit")) > 1500
        ) & E.like(E.col("name"), "Marked-%-Ridge")
        assert eval_tv(pred, tbl.stats)[0] == PARTIAL_MATCH

    def test_sec31_if_expression_prunes_feet_partition(self):
        """A partition that is all-'feet' with low altitude IS prunable:
        the IF range collapses to the feet branch (934*0.3048 < 1500)."""
        tbl = Table.build(
            "trails",
            {
                "unit": np.array(["feet"] * 50 + ["meters"] * 50),
                "altit": np.concatenate([
                    np.linspace(934, 4000, 50),   # feet: max 4000*0.3048=1219m
                    np.linspace(100, 1200, 50),   # meters: max 1200 < 1500
                ]),
            },
            rows_per_partition=50,
        )
        pred = E.if_(E.col("unit") == E.lit("feet"),
                     E.col("altit") * 0.3048, E.col("altit")) > 1500
        tv = eval_tv(pred, tbl.stats)
        assert tv[0] == NO_MATCH   # all feet, converted max < 1500
        assert tv[1] == NO_MATCH   # all meters, max < 1500

    def test_imprecise_rewrite_never_full(self):
        """'Marked-%-Ridge' is widened: it may prune but never certify."""
        tbl = Table.build(
            "t", {"name": np.array(["Marked-A-Ridge", "Marked-B-Ridge"] * 4)},
            rows_per_partition=8,
        )
        tv = eval_tv(E.like(E.col("name"), "Marked-%-Ridge"), tbl.stats)
        assert tv[0] == PARTIAL_MATCH  # truly all-matching, but unprovable
        tv2 = eval_tv(E.like(E.col("name"), "Marked-%"), tbl.stats)
        assert tv2[0] == FULL_MATCH    # trailing-% rewrite is exact


class TestSoundness:
    @settings(max_examples=120, deadline=None)
    @given(tbl=small_tables(), pred=predicates())
    def test_no_false_negatives_and_full_is_full(self, tbl, pred):
        """THE invariant: NO => no row matches; FULL => every row matches."""
        tv = eval_tv(pred, tbl.stats)
        for p in range(tbl.num_partitions):
            m = matches(pred, tbl.partition_ctx(p))
            if tv[p] == NO_MATCH:
                assert not m.any(), f"false negative in partition {p}: {pred!r}"
            elif tv[p] == FULL_MATCH:
                assert m.all(), f"bogus FULL in partition {p}: {pred!r}"

    @settings(max_examples=120, deadline=None)
    @given(tbl=small_tables(with_nulls=False), pred=predicates())
    def test_one_pass_equals_two_pass_without_nulls(self, tbl, pred):
        """DESIGN.md §6.1: on null-free data the lattice FULL equals the
        paper's inverted-predicate second pass exactly."""
        tv = eval_tv(pred, tbl.stats)
        fm = fully_matching_two_pass(pred, tbl.stats)
        np.testing.assert_array_equal(fm, tv == FULL_MATCH)

    @settings(max_examples=120, deadline=None)
    @given(tbl=small_tables(with_nulls=True), pred=predicates())
    def test_one_pass_dominates_two_pass_with_nulls(self, tbl, pred):
        """With NULLs the lattice is strictly STRONGER: the two-pass method
        needs a global null guard (see prune_filter.fully_matching_two_pass)
        which loses cases like OR(p_nullcol, q_full) where q alone certifies
        every row.  One-pass FULL must be a superset — and still sound,
        which test_no_false_negatives_and_full_is_full guarantees."""
        tv = eval_tv(pred, tbl.stats)
        fm = fully_matching_two_pass(pred, tbl.stats)
        assert (~fm | (tv == FULL_MATCH)).all()  # two_pass => one_pass

    @settings(max_examples=80, deadline=None)
    @given(tbl=small_tables())
    def test_complex_arithmetic_soundness(self, tbl):
        pred = arith_pred(30.0)
        tv = eval_tv(pred, tbl.stats)
        for p in range(tbl.num_partitions):
            m = matches(pred, tbl.partition_ctx(p))
            if tv[p] == NO_MATCH:
                assert not m.any()
            elif tv[p] == FULL_MATCH:
                assert m.all()


class TestRangeFastPath:
    def test_extract_simple_conjunction(self):
        tbl = fig5_table()
        pred = E.startswith(E.col("species"), "Alpine") & (E.col("s") >= 50)
        ranges = extract_ranges(pred, tbl.stats)
        assert ranges is not None and len(ranges) == 2
        np.testing.assert_array_equal(
            eval_ranges_tv(ranges, tbl.stats), eval_tv(pred, tbl.stats)
        )

    def test_like_trailing_percent_extracts(self):
        tbl = fig5_table()
        ranges = extract_ranges(PRED_FIG5, tbl.stats)
        assert ranges is not None
        np.testing.assert_array_equal(
            eval_ranges_tv(ranges, tbl.stats), eval_tv(PRED_FIG5, tbl.stats)
        )

    def test_disjunction_rejected(self):
        tbl = fig5_table()
        pred = (E.col("s") > 10) | (E.col("s") < 5)
        assert extract_ranges(pred, tbl.stats) is None

    @settings(max_examples=60, deadline=None)
    @given(tbl=small_tables(with_nulls=True))
    def test_fast_path_matches_general(self, tbl):
        pred = (E.col("x") >= -10) & (E.col("x") < 25) & (E.col("y") > 100)
        ranges = extract_ranges(pred, tbl.stats)
        assert ranges is not None
        np.testing.assert_array_equal(
            eval_ranges_tv(ranges, tbl.stats), eval_tv(pred, tbl.stats)
        )


class TestNullSemantics:
    def test_all_null_partition_prunes(self):
        tbl = Table.build(
            "t", {"x": np.arange(8, dtype=np.int64)},
            rows_per_partition=4,
            nulls={"x": np.array([True] * 4 + [False] * 4)},
        )
        tv = eval_tv(E.col("x") >= 0, tbl.stats)
        assert tv[0] == NO_MATCH     # all-null partition: nothing matches
        assert tv[1] == FULL_MATCH

    def test_nulls_block_full(self):
        tbl = Table.build(
            "t", {"x": np.arange(8, dtype=np.int64)},
            rows_per_partition=8,
            nulls={"x": np.array([True] + [False] * 7)},
        )
        tv = eval_tv(E.col("x") >= 0, tbl.stats)
        assert tv[0] == PARTIAL_MATCH  # one null row fails the predicate

    def test_not_with_nulls_is_conservative(self):
        tbl = Table.build(
            "t", {"x": np.full(8, 5, dtype=np.int64)},
            rows_per_partition=8,
            nulls={"x": np.array([True] * 4 + [False] * 4)},
        )
        tv = eval_tv(E.Not(E.col("x") > 10), tbl.stats)
        assert tv[0] == PARTIAL_MATCH  # nulls satisfy neither branch

    def test_is_null_three_way(self):
        tbl = Table.build(
            "t", {"x": np.arange(12, dtype=np.int64)},
            rows_per_partition=4,
            nulls={"x": np.array([True] * 4 + [False] * 4 + [True, False] * 2)},
        )
        tv = eval_tv(E.is_null(E.col("x")), tbl.stats)
        np.testing.assert_array_equal(tv, [FULL_MATCH, NO_MATCH, PARTIAL_MATCH])
        tv = eval_tv(E.is_not_null(E.col("x")), tbl.stats)
        np.testing.assert_array_equal(tv, [NO_MATCH, FULL_MATCH, PARTIAL_MATCH])
