"""Dropped-partition sentinels through all four batched kernels.

Delta-staged planes tombstone dropped partitions in place — stat rows
``(+f32max, -f32max, demote=1)``, join-key rows the same empty interval,
enumeration width 0, block-top-k rows all -inf — and capacity padding
reuses the identical sentinels.  These tests prove, on the interpret-mode
Pallas kernels AND the jnp/host refs, that sentinel rows are
never-prunable-wrong:

  * live partitions' results are bit-identical with and without sentinel
    rows present (no false NO_MATCH, no lost hits),
  * sentinel partitions themselves come out as skips (NO_MATCH / no
    overlap hit) where skipping is correct, and as keeps (Bloom width-0)
    where only keeping is safe,
  * sentinel block-top-k rows contribute nothing to a Sec. 5.4 boundary
    even when a (buggy) mask selects them.

The sharded classes re-prove all of it through the ``shard_map``
partition-sharded launch path: sentinels are placed on *every shard
edge* (first and last slot of each shard of the plane mesh), where an
off-by-one in shard slicing or a boundary-crossing reduction would
surface — outputs must stay bit-identical to the unsharded launch.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.device_stats import _F32_MAX, DeviceStats, tree_entry_for
from repro.core.metadata import ColumnMeta, PartitionStats
from repro.core.prune_join import BlockedBloom
from repro.kernels import ops

MODES = ("ref", "interpret")

SENT = np.array([0, 3, 7, 11])          # sentinel (dropped) positions
LIVE = np.array([i for i in range(12) if i not in SENT])


def _stats(mins, maxs, C=2):
    P = len(mins)
    return PartitionStats(
        columns=[ColumnMeta(f"c{i}", "int") for i in range(C)],
        mins=np.tile(np.asarray(mins, np.float64)[:, None], (1, C)),
        maxs=np.tile(np.asarray(maxs, np.float64)[:, None], (1, C)),
        null_counts=np.zeros((P, C), dtype=np.int64),
        row_counts=np.full(P, 5, dtype=np.int64),
    )


class TestMinmaxSentinels:
    def test_sentinel_rows_are_no_match_and_live_rows_unchanged(self):
        rng = np.random.default_rng(0)
        base_min = rng.integers(-100, 100, LIVE.size).astype(np.float64)
        base_max = base_min + rng.integers(0, 50, LIVE.size)
        mins = np.full(12, np.inf)
        maxs = np.full(12, -np.inf)      # the drop sentinel, pre-cast
        mins[LIVE], maxs[LIVE] = base_min, base_max
        d_all = DeviceStats.stage(_stats(mins, maxs))
        d_live = DeviceStats.stage(_stats(base_min, base_max))
        range_lists = [
            [(0, -50.0, 75.0)],                      # two-sided
            [(1, 0.0, np.inf)],                      # one-sided lo
            [(0, -np.inf, 10.0)],                    # one-sided hi
            [(0, 42.0, 42.0), (1, -80.0, 120.0)],    # equality + conj
        ]
        for mode in MODES:
            tv = ops.prune_ranges_batched_device(range_lists, d_all,
                                                 mode=mode)
            tv_live = ops.prune_ranges_batched_device(range_lists, d_live,
                                                      mode=mode)
            assert (tv[:, SENT] == 0).all(), mode     # sentinel: NO_MATCH
            np.testing.assert_array_equal(tv[:, LIVE], tv_live,
                                          err_msg=mode)

    def test_capacity_tail_sentinels_sliced_off(self):
        """Capacity-padded staging: the logical slice equals dense."""
        rng = np.random.default_rng(1)
        mins = rng.integers(-100, 100, 10).astype(np.float64)
        maxs = mins + 10
        stats = _stats(mins, maxs)
        padded = DeviceStats.stage(stats, capacity=32)
        dense = DeviceStats.stage(stats)
        assert padded.capacity == 32 and padded.num_partitions == 10
        ranges = [[(0, -200.0, 200.0)], [(1, 0.0, 5.0)]]
        for mode in MODES:
            np.testing.assert_array_equal(
                ops.prune_ranges_batched_device(ranges, padded, mode=mode),
                ops.prune_ranges_batched_device(ranges, dense, mode=mode))


class TestJoinOverlapSentinels:
    def test_empty_interval_never_hits_even_extreme_keys(self):
        rng = np.random.default_rng(2)
        lmin = rng.integers(-1000, 1000, LIVE.size).astype(np.float32)
        lmax = lmin + rng.integers(0, 100, LIVE.size).astype(np.float32)
        pmin = np.full(12, _F32_MAX, dtype=np.float32)
        pmax = np.full(12, -_F32_MAX, dtype=np.float32)
        pmin[LIVE], pmax[LIVE] = lmin, lmax
        distinct = [
            np.sort(rng.integers(-1200, 1200, 9)).astype(np.float32),
            np.array([-_F32_MAX, 0.0, _F32_MAX], dtype=np.float32),
            np.array([_F32_MAX], dtype=np.float32),   # == sentinel pmin
        ]
        for mode in MODES:
            hit = ops.join_overlap_batched_device(
                distinct, jnp.asarray(pmin), jnp.asarray(pmax), mode=mode)
            base = ops.join_overlap_batched_device(
                distinct, jnp.asarray(lmin), jnp.asarray(lmax), mode=mode)
            assert (hit[:, SENT] == 0).all(), mode
            np.testing.assert_array_equal(hit[:, LIVE], base[:, :],
                                          err_msg=mode)


class TestTopKInitSentinels:
    def test_masked_in_sentinel_rows_contribute_nothing(self):
        rng = np.random.default_rng(3)
        K, k = 8, 4
        live_rows = np.sort(
            rng.uniform(-100, 100, (LIVE.size, K)).astype(np.float32),
            axis=1)[:, ::-1]
        plane = np.full((12, K), -np.inf, dtype=np.float32)
        plane[LIVE] = live_rows
        # worst case: the mask wrongly selects every sentinel row too
        mask = np.zeros((3, 12), dtype=np.float32)
        mask[0, :] = 1.0
        mask[1, LIVE[:3]] = 1.0
        mask[1, SENT] = 1.0
        mask[2, SENT] = 1.0                    # only sentinels: empty heap
        base_mask = np.zeros((3, LIVE.size), dtype=np.float32)
        base_mask[0, :] = 1.0
        base_mask[1, :3] = 1.0
        for mode in MODES:
            heap = ops.topk_init_batched_device(
                jnp.asarray(plane), mask, k, mode=mode)
            base = ops.topk_init_batched_device(
                jnp.asarray(live_rows), base_mask, k, mode=mode)
            np.testing.assert_array_equal(heap[:2], base[:2], err_msg=mode)
            assert (heap[2] == -np.inf).all(), mode


class TestBloomProbeSentinels:
    def test_width_zero_sentinel_keeps_and_live_unchanged(self):
        rng = np.random.default_rng(4)
        lmin = rng.integers(0, 500, LIVE.size).astype(np.int32)
        lwidth = rng.integers(1, 12, LIVE.size).astype(np.int32)
        pmin = np.zeros(12, dtype=np.int32)
        width = np.zeros(12, dtype=np.int32)   # width 0 = sentinel = keep
        pmin[LIVE], width[LIVE] = lmin, lwidth
        blooms = []
        for _ in range(3):
            b = BlockedBloom(64)
            b.add(rng.integers(0, 500, 40))
            blooms.append(b)
        wmax = int(lwidth.max())
        for mode in MODES:
            hit = ops.bloom_probe_batched_device(
                blooms, jnp.asarray(pmin), jnp.asarray(width), wmax, 1024,
                mode=mode)
            base = ops.bloom_probe_batched_device(
                blooms, jnp.asarray(lmin), jnp.asarray(lwidth), wmax, 1024,
                mode=mode)
            assert (hit[:, SENT] == 1).all(), mode    # keep: never a false prune
            np.testing.assert_array_equal(hit[:, LIVE], base, err_msg=mode)

    def test_modes_agree(self):
        rng = np.random.default_rng(5)
        pmin = rng.integers(0, 300, 12).astype(np.int32)
        width = rng.integers(0, 9, 12).astype(np.int32)
        b = BlockedBloom(32)
        b.add(rng.integers(0, 300, 20))
        got = [np.asarray(ops.bloom_probe_batched_device(
            [b], jnp.asarray(pmin), jnp.asarray(width),
            int(width.max()), 1024, mode=m)) for m in MODES]
        np.testing.assert_array_equal(got[0], got[1])


# ---------------------------------------------------------------------------
# Sharded launch path: sentinels on every shard edge (interpret + ref)
# ---------------------------------------------------------------------------

def _shard_geometry():
    """(mesh, cap, sentinel ids): EVERY shard's first and last slot is a
    sentinel — including the final shard's trailing slot, the classic
    last-chunk off-by-one position — while each shard keeps live
    interior slots (cap = 4 slots per shard, so 2 live per shard)."""
    if len(jax.devices()) < 2:
        pytest.skip("sharded path needs >= 2 host devices "
                    "(REPRO_CPU_DEVICES forces them; '0' opts out)")
    from repro.launch.mesh import make_plane_mesh
    mesh = make_plane_mesh()
    n = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    cap = max(16, 4 * n)
    assert ops.mesh_shards(mesh, cap) > 1
    s = cap // n
    sent = np.array(sorted({i * s for i in range(n)}
                           | {i * s + s - 1 for i in range(n)}))
    return mesh, cap, sent


class TestShardedMinmaxSentinels:
    def test_edge_sentinels_match_unsharded(self):
        mesh, cap, sent = _shard_geometry()
        rng = np.random.default_rng(6)
        mins = rng.integers(-100, 100, cap).astype(np.float64)
        maxs = mins + rng.integers(0, 50, cap)
        mins[sent], maxs[sent] = np.inf, -np.inf     # drop sentinel, pre-cast
        d = DeviceStats.stage(_stats(mins, maxs))
        ranges = [
            [(0, -50.0, 75.0)],
            [(1, 0.0, np.inf)],
            [(0, 42.0, 42.0), (1, -80.0, 120.0)],
        ]
        for mode in MODES:
            flat = ops.prune_ranges_batched_device(ranges, d, mode=mode)
            tv = ops.prune_ranges_batched_device(ranges, d, mode=mode,
                                                 mesh=mesh)
            np.testing.assert_array_equal(tv, flat, err_msg=mode)
            assert (tv[:, sent] == 0).all(), mode


class TestShardedJoinSentinels:
    def test_edge_sentinels_match_unsharded(self):
        mesh, cap, sent = _shard_geometry()
        rng = np.random.default_rng(7)
        pmin = rng.integers(-1000, 1000, cap).astype(np.float32)
        pmax = pmin + rng.integers(0, 100, cap).astype(np.float32)
        pmin[sent], pmax[sent] = _F32_MAX, -_F32_MAX
        distinct = [
            np.sort(rng.integers(-1200, 1200, 9)).astype(np.float32),
            np.array([-_F32_MAX, 0.0, _F32_MAX], dtype=np.float32),
        ]
        for mode in MODES:
            flat = ops.join_overlap_batched_device(
                distinct, jnp.asarray(pmin), jnp.asarray(pmax), mode=mode)
            hit = ops.join_overlap_batched_device(
                distinct, jnp.asarray(pmin), jnp.asarray(pmax), mode=mode,
                mesh=mesh)
            np.testing.assert_array_equal(hit, flat, err_msg=mode)
            assert (hit[:, sent] == 0).all(), mode


class TestShardedTopKSentinels:
    def test_edge_sentinels_match_unsharded(self):
        mesh, cap, sent = _shard_geometry()
        rng = np.random.default_rng(8)
        K, k = 8, 4
        plane = np.sort(rng.uniform(-100, 100, (cap, K)).astype(np.float32),
                        axis=1)[:, ::-1].copy()
        plane[sent] = -np.inf
        # masks select across shard edges — including only-sentinel rows
        mask = np.zeros((3, cap), dtype=np.float32)
        mask[0, :] = 1.0
        mask[1, sent] = 1.0                       # only sentinels: empty heap
        mask[2, : cap // 2 + 1] = 1.0             # straddles a shard edge
        for mode in MODES:
            flat = ops.topk_init_batched_device(
                jnp.asarray(plane), mask, k, mode=mode)
            heap = ops.topk_init_batched_device(
                jnp.asarray(plane), mask, k, mode=mode, mesh=mesh)
            np.testing.assert_array_equal(heap, flat, err_msg=mode)
            assert (heap[1] == -np.inf).all(), mode


class TestShardedBloomSentinels:
    def test_edge_sentinels_match_unsharded(self):
        mesh, cap, sent = _shard_geometry()
        rng = np.random.default_rng(9)
        pmin = rng.integers(0, 500, cap).astype(np.int32)
        width = rng.integers(1, 12, cap).astype(np.int32)
        pmin[sent], width[sent] = 0, 0            # width 0 = sentinel = keep
        blooms = []
        for _ in range(2):
            b = BlockedBloom(64)
            b.add(rng.integers(0, 500, 40))
            blooms.append(b)
        wmax = int(width.max())
        for mode in MODES:
            flat = ops.bloom_probe_batched_device(
                blooms, jnp.asarray(pmin), jnp.asarray(width), wmax, 1024,
                mode=mode)
            hit = ops.bloom_probe_batched_device(
                blooms, jnp.asarray(pmin), jnp.asarray(width), wmax, 1024,
                mode=mode, mesh=mesh)
            np.testing.assert_array_equal(hit, flat, err_msg=mode)
            assert (hit[:, sent] == 1).all(), mode


# ---------------------------------------------------------------------------
# Hierarchical (tree) plane path: sentinels at the GROUP level (ISSUE 7)
# ---------------------------------------------------------------------------
#
# The group pre-pass aggregates member hulls; a sentinel member
# (+f32max, -f32max) must never widen a hull, a fully-sentinel group's
# empty hull must prune at the group level, and a group that mixes live
# and sentinel members must survive whenever any live member can match.
# Each test proves the tree path bit-identical to the (already
# sentinel-proven) flat path on the same planes, with the pre-pass
# actually engaged (path == 'tree', not a fallback).

TREE_FANOUT = 4
TREE_CAP = 64                      # 16 groups of 4; eligibility needs P>=16
TREE_P = 56                        # live logical slots; 56..63 capacity tail
# group 2 (slots 8..11) fully dropped; singles sit on group edges
TREE_SENT = np.array([0, 8, 9, 10, 11, 19, 20, 34, 55])
TREE_LIVE = np.array([i for i in range(TREE_P) if i not in TREE_SENT])


def _tree_plane_fixture(seed=0, C=2):
    """Clustered stats (sorted mins) so narrow ranges keep few groups."""
    rng = np.random.default_rng(seed)
    mins = np.sort(rng.uniform(-100, 100, TREE_P))
    maxs = mins + rng.uniform(0, 4, TREE_P)
    mins[TREE_SENT], maxs[TREE_SENT] = np.inf, -np.inf
    d = DeviceStats.stage(_stats(mins, maxs, C=C), capacity=TREE_CAP)
    return d, tree_entry_for(d, fanout=TREE_FANOUT), mins, maxs


class TestTreeMinmaxSentinels:
    def test_group_sentinels_bit_identical_to_flat(self):
        d, tree, mins, _ = _tree_plane_fixture()
        lo = float(np.float32(mins[TREE_LIVE[5]]))
        range_lists = [
            [(0, lo, lo + 10.0)],                    # narrow two-sided
            [(1, 80.0, np.inf)],                     # one-sided tail
            [(0, lo, lo), (1, -90.0, -70.0)],        # equality + conj
            [(0, 200.0, 300.0)],                     # misses everything
        ]
        for mode in MODES:
            flat = ops.prune_ranges_batched_device(range_lists, d, mode=mode)
            tv = ops.prune_ranges_batched_tree(range_lists, d, tree,
                                               mode=mode)
            assert ops.last_tree_stats()["path"] == "tree", mode
            np.testing.assert_array_equal(tv, flat, err_msg=mode)
            assert (tv[:, TREE_SENT] == 0).all(), mode

    def test_dense_fallback_is_bit_identical_too(self):
        """A keep-most predicate must fall back flat (coarse density) and
        still agree; the fully-sentinel group stays NO either way."""
        d, tree, _, _ = _tree_plane_fixture(seed=1)
        range_lists = [[(0, -200.0, 200.0)], [(1, -150.0, 150.0)]]
        for mode in MODES:
            flat = ops.prune_ranges_batched_device(range_lists, d, mode=mode)
            tv = ops.prune_ranges_batched_tree(range_lists, d, tree,
                                               mode=mode)
            assert ops.last_tree_stats()["path"] == "flat_dense", mode
            np.testing.assert_array_equal(tv, flat, err_msg=mode)
            assert (tv[:, [8, 9, 10, 11]] == 0).all(), mode


class TestTreeJoinSentinels:
    def test_group_hull_restriction_matches_flat(self):
        d, tree, mins, maxs = _tree_plane_fixture(seed=2)
        # join-key plane: the same widened member intervals, sentinel rows
        # the same empty interval — padded to the plane capacity
        pmin = np.full(TREE_CAP, _F32_MAX, dtype=np.float32)
        pmax = np.full(TREE_CAP, -_F32_MAX, dtype=np.float32)
        pmin[TREE_LIVE] = mins[TREE_LIVE].astype(np.float32)
        pmax[TREE_LIVE] = maxs[TREE_LIVE].astype(np.float32)
        anchor = float(np.float32(mins[TREE_LIVE[8]]))
        distinct = [
            np.sort(np.array([anchor, anchor + 1.0], dtype=np.float32)),
            np.array([_F32_MAX], dtype=np.float32),   # == sentinel pmin
            np.array([-150.0], dtype=np.float32),     # below every hull
        ]
        for mode in MODES:
            flat = ops.join_overlap_batched_device(
                distinct, jnp.asarray(pmin), jnp.asarray(pmax), mode=mode)
            hit = ops.join_overlap_batched_tree(
                distinct, jnp.asarray(pmin), jnp.asarray(pmax), tree, 0,
                mode=mode)
            assert ops.last_tree_stats()["path"] == "tree", mode
            np.testing.assert_array_equal(hit, flat, err_msg=mode)
            assert (hit[:, TREE_SENT] == 0).all(), mode


class TestTreeBloomSentinels:
    def test_width_zero_groups_stay_unconditional_keeps(self):
        d, tree, _, _ = _tree_plane_fixture(seed=3)
        rng = np.random.default_rng(3)
        pmin = np.zeros(TREE_CAP, dtype=np.int32)
        width = np.zeros(TREE_CAP, dtype=np.int32)   # sentinel width 0
        pmin[TREE_LIVE] = rng.integers(0, 500, TREE_LIVE.size)
        width[TREE_LIVE] = rng.integers(1, 12, TREE_LIVE.size)
        blooms = []
        for _ in range(3):
            b = BlockedBloom(64)
            b.add(rng.integers(0, 500, 40))
            blooms.append(b)
        wmax = int(width.max())
        for mode in MODES:
            flat = ops.bloom_probe_batched_device(
                blooms, jnp.asarray(pmin), jnp.asarray(width), wmax, 1024,
                mode=mode)
            hit = ops.bloom_probe_batched_tree(
                blooms, jnp.asarray(pmin), jnp.asarray(width), wmax, 1024,
                tree, mode=mode)
            assert ops.last_tree_stats()["path"] == "tree", mode
            np.testing.assert_array_equal(hit, flat, err_msg=mode)
            # width-0 rows (group 2 is all of them) are unconditional keeps
            assert (np.asarray(hit)[:, [8, 9, 10, 11]] == 1).all(), mode


class TestTreeTopKSentinels:
    def test_compacted_groups_match_flat_heap(self):
        d, tree, _, _ = _tree_plane_fixture(seed=4)
        rng = np.random.default_rng(4)
        K, k = 8, 4
        plane = np.full((TREE_CAP, K), -np.inf, dtype=np.float32)
        plane[TREE_LIVE] = np.sort(
            rng.uniform(-100, 100, (TREE_LIVE.size, K)).astype(np.float32),
            axis=1)[:, ::-1]
        # sparse masks (pre-pass engages) — one selects ONLY the dropped
        # group, whose heap must come back empty, never -f32max garbage
        mask = np.zeros((3, TREE_CAP), dtype=np.float32)
        mask[0, [1, 2, 5, 6, 12, 13]] = 1.0          # two live groups
        mask[1, [8, 9, 10, 11]] = 1.0                # dropped group only
        mask[2, [7, 8]] = 1.0                        # straddles the edge
        for mode in MODES:
            flat = ops.topk_init_batched_device(
                jnp.asarray(plane), mask, k, mode=mode)
            heap = ops.topk_init_batched_tree(
                jnp.asarray(plane), mask, k, tree, mode=mode)
            assert ops.last_tree_stats()["path"] == "tree", mode
            np.testing.assert_array_equal(heap, flat, err_msg=mode)
            assert (np.asarray(heap)[1] == -np.inf).all(), mode
