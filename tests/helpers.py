"""Shared test utilities: small table builders and hypothesis strategies.

When the real ``hypothesis`` package is unavailable (offline containers),
``install_hypothesis_shim`` registers a minimal fixed-example stand-in in
``sys.modules`` so the suite still collects and runs everywhere.  The shim
draws a bounded number of deterministic pseudo-random examples per test
(no shrinking, no database) — property coverage is reduced, not absent.
``tests/conftest.py`` installs it before any test module imports.
"""

from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types

import numpy as np


def _build_hypothesis_shim() -> types.ModuleType:
    """A tiny, deterministic subset of the hypothesis API.

    Supports exactly what this suite uses: ``given`` (keyword strategies,
    ``...`` meaning infer-from-annotation), ``settings(max_examples,
    deadline)``, and ``strategies.{integers, floats, booleans,
    sampled_from, lists, one_of, composite}`` plus ``Strategy.map``.
    """

    class Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def example(self, rnd: random.Random):
            return self._draw_fn(rnd)

        def map(self, fn):
            return Strategy(lambda rnd: fn(self._draw_fn(rnd)))

    def integers(min_value, max_value):
        return Strategy(lambda rnd: rnd.randint(min_value, max_value))

    def floats(min_value=None, max_value=None, **_ignored):
        lo = -1e6 if min_value is None else float(min_value)
        hi = 1e6 if max_value is None else float(max_value)
        return Strategy(lambda rnd: rnd.uniform(lo, hi))

    def booleans():
        return Strategy(lambda rnd: rnd.random() < 0.5)

    def one_of(*strats):
        return Strategy(
            lambda rnd: strats[rnd.randrange(len(strats))].example(rnd))

    def sampled_from(seq):
        seq = list(seq)
        return Strategy(lambda rnd: seq[rnd.randrange(len(seq))])

    def lists(elements, min_size=0, max_size=10):
        def draw(rnd):
            n = rnd.randint(min_size, max_size)
            return [elements.example(rnd) for _ in range(n)]
        return Strategy(draw)

    def composite(fn):
        @functools.wraps(fn)
        def builder(*args, **kwargs):
            def draw_with(rnd):
                return fn(lambda strat: strat.example(rnd), *args, **kwargs)
            return Strategy(draw_with)
        return builder

    def _infer(annotation):
        if annotation is bool:
            return booleans()
        if annotation is int:
            return integers(0, 100)
        if annotation is float:
            return Strategy(lambda rnd: rnd.uniform(-100.0, 100.0))
        raise TypeError(f"shim cannot infer a strategy for {annotation!r}")

    _default_examples = int(os.environ.get("HYPOTHESIS_SHIM_MAX_EXAMPLES", 8))

    def given(**strategy_kwargs):
        def deco(test_fn):
            sig = inspect.signature(test_fn)
            strategies = {}
            for name, strat in strategy_kwargs.items():
                if strat is Ellipsis:
                    strat = _infer(sig.parameters[name].annotation)
                strategies[name] = strat

            def wrapper(*args, **kwargs):
                limit = getattr(wrapper, "_shim_max_examples", None)
                n = min(limit or _default_examples, _default_examples)
                for i in range(n):
                    rnd = random.Random(f"{test_fn.__qualname__}:{i}")
                    drawn = {k: s.example(rnd) for k, s in strategies.items()}
                    test_fn(*args, **kwargs, **drawn)

            wrapper.__name__ = test_fn.__name__
            wrapper.__qualname__ = test_fn.__qualname__
            wrapper.__doc__ = test_fn.__doc__
            wrapper.__module__ = test_fn.__module__
            # Hide the drawn parameters from pytest's fixture resolution.
            kept = [p for p in sig.parameters.values() if p.name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=kept)
            wrapper.hypothesis_shim = True
            return wrapper
        return deco

    def settings(max_examples=None, deadline=None, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    # Profile hooks (no-ops): the shim is deterministic by construction;
    # conftest registers a fixed "ci" profile through the same API when
    # the real package is present.
    settings.register_profile = lambda *a, **k: None
    settings.load_profile = lambda *a, **k: None

    mod = types.ModuleType("hypothesis")
    mod.__doc__ = "fixed-example fallback shim (real hypothesis unavailable)"
    mod.given = given
    mod.settings = settings
    mod.is_shim = True
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.booleans = booleans
    st_mod.sampled_from = sampled_from
    st_mod.lists = lists
    st_mod.one_of = one_of
    st_mod.composite = composite
    mod.strategies = st_mod
    return mod


def install_hypothesis_shim() -> None:
    """Register the shim in sys.modules iff hypothesis is not importable."""
    try:
        import hypothesis  # noqa: F401
    except ImportError:
        mod = _build_hypothesis_shim()
        sys.modules["hypothesis"] = mod
        sys.modules["hypothesis.strategies"] = mod.strategies


install_hypothesis_shim()

from hypothesis import strategies as st  # noqa: E402

from repro.core import expr as E  # noqa: E402
from repro.data.table import Table  # noqa: E402

STR_DOMAIN = [
    "Alpine Chough", "Alpine Ibex", "Alpine Marmot", "Alpine Salamander",
    "Bear", "Duck", "Eagle", "Frog", "Pike", "Wolf",
]


@st.composite
def small_tables(draw, max_rows=120, max_part=8, with_nulls=True):
    n = draw(st.integers(4, max_rows))
    rows_per_part = draw(st.integers(2, max(2, n // 2)))
    x = draw(st.lists(st.integers(-50, 50), min_size=n, max_size=n))
    y = draw(st.lists(st.integers(0, 1000), min_size=n, max_size=n))
    s_idx = draw(st.lists(st.integers(0, len(STR_DOMAIN) - 1), min_size=n, max_size=n))
    sort_x = draw(st.booleans())
    x = np.asarray(x, dtype=np.int64)
    if sort_x:
        x = np.sort(x)
    nulls = {}
    if with_nulls and draw(st.booleans()):
        nm = draw(st.lists(st.booleans(), min_size=n, max_size=n))
        nulls["x"] = np.asarray(nm, dtype=bool)
    tbl = Table.build(
        "t",
        {
            "x": x,
            "y": np.asarray(y, dtype=np.int64),
            "s": np.array([STR_DOMAIN[i] for i in s_idx]),
        },
        rows_per_partition=rows_per_part,
        nulls=nulls,
    )
    return tbl


@st.composite
def predicates(draw, depth=0):
    """Random predicate trees over columns x (int), y (int), s (str)."""
    if depth >= 2:
        choice = draw(st.integers(0, 5))
    else:
        choice = draw(st.integers(0, 8))
    if choice == 0:
        return E.col("x") > draw(st.integers(-60, 60))
    if choice == 1:
        return E.col("x") <= draw(st.integers(-60, 60))
    if choice == 2:
        return E.col("y") == draw(st.integers(0, 1000))
    if choice == 3:
        op = draw(st.sampled_from([">", ">=", "<", "<=", "==", "!="]))
        return E.Cmp(op, E.col("x"), E.Lit(draw(st.integers(-60, 60))))
    if choice == 4:
        prefix = draw(st.sampled_from(["Alpine", "Alpine I", "B", "Z", ""]))
        return E.startswith(E.col("s"), prefix)
    if choice == 5:
        pat = draw(st.sampled_from(
            ["Alpine%", "%mot", "Alpine%mot", "Bear", "%", "A%e%t"]))
        return E.like(E.col("s"), pat)
    if choice == 6:
        return E.Not(draw(predicates(depth=depth + 1)))
    if choice == 7:
        return E.And((draw(predicates(depth=depth + 1)),
                      draw(predicates(depth=depth + 1))))
    return E.Or((draw(predicates(depth=depth + 1)),
                 draw(predicates(depth=depth + 1))))


def arith_pred(threshold: float) -> E.Pred:
    """The paper's Sec. 3.1 complex expression over columns x, y."""
    return (E.if_(E.col("s") == E.lit("Bear"), E.col("x") * 0.3048, E.col("x"))
            + E.col("y") / 10.0) > threshold
