"""Shared test utilities: small table builders and hypothesis strategies."""

from __future__ import annotations

import numpy as np
from hypothesis import strategies as st

from repro.core import expr as E
from repro.data.table import Table

STR_DOMAIN = [
    "Alpine Chough", "Alpine Ibex", "Alpine Marmot", "Alpine Salamander",
    "Bear", "Duck", "Eagle", "Frog", "Pike", "Wolf",
]


@st.composite
def small_tables(draw, max_rows=120, max_part=8, with_nulls=True):
    n = draw(st.integers(4, max_rows))
    rows_per_part = draw(st.integers(2, max(2, n // 2)))
    x = draw(st.lists(st.integers(-50, 50), min_size=n, max_size=n))
    y = draw(st.lists(st.integers(0, 1000), min_size=n, max_size=n))
    s_idx = draw(st.lists(st.integers(0, len(STR_DOMAIN) - 1), min_size=n, max_size=n))
    sort_x = draw(st.booleans())
    x = np.asarray(x, dtype=np.int64)
    if sort_x:
        x = np.sort(x)
    nulls = {}
    if with_nulls and draw(st.booleans()):
        nm = draw(st.lists(st.booleans(), min_size=n, max_size=n))
        nulls["x"] = np.asarray(nm, dtype=bool)
    tbl = Table.build(
        "t",
        {
            "x": x,
            "y": np.asarray(y, dtype=np.int64),
            "s": np.array([STR_DOMAIN[i] for i in s_idx]),
        },
        rows_per_partition=rows_per_part,
        nulls=nulls,
    )
    return tbl


@st.composite
def predicates(draw, depth=0):
    """Random predicate trees over columns x (int), y (int), s (str)."""
    if depth >= 2:
        choice = draw(st.integers(0, 5))
    else:
        choice = draw(st.integers(0, 8))
    if choice == 0:
        return E.col("x") > draw(st.integers(-60, 60))
    if choice == 1:
        return E.col("x") <= draw(st.integers(-60, 60))
    if choice == 2:
        return E.col("y") == draw(st.integers(0, 1000))
    if choice == 3:
        op = draw(st.sampled_from([">", ">=", "<", "<=", "==", "!="]))
        return E.Cmp(op, E.col("x"), E.Lit(draw(st.integers(-60, 60))))
    if choice == 4:
        prefix = draw(st.sampled_from(["Alpine", "Alpine I", "B", "Z", ""]))
        return E.startswith(E.col("s"), prefix)
    if choice == 5:
        pat = draw(st.sampled_from(
            ["Alpine%", "%mot", "Alpine%mot", "Bear", "%", "A%e%t"]))
        return E.like(E.col("s"), pat)
    if choice == 6:
        return E.Not(draw(predicates(depth=depth + 1)))
    if choice == 7:
        return E.And((draw(predicates(depth=depth + 1)),
                      draw(predicates(depth=depth + 1))))
    return E.Or((draw(predicates(depth=depth + 1)),
                 draw(predicates(depth=depth + 1))))


def arith_pred(threshold: float) -> E.Pred:
    """The paper's Sec. 3.1 complex expression over columns x, y."""
    return (E.if_(E.col("s") == E.lit("Bear"), E.col("x") * 0.3048, E.col("x"))
            + E.col("y") / 10.0) > threshold
