"""Device metadata plane: batched multi-query kernel vs per-query kernel
vs the f64 host oracle; DeviceStatsCache staging/invalidation; the f32
precision contract; the vectorized block-topk staging."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import expr as E
from repro.core.device_stats import (DeviceStats, DeviceStatsCache,
                                     cast_bounds_f32, cast_stats_f32,
                                     round_down_f32, round_up_f32)
from repro.core.flow import PruningPipeline, Query, TableScanSpec
from repro.core.metadata import (FULL_MATCH, NO_MATCH, ColumnMeta,
                                 PartitionStats)
from repro.core.prune_filter import eval_ranges_tv, extract_ranges
from repro.data.table import Table
from repro.kernels import minmax_prune_batched, ops, ref
from repro.serve.prune_service import PruningService

from helpers import small_tables


def make_stats(P, C, rng, with_nulls=True, with_empty=True):
    """Randomized f32-exact stats incl. all-null (empty-interval) partitions."""
    mins = rng.integers(-1000, 1000, size=(P, C)).astype(np.float64)
    maxs = mins + rng.integers(0, 100, size=(P, C)).astype(np.float64)
    nulls = np.zeros((P, C), dtype=np.int64)
    if with_nulls:
        nulls = (rng.random((P, C)) < 0.25).astype(np.int64) * 3
    if with_empty:
        empty = rng.random((P, C)) < 0.15
        mins = np.where(empty, np.inf, mins)
        maxs = np.where(empty, -np.inf, maxs)
    return PartitionStats(
        columns=[ColumnMeta(f"c{i}", "int") for i in range(C)],
        mins=mins, maxs=maxs, null_counts=nulls,
        row_counts=np.full(P, 10, dtype=np.int64),
    )


def make_range_lists(Q, C, rng, max_k=5):
    out = []
    for _ in range(Q):
        k = int(rng.integers(0, max_k + 1))
        ranges = []
        for _ in range(k):
            lo = float(rng.integers(-1100, 1100))
            ranges.append((int(rng.integers(0, C)), lo,
                           lo + float(rng.integers(0, 300))))
        out.append(ranges)
    return out


@st.composite
def batched_problems(draw):
    P = draw(st.integers(1, 400))
    C = draw(st.integers(1, 6))
    Q = draw(st.integers(1, 20))
    seed = draw(st.integers(0, 2**31))
    return P, C, Q, seed


class TestBatchedKernelParity:
    """tv[q] from one batched launch == per-query kernel == f64 oracle."""

    @settings(max_examples=25, deadline=None)
    @given(problem=batched_problems())
    def test_batched_matches_oracle_and_per_query(self, problem):
        P, C, Q, seed = problem
        rng = np.random.default_rng(seed)
        stats = make_stats(P, C, rng)
        dstats = DeviceStats.stage(stats)
        range_lists = make_range_lists(Q, C, rng)
        for mode in ("ref", "interpret"):
            tv = ops.prune_ranges_batched_device(range_lists, dstats, mode=mode)
            assert tv.shape == (Q, P)
            for qi, ranges in enumerate(range_lists):
                oracle = eval_ranges_tv(ranges, stats)
                np.testing.assert_array_equal(tv[qi], oracle, err_msg=f"q={qi}")
                if ranges:
                    single = ops.prune_ranges_device(ranges, stats, mode="ref")
                    np.testing.assert_array_equal(tv[qi], single)

    def test_block_boundary_shapes(self):
        """Q and P crossing the BLOCK_Q/BLOCK_P tile edges."""
        rng = np.random.default_rng(7)
        for P in (1, 2048, 2049):
            stats = make_stats(P, 3, rng)
            dstats = DeviceStats.stage(stats)
            for Q in (1, 7, 8, 9, 33):
                range_lists = make_range_lists(Q, 3, rng, max_k=3)
                tv = ops.prune_ranges_batched_device(
                    range_lists, dstats, mode="interpret")
                for qi, ranges in enumerate(range_lists):
                    np.testing.assert_array_equal(
                        tv[qi], eval_ranges_tv(ranges, stats))

    def test_kernel_raw_matches_ref_raw(self):
        """The pallas kernel against the jnp oracle on identical inputs."""
        rng = np.random.default_rng(3)
        C, P, Q, Kb = 4, 300, 16, 4
        mins = rng.uniform(-100, 100, (C, P)).astype(np.float32)
        maxs = mins + rng.uniform(0, 50, (C, P)).astype(np.float32)
        demote = (rng.random((C, P)) < 0.2).astype(np.float32)
        cids = rng.integers(0, C, (Q, Kb)).astype(np.int32)
        lo = rng.uniform(-120, 120, (Q, Kb)).astype(np.float32)
        hi = lo + rng.uniform(0, 100, (Q, Kb)).astype(np.float32)
        # sprinkle no-op padding slots
        noop = rng.random((Q, Kb)) < 0.3
        lo = np.where(noop, -np.inf, lo).astype(np.float32)
        hi = np.where(noop, np.inf, hi).astype(np.float32)
        args = [jnp.asarray(a) for a in (cids, lo, hi, mins, maxs, demote)]
        out_k = minmax_prune_batched(*args, interpret=True)
        out_r = ref.minmax_prune_batched_ref(*args)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    def test_ref_slab_chunking_is_seamless(self, monkeypatch):
        """The memory-bounded P-slab path equals the one-shot path."""
        rng = np.random.default_rng(11)
        stats = make_stats(5000, 3, rng)
        dstats = DeviceStats.stage(stats)
        range_lists = make_range_lists(9, 3, rng)
        whole = ops.prune_ranges_batched_device(range_lists, dstats, mode="ref")
        monkeypatch.setattr(ops, "_REF_SLAB_ELEMS", 4096)
        slabbed = ops.prune_ranges_batched_device(range_lists, dstats, mode="ref")
        np.testing.assert_array_equal(whole, slabbed)

    @settings(max_examples=15, deadline=None)
    @given(tbl=small_tables())
    def test_real_tables_end_to_end(self, tbl):
        preds = [
            (E.col("x") >= -10) & (E.col("y") <= 700),
            E.col("y") == 400,
            E.startswith(E.col("s"), "Alpine"),
        ]
        range_lists = [extract_ranges(p, tbl.stats) for p in preds]
        assert all(r is not None for r in range_lists)
        dstats = DeviceStats.stage(tbl.stats)
        tv = ops.prune_ranges_batched_device(range_lists, dstats, mode="ref")
        for qi, ranges in enumerate(range_lists):
            np.testing.assert_array_equal(tv[qi], eval_ranges_tv(ranges, tbl.stats))


class TestPrecisionContract:
    """core/device_stats.py: f32 downcast is widening + demoting."""

    def test_directed_rounding(self):
        vals = np.array([2**24 + 1, -(2**24) - 1, 0.1, -0.1, np.inf, -np.inf])
        lo = round_down_f32(vals)
        hi = round_up_f32(vals)
        assert (lo.astype(np.float64) <= vals).all()
        assert (hi.astype(np.float64) >= vals).all()

    def test_big_int_keys_never_false_no_match_or_full(self):
        """int64 keys > 2**24: FULL may degrade to PARTIAL, NO_MATCH and
        FULL are never falsely claimed (the regression the cast contract
        guards)."""
        P, C = 64, 2
        rng = np.random.default_rng(5)
        base = 2**24
        mins = (base + rng.integers(0, 1000, size=(P, C))).astype(np.float64)
        maxs = mins + rng.integers(0, 9, size=(P, C)).astype(np.float64)
        stats = PartitionStats(
            columns=[ColumnMeta(f"c{i}", "int") for i in range(C)],
            mins=mins, maxs=maxs,
            null_counts=np.zeros((P, C), dtype=np.int64),
            row_counts=np.full(P, 10, dtype=np.int64),
        )
        dstats = DeviceStats.stage(stats)
        range_lists = []
        for _ in range(32):
            lo = float(base + rng.integers(0, 1000))
            range_lists.append([(int(rng.integers(0, C)), lo,
                                 lo + float(rng.integers(0, 12)))])
        tv = ops.prune_ranges_batched_device(range_lists, dstats, mode="ref")
        some_demotion = False
        for qi, ranges in enumerate(range_lists):
            oracle = eval_ranges_tv(ranges, stats)
            single = ops.prune_ranges_device(ranges, stats, mode="ref")
            for got in (tv[qi], single):
                # never a false NO_MATCH: every pruned partition truly empty
                assert ((got == NO_MATCH) <= (oracle == NO_MATCH)).all()
                # never a false FULL: FULL only where the oracle proves it
                assert ((got == FULL_MATCH) <= (oracle == FULL_MATCH)).all()
            some_demotion |= bool((tv[qi] != oracle).any())
        # the contract is exercised: at least one FULL degraded to PARTIAL
        assert some_demotion

    def test_infinite_float_stats_safe_on_kernel_path(self):
        """Float columns holding real ±inf values: the finite clamp must
        demote, never false-NO/false-FULL — on the kernel path too (the
        one-hot gather would NaN on raw ±inf; regression from review)."""
        fmax = float(np.finfo(np.float32).max)
        mins = np.array([[-np.inf], [0.0], [5.0], [np.inf]], dtype=np.float64)
        maxs = np.array([[5.0], [np.inf], [9.0], [-np.inf]], dtype=np.float64)
        stats = PartitionStats(
            columns=[ColumnMeta("f", "float")],
            mins=mins.T.copy().T.reshape(4, 1), maxs=maxs.reshape(4, 1),
            null_counts=np.zeros((4, 1), dtype=np.int64),
            row_counts=np.full(4, 3, dtype=np.int64),
        )
        dstats = DeviceStats.stage(stats)
        range_lists = [
            [(0, -fmax, 10.0)],            # reviewer repro: was false FULL
            [(0, np.inf, np.inf)],         # x == inf: was false NO
            [(0, -np.inf, 4.0)],           # one-sided, crosses partition 0
            [(0, 6.0, np.inf)],
        ]
        for mode in ("ref", "interpret"):
            tv = ops.prune_ranges_batched_device(range_lists, dstats, mode=mode)
            for qi, ranges in enumerate(range_lists):
                oracle = eval_ranges_tv(ranges, stats)
                got = tv[qi]
                assert ((got == NO_MATCH) <= (oracle == NO_MATCH)).all(), \
                    (mode, qi, got, oracle)
                assert ((got == FULL_MATCH) <= (oracle == FULL_MATCH)).all(), \
                    (mode, qi, got, oracle)

    def test_stats_cast_flags_inexact(self):
        mins = np.array([[0.0, 2**24 + 1]])
        maxs = np.array([[1.0, 2**24 + 3]])
        m32, x32, inexact = cast_stats_f32(mins, maxs)
        assert not inexact[0, 0] and inexact[0, 1]
        assert m32[0, 1].astype(np.float64) <= 2**24 + 1
        assert x32[0, 1].astype(np.float64) >= 2**24 + 3

    def test_bounds_cast_flags_inexact(self):
        lo, hi, exact = cast_bounds_f32([0.0, 2**24 + 1], [10.0, 2**25 + 1])
        assert exact[0] and not exact[1]


class TestDeviceStatsCache:
    def _table(self, n=600, seed=0):
        rng = np.random.default_rng(seed)
        return Table.build(
            "t", {"v": rng.integers(0, 1000, n).astype(np.int64)},
            rows_per_partition=50)

    def test_staged_once_then_hits(self):
        cache = DeviceStatsCache()
        tbl = self._table()
        a = cache.get(tbl)
        b = cache.get(tbl)
        assert a is b
        assert (cache.hits, cache.misses) == (1, 1)
        assert cache.resident_bytes == a.nbytes > 0

    def test_version_bump_invalidates(self):
        """insert_partitions bumps the version -> stale plane is dropped
        and the table re-stages (the DML-safety requirement)."""
        from repro.core.predicate_cache import TableVersion
        cache = DeviceStatsCache()
        tbl = self._table()
        tv = TableVersion(tbl.num_partitions)
        first = cache.get(tbl, tv)
        tv.insert_partitions(0)          # version bump, same partition count
        second = cache.get(tbl, tv)
        assert second is not first
        assert cache.misses == 2
        # the superseded staging was dropped, not retained alongside
        assert len(cache.entries) == 1

    def test_insert_partitions_restages_grown_table(self):
        from repro.core.predicate_cache import TableVersion
        cache = DeviceStatsCache()
        tbl = self._table(n=600)
        tv = TableVersion(tbl.num_partitions)
        cache.get(tbl, tv)
        grown = self._table(n=700)       # same name, more partitions
        tv.insert_partitions(grown.num_partitions - tbl.num_partitions)
        ds = cache.get(grown, tv)
        assert ds.num_partitions == grown.num_partitions
        assert cache.misses == 2         # fresh staging, never the stale plane

    def test_live_same_name_tables_do_not_thrash(self):
        """Two distinct live tables sharing a name must coexist in the
        cache — alternating between them stages each exactly once."""
        cache = DeviceStatsCache()
        a = self._table(seed=1)
        b = self._table(seed=2)          # same name "t", different stats
        for _ in range(3):
            cache.get(a)
            cache.get(b)
        assert cache.misses == 2
        assert cache.hits == 4
        assert len(cache.entries) == 2

    def test_rebuilt_table_never_hits_stale_plane(self):
        """A rebuilt table (same name, same partition count, new data)
        must re-stage — a stale hit would false-NO_MATCH, losing rows
        (regression from review)."""
        rng = np.random.default_rng(0)
        t1 = Table.build("t", {"v": np.arange(100, dtype=np.int64)},
                         rows_per_partition=10)
        pipe = PruningPipeline(filter_mode="device")
        pipe.run(Query(scans={"t": TableScanSpec(t1, E.col("v") >= 0)}))
        t2 = Table.build("t", {"v": np.arange(100, 200, dtype=np.int64)},
                         rows_per_partition=10)
        q = Query(scans={"t": TableScanSpec(t2, E.col("v") >= 190)})
        dev = pipe.run(q)
        host = PruningPipeline(filter_mode="host").run(q)
        np.testing.assert_array_equal(dev.scan_sets["t"].part_ids,
                                      host.scan_sets["t"].part_ids)
        assert len(dev.scan_sets["t"]) == 1

    def test_explicit_invalidation_and_lru(self):
        cache = DeviceStatsCache(max_entries=2)
        tables = [Table.build(f"t{i}", {"v": np.arange(60, dtype=np.int64)},
                              rows_per_partition=10) for i in range(3)]
        for t in tables:
            cache.get(t)
        assert len(cache.entries) == 2          # LRU evicted t0
        cache.invalidate("t2")
        assert len(cache.entries) == 1
        cache.on_update("t1", "v")
        assert len(cache.entries) == 0


class TestPruningService:
    def _tables(self, seed=0):
        rng = np.random.default_rng(seed)
        n = 2000
        t = Table.build("t", {
            "v": rng.permutation(np.arange(n)).astype(np.int64),
            "w": np.sort(rng.integers(0, 10_000, n)).astype(np.int64),
        }, rows_per_partition=50,
            nulls={"v": rng.random(n) < 0.05})
        u = Table.build("u", {
            "a": rng.integers(-50, 50, 400).astype(np.int64)},
            rows_per_partition=20)
        return t, u

    def _queries(self, t, u):
        return [
            Query(scans={"t": TableScanSpec(
                t, (E.col("w") >= 5000) & (E.col("w") < 6000))}),
            Query(scans={"t": TableScanSpec(t, E.col("v") > 1500)}),
            Query(scans={"t": TableScanSpec(
                t, (E.col("w") >= 5000) | (E.col("v") < 10))}),   # fallback
            Query(scans={"u": TableScanSpec(u, E.col("a") == 0)}),
            Query(scans={"t": TableScanSpec(t)}),                 # TruePred
        ]

    def test_batch_equals_host_pipeline(self):
        t, u = self._tables()
        queries = self._queries(t, u)
        svc = PruningService(mode="ref")
        reports = svc.run_batch(queries)
        host = PruningPipeline(filter_mode="host")
        for q, rep in zip(queries, reports):
            h = host.run(q)
            for name in q.scans:
                np.testing.assert_array_equal(
                    rep.scan_sets[name].part_ids, h.scan_sets[name].part_ids)
                np.testing.assert_array_equal(
                    rep.scan_sets[name].match, h.scan_sets[name].match)

    def test_one_launch_per_table_group(self):
        t, u = self._tables()
        svc = PruningService(mode="ref")
        svc.prune_batch(self._queries(t, u))
        assert svc.counters.launches == 2        # tables t and u
        assert svc.counters.host_fallbacks == 1  # the OR predicate
        assert svc.cache.misses == 2             # staged once per table

    def test_second_batch_reuses_resident_plane(self):
        t, u = self._tables()
        svc = PruningService(mode="ref")
        svc.prune_batch(self._queries(t, u))
        misses = svc.cache.misses
        svc.prune_batch(self._queries(t, u))
        assert svc.cache.misses == misses        # pure cache hits

    def test_dml_notifications_invalidate(self):
        t, u = self._tables()
        svc = PruningService(mode="ref")
        svc.register(t)
        svc.prune_batch(self._queries(t, u))
        misses = svc.cache.misses
        svc.notify_insert("t", 2)
        svc.prune_batch(self._queries(t, u))
        assert svc.cache.misses == misses + 1    # t re-staged, u still hit

    def test_pipeline_device_mode_delegates(self):
        t, u = self._tables()
        pipe = PruningPipeline(filter_mode="device")
        for q in self._queries(t, u):
            pipe.run(q)
        svc = pipe.device_service()
        assert svc.counters.launches >= 3
        assert svc.cache.hits > 0                # resident plane reused


class TestBlockTopKVectorized:
    @staticmethod
    def _loop_reference(values, part_bounds, k, mask=None):
        """The original per-partition Python loop, kept as the oracle."""
        P = len(part_bounds) - 1
        out = np.full((P, k), -np.inf, dtype=np.float32)
        for p in range(P):
            s, e = int(part_bounds[p]), int(part_bounds[p + 1])
            v = values[s:e]
            if mask is not None:
                v = v[mask[s:e]]
            if v.size:
                top = np.sort(v)[::-1][:k]
                out[p, : len(top)] = top
        return out

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 400), k=st.sampled_from([1, 2, 4, 8]),
           seed=st.integers(0, 2**31), masked=st.booleans())
    def test_matches_loop_reference(self, n, k, seed, masked):
        rng = np.random.default_rng(seed)
        vals = rng.uniform(-1000, 1000, n).astype(np.float32)
        cuts = np.unique(rng.integers(0, n + 1, size=rng.integers(0, 12)))
        bounds = np.unique(np.concatenate([[0], cuts, [n]]))
        mask = (rng.random(n) < 0.6) if masked else None
        got = ops.build_block_topk(vals, bounds, k, mask=mask)
        want = self._loop_reference(vals, bounds, k, mask=mask)
        np.testing.assert_array_equal(got, want)

    def test_empty_and_degenerate(self):
        out = ops.build_block_topk(np.zeros(0, np.float32), np.array([0]), 4)
        assert out.shape == (0, 4)
        out = ops.build_block_topk(
            np.array([5.0], np.float32), np.array([0, 1]), 4,
            mask=np.array([False]))
        assert (out == -np.inf).all()

    def test_offset_bounds(self):
        """part_bounds need not start at row 0 (kernels_bench slices)."""
        vals = np.arange(100, dtype=np.float32)
        bounds = np.array([40, 60, 100])
        got = ops.build_block_topk(vals, bounds, 2)
        want = self._loop_reference(vals, bounds, 2)
        np.testing.assert_array_equal(got, want)


class TestBenchSmoke:
    def test_batched_prune_bench_runs(self, tmp_path):
        from benchmarks.bench_batched_prune import run
        json_path = str(tmp_path / "BENCH_batched_prune.json")
        rows, cells = run(grid_p=(512,), grid_q=(1, 4), json_path=json_path)
        assert len(cells) == 2
        import json as _json
        with open(json_path) as f:
            payload = _json.load(f)
        assert payload["bench"] == "batched_prune"
        assert len(payload["grid"]) == 2

    def test_run_py_csv_parse_and_json(self, tmp_path):
        from benchmarks.run import parse_csv_rows, write_module_json
        rows = parse_csv_rows(
            "name,us_per_call,derived\nfoo,1.5,bar\n# comment\nbad line\n")
        assert rows == [dict(name="foo", us_per_call=1.5, derived="bar")]
        path = write_module_json(str(tmp_path), "m", rows, 0.1)
        import json as _json
        with open(path) as f:
            assert _json.load(f)["rows"] == rows
