"""Pytest bootstrap: make the hypothesis fallback shim available before any
test module runs its ``from hypothesis import ...`` line (helpers.py holds
the shim so it is importable outside pytest too), and register the fixed
CI profile so property runs are reproducible per PR."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

from helpers import install_hypothesis_shim  # noqa: E402

install_hypothesis_shim()

# Fixed-seed CI profile: with the real hypothesis package installed the
# "ci" profile derandomizes (stable examples per PR, no flaky shrink
# budget); the shim is already deterministic and ignores profiles, but
# exposes no-op register/load hooks so this block is package-agnostic.
from hypothesis import settings as _settings  # noqa: E402

if hasattr(_settings, "register_profile"):
    _settings.register_profile("ci", max_examples=24, deadline=None,
                               derandomize=True)
    profile = os.environ.get("HYPOTHESIS_PROFILE")
    if profile:
        _settings.load_profile(profile)
