"""Pytest bootstrap: make the hypothesis fallback shim available before any
test module runs its ``from hypothesis import ...`` line (helpers.py holds
the shim so it is importable outside pytest too), and register the fixed
CI profile so property runs are reproducible per PR."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

# Multi-device CPU for the sharded-plane tests: REPRO_CPU_DEVICES=n
# forces n host devices before jax's backend initializes, so shard_map
# really runs multi-device (the CI fleet lane sets 8).  Opt-in only —
# the main suite keeps whatever device count the backend picks up
# (several train-substrate tests encode it), and sharded tests skip
# gracefully on a single device.  A pre-existing XLA_FLAGS device-count
# setting is always respected.
_n_cpu = os.environ.get("REPRO_CPU_DEVICES", "0")
_flags = os.environ.get("XLA_FLAGS", "")
if (_n_cpu.isdigit() and int(_n_cpu) > 0
        and "xla_force_host_platform_device_count" not in _flags):
    os.environ["XLA_FLAGS"] = (
        _flags + f" --xla_force_host_platform_device_count={_n_cpu}").strip()

from helpers import install_hypothesis_shim  # noqa: E402

install_hypothesis_shim()

# Fixed-seed CI profile: with the real hypothesis package installed the
# "ci" profile derandomizes (stable examples per PR, no flaky shrink
# budget); the shim is already deterministic and ignores profiles, but
# exposes no-op register/load hooks so this block is package-agnostic.
from hypothesis import settings as _settings  # noqa: E402

if hasattr(_settings, "register_profile"):
    _settings.register_profile("ci", max_examples=24, deadline=None,
                               derandomize=True)
    profile = os.environ.get("HYPOTHESIS_PROFILE")
    if profile:
        _settings.load_profile(profile)
