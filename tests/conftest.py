"""Pytest bootstrap: make the hypothesis fallback shim available before any
test module runs its ``from hypothesis import ...`` line (helpers.py holds
the shim so it is importable outside pytest too)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

from helpers import install_hypothesis_shim  # noqa: E402

install_hypothesis_shim()
