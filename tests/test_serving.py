"""Serving: continuous batching correctness — slot outputs must equal the
single-request Generator outputs regardless of admission interleaving."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.sharding import init_params
from repro.serve.batcher import ContinuousBatcher
from repro.serve.serve_step import Generator


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3.2-3b")
    model = build_model(cfg)
    params = init_params(model.specs, jax.random.PRNGKey(0))
    return cfg, model, params


class TestContinuousBatching:
    def test_matches_single_request_generation(self, setup):
        cfg, model, params = setup
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
                   for n in (5, 9, 3, 7, 6)]
        # oracle: one-at-a-time greedy generation
        gen = Generator(model, params, max_seq=64)
        want = {i: gen.generate(p[None, :], steps=6)[0].tolist()
                for i, p in enumerate(prompts)}
        # continuous batching with fewer slots than requests
        batcher = ContinuousBatcher(model, params, n_slots=2, max_seq=64)
        rids = [batcher.submit(p, max_new=6) for p in prompts]
        got = batcher.run()
        for i, rid in enumerate(rids):
            assert got[rid] == want[i], f"request {i} diverged"

    def test_slots_recycled(self, setup):
        cfg, model, params = setup
        rng = np.random.default_rng(1)
        batcher = ContinuousBatcher(model, params, n_slots=2, max_seq=64)
        for _ in range(5):
            batcher.submit(rng.integers(0, cfg.vocab, size=4), max_new=3)
        out = batcher.run()
        assert len(out) == 5
        assert all(len(v) == 3 for v in out.values())
        assert batcher.active() == 0

    def test_ragged_depths_advance_independently(self, setup):
        cfg, model, params = setup
        rng = np.random.default_rng(2)
        batcher = ContinuousBatcher(model, params, n_slots=3, max_seq=64)
        a = batcher.submit(rng.integers(0, cfg.vocab, size=3), max_new=2)
        b = batcher.submit(rng.integers(0, cfg.vocab, size=12), max_new=8)
        out = batcher.run()
        assert len(out[a]) == 2 and len(out[b]) == 8

    def test_overlong_prompt_rejected_at_submit(self, setup):
        """Regression (ISSUE 3): _admit never validated prompt length, so
        an over-long prompt wrote past the slot's KV region and started
        positions[slot] beyond max_seq.  submit must reject it up front
        (prompt == max_seq is also too long: decode needs one position)."""
        cfg, model, params = setup
        rng = np.random.default_rng(3)
        batcher = ContinuousBatcher(model, params, n_slots=2, max_seq=16)
        with pytest.raises(ValueError, match="slot capacity"):
            batcher.submit(rng.integers(0, cfg.vocab, size=40), max_new=2)
        with pytest.raises(ValueError, match="slot capacity"):
            batcher.submit(rng.integers(0, cfg.vocab, size=16), max_new=2)
        assert not batcher.queue                 # nothing was admitted
        rid = batcher.submit(rng.integers(0, cfg.vocab, size=15), max_new=4)
        out = batcher.run()
        # the slot fills after one decode (15 + 1 == max_seq): the request
        # still finishes cleanly inside its KV region
        assert 1 <= len(out[rid]) <= 4 and batcher.active() == 0


def _greedy_tokens(model, params, prompt, steps):
    """Oracle: one-at-a-time greedy generation, no early stopping."""
    gen = Generator(model, params, max_seq=64)
    return gen.generate(np.asarray(prompt)[None, :], steps=steps)[0].tolist()


def _truncate_at_eos(tokens, eos_id, max_new):
    """What a correct batcher emits: stop after max_new or at eos."""
    out = []
    for t in tokens:
        out.append(t)
        if len(out) >= max_new or t == eos_id:
            break
    return out


class TestAdmitTimeCompletion:
    """Regression (PR 10): _admit appended the prefill-argmax token
    without checking the done conditions — max_new=1 emitted 2 tokens,
    and an eos-as-first-token request occupied a slot and kept
    decoding."""

    def test_max_new_one_emits_exactly_one_token(self, setup):
        cfg, model, params = setup
        rng = np.random.default_rng(10)
        prompt = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
        want = _greedy_tokens(model, params, prompt, steps=1)
        batcher = ContinuousBatcher(model, params, n_slots=2, max_seq=64)
        rid = batcher.submit(prompt, max_new=1)
        out = batcher.run()
        assert out[rid] == want and len(out[rid]) == 1
        assert batcher.active() == 0

    def test_eos_first_token_finishes_without_occupying_a_slot(self, setup):
        cfg, model, params = setup
        rng = np.random.default_rng(11)
        prompt = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
        first = _greedy_tokens(model, params, prompt, steps=1)[0]
        batcher = ContinuousBatcher(model, params, n_slots=2, max_seq=64,
                                    eos_id=first)
        rid = batcher.submit(prompt, max_new=8)
        batcher._admit()                    # one admit pass, no decode
        assert batcher.active() == 0        # finished, slot never taken
        assert batcher.finished[rid].out == [first]
        assert batcher.run() == {rid: [first]}

    def test_admit_time_finish_frees_the_slot_for_the_queue(self, setup):
        """An eos-first request in front of the queue must not starve
        the request behind it out of the only slot."""
        cfg, model, params = setup
        rng = np.random.default_rng(12)
        p_eos = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
        p_live = rng.integers(0, cfg.vocab, size=7).astype(np.int32)
        eos = _greedy_tokens(model, params, p_eos, steps=1)[0]
        want_live = _truncate_at_eos(
            _greedy_tokens(model, params, p_live, steps=4), eos, 4)
        batcher = ContinuousBatcher(model, params, n_slots=1, max_seq=64,
                                    eos_id=eos)
        a = batcher.submit(p_eos, max_new=8)
        b = batcher.submit(p_live, max_new=4)
        out = batcher.run()
        assert out[a] == [eos]
        assert out[b] == want_live


class TestSlotRelease:
    """Regression (PR 10): _step decoded every slot including freed ones
    with stale last_tok/positions, and never zeroed last_tok on release —
    a recycled slot could observe its predecessor's token."""

    def test_last_tok_zeroed_on_release(self, setup):
        cfg, model, params = setup
        rng = np.random.default_rng(13)
        batcher = ContinuousBatcher(model, params, n_slots=2, max_seq=64)
        batcher.submit(rng.integers(0, cfg.vocab, size=5), max_new=4)
        batcher.submit(rng.integers(0, cfg.vocab, size=9), max_new=2)
        batcher.run()
        assert batcher.active() == 0
        np.testing.assert_array_equal(batcher.last_tok,
                                      np.zeros_like(batcher.last_tok))
        np.testing.assert_array_equal(batcher.positions,
                                      np.zeros_like(batcher.positions))

    def test_recycled_slot_parity_after_eos_release(self, setup):
        """A request admitted into a slot an eos-stopped predecessor just
        vacated must generate exactly what it would alone."""
        cfg, model, params = setup
        rng = np.random.default_rng(14)
        p_a = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
        p_b = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
        toks_a = _greedy_tokens(model, params, p_a, steps=6)
        # stop A mid-stream: its second generated token becomes eos
        eos = toks_a[1]
        want_a = _truncate_at_eos(toks_a, eos, 6)
        want_b = _truncate_at_eos(
            _greedy_tokens(model, params, p_b, steps=5), eos, 5)
        batcher = ContinuousBatcher(model, params, n_slots=1, max_seq=64,
                                    eos_id=eos)
        a = batcher.submit(p_a, max_new=6)
        b = batcher.submit(p_b, max_new=5)
        out = batcher.run()
        assert out[a] == want_a
        assert out[b] == want_b
        assert int(batcher.last_tok[0]) == 0
