"""Serving: continuous batching correctness — slot outputs must equal the
single-request Generator outputs regardless of admission interleaving."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.sharding import init_params
from repro.serve.batcher import ContinuousBatcher
from repro.serve.serve_step import Generator


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3.2-3b")
    model = build_model(cfg)
    params = init_params(model.specs, jax.random.PRNGKey(0))
    return cfg, model, params


class TestContinuousBatching:
    def test_matches_single_request_generation(self, setup):
        cfg, model, params = setup
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
                   for n in (5, 9, 3, 7, 6)]
        # oracle: one-at-a-time greedy generation
        gen = Generator(model, params, max_seq=64)
        want = {i: gen.generate(p[None, :], steps=6)[0].tolist()
                for i, p in enumerate(prompts)}
        # continuous batching with fewer slots than requests
        batcher = ContinuousBatcher(model, params, n_slots=2, max_seq=64)
        rids = [batcher.submit(p, max_new=6) for p in prompts]
        got = batcher.run()
        for i, rid in enumerate(rids):
            assert got[rid] == want[i], f"request {i} diverged"

    def test_slots_recycled(self, setup):
        cfg, model, params = setup
        rng = np.random.default_rng(1)
        batcher = ContinuousBatcher(model, params, n_slots=2, max_seq=64)
        for _ in range(5):
            batcher.submit(rng.integers(0, cfg.vocab, size=4), max_new=3)
        out = batcher.run()
        assert len(out) == 5
        assert all(len(v) == 3 for v in out.values())
        assert batcher.active() == 0

    def test_ragged_depths_advance_independently(self, setup):
        cfg, model, params = setup
        rng = np.random.default_rng(2)
        batcher = ContinuousBatcher(model, params, n_slots=3, max_seq=64)
        a = batcher.submit(rng.integers(0, cfg.vocab, size=3), max_new=2)
        b = batcher.submit(rng.integers(0, cfg.vocab, size=12), max_new=8)
        out = batcher.run()
        assert len(out[a]) == 2 and len(out[b]) == 8

    def test_overlong_prompt_rejected_at_submit(self, setup):
        """Regression (ISSUE 3): _admit never validated prompt length, so
        an over-long prompt wrote past the slot's KV region and started
        positions[slot] beyond max_seq.  submit must reject it up front
        (prompt == max_seq is also too long: decode needs one position)."""
        cfg, model, params = setup
        rng = np.random.default_rng(3)
        batcher = ContinuousBatcher(model, params, n_slots=2, max_seq=16)
        with pytest.raises(ValueError, match="slot capacity"):
            batcher.submit(rng.integers(0, cfg.vocab, size=40), max_new=2)
        with pytest.raises(ValueError, match="slot capacity"):
            batcher.submit(rng.integers(0, cfg.vocab, size=16), max_new=2)
        assert not batcher.queue                 # nothing was admitted
        rid = batcher.submit(rng.integers(0, cfg.vocab, size=15), max_new=4)
        out = batcher.run()
        # the slot fills after one decode (15 + 1 == max_seq): the request
        # still finishes cleanly inside its KV region
        assert 1 <= len(out[rid]) <= 4 and batcher.active() == 0
