"""Top-k pruning (paper Sec. 5): correctness vs full-scan oracle."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.metadata import NO_MATCH, ScanSet
from repro.core.prune_filter import eval_tv
from repro.core.prune_topk import (order_partitions, run_topk, topk_oracle,
                                   upfront_boundary)
from repro.data.table import Table

from helpers import predicates, small_tables


def scan_after_filter(tbl, pred):
    if pred is None:
        return ScanSet.full(tbl.num_partitions)
    tv = eval_tv(pred, tbl.stats)
    keep = tv > NO_MATCH
    return ScanSet(np.where(keep)[0], tv[keep])


class TestTopKCorrectness:
    @settings(max_examples=120, deadline=None)
    @given(
        tbl=small_tables(),
        k=st.integers(1, 12),
        desc=st.booleans(),
        strategy=st.sampled_from(["none", "random", "sort"]),
        upfront=st.booleans(),
        use_pred=st.booleans(),
        pred=predicates(),
    )
    def test_values_match_oracle(self, tbl, k, desc, strategy, upfront, use_pred, pred):
        pred = pred if use_pred else None
        scan = scan_after_filter(tbl, pred)
        res = run_topk(tbl, scan, "y", k, pred=pred, desc=desc,
                       strategy=strategy, use_upfront_init=upfront)
        oracle = topk_oracle(tbl, "y", k, pred=pred, desc=desc)
        np.testing.assert_array_equal(np.sort(res.values), np.sort(oracle))

    @settings(max_examples=60, deadline=None)
    @given(tbl=small_tables(), k=st.integers(1, 8))
    def test_order_col_with_nulls(self, tbl, k):
        """ORDER BY x where x may contain nulls: NULLS LAST semantics."""
        scan = scan_after_filter(tbl, None)
        res = run_topk(tbl, scan, "x", k, strategy="sort", use_upfront_init=True)
        oracle = topk_oracle(tbl, "x", k)
        np.testing.assert_array_equal(np.sort(res.values), np.sort(oracle))


class TestProcessingOrder:
    def clustered_table(self, clustering_sorted=True):
        rng = np.random.default_rng(7)
        vals = np.sort(rng.integers(0, 100_000, size=5000))
        if not clustering_sorted:
            vals = rng.permutation(vals)
        return Table.build("t", {"v": vals.astype(np.int64)}, rows_per_partition=100)

    def test_sorting_improves_pruning(self):
        """Fig. 8: sorting partitions by max gives a tight boundary early."""
        tbl = self.clustered_table(clustering_sorted=False)
        scan = ScanSet.full(tbl.num_partitions)
        r_sort = run_topk(tbl, scan, "v", 10, strategy="sort")
        r_none = run_topk(tbl, scan, "v", 10, strategy="random")
        assert r_sort.pruning_ratio >= r_none.pruning_ratio
        # k=10 over 100-row partitions: sorted-by-max order needs at most a
        # handful of partitions before the boundary saturates.
        assert r_sort.pruning_ratio >= 0.75
        assert len(r_sort.scanned) <= 12

    def test_sorted_table_scans_one_partition(self):
        """'Theoretically optimal' case: table physically sorted by the
        ORDER BY key -> only one partition need be fetched."""
        tbl = self.clustered_table(clustering_sorted=True)
        scan = ScanSet.full(tbl.num_partitions)
        res = run_topk(tbl, scan, "v", 10, strategy="sort")
        assert len(res.scanned) == 1

    def test_order_partitions_strategies(self):
        tbl = self.clustered_table()
        scan = ScanSet.full(tbl.num_partitions)
        ordered = order_partitions(scan, tbl.stats, "v", "sort")
        maxs = tbl.stats.col_max("v")[ordered.part_ids]
        assert (np.diff(maxs) <= 0).all()


class TestUpfrontInit:
    def test_boundary_from_fully_matching(self):
        """Sec. 5.4: with row counts + fully-matching partitions the
        boundary starts tight, pruning from the very first partition."""
        tbl = Table.build(
            "t", {"v": np.arange(1000, dtype=np.int64)}, rows_per_partition=100
        )
        scan = ScanSet.full(tbl.num_partitions)  # no predicate: all FULL
        b = upfront_boundary(scan, tbl.stats, "v", k=10)
        # top partition holds 900..999; k=10 rows >= 990 exist; candidate (b)
        # (sort by min desc, cum rows>=10 at first partition) gives 900.
        assert b >= 900
        res = run_topk(tbl, scan, "v", 10, strategy="none", use_upfront_init=True)
        np.testing.assert_array_equal(np.sort(res.values), np.arange(990, 1000))
        # without upfront init, the 'none' order scans everything until the
        # heap fills; with it, the low partitions are skipped immediately.
        res_no = run_topk(tbl, scan, "v", 10, strategy="none", use_upfront_init=False)
        assert res.pruning_ratio >= res_no.pruning_ratio

    def test_all_equal_values_no_overprune(self):
        """Tie-heavy regression guard: every value equal -> the upfront
        boundary equals every block max; nothing may be over-pruned."""
        tbl = Table.build(
            "t", {"v": np.full(100, 42, dtype=np.int64)}, rows_per_partition=10
        )
        scan = ScanSet.full(tbl.num_partitions)
        res = run_topk(tbl, scan, "v", 5, strategy="sort", use_upfront_init=True)
        np.testing.assert_array_equal(res.values, np.full(5, 42))
