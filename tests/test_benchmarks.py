"""Benchmark harness sanity: every paper-figure module runs and its
headline quantities land in the paper's neighborhood."""

import numpy as np


class TestPaperFigures:
    def test_fig06_k_distribution(self):
        from benchmarks.fig06_k_cdf import run
        ks = run(n=20_000, csv=False)
        assert 0.955 <= float((ks <= 10_000).mean()) <= 0.985   # paper 0.97
        assert float((ks <= 2_000_000).mean()) >= 0.997         # paper 0.999

    def test_tab01_classifier_recovers_mix(self):
        from benchmarks.tab01_limit_frequency import PAPER, run
        counts = run(n=5000, csv=False)
        total = sum(counts.values())
        for k, p in PAPER.items():
            got = counts.get(k, 0) / total
            assert abs(got - p) < 0.01, (k, got, p)

    def test_fig13_tpch_prunes_far_less_than_production(self):
        from benchmarks.fig11_flow import run as run_flow
        from benchmarks.fig13_tpch import run as run_tpch
        _, tpch_avg = run_tpch(rounds=2, csv=False)
        _, prod_overall = run_flow(n=60, csv=False)
        # the paper's Sec. 8.3 claim, directionally: production >> TPC-H
        assert prod_overall > 0.9
        assert tpch_avg < 0.5
        assert prod_overall - tpch_avg > 0.4

    def test_fig08_sorting_helps(self):
        from benchmarks.fig08_topk_sorting import run
        out = run(n=15, csv=False)
        assert np.mean(out["sort"]) >= np.mean(out["random"]) - 0.05

    def test_fig09_ratio_tracks_io(self):
        from benchmarks.fig09_topk_impact import run
        ratios, improvements = run(n=12, csv=False)
        if len(ratios) > 3:
            corr = float(np.corrcoef(ratios, improvements)[0, 1])
            assert corr > 0.5

    def test_fig10_join_pruning_effective(self):
        from benchmarks.fig10_join_impact import run
        a = run(n=20, csv=False)
        assert np.median(a) > 0.4


class TestKernelBench:
    def test_kernels_bench_runs(self):
        from benchmarks.kernels_bench import run
        rows = run(P=5000, csv=False)
        names = [r[0] for r in rows]
        assert "kern_minmax_jnp_hot" in names
