"""Mamba2 SSD correctness: the chunked dual form vs a naive recurrence
oracle, and decode-state continuity after prefill."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.mamba import ssd_scan
from repro.models.sharding import init_params


def naive_ssd(x, dt, A, B, C):
    """Reference: the literal SSM recurrence, one step at a time.
    s_t = s_{t-1} * exp(dt_t A) + dt_t B_t x_t ;  y_t = C_t . s_t"""
    b, s, h, p = x.shape
    n = B.shape[-1]
    st = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        decay = np.exp(dt[:, t] * A[None, :])              # [b, h]
        upd = np.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], B[:, t])
        st = st * decay[..., None, None] + upd
        ys[:, t] = np.einsum("bhpn,bn->bhp", st, C[:, t])
    return ys, st


@pytest.mark.parametrize("s,chunk", [(8, 4), (16, 4), (12, 5), (7, 16)])
def test_ssd_scan_matches_recurrence(s, chunk):
    rng = np.random.default_rng(s * 31 + chunk)
    b, h, p, n = 2, 3, 4, 5
    x = rng.normal(size=(b, s, h, p)).astype(np.float32)
    dt = rng.uniform(0.01, 0.5, size=(b, s, h)).astype(np.float32)
    A = -rng.uniform(0.1, 1.0, size=(h,)).astype(np.float32)
    B = rng.normal(size=(b, s, n)).astype(np.float32)
    C = rng.normal(size=(b, s, n)).astype(np.float32)
    y, s_final = ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                          jnp.asarray(B), jnp.asarray(C), chunk)
    y_ref, s_ref = naive_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_final), s_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-2.7b"])
def test_decode_continues_prefill_state(arch):
    """The logits of decoding token S after an S-token prefill must match
    a full (S+1)-token prefill — this requires the prefill to hand the
    REAL final SSM states (+conv tails) to the decode path."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = init_params(model.specs, jax.random.PRNGKey(0))
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)
    logits_full, _ = model.prefill_fn(params, {"tokens": toks}, 24)
    logits_s, cache = model.prefill_fn(params, {"tokens": toks[:, :S]}, 24)
    logits_dec, _ = model.decode_fn(
        params, cache, toks[:, S:], jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full),
                               rtol=4e-2, atol=4e-2)


def test_multi_step_decode_tracks_prefill():
    """Greedy decode for several steps == re-prefilling each time."""
    cfg = get_smoke_config("mamba2-1.3b")
    model = build_model(cfg)
    params = init_params(model.specs, jax.random.PRNGKey(2))
    B, S, steps = 1, 8, 4
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                         cfg.vocab))
    logits, cache = model.prefill_fn(params, {"tokens": jnp.asarray(toks)}, 32)
    seq = toks.copy()
    for i in range(steps):
        tok_dec = np.asarray(jnp.argmax(logits, -1))[:, None]
        # oracle: prefill the grown sequence from scratch
        seq = np.concatenate([seq, tok_dec], axis=1)
        logits_oracle, _ = model.prefill_fn(
            params, {"tokens": jnp.asarray(seq)}, 32)
        logits, cache = model.decode_fn(
            params, cache, jnp.asarray(tok_dec),
            jnp.full((B,), S + i, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(logits_oracle),
                                   rtol=4e-2, atol=4e-2)
