"""Incremental ingest: randomized DML parity + delta-staging counters.

The tentpole guarantee of the delta-staged device planes: after ANY
sequence of streaming DML (append / drop / rewrite / update) interleaved
with queries, the *delta-synced* resident planes produce pruning output
bit-identical to (a) a fresh full restage of the same table state and
(b) the f64 host oracle — for every technique (filter, LIMIT, JOIN
distinct + Bloom, top-k).  The counter tests pin the O(ΔP) staging
claim: appending ΔP partitions to a resident P-partition table stages
bytes proportional to ΔP, and only rewrite or capacity overflow pays a
full restage.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import expr as E
from repro.core.flow import JoinSpec, PruningPipeline, Query, TableScanSpec
from repro.core.metadata import live_full_scan
from repro.core.rowval import matches
from repro.data.table import Table
from repro.serve.prune_service import PruningService

NDV_LIMIT = 12     # straddled by build sides: small -> distinct, big -> Bloom
STR_DOMAIN = ["Bear", "Duck", "Eagle", "Frog", "Pike", "Wolf"]


def _rows(rng, n):
    return {
        "k": rng.integers(0, 60, n).astype(np.int64),
        "v": rng.integers(-200, 1000, n).astype(np.int64),
        "g": rng.integers(0, 50, n).astype(np.int64),
        "s": np.array([STR_DOMAIN[i] for i in rng.integers(0, len(STR_DOMAIN), n)]),
    }


def _base_tables(seed):
    rng = np.random.default_rng(seed)
    fact = Table.build("f", _rows(rng, 110), rows_per_partition=10,
                       nulls={"v": rng.random(110) < 0.1})
    dim = Table.build("d", {
        "a": rng.integers(0, 100, 40).astype(np.int64),
        "k": rng.integers(0, 60, 40).astype(np.int64),
    }, rows_per_partition=8)
    return fact, dim


def _queries(fact, dim, rng):
    """One query per technique family, literals drawn from ``rng``."""
    lo = int(rng.integers(-100, 800))
    a_lo = int(rng.integers(0, 80))
    qs = [
        # filter (device fast path)
        Query(scans={"f": TableScanSpec(
            fact, (E.col("v") >= lo) & (E.col("v") <= lo + 300))}),
        # filter with NOT -> host-fallback shape (and the empty-interval
        # NOT pitfall on dropped partitions)
        Query(scans={"f": TableScanSpec(
            fact, E.Not(E.col("v") > lo) | (E.col("g") == 7))}),
        # TruePred (live-mask full scan)
        Query(scans={"f": TableScanSpec(fact)}),
        # plain LIMIT
        Query(scans={"f": TableScanSpec(fact, E.col("v") >= lo)},
              limit=int(rng.integers(1, 12))),
        # top-k
        Query(scans={"f": TableScanSpec(fact, E.col("v") >= -150)},
              limit=int(rng.integers(1, 8)),
              order_by=("f", "v", bool(rng.integers(0, 2)))),
        # join, small build (distinct summary)
        Query(scans={"f": TableScanSpec(fact),
                     "d": TableScanSpec(dim, (E.col("a") >= a_lo)
                                        & (E.col("a") <= a_lo + 10))},
              join=JoinSpec("d", "f", "k", "k")),
        # join, big build (Bloom summary at NDV_LIMIT)
        Query(scans={"f": TableScanSpec(fact, E.col("v") >= lo - 200),
                     "d": TableScanSpec(dim)},
              join=JoinSpec("d", "f", "k", "k")),
    ]
    return qs


def _apply_dml(fact, op, rng):
    kind = op[0]
    if kind == "append":
        n, parts = op[1], op[2]
        fact.append_partitions(
            _rows(rng, n), nulls={"v": rng.random(n) < 0.1},
            rows_per_partition=None if parts == 1 else max(1, n // parts))
    elif kind == "drop":
        live = np.where(fact.live_mask)[0]
        if live.size > 2:
            fact.drop_partitions(rng.choice(live, size=min(2, live.size - 2),
                                            replace=False))
    elif kind == "rewrite":
        live = np.where(fact.live_mask)[0]
        pid = int(live[rng.integers(0, live.size)])
        n = int(np.diff(fact.part_bounds)[pid])
        fact.rewrite_partitions([pid], _rows(rng, n),
                                nulls={"v": rng.random(n) < 0.1})
    elif kind == "update":
        col = op[1]
        fact.update_column(col, rng.integers(-300, 1100,
                                             fact.num_rows).astype(np.int64))


def _assert_reports_equal(qs, got, want, label):
    for qi, (a, b) in enumerate(zip(got, want)):
        for name in qs[qi].scans:
            np.testing.assert_array_equal(
                a.scan_sets[name].part_ids, b.scan_sets[name].part_ids,
                err_msg=f"{label}: q={qi} scan={name} part_ids")
            np.testing.assert_array_equal(
                a.scan_sets[name].match, b.scan_sets[name].match,
                err_msg=f"{label}: q={qi} scan={name} match")
        if (a.topk is None) != (b.topk is None):
            raise AssertionError(f"{label}: q={qi} topk presence differs")
        if a.topk is not None:
            np.testing.assert_array_equal(a.topk.values, b.topk.values,
                                          err_msg=f"{label}: q={qi} topk")
            np.testing.assert_array_equal(a.topk.skipped, b.topk.skipped,
                                          err_msg=f"{label}: q={qi} skipped")


def _topk_brute(fact, q):
    """Ground-truth top-k multiset over the table's LIVE rows."""
    scan_name, col, desc = q.order_by
    spec = q.scans[scan_name]
    ctx = fact.ctx_for(np.where(fact.live_mask)[0])
    mask = matches(spec.pred, ctx)
    vals, nm = ctx.col(col)
    vals = np.sort(vals[mask & ~nm])
    k = q.effective_k
    return vals[::-1][:k] if desc else vals[:k]


@st.composite
def dml_programs(draw):
    seed = draw(st.integers(0, 2 ** 31))
    ops = draw(st.lists(st.one_of(
        st.integers(5, 35).map(lambda n: ("append", n, 1)),
        st.integers(8, 30).map(lambda n: ("append", n, 3)),
        st.integers(0, 3).map(lambda _: ("drop",)),
        st.integers(0, 3).map(lambda _: ("rewrite",)),
        st.sampled_from(["v", "g"]).map(lambda c: ("update", c)),
    ), min_size=1, max_size=5))
    return seed, ops


class TestRandomizedDMLParity:
    """delta-staged device == fresh-restage device == host oracle."""

    @settings(max_examples=8, deadline=None)
    @given(program=dml_programs())
    def test_dml_interleaved_queries(self, program):
        seed, ops = program
        rng = np.random.default_rng(seed)
        fact, dim = _base_tables(seed)

        svc = PruningService(mode="ref")
        delta_pipe = PruningPipeline(filter_mode="device", service=svc,
                                     join_ndv_limit=NDV_LIMIT)
        host_pipe = PruningPipeline(join_ndv_limit=NDV_LIMIT)

        for step, op in enumerate([("noop",)] + list(ops)):
            if op[0] != "noop":
                _apply_dml(fact, op, rng)
            qs = _queries(fact, dim, rng)
            delta_reports = svc.run_batch(qs, delta_pipe)
            fresh_svc = PruningService(mode="ref")
            fresh_pipe = PruningPipeline(filter_mode="device",
                                         service=fresh_svc,
                                         join_ndv_limit=NDV_LIMIT)
            fresh_reports = fresh_svc.run_batch(qs, fresh_pipe)
            host_reports = [host_pipe.run(q) for q in qs]
            _assert_reports_equal(qs, delta_reports, fresh_reports,
                                  f"step {step} ({op[0]}) delta-vs-fresh")
            _assert_reports_equal(qs, delta_reports, host_reports,
                                  f"step {step} ({op[0]}) delta-vs-host")
            for q, rep in zip(qs, delta_reports):
                if rep.topk is not None:
                    np.testing.assert_array_equal(
                        rep.topk.values, _topk_brute(fact, q),
                        err_msg=f"step {step}: topk vs live-row brute force")

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2 ** 31))
    def test_dropped_partitions_never_scanned(self, seed):
        rng = np.random.default_rng(seed)
        fact, dim = _base_tables(seed)
        drop = rng.choice(fact.num_partitions,
                          size=fact.num_partitions // 3, replace=False)
        fact.drop_partitions(drop)
        svc = PruningService(mode="ref")
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        qs = _queries(fact, dim, rng)
        for rep, q in zip(svc.run_batch(qs, pipe), qs):
            for name, ss in rep.scan_sets.items():
                table = q.scans[name].table
                assert table.live_mask[ss.part_ids].all(), \
                    f"dropped partition entered scan set {name}"
            if rep.topk is not None:
                assert fact.live_mask[rep.topk.scanned].all()


class TestTreeDMLParity:
    """ISSUE 7: the hierarchical tree planes through the same DML wringer.

    A tree-rung service (fanout 4, so the ~20-partition fact table is
    eligible) must stay bit-identical to (a) a fresh tree-plane restage,
    (b) the flat device path (default fanout 256 keeps these tables
    ineligible, so that service serves from the flat rungs), and (c) the
    f64 host oracle — across every DML kind, with tree deltas replayed
    in place rather than rebuilt.
    """

    @staticmethod
    def _tree_tables(seed):
        rng = np.random.default_rng(seed)
        fact = Table.build("f", _rows(rng, 200), rows_per_partition=10,
                           nulls={"v": rng.random(200) < 0.1})
        dim = Table.build("d", {
            "a": rng.integers(0, 100, 40).astype(np.int64),
            "k": rng.integers(0, 60, 40).astype(np.int64),
        }, rows_per_partition=8)
        return fact, dim

    @settings(max_examples=6, deadline=None)
    @given(program=dml_programs())
    def test_tree_dml_interleaved_queries(self, program):
        seed, ops = program
        rng = np.random.default_rng(seed)
        fact, dim = self._tree_tables(seed)

        tree_svc = PruningService(mode="ref", tree_fanout=4)
        tree_pipe = PruningPipeline(filter_mode="device", service=tree_svc,
                                    join_ndv_limit=NDV_LIMIT)
        flat_svc = PruningService(mode="ref")
        flat_pipe = PruningPipeline(filter_mode="device", service=flat_svc,
                                    join_ndv_limit=NDV_LIMIT)
        host_pipe = PruningPipeline(join_ndv_limit=NDV_LIMIT)

        for step, op in enumerate([("noop",)] + list(ops)):
            if op[0] != "noop":
                _apply_dml(fact, op, rng)
            qs = _queries(fact, dim, rng)
            tree_reports = tree_svc.run_batch(qs, tree_pipe)
            fresh_svc = PruningService(mode="ref", tree_fanout=4)
            fresh_pipe = PruningPipeline(filter_mode="device",
                                         service=fresh_svc,
                                         join_ndv_limit=NDV_LIMIT)
            fresh_reports = fresh_svc.run_batch(qs, fresh_pipe)
            flat_reports = flat_svc.run_batch(qs, flat_pipe)
            host_reports = [host_pipe.run(q) for q in qs]
            label = f"step {step} ({op[0]})"
            _assert_reports_equal(qs, tree_reports, fresh_reports,
                                  f"{label} tree-delta-vs-fresh-tree")
            _assert_reports_equal(qs, tree_reports, flat_reports,
                                  f"{label} tree-vs-flat")
            _assert_reports_equal(qs, tree_reports, host_reports,
                                  f"{label} tree-vs-host")
        # the eligible fact table must actually have served tree rungs
        assert tree_svc.counters.tree_launches > 0
        assert flat_svc.counters.tree_launches == 0

    def test_tree_plane_append_delta_replays_in_place(self):
        """An in-capacity append re-aggregates only tail groups: the tree
        plane delta-replays alongside the flat plane (no full restage)."""
        rng = np.random.default_rng(11)
        fact, dim = self._tree_tables(11)
        # verdict-cache off: this pins the flat+tree planes' own delta
        # replays, which a verdict hit would skip entirely
        svc = PruningService(mode="ref", tree_fanout=4,
                             verdict_cache=False)
        pipe = PruningPipeline(filter_mode="device", service=svc)
        qs = [Query(scans={"f": TableScanSpec(fact, E.col("v") >= 0)})]
        svc.run_batch(qs, pipe)            # stages flat + tree planes
        assert svc.cache.tree_planes
        before = svc.cache.staging_snapshot()
        fact.append_partitions(_rows(rng, 30), rows_per_partition=10)
        svc.run_batch(qs, pipe)
        after = svc.cache.staging_snapshot()
        assert after["full_restages"] == before["full_restages"]
        # one flat delta replay + one tree delta replay
        assert after["delta_stages"] >= before["delta_stages"] + 2
        host = PruningPipeline().run(qs[0])
        got = svc.run_batch(qs, pipe)
        _assert_reports_equal(qs, got, [host], "post-append tree-vs-host")

    def test_tree_plane_rewrite_forces_tree_rebuild(self):
        rng = np.random.default_rng(12)
        fact, dim = self._tree_tables(12)
        svc = PruningService(mode="ref", tree_fanout=4)
        pipe = PruningPipeline(filter_mode="device", service=svc)
        qs = [Query(scans={"f": TableScanSpec(fact, E.col("v") >= 0)})]
        svc.run_batch(qs, pipe)
        n = int(np.diff(fact.part_bounds)[3])
        fact.rewrite_partitions([3], _rows(rng, n))
        before_fulls = svc.cache.staging_snapshot()["full_restages"]
        svc.run_batch(qs, pipe)
        assert svc.cache.staging_snapshot()["full_restages"] > before_fulls
        host = PruningPipeline().run(qs[0])
        _assert_reports_equal(qs, svc.run_batch(qs, pipe), [host],
                              "post-rewrite tree-vs-host")


class TestDeltaStagingCounters:
    """The acceptance criterion: staging work proportional to the delta."""

    def _resident(self, n=240, seed=0, rows_per_partition=10):
        rng = np.random.default_rng(seed)
        fact = Table.build("f", _rows(rng, n),
                           rows_per_partition=rows_per_partition)
        # verdict-cache off: these tests pin the *flat* plane families'
        # delta staging; a verdict hit would skip cache.get entirely
        svc = PruningService(mode="ref", verdict_cache=False)
        pipe = PruningPipeline(filter_mode="device", service=svc)
        qs = [Query(scans={"f": TableScanSpec(fact, E.col("v") >= 0)}),
              Query(scans={"f": TableScanSpec(fact, E.col("g") <= 25)},
                    limit=5, order_by=("f", "v", True))]
        svc.run_batch(qs, pipe)       # stage [C, cap] + block-top-k planes
        return fact, svc, pipe, qs, rng

    def test_append_stages_o_delta_bytes(self):
        fact, svc, pipe, qs, rng = self._resident()
        C = len(fact.columns)
        P = fact.num_partitions
        before = svc.cache.staging_snapshot()
        new = fact.append_partitions(_rows(rng, 30), rows_per_partition=10)
        reports = svc.run_batch(qs, pipe)
        staging = reports[0].counters["staging"]
        d_p = len(new)
        assert staging["full_restages"] == 0
        assert staging["delta_stages"] >= 1
        # [C, ΔP] f32 stat planes + the [ΔP, KPLANE] top-k rows — and
        # nothing anywhere near the full [C, P] restage size.
        full_bytes = 3 * C * 4 * P
        assert 0 < staging["staged_bytes"] <= 3 * C * 4 * d_p + 64 * 4 * d_p
        assert staging["staged_bytes"] < full_bytes
        assert svc.cache.staging_snapshot()["full_restages"] == \
            before["full_restages"]
        # plane epoch advanced to the table's DML version
        planes = reports[0].counters["planes"]["f"]
        assert planes["version"] == fact.version
        assert planes["live"] == fact.num_live_partitions

    def test_many_appends_until_capacity_overflow(self):
        fact, svc, pipe, qs, rng = self._resident()
        cap = svc.cache.plane_epoch(fact).capacity
        fulls = 0
        while fact.num_partitions <= cap:
            fact.append_partitions(_rows(rng, 20), rows_per_partition=10)
            staging = svc.run_batch(qs, pipe)[0].counters["staging"]
            fulls += staging["full_restages"]
            if fact.num_partitions <= cap:
                assert staging["full_restages"] == 0   # in-capacity: delta
        # the overflowing append (and only it) paid a full restage, and
        # the new plane has fresh headroom
        assert fulls >= 1
        assert svc.cache.plane_epoch(fact).capacity > cap

    def test_drop_scatters_sentinels_without_restage(self):
        fact, svc, pipe, qs, rng = self._resident()
        fact.drop_partitions([1, 5, 9])
        staging = svc.run_batch(qs, pipe)[0].counters["staging"]
        assert staging["full_restages"] == 0
        assert staging["delta_stages"] >= 1
        C = len(fact.columns)
        assert staging["staged_bytes"] <= (3 * C * 4 + 64 * 4) * 3

    def test_rewrite_forces_full_restage(self):
        fact, svc, pipe, qs, rng = self._resident()
        n = int(np.diff(fact.part_bounds)[3])
        fact.rewrite_partitions([3], _rows(rng, n))
        staging = svc.run_batch(qs, pipe)[0].counters["staging"]
        assert staging["full_restages"] >= 1

    def test_update_restages_only_the_column_rows(self):
        """Satellite fix: an update to a column with NO resident join-key
        / enum / top-k plane must not bump the whole-table plane epoch —
        the [C, cap] planes delta-restage that column's rows only, and
        every other column's resident planes stay put untouched."""
        rng = np.random.default_rng(3)
        fact = Table.build("f", _rows(rng, 240), rows_per_partition=10)
        dim = Table.build("d", {
            "a": rng.integers(0, 100, 40).astype(np.int64),
            "k": rng.integers(0, 60, 40).astype(np.int64),
        }, rows_per_partition=8)
        # verdict-cache off: pins the column-granular [C, P]-row restage,
        # which a verdict hit on the filter stage would skip
        svc = PruningService(mode="ref", verdict_cache=False)
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=4)
        qs = [
            Query(scans={"f": TableScanSpec(fact, E.col("v") >= 0)},
                  limit=5, order_by=("f", "v", True)),
            Query(scans={"f": TableScanSpec(fact),
                         "d": TableScanSpec(dim, E.col("a") <= 90)},
                  join=JoinSpec("d", "f", "k", "k")),   # Bloom at limit 4
        ]
        svc.run_batch(qs, pipe)
        assert svc.cache.key_planes or svc.cache.enum_planes
        assert svc.cache.topk_planes
        plane_misses = svc.cache.plane_misses
        entry = svc.cache.entries[("f", fact.stats.uid)]

        fact.update_column("g", rng.integers(0, 9,
                                             fact.num_rows).astype(np.int64))
        reports = svc.run_batch(qs, pipe)
        staging = reports[0].counters["staging"]
        # column-granular: 3 rows x [P] f32, never a whole-plane restage
        assert staging["full_restages"] == 0
        assert staging["staged_bytes"] == 3 * fact.num_partitions * 4
        # no per-column plane was restaged (none covers column "g")
        assert svc.cache.plane_misses == plane_misses
        # same resident entry object, epoch advanced in place
        assert svc.cache.entries[("f", fact.stats.uid)] is entry
        assert entry.version == fact.version

        # ...while an update to a PLANE-backed column restages that
        # column's planes (and only that column's)
        key_col_planes = len([k for k in svc.cache.topk_planes
                              if k[2] == "v"])
        assert key_col_planes >= 1
        # all-positive values keep partitions fully matching v >= 0, so
        # the top-k boundary init consults (and must restage) the plane
        fact.update_column("v", rng.integers(100, 900,
                                             fact.num_rows).astype(np.int64))
        svc.run_batch(qs, pipe)
        assert svc.cache.plane_misses > plane_misses
        host = [PruningPipeline(join_ndv_limit=4).run(q) for q in qs]
        delta = svc.run_batch(qs, pipe)
        _assert_reports_equal(qs, delta, host, "post-update delta-vs-host")

    def test_legacy_notify_without_table_dml_still_restages(self):
        """A TableVersion bump with no covering delta log must fall back
        to the classic full restage (never serve a stale plane)."""
        fact, svc, pipe, qs, rng = self._resident()
        svc.register(fact)
        svc.run_batch(qs, pipe)
        misses = svc.cache.misses
        svc.notify_insert("f", 0)       # legacy invalidation path
        svc.run_batch(qs, pipe)
        assert svc.cache.misses == misses + 1


class TestTableDML:
    """The Table-level DML contract the planes rely on."""

    def test_append_extends_stats_and_live(self):
        rng = np.random.default_rng(0)
        t = Table.build("t", _rows(rng, 40), rows_per_partition=10)
        uid = t.stats.uid
        new = t.append_partitions(_rows(rng, 25), rows_per_partition=10)
        assert list(new) == [4, 5, 6]
        assert t.num_partitions == 7
        assert t.stats.num_partitions == 7
        assert t.stats.uid == uid                  # same identity: no rebuild
        assert t.num_rows == 65
        assert t.live_mask.all()
        assert t.version == 1 and t.deltas[-1].kind == "append"

    def test_drop_is_sentinel_tombstone(self):
        rng = np.random.default_rng(1)
        t = Table.build("t", _rows(rng, 40), rows_per_partition=10)
        t.drop_partitions([1, 3])
        assert not t.live_mask[1] and not t.live_mask[3]
        assert np.isinf(t.stats.mins[1]).all() and (t.stats.mins[1] > 0).all()
        assert t.stats.row_counts[1] == 0
        assert len(live_full_scan(t)) == 2
        with pytest.raises(ValueError):
            t.drop_partitions([1])                  # double drop
        with pytest.raises(ValueError):
            n = int(np.diff(t.part_bounds)[1])
            t.rewrite_partitions([1], _rows(np.random.default_rng(2), n))

    def test_rewrite_rejects_out_of_range_ids(self):
        """Negative/overflow ids must fail BEFORE any data mutation —
        a partial rewrite would leave stats stale under the new data."""
        rng = np.random.default_rng(4)
        t = Table.build("t", _rows(rng, 40), rows_per_partition=10)
        stats_before = t.stats.mins.copy()
        data_before = t.data["v"].copy()
        n = int(np.diff(t.part_bounds)[0])
        for bad in ([0, -1], [0, 99]):
            with pytest.raises(IndexError):
                t.rewrite_partitions(bad, _rows(rng, 2 * n))
        np.testing.assert_array_equal(t.stats.mins, stats_before)
        np.testing.assert_array_equal(t.data["v"], data_before)
        with pytest.raises(IndexError):
            t.drop_partitions([-1])

    def test_rewrite_keeps_bounds_and_updates_stats(self):
        rng = np.random.default_rng(2)
        t = Table.build("t", _rows(rng, 40), rows_per_partition=10)
        bounds = t.part_bounds.copy()
        vals = _rows(rng, 10)
        vals["v"] = np.full(10, 777, dtype=np.int64)
        t.rewrite_partitions([2], vals)
        np.testing.assert_array_equal(t.part_bounds, bounds)
        ci = t.stats.col_id("v")
        assert t.stats.mins[2, ci] == 777 == t.stats.maxs[2, ci]

    def test_append_unseen_string_rejected(self):
        rng = np.random.default_rng(3)
        t = Table.build("t", _rows(rng, 20), rows_per_partition=10)
        bad = _rows(rng, 5)
        bad["s"] = np.array(["NotInDictionary"] * 5)
        with pytest.raises(KeyError):
            t.append_partitions(bad)
