"""End-to-end behaviour: the combined pruning flow (paper Sec. 7) on the
guiding IUCN example, with execution results proven unchanged by pruning."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import expr as E
from repro.core.flow import JoinSpec, PruningPipeline, Query, TableScanSpec
from repro.data.generator import make_events_table, make_users_table
from repro.data.scan import execute_query
from repro.data.table import Table


def guiding_tables(seed=0):
    """The paper's running example: trails (dimension) + tracking_data
    (fact).  Production-shaped: the fact table arrives clustered by area,
    and species correlates with area (alpine wildlife lives high up) — the
    column-correlation effect Sec. 8.3 credits for real-world pruning."""
    rng = np.random.default_rng(seed)
    n_tr = 2000
    mountains = np.sort(rng.integers(0, 500, size=n_tr))
    trails = Table.build(
        "trails",
        {
            "mountain": mountains.astype(np.int64),
            "altit": rng.uniform(934, 7674, size=n_tr),
            "unit": rng.choice(["feet", "meters"], size=n_tr),
            "name": rng.choice(
                ["Marked-A-Ridge", "Marked-B-Ridge", "Basecamp", "Unmarked"],
                size=n_tr, p=[0.015, 0.015, 0.47, 0.5],
            ),
        },
        rows_per_partition=100,
    )
    n_td = 50_000
    area = np.sort(rng.integers(0, 500, size=n_td)).astype(np.int64)
    alpine = (area >= 350) & (rng.random(n_td) < 0.7)
    species = np.where(
        alpine,
        rng.choice(["Alpine Ibex", "Alpine Marmot", "Alpine Chough"], size=n_td),
        rng.choice(["Bear", "Wolf", "Duck", "Pike"], size=n_td),
    )
    tracking = Table.build(
        "tracking_data",
        {
            "area": area,
            "species": species,
            "s": rng.integers(5, 200, size=n_td).astype(np.int64),
            "num_sightings": rng.integers(0, 100_000, size=n_td).astype(np.int64),
        },
        rows_per_partition=500,
    )
    return trails, tracking


TRAILS_PRED = (
    E.if_(E.col("unit") == E.lit("feet"), E.col("altit") * 0.3048, E.col("altit"))
    > 1500
) & E.like(E.col("name"), "Marked-%-Ridge")
TRACKING_PRED = E.like(E.col("species"), "Alpine%") & (E.col("s") >= 50)


def guiding_query(trails, tracking, limit=3):
    """Sec. 6.1's full example: JOIN + filters + ORDER BY ... LIMIT 3."""
    return Query(
        scans={
            "trails": TableScanSpec(trails, TRAILS_PRED),
            "tracking_data": TableScanSpec(tracking, TRACKING_PRED),
        },
        join=JoinSpec("trails", "tracking_data", "mountain", "area"),
        limit=limit,
        order_by=("tracking_data", "num_sightings", True),
    )


class TestGuidingExample:
    def test_all_three_techniques_fire(self):
        trails, tracking = guiding_tables()
        q = guiding_query(trails, tracking)
        report = PruningPipeline().run(q)
        td = report.per_scan["tracking_data"]
        assert td["filter"].applied
        assert td["join"].applied and td["join"].ratio > 0
        assert td["topk"].applied
        assert report.overall_ratio > 0.5

    def test_pruned_execution_matches_unpruned(self):
        trails, tracking = guiding_tables()
        q = guiding_query(trails, tracking)
        report = PruningPipeline().run(q)
        pruned = execute_query(q, report)
        baseline = execute_query(q, None)
        # top-k output: the ORDER BY column values must be identical
        np.testing.assert_array_equal(
            pruned.columns["tracking_data.num_sightings"],
            baseline.columns["tracking_data.num_sightings"],
        )
        assert pruned.total_bytes() < baseline.total_bytes()

    def test_disabling_techniques_changes_io_not_results(self):
        trails, tracking = guiding_tables()
        q = guiding_query(trails, tracking)
        full = PruningPipeline().run(q)
        no_join = PruningPipeline(enable_join=False).run(q)
        r_full = execute_query(q, full)
        r_nojoin = execute_query(q, no_join)
        np.testing.assert_array_equal(
            r_full.columns["tracking_data.num_sightings"],
            r_nojoin.columns["tracking_data.num_sightings"],
        )
        assert r_full.total_bytes() <= r_nojoin.total_bytes()


class TestLimitFlow:
    def test_limit_query_end_to_end(self):
        rng = np.random.default_rng(1)
        events = make_events_table(rng, n_rows=20_000, rows_per_partition=500)
        q = Query(
            scans={"events": TableScanSpec(events, E.col("ts") >= 9_000_000)},
            limit=50,
        )
        report = PruningPipeline().run(q)
        res = execute_query(q, report)
        assert res.num_rows == 50
        assert (res.columns["events.ts"] >= 9_000_000).all()
        # LIMIT pruning should have cut the scan set hard
        lim = report.per_scan["events"]["limit"]
        assert lim.applied and lim.after <= 2

    def test_limit_without_predicate(self):
        rng = np.random.default_rng(2)
        events = make_events_table(rng, n_rows=10_000, rows_per_partition=500)
        q = Query(scans={"events": TableScanSpec(events)}, limit=10)
        report = PruningPipeline().run(q)
        assert report.per_scan["events"]["limit"].after == 1
        res = execute_query(q, report)
        assert res.num_rows == 10

    @settings(max_examples=25, deadline=None)
    @given(k=st.integers(1, 200), seed=st.integers(0, 5))
    def test_limit_always_yields_k_rows(self, k, seed):
        rng = np.random.default_rng(seed)
        events = make_events_table(rng, n_rows=5000, rows_per_partition=250)
        pred = E.col("ts") >= 2_000_000
        q = Query(scans={"events": TableScanSpec(events, pred)}, limit=k)
        report = PruningPipeline().run(q)
        res = execute_query(q, report)
        baseline = execute_query(q, None)
        assert res.num_rows == baseline.num_rows  # == min(k, matching)
        assert (res.columns["events.ts"] >= 2_000_000).all()


class TestJoinFlow:
    def test_inner_join_results_unchanged(self):
        rng = np.random.default_rng(3)
        events = make_events_table(rng, n_rows=20_000, rows_per_partition=500,
                                   user_clustering=0.997)
        users = make_users_table(rng, n_rows=2000, rows_per_partition=200)
        q = Query(
            scans={
                "users": TableScanSpec(users, E.col("age") >= 85),
                "events": TableScanSpec(events),
            },
            join=JoinSpec("users", "events", "id", "user_id"),
        )
        report = PruningPipeline().run(q)
        res = execute_query(q, report)
        baseline = execute_query(q, None)
        assert res.num_rows == baseline.num_rows
        a = np.sort(res.columns["events.user_id"])
        b = np.sort(baseline.columns["events.user_id"])
        np.testing.assert_array_equal(a, b)
        assert report.per_scan["events"]["join"].ratio > 0.3

    def test_left_outer_join_preserves_probe_rows(self):
        probe = Table.build(
            "p", {"k": np.arange(20, dtype=np.int64)}, rows_per_partition=5
        )
        build = Table.build(
            "b", {"k": np.array([3, 4, 5], dtype=np.int64),
                  "v": np.array([30, 40, 50], dtype=np.int64)},
            rows_per_partition=5,
        )
        q = Query(
            scans={"b": TableScanSpec(build), "p": TableScanSpec(probe)},
            join=JoinSpec("b", "p", "k", "k", kind="left_outer"),
        )
        res = execute_query(q, None)
        assert res.num_rows == 20
        assert res.nulls["b.v"].sum() == 17  # unmatched rows padded with NULL
