"""Dry-run path regression: the identical lower+compile code path at CI
scale (8 virtual devices, 2x2 / 2x2x2 meshes, reduced configs).

The full 512-device sweep is run out-of-band (dryrun_results.json); these
tests keep the machinery honest in the main suite.
"""

import json
import os
import subprocess
import sys

import pytest

CASES = [
    ("llama3.2-3b", "train_4k", []),                 # dense train
    ("kimi-k2-1t-a32b", "train_4k", []),             # MoE + EP
    ("mamba2-1.3b", "long_500k", []),                # SSM decode
    ("zamba2-2.7b", "decode_32k", []),               # hybrid cache
    ("whisper-small", "decode_32k", []),             # enc-dec cross-cache
    ("llava-next-34b", "prefill_32k", []),           # VLM prefix
]


def run_dryrun(arch, shape, extra, multi_pod=False):
    env = dict(
        os.environ,
        PYTHONPATH="src",
        REPRO_DRYRUN_DEVICES="8",
        REPRO_MESH_SCALE="8",
    )
    out = f"/tmp/dryrun_test_{arch}_{shape}_{multi_pod}.json"
    if os.path.exists(out):
        os.unlink(out)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--smoke", "--out", out]
    if multi_pod:
        cmd.append("--multi-pod")
    r = subprocess.run(cmd + extra, capture_output=True, text=True, env=env,
                       cwd="/root/repo", timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    with open(out) as f:
        return json.load(f)


@pytest.mark.parametrize("arch,shape,extra", CASES)
def test_cell_compiles(arch, shape, extra):
    recs = run_dryrun(arch, shape, extra)
    assert recs[0]["status"] == "OK", recs[0]
    rl = recs[0]["roofline"]
    assert rl["compute_s"] > 0 and rl["memory_s"] > 0
    assert rl["bottleneck"] in ("compute", "memory", "collective")


def test_multipod_mesh_shards_pod_axis():
    recs = run_dryrun("llama3.2-3b", "train_4k", [], multi_pod=True)
    assert recs[0]["status"] == "OK"
    assert recs[0]["mesh"] == "2x16x16"
    # collectives must exist: gradient reduction spans the pod axis
    assert recs[0]["roofline"]["coll_bytes"] > 0


def test_long_context_skips_full_attention():
    recs = run_dryrun("glm4-9b", "long_500k", [])
    assert recs[0]["status"] == "SKIP"
    assert "sub-quadratic" in recs[0]["reason"]


def test_full_sweep_results_are_green():
    """The out-of-band 512-device sweep must be complete and FAIL-free:
    10 archs x 4 shapes x 2 meshes = 80 cells = 64 OK + 16 documented
    SKIPs (long_500k on the 8 full-attention archs)."""
    path = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")
    if not os.path.exists(path):
        pytest.skip("full sweep not yet run")
    with open(path) as f:
        recs = json.load(f)
    by_status = {}
    for r in recs:
        by_status.setdefault(r["status"], []).append(r)
    assert not by_status.get("FAIL"), [
        (r["arch"], r["shape"], r["mesh"], r["error"])
        for r in by_status["FAIL"]]
    if len(recs) >= 80:
        assert len(by_status.get("OK", [])) == 64
        assert len(by_status.get("SKIP", [])) == 16
