"""Paper Sec. 8 extensions: predicate caching (8.2), Iceberg two-level
metadata + backfill (8.1), and the device-kernel flow path."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import expr as E
from repro.core.flow import PruningPipeline, Query, TableScanSpec
from repro.core.metadata import ScanSet
from repro.core.predicate_cache import (PredicateCache, TableVersion,
                                        plan_key)
from repro.core.prune_filter import eval_tv
from repro.core.prune_topk import run_topk, topk_oracle
from repro.data.iceberg import IcebergTable, two_level_prune
from repro.data.table import Table

from helpers import predicates, small_tables


def clustered_table(n=4000, rows_pp=100, seed=0):
    rng = np.random.default_rng(seed)
    return Table.build(
        "t", {"v": rng.permutation(np.arange(n)).astype(np.int64),
              "w": np.sort(rng.integers(0, 10_000, size=n)).astype(np.int64)},
        rows_per_partition=rows_pp)


class TestPredicateCache:
    def _run(self, tbl, k=5):
        scan = ScanSet.full(tbl.num_partitions)
        return run_topk(tbl, scan, "v", k, strategy="sort")

    def test_contributing_partitions_suffice(self):
        tbl = clustered_table()
        res = self._run(tbl)
        # re-running restricted to the cached partitions reproduces top-k
        cached = run_topk(tbl, ScanSet(res.contributing), "v", 5, strategy="none")
        np.testing.assert_array_equal(np.sort(cached.values),
                                      np.sort(topk_oracle(tbl, "v", 5)))

    def test_cache_hit_scans_fewer_partitions(self):
        """Sec. 8.2's pitch: on badly-clustered data, a perfect cache scans
        only the contributing partitions — fewer than boundary pruning."""
        tbl = clustered_table()  # random v: pruning struggles
        cache = PredicateCache()
        tv = TableVersion(tbl.num_partitions)
        key = plan_key("t", None, "v", True, 5)
        first = self._run(tbl)
        cache.record(key, first.contributing, tv)
        hit = cache.lookup(key, tv)
        assert hit is not None
        assert len(hit) <= len(first.scanned)
        cached = run_topk(tbl, ScanSet(hit), "v", 5, strategy="none")
        np.testing.assert_array_equal(np.sort(cached.values),
                                      np.sort(first.values))

    def test_insert_is_safe(self):
        """INSERTed partitions are unioned into the cached scan set."""
        tbl = clustered_table(n=1000, rows_pp=100)
        cache = PredicateCache()
        tv = TableVersion(tbl.num_partitions)
        key = plan_key("t", None, "v", True, 3)
        cache.record(key, self._run(tbl, k=3).contributing, tv)
        # append a partition holding the new global maxima
        new_v = np.concatenate([tbl.data["v"], np.arange(5000, 5100)])
        new_w = np.concatenate([tbl.data["w"], np.zeros(100)])
        tbl2 = Table.build("t", {"v": new_v.astype(np.int64),
                                 "w": new_w.astype(np.int64)},
                           rows_per_partition=100)
        tv.insert_partitions(tbl2.num_partitions - tbl.num_partitions)
        hit = cache.lookup(key, tv)
        res = run_topk(tbl2, ScanSet(hit), "v", 3, strategy="none")
        np.testing.assert_array_equal(np.sort(res.values),
                                      np.sort(topk_oracle(tbl2, "v", 3)))

    def test_delete_and_order_update_invalidate(self):
        cache = PredicateCache()
        tv = TableVersion(10)
        key = plan_key("t", None, "v", True, 3)
        cache.record(key, np.array([1, 2]), tv)
        cache.on_update("t", "w")          # non-order column: safe
        assert cache.lookup(key, tv) is not None
        cache.on_update("t", "v")          # order column: invalidate
        assert cache.lookup(key, tv) is None
        cache.record(key, np.array([1, 2]), tv)
        cache.on_delete("t")
        assert cache.lookup(key, tv) is None

    def test_lru_eviction(self):
        cache = PredicateCache(max_entries=2)
        tv = TableVersion(4)
        for i in range(3):
            cache.record(plan_key("t", None, "v", True, i), np.array([i]), tv)
        assert len(cache.entries) == 2
        assert cache.lookup(plan_key("t", None, "v", True, 0), tv) is None


class TestIcebergTwoLevel:
    @settings(max_examples=60, deadline=None)
    @given(tbl=small_tables(), pred=predicates(),
           gpf=st.sampled_from([2, 3, 8]))
    def test_two_level_equals_flat(self, tbl, pred, gpf):
        ice = IcebergTable.from_table(tbl, groups_per_file=gpf)
        res = two_level_prune(pred, ice)
        flat = eval_tv(pred, tbl.stats)
        np.testing.assert_array_equal(res.group_tv, flat)
        # metadata saving: pruned/certified files' groups were never read
        assert res.group_meta_reads <= tbl.num_partitions

    def test_metadata_io_saved_on_clustered_data(self):
        tbl = clustered_table()  # w clustered: file-level pruning bites
        ice = IcebergTable.from_table(tbl, groups_per_file=8)
        res = two_level_prune(E.col("w") >= 9_000, ice)
        assert res.files_pruned > 0
        assert res.group_meta_reads < tbl.num_partitions / 2

    def test_missing_metadata_blocks_pruning_until_backfill(self):
        tbl = clustered_table()
        ice = IcebergTable.from_table(tbl, groups_per_file=8,
                                      missing_meta_files=np.array([0, 1]))
        pred = E.col("w") >= 9_999_999  # matches nothing
        res = two_level_prune(pred, ice)
        sel = np.isin(ice.file_of_group, [0, 1])
        # files without stats descend to group level (still prunable there,
        # since our row groups kept their stats — the conservative part is
        # at FILE level, as in a manifest without column stats)
        assert res.group_meta_reads >= sel.sum()
        cost = ice.backfill(0) + ice.backfill(1)
        assert cost > 0
        res2 = two_level_prune(pred, ice)
        assert res2.group_meta_reads < res.group_meta_reads
        np.testing.assert_array_equal(res2.group_tv, eval_tv(pred, tbl.stats))


class TestDeviceFilterFlow:
    def test_device_mode_matches_host(self):
        tbl = clustered_table()
        pred = (E.col("w") >= 5000) & (E.col("w") < 6000)
        q = Query(scans={"t": TableScanSpec(tbl, pred)})
        host = PruningPipeline(filter_mode="host").run(q)
        dev = PruningPipeline(filter_mode="device").run(q)
        np.testing.assert_array_equal(host.scan_sets["t"].part_ids,
                                      dev.scan_sets["t"].part_ids)
        np.testing.assert_array_equal(host.scan_sets["t"].match,
                                      dev.scan_sets["t"].match)

    def test_device_mode_falls_back_on_complex_predicates(self):
        tbl = clustered_table()
        pred = (E.col("w") >= 5000) | (E.col("v") < 10)  # not conjunctive
        q = Query(scans={"t": TableScanSpec(tbl, pred)})
        host = PruningPipeline(filter_mode="host").run(q)
        dev = PruningPipeline(filter_mode="device").run(q)
        np.testing.assert_array_equal(host.scan_sets["t"].part_ids,
                                      dev.scan_sets["t"].part_ids)
