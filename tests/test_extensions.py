"""Paper Sec. 8 extensions: predicate caching (8.2), Iceberg two-level
metadata + backfill (8.1), and the device-kernel flow path."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import expr as E
from repro.core.flow import PruningPipeline, Query, TableScanSpec
from repro.core.metadata import ScanSet
from repro.core.predicate_cache import (PredicateCache, TableVersion,
                                        plan_key)
from repro.core.prune_filter import eval_tv
from repro.core.prune_topk import run_topk, topk_oracle
from repro.data.iceberg import IcebergTable, two_level_prune
from repro.data.table import Table

from helpers import predicates, small_tables


def clustered_table(n=4000, rows_pp=100, seed=0):
    rng = np.random.default_rng(seed)
    return Table.build(
        "t", {"v": rng.permutation(np.arange(n)).astype(np.int64),
              "w": np.sort(rng.integers(0, 10_000, size=n)).astype(np.int64)},
        rows_per_partition=rows_pp)


class TestPredicateCache:
    def _run(self, tbl, k=5):
        scan = ScanSet.full(tbl.num_partitions)
        return run_topk(tbl, scan, "v", k, strategy="sort")

    def test_contributing_partitions_suffice(self):
        tbl = clustered_table()
        res = self._run(tbl)
        # re-running restricted to the cached partitions reproduces top-k
        cached = run_topk(tbl, ScanSet(res.contributing), "v", 5, strategy="none")
        np.testing.assert_array_equal(np.sort(cached.values),
                                      np.sort(topk_oracle(tbl, "v", 5)))

    def test_cache_hit_scans_fewer_partitions(self):
        """Sec. 8.2's pitch: on badly-clustered data, a perfect cache scans
        only the contributing partitions — fewer than boundary pruning."""
        tbl = clustered_table()  # random v: pruning struggles
        cache = PredicateCache()
        tv = TableVersion(tbl.num_partitions)
        key = plan_key("t", None, "v", True, 5)
        first = self._run(tbl)
        cache.record(key, first.contributing, tv)
        hit = cache.lookup(key, tv)
        assert hit is not None
        assert len(hit) <= len(first.scanned)
        cached = run_topk(tbl, ScanSet(hit), "v", 5, strategy="none")
        np.testing.assert_array_equal(np.sort(cached.values),
                                      np.sort(first.values))

    def test_insert_is_safe(self):
        """INSERTed partitions are unioned into the cached scan set."""
        tbl = clustered_table(n=1000, rows_pp=100)
        cache = PredicateCache()
        tv = TableVersion(tbl.num_partitions)
        key = plan_key("t", None, "v", True, 3)
        cache.record(key, self._run(tbl, k=3).contributing, tv)
        # append a partition holding the new global maxima
        new_v = np.concatenate([tbl.data["v"], np.arange(5000, 5100)])
        new_w = np.concatenate([tbl.data["w"], np.zeros(100)])
        tbl2 = Table.build("t", {"v": new_v.astype(np.int64),
                                 "w": new_w.astype(np.int64)},
                           rows_per_partition=100)
        tv.insert_partitions(tbl2.num_partitions - tbl.num_partitions)
        hit = cache.lookup(key, tv)
        res = run_topk(tbl2, ScanSet(hit), "v", 3, strategy="none")
        np.testing.assert_array_equal(np.sort(res.values),
                                      np.sort(topk_oracle(tbl2, "v", 3)))

    def test_delete_and_order_update_invalidate(self):
        cache = PredicateCache()
        tv = TableVersion(10)
        key = plan_key("t", None, "v", True, 3)
        cache.record(key, np.array([1, 2]), tv)
        cache.on_update("t", "w")          # non-order column: safe
        assert cache.lookup(key, tv) is not None
        cache.on_update("t", "v")          # order column: invalidate
        assert cache.lookup(key, tv) is None
        cache.record(key, np.array([1, 2]), tv)
        cache.on_delete("t")
        assert cache.lookup(key, tv) is None

    def test_lru_eviction(self):
        cache = PredicateCache(max_entries=2)
        tv = TableVersion(4)
        for i in range(3):
            cache.record(plan_key("t", None, "v", True, i), np.array([i]), tv)
        assert len(cache.entries) == 2
        assert cache.lookup(plan_key("t", None, "v", True, 0), tv) is None

    def test_plan_key_canonicalizes_equivalent_predicates(self):
        """Regression: plan_key used raw repr(pred) — commuted conjuncts
        and 1-vs-1.0 literals of one predicate always missed."""
        p1 = (E.col("v") >= 100) & (E.col("w") < 500)
        p2 = (E.col("w") < 500.0) & (E.col("v") >= 100.0)   # commuted + float
        assert plan_key("t", p1, "v", True, 5) == plan_key("t", p2, "v",
                                                           True, 5)
        # lit-on-left orientation normalizes too
        assert E.canonical_key(E.lit(100) <= E.col("v")) == \
            E.canonical_key(E.col("v") >= 100)
        # nested/duplicated conjuncts flatten and dedupe
        assert E.canonical_key(E.And((p1, E.col("v") >= 100))) == \
            E.canonical_key(p2)
        # genuinely different predicates keep distinct keys
        assert plan_key("t", p1, "v", True, 5) != \
            plan_key("t", (E.col("v") >= 101) & (E.col("w") < 500), "v",
                     True, 5)
        # ints too wide for an exact f64 must NOT merge with their float
        assert E.canonical_key(E.col("v") == (2 ** 53 + 1)) != \
            E.canonical_key(E.col("v") == float(2 ** 53))

    def test_update_of_predicate_column_invalidates(self):
        """Regression: on_update matched only the *order* column, so an
        UPDATE to a predicate-only column served a stale contributing set
        — a wrong top-k."""
        tbl = Table.build(
            "t", {"v": np.array([0, 1, 10, 11, 20, 21, 30, 31], np.int64),
                  "w": np.array([1, 1, 1, 1, 0, 0, 0, 0], np.int64)},
            rows_per_partition=2)
        pred = E.col("w") >= 1
        cache = PredicateCache()
        tv = TableVersion(tbl.num_partitions)
        key = plan_key("t", pred, "v", True, 2)
        # top-2 of v among rows passing the predicate lives in partition 1
        cache.record(key, np.array([1]), tv, pred=pred)
        # UPDATE w: now partitions 2,3 pass — the correct top-2 is (30, 31)
        tbl.update_column("w", np.array([0, 0, 0, 0, 1, 1, 1, 1], np.int64))
        # the stale cached set would produce a wrong answer:
        stale_top2 = np.sort(tbl.data["v"][2:4])            # partition 1
        oracle = np.sort(tbl.data["v"][tbl.data["w"] >= 1])[-2:]
        assert not np.array_equal(stale_top2, oracle)
        # ...so an update of a column the predicate reads must invalidate
        cache.on_update("t", "w")
        assert cache.lookup(key, tv) is None

    def test_drop_then_append_freshness_uses_delta_log(self):
        """Regression: the raw-count arange union resurrected dropped
        partition ids (drops tombstone in place; appends extend)."""
        rng = np.random.default_rng(3)
        def cols(n):
            return {"v": rng.integers(0, 100, n).astype(np.int64),
                    "w": rng.integers(0, 100, n).astype(np.int64)}
        tbl = Table.build("t", cols(100), rows_per_partition=10)
        pred = E.col("w") >= 0
        cache = PredicateCache()
        tv = TableVersion(tbl.num_partitions)
        key = plan_key("t", pred, "v", True, 3)
        cache.record(key, np.array([1, 2, 5]), tv, pred=pred, table=tbl)
        tbl.drop_partitions(np.array([2, 7]))
        tv.version += 1
        tbl.append_partitions(cols(20), rows_per_partition=10)  # ids 10, 11
        tv.insert_partitions(2)
        hit = cache.lookup(key, tv, table=tbl)
        assert hit is not None
        assert 2 not in hit and 7 not in hit    # tombstones never resurrect
        assert {1, 5, 10, 11} <= set(hit.tolist())
        # the legacy raw-count path on the same history would have served
        # np.arange(10, 12) unioned onto [1, 2, 5] — including dropped 2
        # rewrite since record time: unsafe, must miss
        n = int(np.diff(tbl.part_bounds)[1])
        tbl.rewrite_partitions([1], cols(n))
        tv.version += 1
        assert cache.lookup(key, tv, table=tbl) is None

    def test_delta_log_update_of_predicate_column_misses(self):
        rng = np.random.default_rng(4)
        tbl = Table.build(
            "t", {"v": rng.integers(0, 100, 40).astype(np.int64),
                  "w": rng.integers(0, 100, 40).astype(np.int64)},
            rows_per_partition=10)
        pred = E.col("w") >= 50
        cache = PredicateCache()
        tv = TableVersion(tbl.num_partitions)
        key = plan_key("t", pred, "v", True, 3)
        cache.record(key, np.array([0, 2]), tv, pred=pred, table=tbl)
        assert cache.lookup(key, tv, table=tbl) is not None
        # update of the predicate column via the delta log: miss
        tbl.update_column("w", rng.integers(0, 100, 40).astype(np.int64))
        tv.version += 1
        assert cache.lookup(key, tv, table=tbl) is None


class TestIcebergTwoLevel:
    @settings(max_examples=60, deadline=None)
    @given(tbl=small_tables(), pred=predicates(),
           gpf=st.sampled_from([2, 3, 8]))
    def test_two_level_equals_flat(self, tbl, pred, gpf):
        ice = IcebergTable.from_table(tbl, groups_per_file=gpf)
        res = two_level_prune(pred, ice)
        flat = eval_tv(pred, tbl.stats)
        np.testing.assert_array_equal(res.group_tv, flat)
        # metadata saving: pruned/certified files' groups were never read
        assert res.group_meta_reads <= tbl.num_partitions

    def test_metadata_io_saved_on_clustered_data(self):
        tbl = clustered_table()  # w clustered: file-level pruning bites
        ice = IcebergTable.from_table(tbl, groups_per_file=8)
        res = two_level_prune(E.col("w") >= 9_000, ice)
        assert res.files_pruned > 0
        assert res.group_meta_reads < tbl.num_partitions / 2

    def test_missing_metadata_blocks_pruning_until_backfill(self):
        tbl = clustered_table()
        ice = IcebergTable.from_table(tbl, groups_per_file=8,
                                      missing_meta_files=np.array([0, 1]))
        pred = E.col("w") >= 9_999_999  # matches nothing
        res = two_level_prune(pred, ice)
        sel = np.isin(ice.file_of_group, [0, 1])
        # files without stats descend to group level (still prunable there,
        # since our row groups kept their stats — the conservative part is
        # at FILE level, as in a manifest without column stats)
        assert res.group_meta_reads >= sel.sum()
        cost = ice.backfill(0) + ice.backfill(1)
        assert cost > 0
        res2 = two_level_prune(pred, ice)
        assert res2.group_meta_reads < res.group_meta_reads
        np.testing.assert_array_equal(res2.group_tv, eval_tv(pred, tbl.stats))


class TestDeviceFilterFlow:
    def test_device_mode_matches_host(self):
        tbl = clustered_table()
        pred = (E.col("w") >= 5000) & (E.col("w") < 6000)
        q = Query(scans={"t": TableScanSpec(tbl, pred)})
        host = PruningPipeline(filter_mode="host").run(q)
        dev = PruningPipeline(filter_mode="device").run(q)
        np.testing.assert_array_equal(host.scan_sets["t"].part_ids,
                                      dev.scan_sets["t"].part_ids)
        np.testing.assert_array_equal(host.scan_sets["t"].match,
                                      dev.scan_sets["t"].match)

    def test_device_mode_falls_back_on_complex_predicates(self):
        tbl = clustered_table()
        pred = (E.col("w") >= 5000) | (E.col("v") < 10)  # not conjunctive
        q = Query(scans={"t": TableScanSpec(tbl, pred)})
        host = PruningPipeline(filter_mode="host").run(q)
        dev = PruningPipeline(filter_mode="device").run(q)
        np.testing.assert_array_equal(host.scan_sets["t"].part_ids,
                                      dev.scan_sets["t"].part_ids)
