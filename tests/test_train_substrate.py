"""Train substrate: optimizer, microbatching, compression, checkpoint/
restart, elastic resharding, work stealing."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import expr as E
from repro.data.pipeline import (PrunedDataLoader, WorkQueue, curate,
                                 make_corpus_metadata, shard_tokens)
from repro.models import build_model
from repro.launch.train import default_config
from repro.models.sharding import init_params
from repro.train import checkpoint as ckpt
from repro.train.compress import compress_grads, init_error
from repro.train.elastic import plan_mesh, scale_batch
from repro.train.optimizer import AdamW, cosine_schedule
from repro.train.train_step import init_state, make_train_step


def tiny_model():
    import dataclasses
    cfg = dataclasses.replace(default_config(vocab=128), n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=2, d_ff=128)
    return build_model(cfg)


def tiny_batch(key, cfg, B=4, S=16):
    kt, kl = jax.random.split(key)
    return {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab),
    }


class TestOptimizer:
    def test_loss_decreases(self):
        model = tiny_model()
        opt = AdamW(lr=cosine_schedule(1e-2, warmup=5, total=100))
        step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
        state = init_state(model, opt, jax.random.PRNGKey(0))
        batch = tiny_batch(jax.random.PRNGKey(1), model.cfg)
        losses = []
        for _ in range(30):
            state, m = step(state, batch)  # overfit one batch
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] * 0.7, losses[::10]

    def test_microbatching_matches_full_batch(self):
        model = tiny_model()
        opt = AdamW(lr=lambda s: 1e-3, clip_norm=None)
        s1 = jax.jit(make_train_step(model, opt, microbatches=1))
        s4 = jax.jit(make_train_step(model, opt, microbatches=4))
        state = init_state(model, opt, jax.random.PRNGKey(0))
        batch = tiny_batch(jax.random.PRNGKey(1), model.cfg, B=8)
        st1, m1 = s1(state, batch)
        st4, m4 = s4(state, batch)
        # Losses are bit-identical; params may differ by one bf16 ulp
        # (2^-9 at |w|<1) where the f32 update rounds either way.
        np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                                   rtol=1e-6)
        for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st4.params)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=5e-2, atol=2.5e-3)

    def test_bf16_optimizer_state(self):
        model = tiny_model()
        opt = AdamW(lr=lambda s: 1e-3, state_dtype=jnp.bfloat16)
        state = init_state(model, opt, jax.random.PRNGKey(0))
        assert all(m.dtype == jnp.bfloat16 for m in jax.tree.leaves(state.opt.m))
        step = jax.jit(make_train_step(model, opt))
        state, m = step(state, tiny_batch(jax.random.PRNGKey(1), model.cfg))
        assert np.isfinite(float(m["loss"]))


class TestCompression:
    def test_quantization_error_bounded(self):
        g = {"w": jnp.linspace(-3, 3, 1000)}
        e = init_error(g)
        gq, e2 = compress_grads(g, e)
        err = np.abs(np.asarray(gq["w"]) - np.asarray(g["w"])).max()
        assert err <= 3 / 127 + 1e-6

    def test_error_feedback_reinjects(self):
        g = {"w": jnp.full((100,), 1e-4)}  # below one quantization step
        e = init_error(g)
        total = np.zeros(100, np.float32)
        for _ in range(50):
            gq, e = compress_grads(g, e)
            total += np.asarray(gq["w"])
        # long-run average must recover the true signal
        np.testing.assert_allclose(total / 50, 1e-4, rtol=0.3)

    def test_training_converges_with_compression(self):
        model = tiny_model()
        opt = AdamW(lr=cosine_schedule(1e-2, warmup=5, total=100))
        step = jax.jit(make_train_step(model, opt, compress=True),
                       donate_argnums=(0,))
        state = init_state(model, opt, jax.random.PRNGKey(0), compress=True)
        batch = tiny_batch(jax.random.PRNGKey(1), model.cfg)
        losses = []
        for _ in range(30):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] * 0.75, losses[::10]

    def test_compressed_psum_matches_psum(self):
        from repro.train.compress import compressed_psum
        from jax import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
        x = jnp.linspace(-1, 1, 64).reshape(1, 64)

        f = shard_map(lambda v: compressed_psum(v, "pod"), mesh=mesh,
                      in_specs=P("pod"), out_specs=P("pod"))
        out = f(x)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(x[0]),
                                   atol=2 / 127)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        model = tiny_model()
        opt = AdamW(lr=lambda s: 1e-3)
        state = init_state(model, opt, jax.random.PRNGKey(0))
        path = ckpt.save(str(tmp_path), 7, state, extra={"note": "x"})
        assert os.path.basename(path) == "step_00000007"
        restored, manifest = ckpt.restore(str(tmp_path), 7, state)
        assert manifest["step"] == 7 and manifest["extra"]["note"] == "x"
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_latest_step_ignores_tmp(self, tmp_path):
        model = tiny_model()
        opt = AdamW(lr=lambda s: 1e-3)
        state = init_state(model, opt, jax.random.PRNGKey(0))
        ckpt.save(str(tmp_path), 5, state)
        os.makedirs(tmp_path / "step_00000009.tmp")  # simulated crash
        assert ckpt.latest_step(str(tmp_path)) == 5

    def test_restart_resumes_training(self, tmp_path):
        """Full restart drill: run the driver, kill it at step 6, re-run,
        confirm it resumes and completes with identical data order."""
        env = dict(os.environ, PYTHONPATH="src")
        cmd = [sys.executable, "-m", "repro.launch.train",
               "--steps", "10", "--ckpt-every", "5", "--batch", "2",
               "--seq", "32", "--ckpt-dir", str(tmp_path / "ck"),
               "--log-every", "5"]
        r1 = subprocess.run(cmd + ["--simulate-failure", "6"],
                            capture_output=True, text=True, env=env,
                            cwd="/root/repo")
        assert r1.returncode == 42, r1.stderr[-2000:]
        assert "checkpoint ->" in r1.stdout
        r2 = subprocess.run(cmd, capture_output=True, text=True, env=env,
                            cwd="/root/repo")
        assert r2.returncode == 0, r2.stderr[-2000:]
        assert "resumed from step 5" in r2.stdout
        assert "done:" in r2.stdout


class TestElastic:
    def test_plan_mesh_shrinks_model_axis_when_needed(self):
        mesh = plan_mesh(jax.devices(), model_parallel=16)
        assert mesh.shape["model"] == 1  # single CPU device
        assert mesh.shape["data"] == 1

    def test_scale_batch(self):
        gb, mb = scale_batch(256, old_data=32, new_data=16, microbatches=1)
        assert gb == 256 and mb == 2
        gb, mb = scale_batch(250, old_data=32, new_data=16, microbatches=1)
        assert gb % 16 == 0

    def test_elastic_dryrun_resharding(self):
        """512-dev subprocess: save on 2x16x16, reshard+resume on 16x16
        minus a 'failed' pod — the real elastic path."""
        code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
import jax.numpy as jnp
from repro.launch.train import default_config
import dataclasses
from repro.models import build_model
from repro.models.sharding import tree_shardings, init_params
from repro.train.optimizer import AdamW
from repro.train.train_step import init_state
from repro.train import checkpoint as ckpt
from repro.train.elastic import plan_mesh, reshard

cfg = dataclasses.replace(default_config(vocab=128), n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128)
model = build_model(cfg)
opt = AdamW(lr=lambda s: 1e-3)
state = init_state(model, opt, jax.random.PRNGKey(0))
path = ckpt.save("/tmp/elastic_ck", 3, state)
# 'lose' half the devices
survivors = jax.devices()[:4]
mesh = plan_mesh(survivors, model_parallel=2)
assert dict(mesh.shape) == {"data": 2, "model": 2}, mesh.shape
restored, _ = ckpt.restore("/tmp/elastic_ck", 3, state)
resharded = reshard(restored, model.specs, mesh)
leaf = jax.tree.leaves(resharded.params)[0]
assert len(leaf.sharding.device_set) <= 4
print("ELASTIC_OK")
"""
        env = dict(os.environ, PYTHONPATH="src")
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, env=env, cwd="/root/repo")
        assert "ELASTIC_OK" in r.stdout, r.stderr[-3000:]


class TestWorkStealing:
    def test_all_shards_processed_exactly_once(self):
        q = WorkQueue(np.arange(37), n_workers=4)
        seen = []
        # worker 3 is a straggler: never asks for work after its first item
        order = [0, 1, 2, 3] + [0, 1, 2] * 20
        for w in order:
            sid = q.next_for(w)
            if sid is not None:
                seen.append(sid)
        assert sorted(seen) == list(range(37))

    def test_fast_workers_steal_from_straggler(self):
        q = WorkQueue(np.arange(40), n_workers=2)
        done_by_0 = []
        for _ in range(35):
            sid = q.next_for(0)
            if sid is None:
                break
            done_by_0.append(sid)
        # worker 0 did its 20 plus stole from worker 1's tail
        assert len(done_by_0) > 20

    def test_queue_state_roundtrip(self):
        q = WorkQueue(np.arange(10), n_workers=2)
        for _ in range(3):
            q.next_for(0)
        st = q.state()
        q2 = WorkQueue(np.arange(10), n_workers=2)
        q2.restore(st)
        assert q2.next_for(0) == q.next_for(0)


class TestPrunedPipeline:
    def test_curation_prunes_and_loader_yields(self):
        rng = np.random.default_rng(0)
        meta = make_corpus_metadata(rng, n_shards=128, docs_per_shard=8)
        pred = E.col("quality") >= 0.5
        scan, report = curate(meta, pred)
        assert 0.1 < report.pruning_ratio < 0.9
        loader = PrunedDataLoader(scan, worker=0, n_workers=1, batch_size=2,
                                  seq_len=64, vocab=1000)
        batches = list(iter(loader))
        assert len(batches) > 10
        assert batches[0]["tokens"].shape == (2, 64)
        assert (batches[0]["tokens"] < 1000).all()

    def test_deterministic_replay(self):
        rng = np.random.default_rng(1)
        meta = make_corpus_metadata(rng, n_shards=64, docs_per_shard=8)
        scan, _ = curate(meta, E.col("quality") >= 0.3)
        mk = lambda: PrunedDataLoader(scan, 0, 1, 2, 32, 500, seed=7)
        a = [b["tokens"] for b in mk()]
        b = [b["tokens"] for b in mk()]
        assert len(a) == len(b)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
