"""LIMIT pruning (paper Sec. 4): IO-optimality and Table 2 categories."""

import numpy as np
from hypothesis import given, settings

from repro.core import expr as E
from repro.core.metadata import NO_MATCH, ScanSet
from repro.core.prune_filter import eval_tv
from repro.core.prune_limit import (ALREADY_MINIMAL, NO_FULLY_MATCHING,
                                    PRUNED_TO_0, PRUNED_TO_1, PRUNED_TO_N,
                                    UNSUPPORTED_SHAPE, limit_prune)
from repro.core.rowval import matches
from repro.data.table import Table

from helpers import predicates, small_tables


def scan_after_filter(tbl, pred):
    tv = eval_tv(pred, tbl.stats)
    keep = tv > NO_MATCH
    return ScanSet(np.where(keep)[0], tv[keep])


def count_matching(tbl, pred, part_ids):
    return sum(int(matches(pred, tbl.partition_ctx(int(p))).sum()) for p in part_ids)


class TestLimitPrune:
    def make_sorted_table(self):
        # x sorted across partitions: predicate x >= 40 gives partitions
        # 0 (NO), 1 (partial at boundary), 2..9 (fully matching).
        return Table.build(
            "t", {"x": np.arange(100, dtype=np.int64)}, rows_per_partition=10
        )

    def test_prunes_to_single_partition(self):
        tbl = self.make_sorted_table()
        pred = E.col("x") >= 35
        scan = scan_after_filter(tbl, pred)
        res = limit_prune(scan, tbl.stats, k=3)
        assert res.applied and res.category == PRUNED_TO_1
        assert res.partitions_after == 1
        # the retained partition really yields >= 3 qualifying rows
        assert count_matching(tbl, pred, res.scan.part_ids) >= 3

    def test_prunes_to_minimal_multiple(self):
        tbl = self.make_sorted_table()
        pred = E.col("x") >= 35
        scan = scan_after_filter(tbl, pred)
        res = limit_prune(scan, tbl.stats, k=25)
        assert res.applied and res.category == PRUNED_TO_N
        assert res.partitions_after == 3  # ceil(25/10): IO-optimal
        assert count_matching(tbl, pred, res.scan.part_ids) >= 25

    def test_k0_empties_scan(self):
        tbl = self.make_sorted_table()
        res = limit_prune(scan_after_filter(tbl, E.true()), tbl.stats, k=0)
        assert res.applied and res.partitions_after == 0
        # honest Table 2 accounting: 0 partitions is not "pruned to 1"
        assert res.category == PRUNED_TO_0

    def test_k0_single_partition_scan_also_emptied(self):
        """Regression (ISSUE 3): LIMIT 0 was checked after the
        already-minimal early return, so a single-partition scan kept its
        partition instead of being wiped."""
        tbl = Table.build("t", {"x": np.arange(5, dtype=np.int64)},
                          rows_per_partition=5)            # one partition
        res = limit_prune(scan_after_filter(tbl, E.true()), tbl.stats, k=0)
        assert res.applied and res.partitions_after == 0
        assert len(res.scan) == 0
        assert res.category == PRUNED_TO_0

    def test_no_fully_matching_reorders_only(self):
        # random layout: no fully-matching partitions for a tight predicate
        rng = np.random.default_rng(0)
        tbl = Table.build(
            "t", {"x": rng.permutation(100).astype(np.int64)}, rows_per_partition=10
        )
        pred = E.col("x") >= 95
        scan = scan_after_filter(tbl, pred)
        res = limit_prune(scan, tbl.stats, k=3)
        assert not res.applied and res.category == NO_FULLY_MATCHING
        assert res.partitions_after == res.partitions_before

    def test_unsupported_shape(self):
        tbl = self.make_sorted_table()
        res = limit_prune(
            scan_after_filter(tbl, E.true()), tbl.stats, k=3, supported_shape=False
        )
        assert res.category == UNSUPPORTED_SHAPE

    def test_already_minimal(self):
        tbl = Table.build("t", {"x": np.arange(5, dtype=np.int64)},
                          rows_per_partition=5)
        res = limit_prune(scan_after_filter(tbl, E.true()), tbl.stats, k=3)
        assert res.category == ALREADY_MINIMAL

    def test_no_predicate_all_partitions_fully_match(self):
        """Trivially, without predicates every partition is fully matching
        (Sec. 4.2) -> LIMIT pruning cuts to one partition."""
        tbl = self.make_sorted_table()
        res = limit_prune(scan_after_filter(tbl, E.true()), tbl.stats, k=7)
        assert res.applied and res.partitions_after == 1

    @settings(max_examples=80, deadline=None)
    @given(tbl=small_tables(), pred=predicates(), k=...)
    def test_pruned_scan_still_satisfies_k(self, tbl, pred, k: bool):
        """Whenever pruning applies, the retained fully-matching partitions
        alone must contain >= k qualifying rows (global IO-optimality means
        correctness must not depend on any pruned partition)."""
        k = 5 if k else 1
        scan = scan_after_filter(tbl, pred)
        res = limit_prune(scan, tbl.stats, k=k)
        if res.applied and k > 0:
            assert count_matching(tbl, pred, res.scan.part_ids) >= k
            # minimality: dropping the smallest retained partition breaks k
            rows = tbl.stats.row_counts[res.scan.part_ids]
            assert rows.sum() - rows.min() < k or len(res.scan) == 1
