"""Adaptive pruning-tree (paper Sec. 3.2): reorder + cutoff invariants."""

import numpy as np
from hypothesis import given, settings

from repro.core import expr as E
from repro.core.metadata import NO_MATCH
from repro.core.prune_filter import eval_tv
from repro.core.prune_tree import AdaptivePruner

from helpers import predicates, small_tables


class TestAdaptiveTree:
    @settings(max_examples=80, deadline=None)
    @given(tbl=small_tables(), pred=predicates())
    def test_no_cutoff_matches_exact(self, tbl, pred):
        res = AdaptivePruner(pred, cutoff=False).run(tbl.stats, batch_size=3)
        np.testing.assert_array_equal(res.tv, eval_tv(pred, tbl.stats))

    @settings(max_examples=80, deadline=None)
    @given(tbl=small_tables(), pred=predicates())
    def test_cutoff_never_overprunes(self, tbl, pred):
        """Disabling a pruner may only LOSE pruning power, never gain it."""
        res = AdaptivePruner(pred, cutoff=True, scan_cost=2.0).run(
            tbl.stats, batch_size=2
        )
        exact = eval_tv(pred, tbl.stats)
        assert not ((res.tv == NO_MATCH) & (exact != NO_MATCH)).any()

    def test_reordering_reduces_work(self):
        """A cheap, highly selective filter should migrate to the front of
        the AND and short-circuit the expensive one."""
        rng = np.random.default_rng(3)
        n = 20_000
        tbl_raw = {
            "a": np.sort(rng.integers(0, 1000, size=n)),  # selective, clustered
            "b": rng.integers(0, 10, size=n),             # useless filter
        }
        from repro.data.table import Table
        tbl = Table.build("t", tbl_raw, rows_per_partition=100)
        # expensive unselective leaf FIRST in written order
        expensive = (E.col("b") * 1.0 + E.col("b") * 2.0 + E.col("b") * 3.0) >= 0.0
        selective = E.col("a") >= 995
        pred = E.And((expensive, selective))
        adaptive = AdaptivePruner(pred, reorder=True, cutoff=False)
        r1 = adaptive.run(tbl.stats, batch_size=10)
        fixed = AdaptivePruner(pred, reorder=False, cutoff=False)
        r2 = fixed.run(tbl.stats, batch_size=10)
        np.testing.assert_array_equal(r1.tv, r2.tv)
        assert r1.work_units < r2.work_units, (r1.work_units, r2.work_units)

    def test_cutoff_disables_ineffective_and_child(self):
        rng = np.random.default_rng(4)
        n = 10_000
        from repro.data.table import Table
        tbl = Table.build(
            "t",
            {"a": np.sort(rng.integers(0, 1000, size=n)),
             "b": rng.integers(0, 10, size=n)},
            rows_per_partition=100,
        )
        useless = (E.col("b") >= 0)          # never prunes anything
        selective = E.col("a") >= 900
        pruner = AdaptivePruner(E.And((useless, selective)),
                                scan_cost=5.0, cutoff=True)
        res = pruner.run(tbl.stats, batch_size=10)
        report = {r["pred"]: r for r in res.leaf_report}
        assert report[repr(useless)]["disabled"]
        assert not report[repr(selective)]["disabled"]
        # correctness preserved
        exact = eval_tv(E.And((useless, selective)), tbl.stats)
        assert not ((res.tv == NO_MATCH) & (exact != NO_MATCH)).any()

    def test_or_children_never_cut(self):
        """Paper: removing an OR child poisons the whole branch."""
        rng = np.random.default_rng(5)
        from repro.data.table import Table
        tbl = Table.build(
            "t",
            {"a": np.sort(rng.integers(0, 1000, size=5000)),
             "b": rng.integers(0, 10, size=5000)},
            rows_per_partition=50,
        )
        useless = E.col("b") >= 0
        selective = E.col("a") >= 900
        pruner = AdaptivePruner(E.Or((useless, selective)),
                                scan_cost=0.1, cutoff=True)
        res = pruner.run(tbl.stats, batch_size=10)
        assert not any(r["disabled"] for r in res.leaf_report)


# ---------------------------------------------------------------------------
# ISSUE 7 regression pins for the module docstring invariant — "with
# cutoff disabled [the adaptive tree] is bit-identical to eval_tv" — at
# the service path, and its parity with the device group pre-pass.
# ---------------------------------------------------------------------------

from hypothesis import strategies as st  # noqa: E402

from repro.core.device_stats import (  # noqa: E402
    DeviceStats, plane_capacity, tree_entry_for)
from repro.core.flow import PruningPipeline, Query, TableScanSpec  # noqa: E402
from repro.core.metadata import (  # noqa: E402
    FULL_MATCH, ColumnMeta, PartitionStats)
from repro.core.prune_tree import AdaptivePruner  # noqa: E402
from repro.kernels import ops  # noqa: E402


class TestAdaptiveServicePath:
    """The invariant through ``PruningPipeline(adaptive=True)`` itself."""

    @settings(max_examples=40, deadline=None)
    @given(tbl=small_tables(), pred=predicates())
    def test_adaptive_pipeline_sound_vs_exact_pipeline(self, pred, tbl):
        """Cutoff (enabled on the service path) may only widen the scan
        set and weaken FULL to PARTIAL — never the reverse."""
        exact = PruningPipeline().run(
            Query(scans={"t": TableScanSpec(tbl, pred)}))
        adapt = PruningPipeline(adaptive=True).run(
            Query(scans={"t": TableScanSpec(tbl, pred)}))
        e, a = exact.scan_sets["t"], adapt.scan_sets["t"]
        assert set(e.part_ids) <= set(a.part_ids), \
            "adaptive pruned a partition exact evaluation keeps"
        e_full = set(np.asarray(e.part_ids)[np.asarray(e.match)
                                            == FULL_MATCH])
        a_full = set(np.asarray(a.part_ids)[np.asarray(a.match)
                                            == FULL_MATCH])
        assert a_full <= e_full, \
            "adaptive certified FULL where exact evaluation does not"

    @settings(max_examples=40, deadline=None)
    @given(tbl=small_tables(), thresh=st.integers(-60, 60))
    def test_adaptive_pipeline_exact_on_uncuttable_predicates(self, tbl,
                                                              thresh):
        """A single-leaf predicate gives cutoff nothing to disable, so the
        service path must be bit-identical to exact evaluation — the
        docstring invariant observed end-to-end."""
        pred = E.col("x") > thresh
        exact = PruningPipeline().run(
            Query(scans={"t": TableScanSpec(tbl, pred)}))
        adapt = PruningPipeline(adaptive=True).run(
            Query(scans={"t": TableScanSpec(tbl, pred)}))
        np.testing.assert_array_equal(adapt.scan_sets["t"].part_ids,
                                      exact.scan_sets["t"].part_ids)
        np.testing.assert_array_equal(adapt.scan_sets["t"].match,
                                      exact.scan_sets["t"].match)


class TestTreePrepassOracleParity:
    """The host adaptive tree and the device group pre-pass share one
    soundness root: a hull-proven NO is final.  Property: over random
    integer stats and range workloads, the device tree path ==
    the pure-host batched oracle == per-query ``AdaptivePruner`` with
    cutoff disabled (== eval_tv by the docstring invariant)."""

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2 ** 31))
    def test_tree_kernel_matches_host_oracle_and_adaptive(self, seed):
        rng = np.random.default_rng(seed)
        P = int(rng.integers(16, 80))
        C = 2
        # integer-valued, sorted (clustered) stats: f32-exact, so the
        # staged planes agree with the f64 host oracle bit-for-bit
        mins = np.sort(rng.integers(-100, 100, (P, C)), axis=0).astype(
            np.float64)
        maxs = mins + rng.integers(0, 8, (P, C))
        stats = PartitionStats(
            columns=[ColumnMeta(f"c{i}", "int") for i in range(C)],
            mins=mins, maxs=maxs,
            null_counts=np.zeros((P, C), dtype=np.int64),
            row_counts=np.full(P, 5, dtype=np.int64))
        dstats = DeviceStats.stage(stats, capacity=plane_capacity(P))
        tree = tree_entry_for(dstats, fanout=4)
        range_lists = []
        for _ in range(int(rng.integers(1, 8))):
            k = int(rng.integers(1, 3))
            cids = rng.choice(C, size=k, replace=False)
            q = []
            for c in cids:
                lo = int(rng.integers(-120, 120))
                # narrow and keep-most widths both appear: the pre-pass
                # and its dense fallback are each exercised across seeds
                hi = lo + int(rng.integers(0, 240))
                q.append((int(c), float(lo), float(hi)))
            range_lists.append(q)
        tv_tree = ops.prune_ranges_batched_tree(range_lists, dstats, tree,
                                                mode="ref")
        tv_host = ops.prune_ranges_batched_host(range_lists, stats)
        np.testing.assert_array_equal(tv_tree, tv_host)
        for qi, ranges in enumerate(range_lists):
            pred = None
            for c, lo, hi in ranges:
                term = (E.col(f"c{c}") >= lo) & (E.col(f"c{c}") <= hi)
                pred = term if pred is None else E.And((pred, term))
            res = AdaptivePruner(pred, cutoff=False).run(
                stats, batch_size=max(P // 4, 1))
            np.testing.assert_array_equal(
                tv_tree[qi], res.tv,
                err_msg=f"q={qi}: device tree vs cutoff-free host tree")
