"""Adaptive pruning-tree (paper Sec. 3.2): reorder + cutoff invariants."""

import numpy as np
from hypothesis import given, settings

from repro.core import expr as E
from repro.core.metadata import NO_MATCH
from repro.core.prune_filter import eval_tv
from repro.core.prune_tree import AdaptivePruner

from helpers import predicates, small_tables


class TestAdaptiveTree:
    @settings(max_examples=80, deadline=None)
    @given(tbl=small_tables(), pred=predicates())
    def test_no_cutoff_matches_exact(self, tbl, pred):
        res = AdaptivePruner(pred, cutoff=False).run(tbl.stats, batch_size=3)
        np.testing.assert_array_equal(res.tv, eval_tv(pred, tbl.stats))

    @settings(max_examples=80, deadline=None)
    @given(tbl=small_tables(), pred=predicates())
    def test_cutoff_never_overprunes(self, tbl, pred):
        """Disabling a pruner may only LOSE pruning power, never gain it."""
        res = AdaptivePruner(pred, cutoff=True, scan_cost=2.0).run(
            tbl.stats, batch_size=2
        )
        exact = eval_tv(pred, tbl.stats)
        assert not ((res.tv == NO_MATCH) & (exact != NO_MATCH)).any()

    def test_reordering_reduces_work(self):
        """A cheap, highly selective filter should migrate to the front of
        the AND and short-circuit the expensive one."""
        rng = np.random.default_rng(3)
        n = 20_000
        tbl_raw = {
            "a": np.sort(rng.integers(0, 1000, size=n)),  # selective, clustered
            "b": rng.integers(0, 10, size=n),             # useless filter
        }
        from repro.data.table import Table
        tbl = Table.build("t", tbl_raw, rows_per_partition=100)
        # expensive unselective leaf FIRST in written order
        expensive = (E.col("b") * 1.0 + E.col("b") * 2.0 + E.col("b") * 3.0) >= 0.0
        selective = E.col("a") >= 995
        pred = E.And((expensive, selective))
        adaptive = AdaptivePruner(pred, reorder=True, cutoff=False)
        r1 = adaptive.run(tbl.stats, batch_size=10)
        fixed = AdaptivePruner(pred, reorder=False, cutoff=False)
        r2 = fixed.run(tbl.stats, batch_size=10)
        np.testing.assert_array_equal(r1.tv, r2.tv)
        assert r1.work_units < r2.work_units, (r1.work_units, r2.work_units)

    def test_cutoff_disables_ineffective_and_child(self):
        rng = np.random.default_rng(4)
        n = 10_000
        from repro.data.table import Table
        tbl = Table.build(
            "t",
            {"a": np.sort(rng.integers(0, 1000, size=n)),
             "b": rng.integers(0, 10, size=n)},
            rows_per_partition=100,
        )
        useless = (E.col("b") >= 0)          # never prunes anything
        selective = E.col("a") >= 900
        pruner = AdaptivePruner(E.And((useless, selective)),
                                scan_cost=5.0, cutoff=True)
        res = pruner.run(tbl.stats, batch_size=10)
        report = {r["pred"]: r for r in res.leaf_report}
        assert report[repr(useless)]["disabled"]
        assert not report[repr(selective)]["disabled"]
        # correctness preserved
        exact = eval_tv(E.And((useless, selective)), tbl.stats)
        assert not ((res.tv == NO_MATCH) & (exact != NO_MATCH)).any()

    def test_or_children_never_cut(self):
        """Paper: removing an OR child poisons the whole branch."""
        rng = np.random.default_rng(5)
        from repro.data.table import Table
        tbl = Table.build(
            "t",
            {"a": np.sort(rng.integers(0, 1000, size=5000)),
             "b": rng.integers(0, 10, size=5000)},
            rows_per_partition=50,
        )
        useless = E.col("b") >= 0
        selective = E.col("a") >= 900
        pruner = AdaptivePruner(E.Or((useless, selective)),
                                scan_cost=0.1, cutoff=True)
        res = pruner.run(tbl.stats, batch_size=10)
        assert not any(r["disabled"] for r in res.leaf_report)
