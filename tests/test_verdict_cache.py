"""ISSUE 9: the device-resident verdict cache through the DML wringer.

The tentpole guarantee: with repeated traffic, resident per-(table
version, canonical predicate) verdict rows serve whole batches without
touching a kernel, are delta-repaired on append (only the new
partitions evaluated host-side, patched in place) and tombstoned on
drop — and stay **bit-identical** to both the cache-disabled service
and the f64 host oracle after ANY sequence of append / drop / rewrite /
update.  A torn verdict plane is a quarantine plus a ladder demotion to
the ordinary kernel chain — a counter, never a wrong verdict.
"""

import numpy as np
from hypothesis import given, settings

from repro.core import expr as E
from repro.core.flow import PruningPipeline, Query, TableScanSpec
from repro.data.table import Table
from repro.serve.prune_service import PruningService
from repro.serve.resilience import FaultInjector

from test_ingest_parity import (NDV_LIMIT, _apply_dml, _assert_reports_equal,
                                _base_tables, _queries, dml_programs)

NO_SLEEP = lambda d: None  # noqa: E731


def _svc(pipe_kw=None, **kw):
    svc = PruningService(mode="ref", **kw)
    pipe = PruningPipeline(filter_mode="device", service=svc,
                           join_ndv_limit=NDV_LIMIT, **(pipe_kw or {}))
    return svc, pipe


def _small_table(seed=0, n=110):
    rng = np.random.default_rng(seed)
    return Table.build(
        "t", {"v": rng.integers(-200, 1000, n).astype(np.int64),
              "w": rng.integers(0, 100, n).astype(np.int64)},
        rows_per_partition=10)


def _q(tbl, pred):
    return Query(scans={tbl.name: TableScanSpec(tbl, pred)})


class TestVerdictDMLParity:
    """cache-enabled run_batch == cache-disabled == f64 host oracle."""

    @settings(max_examples=8, deadline=None)
    @given(program=dml_programs())
    def test_repeated_batches_under_dml(self, program):
        seed, ops = program
        rng = np.random.default_rng(seed)
        fact, dim = _base_tables(seed)

        cached_svc, cached_pipe = _svc()                 # default: cache on
        plain_svc, plain_pipe = _svc(verdict_cache=False)
        host_pipe = PruningPipeline(join_ndv_limit=NDV_LIMIT)

        for step, op in enumerate([("noop",)] + list(ops)):
            if op[0] != "noop":
                _apply_dml(fact, op, rng)
            # identical literals at every step: repeated traffic, so the
            # cached service serves delta-repaired verdict rows rather
            # than relaunching — exactly the state parity must pin
            qs = _queries(fact, dim, np.random.default_rng(seed % 9973))
            # run the cached service twice per step: the second pass is
            # the hit-served one (seen-once admission records on the
            # second sighting of a predicate) — both must match
            cached = cached_svc.run_batch(qs, cached_pipe)
            cached2 = cached_svc.run_batch(qs, cached_pipe)
            plain = plain_svc.run_batch(qs, plain_pipe)
            host = [host_pipe.run(q) for q in qs]
            _assert_reports_equal(qs, cached, plain,
                                  f"step {step} ({op[0]}) cached-vs-plain")
            _assert_reports_equal(qs, cached, host,
                                  f"step {step} ({op[0]}) cached-vs-host")
            _assert_reports_equal(qs, cached2, host,
                                  f"step {step} ({op[0]}) hit-vs-host")
        # harness sanity: the cache actually served (not vacuous parity)
        res = cached_svc.resilience
        assert res["verdict_hits"] > 0
        assert plain_svc.resilience["verdict_hits"] == 0


class TestVerdictDedupeAndHits:
    def test_batch_dedupes_equivalent_predicates_before_launch(self):
        tbl = _small_table()
        svc, pipe = _svc()
        p = (E.col("v") >= 100) & (E.col("w") < 50)
        qs = [_q(tbl, p),
              _q(tbl, (E.col("w") < 50) & (E.col("v") >= 100)),   # commuted
              _q(tbl, (E.col("v") >= 100.0) & (E.col("w") < 50)),  # 100.0
              _q(tbl, E.col("v") >= 700)]                          # distinct
        got = svc.run_batch(qs, pipe)
        assert svc.resilience["verdict_deduped"] == 2
        assert svc.resilience["verdict_misses"] == 2   # two unique keys
        assert svc.resilience["verdict_hits"] == 0
        # equivalent predicates share one verdict row, bit-identical
        for rep in got[:3]:
            np.testing.assert_array_equal(
                rep.scan_sets["t"].part_ids, got[0].scan_sets["t"].part_ids)
            np.testing.assert_array_equal(
                rep.scan_sets["t"].match, got[0].scan_sets["t"].match)

    def test_full_hit_batch_never_touches_a_kernel(self):
        tbl = _small_table()
        svc, pipe = _svc()
        qs = [_q(tbl, (E.col("v") >= 100) & (E.col("w") < 50)),
              _q(tbl, E.col("v") >= 700)]
        first = svc.run_batch(qs, pipe)
        svc.run_batch(qs, pipe)     # second sighting: doorkeeper admits
        launches_so_far = svc.counters.launches
        third = svc.run_batch(qs, pipe)
        assert svc.counters.launches == launches_so_far   # zero new
        assert svc.resilience["verdict_hits"] == 2
        _assert_reports_equal(qs, third, first, "full-hit repeat")

    def test_append_repairs_in_place_instead_of_relaunching(self):
        rng = np.random.default_rng(7)
        tbl = _small_table(seed=7)
        svc, pipe = _svc()
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)
        qs = [_q(tbl, (E.col("v") >= 100) & (E.col("w") < 50))]
        svc.run_batch(qs, pipe)
        svc.run_batch(qs, pipe)     # second sighting: verdict row recorded
        tbl.append_partitions(
            {"v": rng.integers(-200, 1000, 30).astype(np.int64),
             "w": rng.integers(0, 100, 30).astype(np.int64)},
            rows_per_partition=10)
        tbl.drop_partitions([2])
        got = svc.run_batch(qs, pipe)
        assert svc.resilience["verdict_hits"] == 1       # repaired, not missed
        assert svc.cache.integrity["verdict_repairs"] >= 1
        _assert_reports_equal(qs, got, [host.run(q) for q in qs],
                              "append+drop repair")


class TestVerdictChaos:
    def test_torn_resident_row_quarantined_then_serves_truth(self):
        """A verdict row torn at record time: the sampled verifier
        catches it on the next serve, quarantines, and the miss relaunch
        records a clean row — a counter, never a wrong verdict."""
        tbl = _small_table(seed=10)
        inj = FaultInjector(seed=1)
        inj.add("stage.verdict", kind="corrupt", times=1)
        svc, pipe = _svc(fault_injector=inj)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)
        qs = [_q(tbl, (E.col("v") >= 100) & (E.col("w") < 50))]
        svc.cache.integrity_sample = 0          # record the torn row blind
        svc.run_batch(qs, pipe)
        svc.run_batch(qs, pipe)     # second sighting records (torn)
        svc.cache.integrity_sample = 1          # verify on every serve
        got = svc.run_batch(qs, pipe)
        integ = svc.cache.integrity
        assert integ["checksum_failures"] >= 1
        assert integ["quarantines"] >= 1
        assert svc.resilience["verdict_misses"] >= 3  # cold x2 + quarantine
        _assert_reports_equal(qs, got, [host.run(q) for q in qs],
                              "torn-verdict")
        # the relaunch recorded clean: the third batch is a verified hit
        third = svc.run_batch(qs, pipe)
        assert svc.resilience["verdict_hits"] >= 1
        _assert_reports_equal(qs, third, got, "post-quarantine hit")

    def test_persistent_corruption_demotes_never_wrong(self):
        """Every verdict staging torn: the integrity protocol raises
        internally, the ladder demotes cache-off to the flat kernel
        chain, and the batch still returns the exact answer."""
        tbl = _small_table(seed=11)
        inj = FaultInjector(seed=2)
        inj.add("stage.verdict", kind="corrupt")        # no times cap
        svc, pipe = _svc(fault_injector=inj, integrity_sample=1,
                         sleep=NO_SLEEP)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)
        qs = [_q(tbl, (E.col("v") >= 100) & (E.col("w") < 50)),
              _q(tbl, E.col("v") >= 700)]
        svc.run_batch(qs, pipe)     # first sighting: nothing recorded yet
        got = svc.run_batch(qs, pipe)   # records -> torn -> demote
        _assert_reports_equal(qs, got, [host.run(q) for q in qs],
                              "persistent-verdict-corruption")
        res = got[0].counters["resilience"]
        assert sum(res["demotions"].values()) >= 1      # cache-off demotion
        assert res["passthroughs"] == 0
        assert svc.cache.integrity["quarantines"] >= 1
