"""Async serving front-end (PR 10): admission, micro-batching, SLOs.

Contracts pinned here:

  * **scheduling is deterministic under an injected clock** — inline
    (``threaded=False``) mode dispatches on the size cap at submit time
    and on the deadline at ``poll()`` time, per the FakeClock, with no
    real sleeps anywhere;
  * **the front-end adds scheduling, never semantics** — batched results
    are bit-identical to calling ``run_batch`` directly on the same
    queries (the acceptance parity);
  * **observability** — every response carries its queue/stage/launch
    timestamps, every report carries a ``counters["latency"]`` block
    whose keys are all declared in ``COUNTER_REGISTRY`` (CL006), and
    ``fleet_summary()["latency"]`` accumulates the lifetime view;
  * **staging overlap** — ``prestage``/``prefetch`` stage a cold
    table's planes ahead of the launch (counted in ``prefetch_stages``)
    and the launch then stages nothing new.
"""

import threading

import numpy as np
import pytest

from repro.core import expr as E
from repro.core.flow import PruningPipeline, Query, TableScanSpec
from repro.data.table import Table
from repro.serve.frontend import FrontendResponse, ServingFrontend
from repro.serve.prune_service import (LADDER_LAUNCH_SITES, PruningService)
from repro.serve.resilience import COUNTER_REGISTRY, new_latency_counters

from test_fleet_parity import (assert_reports_equal, build_fleet,
                               fleet_queries)


class FakeClock:
    """Monotonic clock whose time only moves when the test advances it."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, d):
        self.t += d


def small_table(name="fe_t", rows=240, seed=5):
    rng = np.random.default_rng(seed)
    return Table.build(name, {
        "ts": np.sort(rng.integers(0, 10_000, rows)).astype(np.int64),
        "v": rng.integers(0, 1_000, rows).astype(np.int64),
    }, rows_per_partition=8)


def window_query(table, lo, width=2_000):
    return Query(scans={table.name: TableScanSpec(
        table, (E.col("ts") >= int(lo)) & (E.col("ts") <= int(lo + width)))})


def make_frontend(max_batch=4, deadline_s=1.0, clock=None, threaded=False,
                  prefetch=True):
    svc = PruningService(mode="ref", verdict_cache=False)
    pipe = PruningPipeline(filter_mode="device", service=svc)
    fe = ServingFrontend(svc, pipe, max_batch=max_batch,
                         deadline_s=deadline_s, clock=clock,
                         threaded=threaded, prefetch=prefetch)
    return svc, pipe, fe


class TestScheduling:
    """Inline mode + FakeClock: dispatch causes are fully deterministic."""

    def test_size_cap_fires_at_submit(self):
        clock = FakeClock()
        t = small_table()
        _svc, _pipe, fe = make_frontend(max_batch=3, deadline_s=5.0,
                                        clock=clock)
        futs = [fe.submit(window_query(t, 100 * i)) for i in range(3)]
        # the third submit filled the cap: all three resolved inline,
        # with zero clock movement (the deadline never came into it)
        assert all(f.done() for f in futs)
        assert [f.result().cause for f in futs] == ["size"] * 3
        assert clock.t == 0.0

    def test_deadline_fires_at_poll(self):
        clock = FakeClock()
        t = small_table()
        _svc, _pipe, fe = make_frontend(max_batch=8, deadline_s=5.0,
                                        clock=clock)
        futs = [fe.submit(window_query(t, 100 * i)) for i in range(2)]
        assert not any(f.done() for f in futs)
        assert fe.poll() is None            # deadline not reached yet
        clock.advance(4.999)
        assert fe.poll() is None
        clock.advance(0.001)
        assert fe.poll() == "deadline"      # T since the oldest submit
        assert [f.result().cause for f in futs] == ["deadline"] * 2

    def test_deadline_anchored_to_oldest_submission(self):
        clock = FakeClock()
        t = small_table()
        _svc, _pipe, fe = make_frontend(max_batch=8, deadline_s=5.0,
                                        clock=clock)
        fe.submit(window_query(t, 0))
        clock.advance(4.0)
        late = fe.submit(window_query(t, 500))
        clock.advance(1.0)                  # oldest is now 5.0s old
        assert fe.poll() == "deadline"
        # the late submission rode along instead of waiting its own T
        assert late.result().cause == "deadline"

    def test_flush_dispatches_partial_batch(self):
        clock = FakeClock()
        t = small_table()
        _svc, _pipe, fe = make_frontend(max_batch=8, deadline_s=5.0,
                                        clock=clock)
        futs = [fe.submit(window_query(t, 100 * i)) for i in range(2)]
        assert fe.flush() == 2
        assert [f.result().cause for f in futs] == ["flush", "flush"]
        assert fe.flush() == 0              # nothing pending: no-op

    def test_close_flushes_and_rejects_new_submits(self):
        clock = FakeClock()
        t = small_table()
        _svc, _pipe, fe = make_frontend(max_batch=8, deadline_s=5.0,
                                        clock=clock)
        fut = fe.submit(window_query(t, 0))
        fe.close()
        assert fut.result().cause == "flush"
        with pytest.raises(RuntimeError, match="closed"):
            fe.submit(window_query(t, 100))

    def test_oversize_burst_splits_into_capped_batches(self):
        clock = FakeClock()
        t = small_table()
        svc, _pipe, fe = make_frontend(max_batch=2, deadline_s=5.0,
                                       clock=clock)
        futs = [fe.submit(window_query(t, 100 * i)) for i in range(5)]
        assert [f.done() for f in futs] == [True] * 4 + [False]
        fe.flush()
        assert svc.latency["batches"] == 3
        assert svc.latency["size_fired"] == 2
        assert svc.latency["flush_fired"] == 1


class TestParity:
    """Acceptance: frontend-batched results bit-identical to run_batch."""

    def test_frontend_bit_identical_to_direct_run_batch(self):
        tables, dim = build_fleet(6, seed=29)
        rng = np.random.default_rng(29)
        qs = fleet_queries(tables, dim, rng, 24)
        direct_svc = PruningService(mode="ref", verdict_cache=False)
        direct_pipe = PruningPipeline(filter_mode="device",
                                      service=direct_svc)
        want = direct_svc.run_batch(qs, direct_pipe)

        clock = FakeClock()
        _svc, _pipe, fe = make_frontend(max_batch=len(qs), deadline_s=60.0,
                                        clock=clock)
        futs = [fe.submit(q) for q in qs]    # last submit fills the cap
        fe.close()
        got = [f.result().report for f in futs]
        assert_reports_equal(qs, got, want, "frontend vs run_batch")

    def test_parity_survives_micro_batch_splits(self):
        """Splitting the workload into deadline/size micro-batches must
        not change any answer (run_batch is batch-size invariant)."""
        tables, dim = build_fleet(4, seed=31)
        rng = np.random.default_rng(31)
        qs = fleet_queries(tables, dim, rng, 10)
        direct_svc = PruningService(mode="ref", verdict_cache=False)
        want = direct_svc.run_batch(
            qs, PruningPipeline(filter_mode="device", service=direct_svc))

        clock = FakeClock()
        _svc, _pipe, fe = make_frontend(max_batch=3, deadline_s=2.0,
                                        clock=clock)
        futs = []
        for q in qs:                         # 3 size batches + 1 flush
            futs.append(fe.submit(q))
        fe.close()
        got = [f.result().report for f in futs]
        assert_reports_equal(qs, got, want, "micro-batched vs run_batch")


class TestObservability:
    def test_response_timestamps_and_latency_block(self):
        clock = FakeClock()
        t = small_table()
        svc, _pipe, fe = make_frontend(max_batch=8, deadline_s=5.0,
                                       clock=clock)
        fe.submit(window_query(t, 0))
        clock.advance(2.0)
        fut = fe.submit(window_query(t, 300))
        clock.advance(3.0)
        assert fe.poll() == "deadline"
        resp = fut.result()
        assert isinstance(resp, FrontendResponse)
        ts = resp.timestamps
        assert ts["queued"] == 2.0           # clock units, per FakeClock
        assert ts["queued"] <= ts["dispatched"] <= ts["launched"] \
            <= ts["done"]
        assert ts["staged"] is not None      # inline prestage ran
        assert resp.queue_ms == pytest.approx(3_000.0)
        assert resp.latency_ms >= resp.queue_ms
        assert resp.queue_depth == 2
        block = resp.report.counters["latency"]
        assert block["requests"] == 2 and block["deadline_fired"] == 1
        assert block["p50_ms"] <= block["p99_ms"] <= block["max_ms"]
        # lifetime view surfaces through fleet_summary()
        summary = svc.fleet_summary()["latency"]
        assert summary["requests"] == 2 and summary["batches"] == 1
        assert summary["queue_depth_peak"] == 2

    def test_latency_counter_keys_all_registered(self):
        """CL006 satellite: every key the front-end emits — the factory
        family, the per-batch block, and the section name itself — is
        declared in COUNTER_REGISTRY."""
        assert "latency" in COUNTER_REGISTRY
        for key in new_latency_counters():
            assert key in COUNTER_REGISTRY, key
        clock = FakeClock()
        t = small_table()
        _svc, _pipe, fe = make_frontend(max_batch=2, deadline_s=5.0,
                                        clock=clock)
        f = fe.submit(window_query(t, 0))
        fe.submit(window_query(t, 100))
        for key in f.result().report.counters["latency"]:
            assert key in COUNTER_REGISTRY, key

    def test_frontend_dispatch_registered_as_launch_site(self):
        """CL001 satellite: the dispatch path is in the reviewed
        launch-site registry."""
        assert "ServingFrontend._execute" in LADDER_LAUNCH_SITES


class TestStagingOverlap:
    def test_prestage_then_launch_stages_nothing_new(self):
        t = small_table("fe_cold", seed=7)
        svc = PruningService(mode="ref", verdict_cache=False)
        qs = [window_query(t, 100 * i) for i in range(4)]
        staged = svc.prestage(qs)
        snap = svc.cache.staging_snapshot()
        assert staged == 1                   # one distinct table
        assert snap["prefetch_stages"] == 1
        assert snap["staged_bytes"] > 0
        pipe = PruningPipeline(filter_mode="device", service=svc)
        svc.run_batch(qs, pipe)
        after = svc.cache.staging_snapshot()
        assert after["staged_bytes"] == snap["staged_bytes"]
        # idempotent: a resident plane is not a prefetch
        assert svc.prestage(qs) == 0
        assert svc.cache.staging_snapshot()["prefetch_stages"] == 1

    def test_inline_prefetch_marks_submissions_staged(self):
        clock = FakeClock()
        t = small_table("fe_cold2", seed=9)
        svc, _pipe, fe = make_frontend(max_batch=2, deadline_s=5.0,
                                       clock=clock)
        f = fe.submit(window_query(t, 0))
        fe.submit(window_query(t, 100))
        assert f.result().timestamps["staged"] is not None
        assert svc.cache.staging_snapshot()["prefetch_stages"] == 1
        assert svc.latency["prefetches"] == 2

    def test_prefetch_never_raises(self):
        svc = PruningService(mode="ref", verdict_cache=False)
        assert svc.cache.prefetch(object()) is False


class TestThreaded:
    """Real-clock mode: the batcher/worker threads own timing.  Kept to
    generous deadlines so the suite stays fast and unflaky."""

    def test_deadline_dispatches_partial_batch(self):
        t = small_table()
        svc, _pipe, fe = make_frontend(max_batch=64, deadline_s=0.02,
                                       threaded=True)
        with fe:
            futs = [fe.submit(window_query(t, 100 * i)) for i in range(3)]
            resps = [f.result(timeout=30) for f in futs]
        assert [r.cause for r in resps] == ["deadline"] * 3
        assert svc.latency["deadline_fired"] == 1

    def test_size_cap_and_drain(self):
        t = small_table()
        svc, _pipe, fe = make_frontend(max_batch=2, deadline_s=30.0,
                                       threaded=True)
        with fe:
            futs = [fe.submit(window_query(t, 70 * i)) for i in range(5)]
            fe.drain()                       # flushes the odd one out
            assert all(f.done() for f in futs)
        causes = [f.result().cause for f in futs]
        assert causes.count("size") == 4 and causes.count("flush") == 1
        assert svc.latency["requests"] == 5

    def test_concurrent_submitters_all_resolve(self):
        t = small_table()
        _svc, _pipe, fe = make_frontend(max_batch=4, deadline_s=0.02,
                                        threaded=True)
        results, errs = [], []

        def client(base):
            try:
                fs = [fe.submit(window_query(t, base + 50 * i))
                      for i in range(6)]
                results.extend(f.result(timeout=30) for f in fs)
            except Exception as exc:  # pragma: no cover - failure detail
                errs.append(exc)

        with fe:
            threads = [threading.Thread(target=client, args=(800 * k,))
                       for k in range(3)]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            fe.drain()
        assert not errs and len(results) == 18
        rids = [r.rid for r in results]
        assert len(set(rids)) == 18          # one response per submission
