"""Flash attention Pallas kernel vs naive-softmax oracle (interpret mode),
with hypothesis shape/dtype sweeps."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.models.layers import chunked_attention


@st.composite
def attn_problems(draw):
    BH = draw(st.integers(1, 4))
    Sq = draw(st.sampled_from([1, 7, 128, 130, 256]))
    same = draw(st.booleans())
    Sk = Sq if same else draw(st.sampled_from([128, 200, 256]))
    D = draw(st.sampled_from([8, 64, 128]))
    dtype = draw(st.sampled_from([np.float32, jnp.bfloat16]))
    causal = draw(st.booleans()) if Sq == Sk else False
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    q = rng.normal(size=(BH, Sq, D)).astype(np.float32)
    k = rng.normal(size=(BH, Sk, D)).astype(np.float32)
    v = rng.normal(size=(BH, Sk, D)).astype(np.float32)
    return (jnp.asarray(q, dtype), jnp.asarray(k, dtype),
            jnp.asarray(v, dtype), causal)


class TestFlashAttention:
    @settings(max_examples=25, deadline=None)
    @given(problem=attn_problems())
    def test_kernel_matches_oracle(self, problem):
        q, k, v, causal = problem
        out = flash_attention(q, k, v, causal=causal, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        tol = 2e-2 if q.dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)

    def test_causal_long_context(self):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(2, 512, 64)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, 512, 64)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, 512, 64)), jnp.float32)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_matches_model_chunked_attention(self):
        """The model's pure-jnp chunked path and the kernel agree (they
        are the same algorithm at different altitudes)."""
        rng = np.random.default_rng(1)
        B, S, H, D = 2, 256, 4, 64
        q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
        model_out = chunked_attention(q, k, v, causal=True, chunk=128)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
        kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
        vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
        kern = flash_attention(qf, kf, vf, causal=True, interpret=True)
        kern = kern.reshape(B, H, S, D).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(model_out), np.asarray(kern),
                                   rtol=3e-4, atol=3e-4)
