"""§Perf optimization variants must be numerically faithful to baseline."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.sharding import init_params
from repro.serve.serve_step import Generator


def _batch(cfg, key, B=2, S=32):
    kt, kl = jax.random.split(key)
    return {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab)}


class TestMoEDispatchVariants:
    @pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "kimi-k2-1t-a32b"])
    def test_grouped_equals_scatter(self, arch):
        """H1: grouped dispatch == scatter dispatch (same routing, same
        capacity per token population)."""
        cfg_s = get_smoke_config(arch)
        cfg_g = dataclasses.replace(cfg_s, moe_dispatch="grouped")
        m_s, m_g = build_model(cfg_s), build_model(cfg_g)
        params = init_params(m_s.specs, jax.random.PRNGKey(0))
        batch = _batch(cfg_s, jax.random.PRNGKey(1))
        l_s, _ = m_s.loss_fn(params, batch)
        l_g, _ = m_g.loss_fn(params, batch)
        np.testing.assert_allclose(float(l_s), float(l_g), rtol=2e-2)

    def test_expert_only_sharding_same_specs_shapes(self):
        cfg = get_smoke_config("qwen3-moe-30b-a3b")
        cfg_e = dataclasses.replace(cfg, moe_sharding="expert_only")
        a = jax.tree.leaves(build_model(cfg).specs)
        b = jax.tree.leaves(build_model(cfg_e).specs)
        assert [x.shape for x in a] == [y.shape for y in b]


class TestVocabPadding:
    def test_padded_vocab_loss_close_and_decode_valid(self):
        """H3: vocab padding must not change the CE materially nor let the
        decoder emit padded token ids."""
        cfg = get_smoke_config("whisper-small")            # vocab 256
        cfg_odd = dataclasses.replace(cfg, vocab=251)      # not % 16
        cfg_pad = dataclasses.replace(cfg_odd, pad_vocab_to=16)
        m0, m1 = build_model(cfg_odd), build_model(cfg_pad)
        # same seed: shared-shape leaves start identical; padded rows extra
        p1 = init_params(m1.specs, jax.random.PRNGKey(0))
        batch = _batch(cfg_odd, jax.random.PRNGKey(1))
        batch["prefix"] = jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg.n_prefix, cfg.d_model))
        loss, _ = m1.loss_fn(p1, batch)
        assert np.isfinite(float(loss))
        logits, cache = m1.prefill_fn(p1, batch, 40)
        assert logits.shape[-1] == 256  # padded width
        assert int(jnp.argmax(logits, -1).max()) < 251  # never a pad id

    def test_padded_vocab_property(self):
        cfg = dataclasses.replace(get_smoke_config("llama3.2-3b"),
                                  vocab=1000, pad_vocab_to=128)
        assert cfg.padded_vocab == 1024


class TestGroupedGQADecode:
    @pytest.mark.parametrize("arch", ["glm4-9b", "llama3.2-3b", "gemma-7b"])
    def test_decode_matches_prefill(self, arch):
        """H2: the grouped-GQA decode path must reproduce prefill logits."""
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = init_params(model.specs, jax.random.PRNGKey(3))
        toks = jax.random.randint(jax.random.PRNGKey(4), (1, 9), 0, cfg.vocab)
        logits_full, _ = model.prefill_fn(params, {"tokens": toks}, 16)
        logits_s, cache = model.prefill_fn(params, {"tokens": toks[:, :8]}, 16)
        logits_dec, _ = model.decode_fn(
            params, cache, toks[:, 8:], jnp.full((1,), 8, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits_dec),
                                   np.asarray(logits_full),
                                   rtol=3e-2, atol=3e-2)


class TestGenerator:
    def test_greedy_generation_deterministic(self):
        cfg = get_smoke_config("qwen1.5-4b")
        model = build_model(cfg)
        params = init_params(model.specs, jax.random.PRNGKey(5))
        gen = Generator(model, params, max_seq=32)
        prompts = np.array([[1, 2, 3, 4]] * 2)
        a = gen.generate(prompts, steps=6)
        b = gen.generate(prompts, steps=6)
        np.testing.assert_array_equal(a, b)
        assert a.shape == (2, 6)
        assert (a < cfg.vocab).all()
