"""Unified runtime pruning engine (ISSUES 2+3): batched join-overlap,
Bloom-probe and top-k boundary-init kernels vs their oracles; technique-
executor parity — ``PruningService.run_batch`` vs per-query
``PruningPipeline.run`` vs the host engine (distinct and Bloom summaries);
per-technique launch bounding and counters; DML invalidation of the
join-key / enumeration / block-top-k planes; overall_ratio guard."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import expr as E
from repro.core.device_stats import DeviceStatsCache
from repro.core.flow import (JoinSpec, PruningPipeline, PruningReport, Query,
                             TableScanSpec, TechniqueReport)
from repro.core.metadata import FULL_MATCH, ScanSet
from repro.core.prune_join import (BlockedBloom, prune_probe, summarize_build)
from repro.core.prune_topk import TopKResult
from repro.data.table import Table
from repro.kernels import (bloom_probe_batched, join_overlap_batched, ops,
                           ref, topk_init_batched)
from repro.serve.prune_service import PruningService


# ---------------------------------------------------------------------------
# join_overlap_batched kernel
# ---------------------------------------------------------------------------

@st.composite
def batched_overlap_problems(draw):
    P = draw(st.integers(1, 400))
    Q = draw(st.integers(1, 12))
    seed = draw(st.integers(0, 2**31))
    return P, Q, seed


def _make_overlap_inputs(P, Q, rng):
    pmin = rng.integers(0, 10_000, size=P).astype(np.float32)
    pmax = pmin + rng.integers(0, 100, size=P).astype(np.float32)
    fmax = np.float32(np.finfo(np.float32).max)
    empty = rng.random(P) < 0.1
    pmin = np.where(empty, fmax, pmin).astype(np.float32)
    pmax = np.where(empty, -fmax, pmax).astype(np.float32)
    lists = [np.unique(rng.integers(0, 10_000,
                                    size=rng.integers(1, 200))).astype(np.float32)
             for _ in range(Q)]
    return pmin, pmax, lists


class TestJoinOverlapBatchedKernel:
    @settings(max_examples=20, deadline=None)
    @given(problem=batched_overlap_problems())
    def test_kernel_matches_ref_and_brute(self, problem):
        P, Q, seed = problem
        rng = np.random.default_rng(seed)
        pmin, pmax, lists = _make_overlap_inputs(P, Q, rng)
        dist = ops.pack_distinct(lists)
        out_k = np.asarray(join_overlap_batched(
            jnp.asarray(dist), jnp.asarray(pmin), jnp.asarray(pmax),
            interpret=True))[:Q]
        out_r = np.asarray(ref.join_overlap_batched_ref(
            jnp.asarray(dist), jnp.asarray(pmin), jnp.asarray(pmax)))[:Q]
        np.testing.assert_array_equal(out_k, out_r)
        for qi, d in enumerate(lists):
            brute = np.array([((d >= lo) & (d <= hi)).any()
                              for lo, hi in zip(pmin, pmax)], dtype=np.int32)
            np.testing.assert_array_equal(out_k[qi], brute, err_msg=f"q={qi}")

    def test_wrapper_modes_agree_and_single_query_row(self):
        rng = np.random.default_rng(3)
        pmin, pmax, lists = _make_overlap_inputs(3000, 9, rng)
        pmin_d, pmax_d = jnp.asarray(pmin), jnp.asarray(pmax)
        ref_hit = ops.join_overlap_batched_device(lists, pmin_d, pmax_d,
                                                  mode="ref")
        int_hit = ops.join_overlap_batched_device(lists, pmin_d, pmax_d,
                                                  mode="interpret")
        np.testing.assert_array_equal(ref_hit, int_hit)
        # a Q=1 batch row equals the same query inside a bigger batch
        solo = ops.join_overlap_batched_device([lists[4]], pmin_d, pmax_d,
                                               mode="ref")
        np.testing.assert_array_equal(solo[0], ref_hit[4])

    def test_large_p_modes_agree(self):
        """P well past the kernel tile edge: numpy ref == interpret."""
        rng = np.random.default_rng(11)
        pmin, pmax, lists = _make_overlap_inputs(5000, 9, rng)
        pmin_d, pmax_d = jnp.asarray(pmin), jnp.asarray(pmax)
        ref_hit = ops.join_overlap_batched_device(lists, pmin_d, pmax_d, "ref")
        int_hit = ops.join_overlap_batched_device(lists, pmin_d, pmax_d,
                                                  "interpret")
        np.testing.assert_array_equal(ref_hit, int_hit)


# ---------------------------------------------------------------------------
# topk_init_batched kernel
# ---------------------------------------------------------------------------

@st.composite
def init_problems(draw):
    P = draw(st.integers(1, 300))
    K = draw(st.sampled_from([2, 4, 8]))
    k = draw(st.sampled_from([1, 4, 8, 16]))
    Q = draw(st.integers(1, 10))
    seed = draw(st.integers(0, 2**31))
    return P, K, k, Q, seed


def _make_init_inputs(P, K, Q, rng):
    plane = rng.integers(-1000, 1000, size=(P, K)).astype(np.float32)
    fill = rng.integers(0, K + 1, size=P)
    for p in range(P):
        plane[p, fill[p]:] = -np.inf
    plane = -np.sort(-plane, axis=1)
    mask = (rng.random((Q, P)) < 0.3).astype(np.float32)
    return plane, mask


def _init_oracle(plane, mask, k):
    Q = mask.shape[0]
    out = np.full((Q, k), -np.inf, dtype=np.float32)
    for qi in range(Q):
        vals = plane[mask[qi] > 0].ravel()
        vals = np.sort(vals[vals > -np.inf])[::-1][:k]
        out[qi, : len(vals)] = vals
    return out


class TestTopKInitBatchedKernel:
    @settings(max_examples=20, deadline=None)
    @given(problem=init_problems())
    def test_kernel_matches_ref_and_oracle(self, problem):
        P, K, k, Q, seed = problem
        rng = np.random.default_rng(seed)
        plane, mask = _make_init_inputs(P, K, Q, rng)
        out_k = np.asarray(topk_init_batched(
            jnp.asarray(plane), jnp.asarray(mask.T), k, interpret=True))
        out_r = np.asarray(ref.topk_init_batched_ref(
            jnp.asarray(plane), jnp.asarray(mask.T), k))
        oracle = _init_oracle(plane, mask, k)
        np.testing.assert_array_equal(out_k, oracle)
        np.testing.assert_array_equal(out_r, oracle)

    def test_wrapper_modes_agree_across_blocks(self):
        """P crossing BLOCK_PI and Q crossing BLOCK_QI tile edges."""
        rng = np.random.default_rng(5)
        for P, Q in ((1, 1), (129, 9), (300, 17)):
            plane, mask = _make_init_inputs(P, 8, Q, rng)
            plane_d = jnp.asarray(plane)
            out_ref = ops.topk_init_batched_device(plane_d, mask, 4, "ref")
            out_int = ops.topk_init_batched_device(plane_d, mask, 4,
                                                   "interpret")
            np.testing.assert_array_equal(out_ref, out_int)
            np.testing.assert_array_equal(out_ref, _init_oracle(plane, mask, 4))


# ---------------------------------------------------------------------------
# bloom_probe_batched kernel (ISSUE 3 tentpole)
# ---------------------------------------------------------------------------

@st.composite
def bloom_probe_problems(draw):
    P = draw(st.integers(1, 300))
    Q = draw(st.integers(1, 6))
    enum_limit = draw(st.sampled_from([4, 32, 96]))
    seed = draw(st.integers(0, 2**31))
    return P, Q, enum_limit, seed


def _make_bloom_inputs(P, Q, rng):
    """Random enumeration plane (negative domains, non-narrow rows) and
    Q filters of mixed NDV (mixed n_blocks exercises the tiling)."""
    pmin = rng.integers(-5000, 5000, size=P).astype(np.int32)
    width = rng.integers(0, 120, size=P).astype(np.int32)
    width[rng.random(P) < 0.25] = 0
    blooms = []
    for _ in range(Q):
        keys = np.unique(rng.integers(-6000, 6000,
                                      size=int(rng.integers(10, 4000))))
        b = BlockedBloom(len(keys))
        b.add(keys)
        blooms.append(b)
    return pmin, width, blooms


def _bloom_brute(blooms, pmin, width, enum_limit):
    """The (fixed) host matcher's enumeration, partition by partition."""
    Q, P = len(blooms), len(pmin)
    hit = np.ones((Q, P), dtype=np.int32)
    for qi, b in enumerate(blooms):
        for p in range(P):
            if 0 < width[p] <= enum_limit:
                cand = np.int64(pmin[p]) + np.arange(width[p])
                hit[qi, p] = int(b.contains(cand).any())
    return hit


class TestBloomProbeBatchedKernel:
    @settings(max_examples=15, deadline=None)
    @given(problem=bloom_probe_problems())
    def test_kernel_matches_oracle_and_host_matcher(self, problem):
        """Device (interpret) == jnp oracle == host BlockedBloom probe,
        bit for bit — the ISSUE 3 acceptance parity."""
        P, Q, enum_limit, seed = problem
        rng = np.random.default_rng(seed)
        pmin, width, blooms = _make_bloom_inputs(P, Q, rng)
        brute = _bloom_brute(blooms, pmin, width, enum_limit)
        pmin_d = jnp.asarray(pmin)
        width_d = jnp.asarray(width)
        wmax = int(width.max()) if P else 0
        out_i = ops.bloom_probe_batched_device(
            blooms, pmin_d, width_d, wmax, enum_limit, mode="interpret")
        np.testing.assert_array_equal(out_i, brute)
        lo, hi = ops.pack_blooms(blooms)
        weff = jnp.where(width_d <= enum_limit, width_d, 0)
        eb = ops.enum_bucket(max(1, min(wmax, enum_limit)))
        out_r = np.asarray(ref.bloom_probe_batched_ref(
            jnp.asarray(lo), jnp.asarray(hi), pmin_d, weff, eb))[:Q]
        np.testing.assert_array_equal(out_r, brute)

    def test_sparse_fallback_matches_and_respects_part_ids(self):
        """The no-Pallas fallback equals the kernel on the entries it is
        allowed to read (each query's part_ids); other entries stay 1."""
        rng = np.random.default_rng(4)
        pmin, width, blooms = _make_bloom_inputs(500, 4, rng)
        pmin_d, width_d = jnp.asarray(pmin), jnp.asarray(width)
        wmax = int(width.max())
        full = ops.bloom_probe_batched_device(
            blooms, pmin_d, width_d, wmax, 64, mode="ref")
        np.testing.assert_array_equal(
            full, _bloom_brute(blooms, pmin, width, 64))
        ids = [np.sort(rng.choice(500, size=80, replace=False))
               for _ in blooms]
        part = ops.bloom_probe_batched_device(
            blooms, pmin_d, width_d, wmax, 64, mode="ref",
            part_ids_lists=ids)
        for qi, pid in enumerate(ids):
            np.testing.assert_array_equal(part[qi, pid], full[qi, pid])
            outside = np.setdiff1d(np.arange(500), pid)
            assert (part[qi, outside] == 1).all()

    def test_filter_tiling_preserves_probe_results(self):
        """pack_blooms tiles filters to the common pow-2 block bucket;
        probing under the larger mask must be identical — verified by
        batching a small filter next to a much larger one."""
        rng = np.random.default_rng(5)
        small_keys = np.arange(40, dtype=np.int64)        # few blocks
        big_keys = rng.integers(0, 10**6, size=30_000)    # many blocks
        small, big = BlockedBloom(40), BlockedBloom(30_000)
        small.add(small_keys)
        big.add(np.unique(big_keys))
        assert small.n_blocks < big.n_blocks
        pmin = np.arange(0, 200, dtype=np.int32)
        width = np.full(200, 3, dtype=np.int32)
        solo = ops.bloom_probe_batched_device(
            [small], jnp.asarray(pmin), jnp.asarray(width), 3, 64,
            mode="interpret")
        pair = ops.bloom_probe_batched_device(
            [small, big], jnp.asarray(pmin), jnp.asarray(width), 3, 64,
            mode="interpret")
        np.testing.assert_array_equal(pair[0], solo[0])
        np.testing.assert_array_equal(
            pair[1], _bloom_brute([big], pmin, width, 64)[0])


# ---------------------------------------------------------------------------
# technique-executor engine: batched == per-query == host
# ---------------------------------------------------------------------------

def _engine_tables(seed=0):
    rng = np.random.default_rng(seed)
    n = 3000
    events = Table.build("events", {
        "ts": np.sort(rng.integers(0, 1_000_000, n)).astype(np.int64),
        "uid": rng.integers(0, 400, n).astype(np.int64),
        "val": rng.integers(0, 10_000, n).astype(np.int64),
    }, rows_per_partition=30, nulls={"val": rng.random(n) < 0.03})
    users = Table.build("users", {
        "id": np.arange(400, dtype=np.int64),
        "grp": rng.integers(0, 8, 400).astype(np.int64),
    }, rows_per_partition=40)
    return events, users


def _mixed_workload(events, users, rng, n=64):
    """Filter + join + top-k + join-top-k queries (device-exact int keys)."""
    qs = []
    for i in range(n):
        lo = int(rng.integers(0, 900_000))
        pred = (E.col("ts") >= lo) & (E.col("ts") <= lo + 150_000)
        g = int(rng.integers(0, 8))
        kind = i % 4
        if kind == 0:
            qs.append(Query(scans={"e": TableScanSpec(events, pred)}))
        elif kind == 1:
            qs.append(Query(
                scans={"e": TableScanSpec(events, pred),
                       "u": TableScanSpec(users, E.col("grp") == g)},
                join=JoinSpec("u", "e", "id", "uid")))
        elif kind == 2:
            qs.append(Query(scans={"e": TableScanSpec(events, pred)},
                            limit=int(rng.integers(1, 30)),
                            order_by=("e", "val", bool(i % 8 < 4))))
        else:
            qs.append(Query(
                scans={"e": TableScanSpec(events, pred),
                       "u": TableScanSpec(users, E.col("grp") == g)},
                join=JoinSpec("u", "e", "id", "uid"),
                limit=10, order_by=("e", "val", True)))
    return qs


def _assert_reports_equal(a, b):
    assert a.scan_sets.keys() == b.scan_sets.keys()
    for name in a.scan_sets:
        np.testing.assert_array_equal(a.scan_sets[name].part_ids,
                                      b.scan_sets[name].part_ids)
        np.testing.assert_array_equal(a.scan_sets[name].match,
                                      b.scan_sets[name].match)
        assert a.per_scan[name].keys() == b.per_scan[name].keys()
        for tech in a.per_scan[name]:
            ra, rb = a.per_scan[name][tech], b.per_scan[name][tech]
            assert (ra.before, ra.after, ra.applied) == \
                (rb.before, rb.after, rb.applied), (name, tech)
            assert ra.detail == rb.detail, (name, tech)
    assert (a.topk is None) == (b.topk is None)
    if a.topk is not None:
        np.testing.assert_array_equal(a.topk.values, b.topk.values)
        np.testing.assert_array_equal(a.topk.scanned, b.topk.scanned)
        np.testing.assert_array_equal(a.topk.skipped, b.topk.skipped)
        assert a.topk_scan == b.topk_scan


class TestUnifiedEngine:
    def test_batched_equals_per_query_and_launches_bounded(self):
        """The ISSUE 2 acceptance shape: >= 64 mixed queries, batched
        run_batch output identical to per-query pipeline.run, with kernel
        launches per stage bounded by distinct table groups."""
        events, users = _engine_tables()
        rng = np.random.default_rng(1)
        queries = _mixed_workload(events, users, rng, n=64)
        svc = PruningService(mode="ref")
        pipe = PruningPipeline(filter_mode="device", service=svc)
        before = svc.counters.snapshot()
        batch = svc.run_batch(queries, pipe)
        after = svc.counters.snapshot()
        seq = [pipe.run(q) for q in queries]
        for b, s in zip(batch, seq):
            _assert_reports_equal(b, s)
        # launches per stage: bounded by table groups, not queries
        t = {k: after["technique"][k]["launches"]
             - before["technique"].get(k, dict(launches=0))["launches"]
             for k in after["technique"]}
        assert t["filter"] == 2          # tables e and u
        assert t["join"] == 1            # one (events, uid) group
        assert 1 <= t["topk"] <= 2       # (events, val) x {asc, desc}
        # only join-top-k queries (extra mask -> host-only init) fall back
        n_join_topk = sum(1 for q in queries
                          if q.is_topk and q.join is not None)
        fb = {k: after["technique"][k]["fallbacks"]
              - before["technique"].get(k, dict(fallbacks=0))["fallbacks"]
              for k in after["technique"]}
        assert fb["filter"] == 0 and fb["join"] == 0
        assert fb["topk"] == n_join_topk

    def test_device_engine_matches_host_on_exact_workload(self):
        """On int workloads (< 2**24, exact f32) the device join path
        prunes exactly like the host matcher; top-k values are identical
        and the device boundary-init only ever *adds* skips."""
        events, users = _engine_tables(seed=3)
        rng = np.random.default_rng(4)
        queries = _mixed_workload(events, users, rng, n=32)
        svc = PruningService(mode="ref")
        dev = PruningPipeline(filter_mode="device", service=svc)
        host = PruningPipeline(filter_mode="host")
        for q in queries:
            rd, rh = dev.run(q), host.run(q)
            for name in rh.scan_sets:
                np.testing.assert_array_equal(
                    rd.scan_sets[name].part_ids, rh.scan_sets[name].part_ids)
            if rh.topk is not None:
                np.testing.assert_array_equal(rd.topk.values, rh.topk.values)
                assert set(rh.topk.skipped) <= set(rd.topk.skipped)

    def test_report_counters_attribute_stages(self):
        events, users = _engine_tables(seed=5)
        rng = np.random.default_rng(6)
        queries = _mixed_workload(events, users, rng, n=16)
        svc = PruningService(mode="ref")
        reports = svc.run_batch(queries)
        snap = reports[0].counters
        assert snap["technique"]["filter"]["launches"] >= 1
        assert snap["technique"]["join"]["launches"] >= 1
        assert snap["technique"]["topk"]["launches"] >= 1
        # per-report technique details carry the execution path
        join_reps = [r.per_scan["e"]["join"] for r in reports
                     if "join" in r.per_scan.get("e", {})]
        assert join_reps and all(j.detail["path"] == "device"
                                 for j in join_reps)

    def test_disabled_filter_never_certifies_full_match(self):
        """enable_filter=False with a real predicate must not mark
        partitions FULL_MATCH — an uncertified FULL would seed the
        Sec. 5.4 boundary (host and device) from non-matching rows and
        return wrong (even empty) top-k results."""
        from repro.core.prune_topk import topk_oracle
        events, _users = _engine_tables(seed=21)
        pred = E.col("uid") <= 20           # selective, uncertified
        q = Query(scans={"e": TableScanSpec(events, pred)},
                  limit=5, order_by=("e", "val", True))
        oracle = topk_oracle(events, "val", 5, pred=pred)
        for pipe in (PruningPipeline(enable_filter=False),
                     PruningPipeline(enable_filter=False,
                                     filter_mode="device",
                                     service=PruningService(mode="ref"))):
            rep = pipe.run(q)
            assert (rep.scan_sets["e"].match != FULL_MATCH).all()
            np.testing.assert_array_equal(rep.topk.values, oracle)

    def test_bloom_summaries_take_device_path(self):
        """NDV above the distinct limit -> Bloom summary -> batched
        enumeration launch (ISSUE 3), same scan sets as the host pipeline
        and no host fallback on an integer key domain."""
        events, users = _engine_tables(seed=7)
        rng = np.random.default_rng(8)
        q = _mixed_workload(events, users, rng, n=2)[1]   # join query
        svc = PruningService(mode="ref")
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=2)
        rep = svc.run_batch([q], pipe)[0]
        assert rep.per_scan["e"]["join"].detail["path"] == "device"
        assert rep.per_scan["e"]["join"].detail["summary_kind"] == "bloom"
        assert svc.counters.technique["join_bloom"]["launches"] == 1
        assert svc.counters.technique["join_bloom"]["fallbacks"] == 0
        assert "join" not in svc.counters.technique  # no distinct work
        host = PruningPipeline(filter_mode="host", join_ndv_limit=2).run(q)
        np.testing.assert_array_equal(rep.scan_sets["e"].part_ids,
                                      host.scan_sets["e"].part_ids)

    def test_float_key_bloom_summaries_fall_back_to_host(self):
        """A float probe key domain is ineligible for the integer
        enumeration kernel: the Bloom path must keep the host matcher,
        counted under join_bloom, with identical scan sets."""
        rng = np.random.default_rng(9)
        probe = Table.build(
            "fp", {"k": rng.uniform(0, 100, 400)}, rows_per_partition=4)
        build = Table.build(
            "bld", {"k": rng.uniform(0, 100, 64)}, rows_per_partition=8)
        q = Query(scans={"p": TableScanSpec(probe),
                         "b": TableScanSpec(build)},
                  join=JoinSpec("b", "p", "k", "k"))
        svc = PruningService(mode="ref")
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=4)
        rep = svc.run_batch([q], pipe)[0]
        assert rep.per_scan["p"]["join"].detail["summary_kind"] == "bloom"
        assert rep.per_scan["p"]["join"].detail["path"] == "host"
        assert svc.counters.technique["join_bloom"]["fallbacks"] == 1
        assert svc.counters.technique["join_bloom"]["launches"] == 0
        host = PruningPipeline(filter_mode="host", join_ndv_limit=4).run(q)
        np.testing.assert_array_equal(rep.scan_sets["p"].part_ids,
                                      host.scan_sets["p"].part_ids)


def _bloom_mixed_workload(events, users, rng, n=24):
    """Joins whose build NDV straddles a small ndv_limit: grp-filtered
    builds (~50 ids) summarize as Bloom, id-capped builds (<= 6 ids) as
    distinct — plus plain filter queries (run with join_ndv_limit=8)."""
    qs = []
    for i in range(n):
        lo = int(rng.integers(0, 900_000))
        pred = (E.col("ts") >= lo) & (E.col("ts") <= lo + 150_000)
        g = int(rng.integers(0, 8))
        kind = i % 3
        if kind == 0:
            qs.append(Query(scans={"e": TableScanSpec(events, pred)}))
        elif kind == 1:   # Bloom summary: ~400/8 distinct build ids > 8
            qs.append(Query(
                scans={"e": TableScanSpec(events, pred),
                       "u": TableScanSpec(users, E.col("grp") == g)},
                join=JoinSpec("u", "e", "id", "uid")))
        else:             # distinct summary: <= 6 build ids
            qs.append(Query(
                scans={"e": TableScanSpec(events, pred),
                       "u": TableScanSpec(users, E.col("id") <= 5)},
                join=JoinSpec("u", "e", "id", "uid")))
    return qs


class TestBloomEngineParity:
    def test_mixed_distinct_bloom_batched_parity_and_launch_bounds(self):
        """The ISSUE 3 acceptance shape: a mixed distinct/Bloom workload
        where run_batch == per-query device == host pipeline, with one
        distinct launch and one Bloom launch per (table, key col) group
        and zero host fallbacks."""
        events, users = _engine_tables(seed=23)
        rng = np.random.default_rng(24)
        queries = _bloom_mixed_workload(events, users, rng, n=24)
        svc = PruningService(mode="ref")
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=8)
        before = svc.counters.snapshot()
        batch = svc.run_batch(queries, pipe)
        after = svc.counters.snapshot()
        seq = [pipe.run(q) for q in queries]
        for b, s in zip(batch, seq):
            _assert_reports_equal(b, s)
        host = PruningPipeline(filter_mode="host", join_ndv_limit=8)
        for q, b in zip(queries, batch):
            h = host.run(q)
            for name in h.scan_sets:
                np.testing.assert_array_equal(b.scan_sets[name].part_ids,
                                              h.scan_sets[name].part_ids)
        kinds = {b.per_scan["e"]["join"].detail["summary_kind"]
                 for b in batch if "join" in b.per_scan.get("e", {})}
        assert kinds == {"distinct", "bloom"}
        delta = {t: {f: after["technique"][t][f]
                     - before["technique"].get(t, dict(launches=0,
                                                       fallbacks=0))[f]
                     for f in ("launches", "fallbacks")}
                 for t in after["technique"]}
        assert delta["join"] == dict(launches=1, fallbacks=0)
        assert delta["join_bloom"] == dict(launches=1, fallbacks=0)

    def test_interpret_mode_engine_matches_ref(self):
        """The Pallas kernel (interpret) drives the same engine results
        as the jnp/numpy ref backend on a Bloom workload."""
        events, users = _engine_tables(seed=25)
        rng = np.random.default_rng(26)
        queries = [q for q in _bloom_mixed_workload(events, users, rng, n=6)
                   if q.join is not None]
        out = {}
        for mode in ("ref", "interpret"):
            svc = PruningService(mode=mode)
            pipe = PruningPipeline(filter_mode="device", service=svc,
                                   join_ndv_limit=8)
            out[mode] = svc.run_batch(queries, pipe)
        for a, b in zip(out["ref"], out["interpret"]):
            for name in a.scan_sets:
                np.testing.assert_array_equal(a.scan_sets[name].part_ids,
                                              b.scan_sets[name].part_ids)

    @settings(max_examples=25, deadline=None)
    @given(
        build=st.lists(st.one_of(st.integers(0, 300),
                                 st.floats(0, 300, allow_nan=False)),
                       min_size=5, max_size=60),
        probe_seed=st.integers(0, 2**31),
        float_probe=st.booleans(),
    )
    def test_device_bloom_never_prunes_joinable(self, build, probe_seed,
                                                float_probe):
        """Sec. 6.2 guarantee through the device path, integer and float
        probe domains, fractional build keys included: a partition
        containing a joinable key is never pruned, and on integer domains
        the device result is bit-identical to the host matcher."""
        rng = np.random.default_rng(probe_seed)
        vals = (rng.uniform(0, 300, 160) if float_probe
                else rng.integers(0, 300, 160).astype(np.int64))
        probe = Table.build("p", {"k": vals}, rows_per_partition=4)
        build_keys = np.asarray(build, dtype=np.float64)
        summary = summarize_build(build_keys, ndv_limit=0)  # force Bloom
        assert summary.bloom is not None
        svc = PruningService(mode="ref")
        scan = ScanSet.full(probe.num_partitions)
        hit = svc.join_hit(probe, "k", summary, part_ids=scan.part_ids)
        bh = None if hit is None else np.asarray(hit)[scan.part_ids] > 0
        res = prune_probe(scan, probe.stats, "k", summary, bloom_hit=bh)
        host = prune_probe(ScanSet.full(probe.num_partitions), probe.stats,
                           "k", summary)
        np.testing.assert_array_equal(res.scan.part_ids,
                                      host.scan.part_ids)
        kept = set(res.scan.part_ids.tolist())
        for p in range(probe.num_partitions):
            v, _ = probe.partition_ctx(p).col("k")
            if np.isin(v, build_keys).any():
                assert p in kept, f"pruned joinable partition {p}"


# ---------------------------------------------------------------------------
# DML invalidation of the runtime-technique planes
# ---------------------------------------------------------------------------

class TestPlaneInvalidation:
    def _service_with_staged_planes(self):
        events, users = _engine_tables(seed=9)
        # verdict cache off: these tests pin exact flat plane-staging
        # miss counts, which verdict-plane misses would perturb
        svc = PruningService(mode="ref", verdict_cache=False)
        pipe = PruningPipeline(filter_mode="device", service=svc)
        rng = np.random.default_rng(10)
        svc.run_batch(_mixed_workload(events, users, rng, n=8), pipe)
        return svc, pipe, events, users

    def test_update_on_join_key_restages_plane(self):
        svc, pipe, events, users = self._service_with_staged_planes()
        misses = svc.cache.plane_misses
        rng = np.random.default_rng(11)
        work = _mixed_workload(events, users, rng, n=8)
        svc.run_batch(work, pipe)
        assert svc.cache.plane_misses == misses      # planes resident
        svc.notify_update("events", "uid")           # the join key column
        svc.run_batch(work, pipe)
        assert svc.cache.plane_misses == misses + 1  # key plane re-staged

    def test_update_on_order_column_restages_topk_plane(self):
        svc, pipe, events, users = self._service_with_staged_planes()
        n_topk = len(svc.cache.topk_planes)
        assert n_topk >= 1
        svc.notify_update("events", "val")           # the order column
        assert len(svc.cache.topk_planes) == 0
        rng = np.random.default_rng(12)
        misses = svc.cache.plane_misses
        svc.run_batch(_mixed_workload(events, users, rng, n=8), pipe)
        assert svc.cache.plane_misses > misses

    def test_wrong_column_update_keeps_planes(self):
        """An update to an unrelated column must NOT re-stage the join-key
        or block-top-k planes (it cannot change their values) — while the
        [C, P] min/max planes do re-stage (they carry every column)."""
        svc, pipe, events, users = self._service_with_staged_planes()
        key_planes = dict(svc.cache.key_planes)
        topk_planes = dict(svc.cache.topk_planes)
        stat_misses = svc.cache.misses
        svc.notify_update("events", "ts")            # neither key nor order
        assert dict(svc.cache.key_planes) == key_planes
        assert dict(svc.cache.topk_planes) == topk_planes
        rng = np.random.default_rng(13)
        misses = svc.cache.plane_misses
        svc.run_batch(_mixed_workload(events, users, rng, n=8), pipe)
        assert svc.cache.plane_misses == misses      # planes survived
        assert svc.cache.misses > stat_misses        # min/max re-staged

    def test_insert_and_delete_drop_all_planes(self):
        svc, pipe, events, users = self._service_with_staged_planes()
        assert svc.cache.key_planes and svc.cache.topk_planes
        svc.notify_insert("events", 2)
        assert not any(k[0] == "events" for k in svc.cache.key_planes)
        assert not any(k[0] == "events" for k in svc.cache.topk_planes)
        svc2, _, ev2, us2 = self._service_with_staged_planes()
        svc2.notify_delete("events")
        assert not any(k[0] == "events" for k in svc2.cache.topk_planes)

    def test_enum_plane_column_granular_invalidation(self):
        """The enumeration plane follows the join-key plane's DML
        discipline: a key-column update re-stages it, an unrelated-column
        update keeps it resident, insert/delete drop it."""
        events, users = _engine_tables(seed=27)
        # verdict cache off: the test counts enum-plane misses exactly
        svc = PruningService(mode="ref", verdict_cache=False)
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=8)
        rng = np.random.default_rng(28)
        work = _bloom_mixed_workload(events, users, rng, n=9)
        svc.run_batch(work, pipe)
        assert any(k[0] == "events" and k[2] == "uid"
                   for k in svc.cache.enum_planes)
        misses = svc.cache.plane_misses
        svc.run_batch(work, pipe)
        assert svc.cache.plane_misses == misses      # plane resident
        svc.notify_update("events", "ts")            # unrelated column
        svc.run_batch(work, pipe)
        assert svc.cache.plane_misses == misses      # still resident
        svc.notify_update("events", "uid")           # the join key column
        assert not any(k[0] == "events" and k[2] == "uid"
                       for k in svc.cache.enum_planes)
        svc.run_batch(work, pipe)
        assert svc.cache.plane_misses > misses       # re-staged
        svc.notify_insert("events", 1)
        assert not any(k[0] == "events" for k in svc.cache.enum_planes)

    def test_enum_plane_guards_non_enumerable_rows(self):
        """Width rows are 0 (= keep, never prune) wherever enumeration
        would be unsound: empty intervals and out-of-int32 bounds."""
        cache = DeviceStatsCache()
        big = np.array([0, 1, 2**40, 2**40 + 1, 5, 6], dtype=np.int64)
        t = Table.build("t", {"k": big}, rows_per_partition=2,
                        nulls={"k": np.array([0, 0, 0, 0, 1, 1], bool)})
        pmin, width, wmax, domain_ok = cache.enum_plane(t, "k")
        width = np.asarray(width)
        assert width[1] == 0                 # 2**40 range: outside int32
        assert width[2] == 0                 # all-null partition: empty
        assert width[0] == 2 and wmax == 2   # [0, 1] enumerates fine
        assert not domain_ok                 # a live partition exceeds int32
        small = Table.build("s", {"k": np.arange(8, dtype=np.int64)},
                            rows_per_partition=4)
        assert cache.enum_plane(small, "k")[3]

    def test_rebuilt_table_never_hits_stale_plane(self):
        """Same name + shape, new data: stats.uid keying must re-stage
        (a stale block-top-k plane would fabricate a boundary witness)."""
        cache = DeviceStatsCache()
        t1 = Table.build("t", {"v": np.arange(100, dtype=np.int64)},
                         rows_per_partition=10)
        p1 = cache.block_topk_plane(t1, "v", True)
        t2 = Table.build("t", {"v": np.arange(500, 600, dtype=np.int64)},
                         rows_per_partition=10)
        p2 = cache.block_topk_plane(t2, "v", True)
        assert float(np.asarray(p2).max()) == 599.0
        assert cache.plane_misses == 2 and p1 is not p2


# ---------------------------------------------------------------------------
# PruningReport.overall_ratio guard (satellite)
# ---------------------------------------------------------------------------

class TestOverallRatioGuard:
    def _report(self, scan_ids, skipped, topk_scan="e"):
        tbl = Table.build("t", {"v": np.arange(100, dtype=np.int64)},
                          rows_per_partition=10)           # 10 partitions
        res = TopKResult(values=np.zeros(1), scanned=np.zeros(0, np.int64),
                         skipped=np.asarray(skipped, dtype=np.int64),
                         pruning_ratio=0.0, rows_scanned=0,
                         boundary_final=0.0)
        rep = PruningReport(
            per_scan={"e": {}},
            scan_sets={"e": ScanSet(np.asarray(scan_ids, dtype=np.int64))},
            topk=res, topk_scan=topk_scan)
        rep._scan_specs = {"e": TableScanSpec(tbl)}
        return rep

    def test_skipped_partitions_present_are_subtracted(self):
        rep = self._report(scan_ids=[0, 1, 2, 3], skipped=[2, 3])
        # 10 total, 4 remaining - 2 skipped = 2 -> ratio 0.8
        assert rep.overall_ratio == pytest.approx(0.8)

    def test_skipped_partitions_already_removed_not_double_subtracted(self):
        """Regression: skipped partitions already gone from scan_sets must
        not be subtracted again (the old code could push remaining
        negative and the ratio past 1.0)."""
        rep = self._report(scan_ids=[0, 1], skipped=[2, 3])
        assert rep.overall_ratio == pytest.approx(0.8)     # not 1.0+
        rep2 = self._report(scan_ids=[0, 1, 2], skipped=[2, 3])
        assert rep2.overall_ratio == pytest.approx(0.8)    # only #2 present
        assert 0.0 <= rep2.overall_ratio <= 1.0

    def test_legacy_report_without_target_scan_stays_guarded(self):
        """topk_scan=None (reports built outside the engine): the guard
        still applies per single scan — table-local partition ids from
        other scans must not satisfy the presence check."""
        rep = self._report(scan_ids=[0, 1, 2, 3], skipped=[2, 3],
                           topk_scan=None)
        assert rep.overall_ratio == pytest.approx(0.8)
        rep2 = self._report(scan_ids=[0, 1], skipped=[2, 3], topk_scan=None)
        assert rep2.overall_ratio == pytest.approx(0.8)    # none present
        assert 0.0 <= rep2.overall_ratio <= 1.0

    def test_engine_reports_stay_in_range(self):
        events, users = _engine_tables(seed=15)
        rng = np.random.default_rng(16)
        for q in _mixed_workload(events, users, rng, n=12):
            r = PruningPipeline().run(q)
            assert 0.0 <= r.overall_ratio <= 1.0


# ---------------------------------------------------------------------------
# benchmark smoke (satellite)
# ---------------------------------------------------------------------------

class TestBenchSmoke:
    def test_runtime_prune_bench_runs(self, tmp_path):
        from benchmarks.bench_runtime_prune import run
        json_path = str(tmp_path / "BENCH_runtime_prune.json")
        rows, cells = run(grid_p=(512,), grid_q=(8,), json_path=json_path)
        assert len(cells) == 1
        assert cells[0]["launches"]["filter"]["launches"] >= 1
        import json as _json
        with open(json_path) as f:
            payload = _json.load(f)
        assert payload["bench"] == "runtime_prune"
        assert len(payload["grid"]) == 1
        # Bloom cell: batched enumeration launches, no host fallbacks
        assert payload["bloom"]["bloom_launches"] >= 1
        assert payload["bloom"]["bloom_fallbacks"] == 0
        assert "bloom_qps_delta" in payload["acceptance"]
