"""Tests for tools/contract_lint — each checker has at least one
should-flag and one should-pass fixture, plus finding/baseline engine
coverage.  Fixtures are inline sources run through ``lint_sources`` under
synthetic repo-relative paths, so no real tree (and no jax) is needed."""

import json
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.contract_lint import Baseline, lint_sources          # noqa: E402
from tools.contract_lint.__main__ import main as lint_main      # noqa: E402


def lint(path, source, extra=None):
    sources = {path: textwrap.dedent(source)}
    for p, s in (extra or {}).items():
        sources[p] = textwrap.dedent(s)
    return lint_sources(sources)


def rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# CL001 · ladder discipline
# ---------------------------------------------------------------------------

class TestLadderDiscipline:
    REGISTRY = {"src/repro/serve/reg.py":
                'LADDER_LAUNCH_SITES = frozenset({"Svc.launch_rungs"})\n'}

    def test_flags_direct_batched_call_from_serve(self):
        findings = lint("src/repro/serve/svc.py", """\
            class Svc:
                def sneak(self, lo, hi):
                    return kops.prune_ranges_batched_device(lo, hi)
            """, extra=self.REGISTRY)
        assert "CL001" in rules(findings)
        (f,) = [f for f in findings if f.rule == "CL001"]
        assert "prune_ranges_batched_device" in f.message
        assert f.context == "Svc.sneak"

    def test_flags_batched_call_from_flow(self):
        findings = lint("src/repro/core/flow.py", """\
            def run(pipe):
                return kops.join_overlap_batched_tree(pipe)
            """)
        assert "CL001" in rules(findings)

    def test_registered_site_passes_including_nested_thunks(self):
        findings = lint("src/repro/serve/svc.py", """\
            class Svc:
                def launch_rungs(self, lo, hi):
                    def thunk():
                        return kops.prune_ranges_batched_device(lo, hi)
                    return [("device", thunk)]
            """, extra=self.REGISTRY)
        assert "CL001" not in rules(findings)

    def test_out_of_scope_module_passes(self):
        findings = lint("src/repro/kernels/ops.py", """\
            def prune_ranges_batched_host(lo, hi):
                return minmax_prune_batched_ref(lo, hi)
            """)
        assert "CL001" not in rules(findings)


# ---------------------------------------------------------------------------
# CL002 · integrity protocol
# ---------------------------------------------------------------------------

class TestIntegrityProtocol:
    GOOD = """\
        PLANE_FAMILIES = ("stat",)

        class DeviceStatsCache:
            def __init__(self):
                self.entries = {}
                self._stores = {"stat": self.entries}

            def _admit(self, family, key, nbytes):
                self.memory.admit(family, key, nbytes)

            def get(self, key):
                arrays = self._build(key)
                stamp = plane_checksum(arrays)
                self._admit("stat", key, 8)
                return arrays, stamp
        """

    def test_protocol_compliant_getter_passes(self):
        findings = lint("src/repro/core/device_stats.py", self.GOOD)
        assert "CL002" not in rules(findings)

    def test_flags_getter_missing_checksum_and_accounting(self):
        findings = lint("src/repro/core/device_stats.py", """\
            PLANE_FAMILIES = ("stat",)

            class DeviceStatsCache:
                def __init__(self):
                    self.entries = {}
                    self._stores = {"stat": self.entries}

                def tree_plane(self, key):
                    return self.entries[key]
            """)
        msgs = [f.message for f in findings if f.rule == "CL002"]
        assert any("plane_checksum" in m for m in msgs)
        assert any("PlaneMemoryManager" in m for m in msgs)

    def test_verdict_plane_getter_with_protocol_passes(self):
        """A verdict-family getter that re-stamps on replay and touches
        the memory accounting is protocol-compliant."""
        findings = lint("src/repro/core/device_stats.py", """\
            PLANE_FAMILIES = ("verdict",)

            class DeviceStatsCache:
                def __init__(self):
                    self.verdict_planes = {}
                    self._stores = {"verdict": self.verdict_planes}

                def _touch(self, family, key):
                    self.memory.touch(family, key)

                def verdict_plane(self, table, pred, ckey):
                    e = self.verdict_planes[(table.name, ckey)]
                    e.meta["checksum"] = plane_checksum(e.arrays)
                    self._touch("verdict", (table.name, ckey))
                    return e.arrays[0]
            """)
        assert "CL002" not in rules(findings)

    def test_flags_verdict_plane_getter_skipping_protocol(self):
        """A verdict getter that serves rows without checksum stamping or
        byte accounting violates the integrity protocol."""
        findings = lint("src/repro/core/device_stats.py", """\
            PLANE_FAMILIES = ("verdict",)

            class DeviceStatsCache:
                def __init__(self):
                    self.verdict_planes = {}
                    self._stores = {"verdict": self.verdict_planes}

                def verdict_plane(self, table, pred, ckey):
                    return self.verdict_planes[(table.name, ckey)].arrays[0]
            """)
        msgs = [f.message for f in findings if f.rule == "CL002"]
        assert any("plane_checksum" in m for m in msgs)
        assert any("PlaneMemoryManager" in m for m in msgs)

    def test_flags_verdict_store_missing_from_registry(self):
        """Shipping the verdict store without declaring the family in
        PLANE_FAMILIES is exactly what CL002 exists to catch."""
        findings = lint("src/repro/core/device_stats.py", """\
            PLANE_FAMILIES = ("stat",)

            class DeviceStatsCache:
                def __init__(self):
                    self._stores = {"stat": self.entries,
                                    "verdict": self.verdict_planes}
            """)
        msgs = [f.message for f in findings if f.rule == "CL002"]
        assert any("'verdict'" in m and "integrity protocol" in m
                   for m in msgs)

    def test_flags_store_family_not_in_registry(self):
        findings = lint("src/repro/core/device_stats.py", """\
            PLANE_FAMILIES = ("stat",)

            class DeviceStatsCache:
                def __init__(self):
                    self._stores = {"stat": self.entries, "rogue": self.rogue}
            """)
        msgs = [f.message for f in findings if f.rule == "CL002"]
        assert any("'rogue'" in m and "integrity protocol" in m for m in msgs)

    def test_flags_missing_registry(self):
        findings = lint("src/repro/core/device_stats.py", """\
            class DeviceStatsCache:
                def __init__(self):
                    self._stores = {"stat": self.entries}
            """)
        msgs = [f.message for f in findings if f.rule == "CL002"]
        assert any("PLANE_FAMILIES" in m for m in msgs)


# ---------------------------------------------------------------------------
# CL003 · lock discipline
# ---------------------------------------------------------------------------

class TestLockDiscipline:
    def test_flags_guarded_read_outside_lock(self):
        findings = lint("src/repro/core/cache.py", """\
            class Cache:
                def __init__(self):
                    self._lock = threading.RLock()
                    self.entries = {}  # guarded-by: _lock

                def peek(self, k):
                    return self.entries.get(k)
            """)
        (f,) = [f for f in findings if f.rule == "CL003"]
        assert f.context == "Cache.peek"
        assert "'entries'" in f.message

    def test_flags_guarded_write_outside_lock(self):
        findings = lint("src/repro/core/cache.py", """\
            class Cache:
                def __init__(self):
                    self._lock = threading.RLock()
                    self.tick = 0  # guarded-by: _lock

                def bump(self):
                    self.tick += 1
            """)
        assert "CL003" in rules(findings)

    def test_with_lock_scopes_and_nested_functions_pass(self):
        findings = lint("src/repro/core/cache.py", """\
            class Cache:
                def __init__(self):
                    self._lock = threading.RLock()
                    self.entries = {}  # guarded-by: _lock

                def get(self, k):
                    with self._lock:
                        def build():
                            return self.entries[k]
                        return build()

                def _count(self):
                    return len(self.entries)

                def size(self):
                    with self._lock:
                        return self._count()
            """)
        assert "CL003" not in rules(findings)

    def test_private_helper_with_unlocked_caller_flagged(self):
        findings = lint("src/repro/core/cache.py", """\
            class Cache:
                def __init__(self):
                    self._lock = threading.RLock()
                    self.entries = {}  # guarded-by: _lock

                def _count(self):
                    return len(self.entries)

                def size(self):
                    return self._count()
            """)
        assert "CL003" in rules(findings)

    def test_unannotated_fields_ignored(self):
        findings = lint("src/repro/core/cache.py", """\
            class Cache:
                def __init__(self):
                    self._lock = threading.RLock()
                    self.hits = 0

                def bump(self):
                    self.hits += 1
            """)
        assert "CL003" not in rules(findings)


# ---------------------------------------------------------------------------
# CL004 · precision contract
# ---------------------------------------------------------------------------

class TestPrecisionContract:
    def test_flags_raw_astype_in_kernels(self):
        findings = lint("src/repro/kernels/stage.py", """\
            def stage(stats):
                return stats.mins.astype(np.float32)
            """)
        assert "CL004" in rules(findings)

    def test_flags_raw_float32_call(self):
        findings = lint("src/repro/core/bounds.py", """\
            def narrow(b):
                return jnp.float32(b)
            """)
        assert "CL004" in rules(findings)

    def test_widening_helpers_bool_masks_and_constants_pass(self):
        findings = lint("src/repro/kernels/stage.py", """\
            def stage(stats, lo, hi):
                mins = round_down_f32(stats.mins).astype(np.float32)
                demote = ((stats.nulls > 0) | inexact).astype(np.float32)
                pad = np.float32(-np.inf)
                return mins, demote, pad
            """)
        assert "CL004" not in rules(findings)

    def test_out_of_scope_module_passes(self):
        findings = lint("src/repro/serve/glue.py", """\
            def narrow(x):
                return x.astype(np.float32)
            """)
        assert "CL004" not in rules(findings)


# ---------------------------------------------------------------------------
# CL005 · trace safety
# ---------------------------------------------------------------------------

class TestTraceSafety:
    def test_flags_python_if_on_traced_param_in_jitted_fn(self):
        findings = lint("src/repro/kernels/op.py", """\
            @jax.jit
            def f(x):
                if x > 0:
                    return x
                return -x
            """)
        (f,) = [f for f in findings if f.rule == "CL005"]
        assert "`if`" in f.message and "['x']" in f.message

    def test_flags_item_and_nondeterminism_in_kernel_body(self):
        findings = lint("src/repro/kernels/op.py", """\
            def _scan_kernel(x_ref, o_ref):
                t = time.time()
                o_ref[...] = x_ref[...].item() + t
            """)
        msgs = [f.message for f in findings if f.rule == "CL005"]
        assert any(".item()" in m for m in msgs)
        assert any("time.time" in m for m in msgs)

    def test_flags_float_concretization(self):
        findings = lint("src/repro/kernels/op.py", """\
            @functools.partial(jax.jit, static_argnames=("k",))
            def f(x, k):
                return float(x) + k
            """)
        msgs = [f.message for f in findings if f.rule == "CL005"]
        assert any("float" in m for m in msgs)

    def test_static_argnames_and_closure_config_pass(self):
        findings = lint("src/repro/kernels/op.py", """\
            @functools.partial(jax.jit, static_argnames=("interpret", "k"))
            def f(x, interpret, k):
                if interpret:
                    return x * k
                return x

            def _build(use_kernel):
                def body(x):
                    if use_kernel:
                        return _launch(x)
                    return _ref(x)
                return jax.jit(shard_map(body, mesh))
            """)
        assert "CL005" not in rules(findings)

    def test_kernel_kwonly_config_params_are_static(self):
        findings = lint("src/repro/kernels/op.py", """\
            def _flash_kernel(q_ref, o_ref, *, causal, nk):
                if causal:
                    o_ref[...] = q_ref[...]
            """)
        assert "CL005" not in rules(findings)

    def test_untraced_function_passes(self):
        findings = lint("src/repro/kernels/op.py", """\
            def host_side(x):
                if x > 0:
                    return float(x)
                return time.time()
            """)
        assert "CL005" not in rules(findings)


# ---------------------------------------------------------------------------
# CL006 · counter registration
# ---------------------------------------------------------------------------

class TestCounterRegistration:
    REGISTRY = {"src/repro/serve/resilience.py":
                'COUNTER_REGISTRY = frozenset({"retries", "filter"})\n'}

    def test_flags_unregistered_key_write(self):
        findings = lint("src/repro/serve/svc.py", """\
            class Svc:
                def run(self):
                    self.counters["rogue"] += 1
            """, extra=self.REGISTRY)
        (f,) = [f for f in findings if f.rule == "CL006"]
        assert "'rogue'" in f.message

    def test_flags_unregistered_key_through_alias(self):
        findings = lint("src/repro/serve/svc.py", """\
            class Svc:
                def run(self):
                    c = self.counters
                    c["rogue"] += 1
            """, extra=self.REGISTRY)
        assert "CL006" in rules(findings)

    def test_flags_unregistered_factory_and_bump_keys(self):
        findings = lint("src/repro/serve/svc.py", """\
            def new_svc_counters():
                return dict(retries=0, rogue=0)

            class Svc:
                def run(self):
                    self.counters.bump("mystery", launches=1)
            """, extra=self.REGISTRY)
        msgs = [f.message for f in findings if f.rule == "CL006"]
        assert any("'rogue'" in m for m in msgs)
        assert any("'mystery'" in m for m in msgs)

    def test_registered_keys_pass(self):
        findings = lint("src/repro/serve/svc.py", """\
            def new_svc_counters():
                return dict(retries=0)

            class Svc:
                def run(self):
                    c = self.counters
                    c["retries"] += 1
                    self.counters.bump("filter", launches=1)
            """, extra=self.REGISTRY)
        assert "CL006" not in rules(findings)

    def test_non_counter_dicts_ignored(self):
        findings = lint("src/repro/serve/svc.py", """\
            class Svc:
                def run(self):
                    cfg = {}
                    cfg["anything"] = 1
            """, extra=self.REGISTRY)
        assert "CL006" not in rules(findings)

    def test_flags_unregistered_report_section_write(self):
        """PR 10 shape: the front-end attaches a new section to each
        report's counters dict — the section name itself is a counter
        key and must be registered."""
        findings = lint("src/repro/serve/frontend.py", """\
            class Frontend:
                def _execute(self, rep):
                    rep.counters["latency"] = dict(p50_ms=0.0)
            """, extra=self.REGISTRY)
        (f,) = [f for f in findings if f.rule == "CL006"]
        assert "'latency'" in f.message

    def test_registered_latency_family_passes(self):
        reg = {"src/repro/serve/resilience.py":
               'COUNTER_REGISTRY = frozenset({"latency", "p50_ms"})\n'}
        findings = lint("src/repro/serve/frontend.py", """\
            def new_latency_counters():
                return dict(p50_ms=0.0)

            class Frontend:
                def _execute(self, rep):
                    rep.counters["latency"] = dict(new_latency_counters())
            """, extra=reg)
        assert "CL006" not in rules(findings)


# ---------------------------------------------------------------------------
# finding / baseline engine
# ---------------------------------------------------------------------------

BAD_SERVE = """\
LADDER_LAUNCH_SITES = frozenset()

class Svc:
    def sneak(self, lo):
        return kops.prune_ranges_batched_device(lo)
"""


class TestBaselineEngine:
    def _finding(self, pad_lines=0):
        src = ("\n" * pad_lines) + BAD_SERVE
        (f,) = [f for f in lint("src/repro/serve/svc.py", src)
                if f.rule == "CL001"]
        return f

    def test_baseline_suppresses_matching_finding(self):
        f = self._finding()
        bl = Baseline([dict(rule=f.rule, path=f.path, context=f.context,
                            snippet=f.snippet, justification="test")])
        new, accepted = bl.split([f])
        assert not new and accepted == [f]

    def test_baseline_match_is_line_number_independent(self):
        f = self._finding()
        shifted = self._finding(pad_lines=7)
        assert shifted.line != f.line
        bl = Baseline(Baseline.seed([f], justification="test"))
        new, accepted = bl.split([shifted])
        assert not new and accepted == [shifted]

    def test_edited_snippet_resurfaces(self):
        f = self._finding()
        entry = Baseline.seed([f], justification="test")[0]
        entry["snippet"] = entry["snippet"].replace("lo", "hi")
        new, _ = Baseline([entry]).split([f])
        assert new == [f]

    def test_justification_required(self, tmp_path):
        p = tmp_path / "baseline.json"
        p.write_text(json.dumps({"findings": [
            dict(rule="CL001", path="x.py", context="c", snippet="s")]}))
        try:
            Baseline.load(p)
        except ValueError as exc:
            assert "justification" in str(exc)
        else:
            raise AssertionError("missing justification accepted")

    def test_stale_entries_reported(self):
        bl = Baseline([dict(rule="CL001", path="gone.py", context="c",
                            snippet="s", justification="old")])
        assert bl.unused([]) == [dict(rule="CL001", path="gone.py",
                                      context="c", snippet="s",
                                      justification="old")]


class TestCli:
    def _tree(self, tmp_path):
        serve = tmp_path / "src" / "repro" / "serve"
        serve.mkdir(parents=True)
        (serve / "svc.py").write_text(BAD_SERVE)
        return tmp_path

    def test_exit_one_on_new_finding_and_json_artifact(self, tmp_path,
                                                       monkeypatch, capsys):
        root = self._tree(tmp_path)
        monkeypatch.chdir(root)
        out = root / "findings.json"
        assert lint_main(["src/", "--json", str(out)]) == 1
        report = json.loads(out.read_text())
        assert report["new"] and report["new"][0]["rule"] == "CL001"
        assert "CL001" in capsys.readouterr().out

    def test_exit_zero_with_baseline(self, tmp_path, monkeypatch):
        root = self._tree(tmp_path)
        monkeypatch.chdir(root)
        bl = root / "baseline.json"
        assert lint_main(["src/", "--write-baseline", str(bl)]) == 0
        data = json.loads(bl.read_text())
        for e in data["findings"]:
            e["justification"] = "accepted for test"
        bl.write_text(json.dumps(data))
        assert lint_main(["src/", "--baseline", str(bl)]) == 0

    def test_select_restricts_rules(self, tmp_path, monkeypatch):
        root = self._tree(tmp_path)
        monkeypatch.chdir(root)
        assert lint_main(["src/", "--select", "CL004"]) == 0
        assert lint_main(["src/", "--select", "CL001"]) == 1

    def test_list_rules(self, capsys):
        assert lint_main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for rule in ("CL001", "CL002", "CL003", "CL004", "CL005", "CL006"):
            assert rule in out


class TestRealTreeClean:
    def test_repo_lints_clean_against_committed_baseline(self, monkeypatch):
        monkeypatch.chdir(REPO)
        assert lint_main(["src/", "--baseline",
                          "tools/contract_lint/baseline.json"]) == 0
