"""Fleet-scale parity: the memory-budgeted, partition-sharded plane.

The paper's 99.4% pruning win assumes min/max metadata stays *always hot*
across a fleet of thousands of tables — which only works if residency is
bounded.  This suite pins the contract of the ``PlaneMemoryManager`` +
sharded launch path:

  * **golden parity**: over many-table workloads with skewed table
    popularity and interleaved DML, the budgeted + partition-sharded
    engine's output is bit-identical to the unbounded unsharded engine
    and to the f64 host oracle, for every technique;
  * **eviction invariants**: pinned planes are never evicted mid-launch,
    the budget is never exceeded (except counter-pinned), and a
    re-staged evicted plane serves the table's *current* state — then
    resumes delta-replaying its log;
  * **atomicity**: getters' epoch check + plane read cannot race DML
    invalidation under the eviction path (the satellite-4 regression).

Sharded cases need a multi-device CPU mesh (tests/conftest.py forces 8
host devices; REPRO_CPU_DEVICES=0 opts out and the sharded cases skip).
"""

import threading

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

from repro.core import expr as E
from repro.core.device_stats import PlaneMemoryManager
from repro.core.flow import JoinSpec, PruningPipeline, Query, TableScanSpec
from repro.data.table import Table
from repro.serve.prune_service import PruningService

NDV_LIMIT = 8      # straddled by build sides: small -> distinct, big -> Bloom


def _plane_mesh_or_none():
    if len(jax.devices()) < 2:
        return None
    from repro.launch.mesh import make_plane_mesh
    return make_plane_mesh()


def _rows(rng, n):
    return {
        "k": rng.integers(0, 60, n).astype(np.int64),
        "v": rng.integers(-200, 1000, n).astype(np.int64),
        "g": rng.integers(0, 50, n).astype(np.int64),
    }


def build_fleet(n_tables, seed, rows=48, rows_per_partition=4):
    """``n_tables`` small fact tables + one shared dimension table."""
    rng = np.random.default_rng(seed)
    tables = [
        Table.build(f"t{i:03d}", _rows(rng, rows),
                    rows_per_partition=rows_per_partition,
                    nulls={"v": rng.random(rows) < 0.08})
        for i in range(n_tables)
    ]
    dim = Table.build("dim", {
        "a": rng.integers(0, 100, 40).astype(np.int64),
        "k": rng.integers(0, 60, 40).astype(np.int64),
    }, rows_per_partition=8)
    return tables, dim


def _zipf_weights(n, s=1.2):
    w = 1.0 / np.arange(1, n + 1) ** s
    return w / w.sum()


def fleet_queries(tables, dim, rng, n_queries):
    """Skewed-popularity workload mixing every technique family."""
    weights = _zipf_weights(len(tables))
    qs = []
    for _ in range(n_queries):
        t = tables[int(rng.choice(len(tables), p=weights))]
        lo = int(rng.integers(-100, 800))
        kind = int(rng.integers(0, 6))
        if kind == 0:      # filter (device fast path)
            qs.append(Query(scans={t.name: TableScanSpec(
                t, (E.col("v") >= lo) & (E.col("v") <= lo + 300))}))
        elif kind == 1:    # filter with NOT -> host-fallback shape
            qs.append(Query(scans={t.name: TableScanSpec(
                t, E.Not(E.col("v") > lo) | (E.col("g") == 7))}))
        elif kind == 2:    # plain LIMIT
            qs.append(Query(scans={t.name: TableScanSpec(
                t, E.col("v") >= lo)}, limit=int(rng.integers(1, 10))))
        elif kind == 3:    # top-k (block-top-k plane)
            qs.append(Query(scans={t.name: TableScanSpec(
                t, E.col("v") >= -150)}, limit=int(rng.integers(1, 6)),
                order_by=(t.name, "v", bool(rng.integers(0, 2)))))
        elif kind == 4:    # join, small build -> distinct summary
            a_lo = int(rng.integers(0, 85))
            qs.append(Query(
                scans={t.name: TableScanSpec(t),
                       "dim": TableScanSpec(dim, (E.col("a") >= a_lo)
                                            & (E.col("a") <= a_lo + 8))},
                join=JoinSpec("dim", t.name, "k", "k")))
        else:              # join, full build -> Bloom summary
            qs.append(Query(
                scans={t.name: TableScanSpec(t, E.col("v") >= lo - 300),
                       "dim": TableScanSpec(dim)},
                join=JoinSpec("dim", t.name, "k", "k")))
    return qs


def warm_queries(tables, dim):
    """One query per technique per table: stages every plane family —
    the unbounded working set whose resident bytes size the budget."""
    qs = []
    for t in tables:
        qs.append(Query(scans={t.name: TableScanSpec(
            t, (E.col("v") >= 0) & (E.col("v") <= 500))}))
        qs.append(Query(scans={t.name: TableScanSpec(t, E.col("v") >= -150)},
                        limit=3, order_by=(t.name, "v", True)))
        qs.append(Query(
            scans={t.name: TableScanSpec(t), "dim": TableScanSpec(dim)},
            join=JoinSpec("dim", t.name, "k", "k")))
    return qs


def measure_working_set(tables, dim):
    """Resident bytes after an unbounded warm pass over every table."""
    svc = PruningService(mode="ref")
    pipe = PruningPipeline(filter_mode="device", service=svc,
                           join_ndv_limit=NDV_LIMIT)
    svc.run_batch(warm_queries(tables, dim), pipe)
    return svc.cache.resident_bytes


def assert_reports_equal(qs, got, want, label):
    for qi, (a, b) in enumerate(zip(got, want)):
        for name in qs[qi].scans:
            np.testing.assert_array_equal(
                a.scan_sets[name].part_ids, b.scan_sets[name].part_ids,
                err_msg=f"{label}: q={qi} scan={name} part_ids")
            np.testing.assert_array_equal(
                a.scan_sets[name].match, b.scan_sets[name].match,
                err_msg=f"{label}: q={qi} scan={name} match")
        assert (a.topk is None) == (b.topk is None), \
            f"{label}: q={qi} topk presence differs"
        if a.topk is not None:
            np.testing.assert_array_equal(a.topk.values, b.topk.values,
                                          err_msg=f"{label}: q={qi} topk")
            np.testing.assert_array_equal(a.topk.skipped, b.topk.skipped,
                                          err_msg=f"{label}: q={qi} skipped")


class TestGoldenFleetParity:
    """budgeted + sharded == unbounded unsharded == host oracle."""

    def test_acceptance_64_tables_25pct_budget(self):
        """The PR's acceptance cell: 64 tables, budget = 25% of the
        working set, skewed popularity — outputs bit-identical, the
        memory counters show evictions, and the budget holds."""
        tables, dim = build_fleet(64, seed=11)
        ws = measure_working_set(tables, dim)
        budget = int(ws * 0.25)
        mesh = _plane_mesh_or_none()

        unbounded = PruningService(mode="ref")
        pipe_u = PruningPipeline(filter_mode="device", service=unbounded,
                                 join_ndv_limit=NDV_LIMIT)
        budgeted = PruningService(mode="ref", budget_bytes=budget,
                                  shard_mesh=mesh)
        pipe_b = PruningPipeline(filter_mode="device", service=budgeted,
                                 join_ndv_limit=NDV_LIMIT)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)

        rng = np.random.default_rng(5)
        # Round 0 sweeps every table (the fleet's full working set — 4x
        # the budget, so the LRU must churn), then skewed rounds model
        # the shifting-popularity steady state.
        batches = [warm_queries(tables, dim)] + [
            fleet_queries(tables, dim, rng, 16) for _ in range(2)]
        reps_b = budgeted.run_fleet(batches, pipe_b)
        reps_u = unbounded.run_fleet(batches, pipe_u)
        for rnd, (qs, rb, ru) in enumerate(zip(batches, reps_b, reps_u)):
            assert_reports_equal(qs, rb, ru,
                                 f"round {rnd} budgeted-vs-unbounded")
            rh = [host.run(q) for q in qs]
            assert_reports_equal(qs, rb, rh, f"round {rnd} budgeted-vs-host")

        mem = budgeted.cache.memory
        assert mem.evictions > 0, "25% budget over 64 tables must evict"
        assert mem.peak_bytes <= budget, "budget exceeded"
        assert mem.over_budget_events == 0 and mem.pin_denied == 0
        assert mem.bytes_in_use == budgeted.cache.resident_bytes
        # the per-batch report counters surface the same story
        last = reps_b[-1][0].counters["memory"]
        assert last["budget_bytes"] == budget
        assert last["bytes_in_use"] <= budget
        if mesh is not None:
            assert budgeted.counters.sharded_launches > 0
            assert unbounded.counters.sharded_launches == 0

    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 2 ** 31),
           n_tables=st.integers(3, 6),
           budget_frac=st.sampled_from([0.2, 0.35, 0.5]),
           dml=st.lists(st.sampled_from(
               ["append", "drop", "rewrite", "update"]),
               min_size=1, max_size=3))
    def test_skewed_workload_with_dml(self, seed, n_tables, budget_frac,
                                      dml):
        """Rounds of skewed queries with DML interleaved: parity holds
        whether a touched table's planes were delta-synced (resident) or
        re-staged from scratch (evicted)."""
        rng = np.random.default_rng(seed)
        tables, dim = build_fleet(n_tables, seed)
        ws = measure_working_set(tables, dim)
        budget = max(1, int(ws * budget_frac))
        mesh = _plane_mesh_or_none()

        budgeted = PruningService(mode="ref", budget_bytes=budget,
                                  shard_mesh=mesh)
        pipe_b = PruningPipeline(filter_mode="device", service=budgeted,
                                 join_ndv_limit=NDV_LIMIT)
        unbounded = PruningService(mode="ref")
        pipe_u = PruningPipeline(filter_mode="device", service=unbounded,
                                 join_ndv_limit=NDV_LIMIT)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)

        for rnd, op in enumerate(["noop"] + list(dml)):
            t = tables[int(rng.integers(0, len(tables)))]
            if op == "append":
                n = int(rng.integers(4, 16))
                t.append_partitions(_rows(rng, n),
                                    nulls={"v": rng.random(n) < 0.08},
                                    rows_per_partition=4)
            elif op == "drop":
                live = np.where(t.live_mask)[0]
                if live.size > 2:
                    t.drop_partitions(rng.choice(live, size=1))
            elif op == "rewrite":
                live = np.where(t.live_mask)[0]
                pid = int(live[rng.integers(0, live.size)])
                n = int(np.diff(t.part_bounds)[pid])
                t.rewrite_partitions([pid], _rows(rng, n))
            elif op == "update":
                t.update_column("g", rng.integers(0, 40, t.num_rows)
                                .astype(np.int64))
            qs = fleet_queries(tables, dim, rng, 10)
            rb = budgeted.run_batch(qs, pipe_b)
            ru = unbounded.run_batch(qs, pipe_u)
            rh = [host.run(q) for q in qs]
            assert_reports_equal(qs, rb, ru,
                                 f"round {rnd} ({op}) budgeted-vs-unbounded")
            assert_reports_equal(qs, rb, rh,
                                 f"round {rnd} ({op}) budgeted-vs-host")
            mem = budgeted.cache.memory
            assert mem.bytes_in_use == budgeted.cache.resident_bytes
            assert mem.peak_bytes <= budget or mem.over_budget_events > 0


class TestEvictionInvariants:
    def test_manager_never_evicts_pinned(self):
        mgr = PlaneMemoryManager(budget_bytes=100)
        evicted = []
        mgr.bind(lambda fam, key: evicted.append((fam, key)))
        mgr.admit("stat", ("a",), 60)
        assert mgr.pin("stat", ("a",))
        mgr.admit("stat", ("b",), 60)       # only unpinned candidate is b's
        assert ("stat", ("a",)) not in evicted
        assert mgr.pin_denied == 1 and mgr.over_budget_events == 1
        assert mgr.bytes_in_use == 120      # pinned overflow, accounted
        mgr.unpin("stat", ("a",))
        mgr.admit("stat", ("c",), 50)       # now a (LRU) and b both go
        assert evicted == [("stat", ("a",)), ("stat", ("b",))]
        assert mgr.bytes_in_use == 50 <= 100
        assert mgr.evictions == 2

    def test_restage_storm_counter(self):
        mgr = PlaneMemoryManager(budget_bytes=100)
        mgr.bind(lambda fam, key: None)
        mgr.admit("stat", ("a",), 80)
        mgr.admit("stat", ("b",), 80)       # evicts a
        assert mgr.restage_storms == 0
        mgr.admit("stat", ("a",), 80)       # a returns: thrash
        assert mgr.restage_storms == 1

    def test_unbudgeted_manager_never_evicts(self):
        mgr = PlaneMemoryManager()
        mgr.bind(lambda fam, key: pytest.fail("evicted without a budget"))
        for i in range(50):
            mgr.admit("stat", (i,), 1 << 20)
        assert mgr.evictions == 0
        assert mgr.bytes_in_use == 50 << 20

    def test_pinned_planes_survive_launch_pressure(self):
        """A plane acquired inside a pin scope stays resident while the
        scope is open even when admitting another table would otherwise
        evict it — and goes first once the scope closes."""
        tables, dim = build_fleet(2, seed=3)
        a, b = tables
        # verdict-cache off: a repeat of q(b) must re-stage b's stat
        # plane (a verdict hit would serve without touching the budget)
        svc = PruningService(mode="ref", verdict_cache=False)
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        q = lambda t: Query(scans={t.name: TableScanSpec(  # noqa: E731
            t, (E.col("v") >= 0) & (E.col("v") <= 400))})
        svc.run_batch([q(a)], pipe)
        a_bytes = svc.cache.resident_bytes
        svc.cache.memory.budget_bytes = int(a_bytes * 1.5)  # < a + b

        key_a = (a.name, a.stats.uid)
        with svc.cache.pin_scope():
            svc.cache.get(a)                 # pin a's stat plane
            svc.run_batch([q(b)], pipe)      # b's staging wants a's bytes
            assert key_a in svc.cache.entries, "pinned plane evicted"
            assert svc.cache.memory.pin_denied >= 1
        svc.run_batch([q(b), q(b)], pipe)    # scope closed: a is fair game
        assert key_a not in svc.cache.entries
        assert svc.cache.memory.evictions >= 1
        mem = svc.cache.memory
        assert mem.bytes_in_use == svc.cache.resident_bytes

    def test_evicted_plane_restages_current_state_then_deltas(self):
        """An evicted plane must come back reflecting the table's current
        version (DML that happened while it was cold included), and the
        delta log must resume replaying afterwards — never stale bounds,
        never a permanent full-restage regime."""
        tables, dim = build_fleet(2, seed=7)
        a, b = tables
        rng = np.random.default_rng(7)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)

        svc = PruningService(mode="ref")
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        q = lambda t, lo: Query(scans={t.name: TableScanSpec(  # noqa: E731
            t, (E.col("v") >= lo) & (E.col("v") <= lo + 350))})
        svc.run_batch([q(a, 0)], pipe)
        a_bytes = svc.cache.resident_bytes
        svc.cache.memory.budget_bytes = int(a_bytes * 1.5)

        svc.run_batch([q(b, 0)], pipe)       # evicts a's planes
        assert (a.name, a.stats.uid) not in svc.cache.entries
        # DML lands while a is cold
        a.append_partitions(_rows(rng, 8), rows_per_partition=4)
        a.drop_partitions([1])
        qs = [q(a, 100)]
        got = svc.run_batch(qs, pipe)
        assert_reports_equal(qs, got, [host.run(qq) for qq in qs],
                             "post-eviction restage")
        assert svc.cache.memory.restage_storms >= 1
        # With pressure off (the appended partitions grew a's plane past
        # the old budget), the re-staged plane resumes delta-replaying
        # its log: the next append is O(ΔP), never a full restage.
        svc.cache.memory.budget_bytes = None
        svc.run_batch(qs, pipe)                  # ensure resident
        a.append_partitions(_rows(rng, 4), rows_per_partition=4)
        staging = svc.run_batch(qs, pipe)[0].counters["staging"]
        assert staging["full_restages"] == 0
        assert staging["delta_stages"] >= 1

    def test_nested_equal_pin_scopes_unwind_by_identity(self):
        """A nested scope whose frame is equal-by-content to the outer
        one (same single plane pinned) must pop ITS OWN frame — an
        equality-based removal popped the outer frame instead, leaked
        its pins forever, and raised on the outer exit."""
        tables, _dim = build_fleet(1, seed=4)
        a = tables[0]
        svc = PruningService(mode="ref", budget_bytes=1 << 20)
        cache = svc.cache
        with cache.pin_scope():
            cache.get(a)
            with cache.pin_scope():
                cache.get(a)             # frame == outer frame by content
            cache.get(a)                 # must land in the OUTER frame
        assert cache.memory.pinned_bytes == 0
        key = (a.name, a.stats.uid)
        assert cache.memory._resident[("stat", key)].pins == 0

    def test_oversized_plane_counts_over_budget_not_pin_denied(self):
        """A plane larger than the whole budget is an over-budget event,
        not pin pressure — and admitting it neither flushes the rest of
        the fleet (pointless) nor survives the next reclaim."""
        mgr = PlaneMemoryManager(budget_bytes=100)
        evicted = []
        mgr.bind(lambda fam, key: evicted.append(key))
        mgr.admit("stat", ("a",), 40)
        mgr.admit("stat", ("b",), 40)
        mgr.admit("stat", ("huge",), 150)
        assert mgr.over_budget_events == 1 and mgr.pin_denied == 0
        assert evicted == []                 # no collateral fleet flush
        mgr.reclaim()                        # pin-scope exit
        assert evicted == [("huge",)]        # the unfittable plane goes first
        assert mgr.bytes_in_use == 80

    def test_release_parks_pins_as_debt(self):
        """An invalidate that drops a pinned record must not let the
        pinning scope's later unpin strip a DIFFERENT scope's pin on a
        re-admitted record under the same key (which would allow a
        mid-launch eviction)."""
        mgr = PlaneMemoryManager(budget_bytes=100)
        mgr.bind(lambda fam, key: None)
        mgr.admit("stat", ("x",), 10)
        assert mgr.pin("stat", ("x",))          # scope A pins
        mgr.release("stat", ("x",))             # DML invalidate mid-scope
        mgr.admit("stat", ("x",), 10)           # scope B restages...
        assert mgr.pin("stat", ("x",))          # ...and pins the fresh record
        mgr.unpin("stat", ("x",))               # scope A exits: consumes debt
        assert mgr._resident[("stat", ("x",))].pins == 1   # B's pin intact
        mgr.unpin("stat", ("x",))               # scope B exits
        assert mgr._resident[("stat", ("x",))].pins == 0
        assert not mgr._orphan_pins

    def test_flow_rejects_budget_args_with_explicit_service(self):
        svc = PruningService(mode="ref")
        with pytest.raises(ValueError):
            PruningPipeline(filter_mode="device", service=svc,
                            budget_bytes=1 << 20)
        with pytest.raises(ValueError):
            PruningPipeline(filter_mode="device", service=svc,
                            shard_planes=True)

    def test_budget_counter_pinned_in_reports(self):
        """counters['memory'] carries the per-batch delta + gauges."""
        tables, dim = build_fleet(6, seed=9)
        ws = measure_working_set(tables, dim)
        svc = PruningService(mode="ref", budget_bytes=int(ws * 0.3))
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        rng = np.random.default_rng(2)
        last = None
        for _ in range(3):
            last = svc.run_batch(fleet_queries(tables, dim, rng, 12), pipe)
        mem = last[0].counters["memory"]
        for k in PlaneMemoryManager.MONOTONIC + PlaneMemoryManager.GAUGES:
            assert k in mem
        assert mem["budget_bytes"] == int(ws * 0.3)
        assert mem["bytes_in_use"] <= mem["budget_bytes"]
        assert svc.cache.memory.evictions > 0


class TestGetterAtomicity:
    """Satellite 4: epoch check + plane read are atomic per getter."""

    def test_concurrent_getters_vs_invalidation(self):
        tables, dim = build_fleet(3, seed=13)
        svc = PruningService(mode="ref", budget_bytes=1 << 20)
        cache = svc.cache
        errors = []
        stop = threading.Event()

        def reader(t):
            try:
                while not stop.is_set():
                    e = cache.get(t)
                    # the read the epoch check must cover: a stale entry
                    # handed out mid-invalidate would mix versions
                    assert e.mins.shape[0] == len(t.stats.columns)
                    cache.join_key_plane(t, "k")
                    cache.block_topk_plane(t, "v", True)
            except Exception as exc:        # pragma: no cover - regression
                errors.append(exc)

        def invalidator():
            try:
                for i in range(200):
                    cache.on_update(tables[i % 3].name, "v")
                    cache.invalidate(tables[(i + 1) % 3].name)
            except Exception as exc:        # pragma: no cover - regression
                errors.append(exc)
            finally:
                stop.set()

        threads = [threading.Thread(target=reader, args=(t,))
                   for t in tables for _ in range(2)]
        threads.append(threading.Thread(target=invalidator))
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=60)
        assert not errors, errors
        # accounting stayed atomic: manager bytes == store truth
        assert cache.memory.bytes_in_use == cache.resident_bytes
        assert cache.memory.pinned_bytes == 0


class TestShardedLaunches:
    def test_sharded_engine_runs_and_counts(self):
        mesh = _plane_mesh_or_none()
        if mesh is None:
            pytest.skip("needs >= 2 host devices (REPRO_CPU_DEVICES)")
        tables, dim = build_fleet(3, seed=21)
        svc = PruningService(mode="ref", shard_mesh=mesh)
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        rng = np.random.default_rng(0)
        qs = fleet_queries(tables, dim, rng, 16) + warm_queries(tables, dim)
        reps = svc.run_batch(qs, pipe)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)
        assert_reports_equal(qs, reps, [host.run(q) for q in qs],
                             "sharded-vs-host")
        assert svc.counters.sharded_launches > 0
        assert reps[0].counters["sharded_launches"] > 0

    def test_flow_level_budget_and_shard_args(self):
        """PruningPipeline builds its lazy service budgeted + sharded."""
        tables, dim = build_fleet(2, seed=22)
        pipe = PruningPipeline(filter_mode="device", budget_bytes=1 << 20,
                               shard_planes=len(jax.devices()) > 1,
                               join_ndv_limit=NDV_LIMIT)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)
        rng = np.random.default_rng(1)
        for q in fleet_queries(tables, dim, rng, 8):
            got = pipe.run(q)
            want = host.run(q)
            assert_reports_equal([q], [got], [want], "flow-level")
        svc = pipe.device_service()
        assert svc.cache.memory.budget_bytes == 1 << 20
        if len(jax.devices()) > 1:
            assert svc.counters.sharded_launches > 0
