"""JOIN pruning (paper Sec. 6): probabilistic but never incorrect."""

import warnings

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.metadata import ScanSet
from repro.core.prune_join import (BlockedBloom, prune_probe, summarize_build)
from repro.data.table import Table


class TestBlockedBloom:
    @settings(max_examples=60, deadline=None)
    @given(keys=st.lists(st.integers(-2**40, 2**40), min_size=1, max_size=300))
    def test_no_false_negatives(self, keys):
        keys = np.asarray(keys, dtype=np.int64)
        bloom = BlockedBloom(len(keys))
        bloom.add(keys)
        assert bloom.contains(keys).all()

    def test_false_positive_rate_reasonable(self):
        rng = np.random.default_rng(0)
        keys = rng.choice(2**40, size=10_000, replace=False)
        bloom = BlockedBloom(len(keys), bits_per_key=16)
        bloom.add(keys)
        probe = rng.choice(2**40, size=50_000, replace=False)
        probe = probe[~np.isin(probe, keys)]
        fpr = bloom.contains(probe).mean()
        assert fpr < 0.01, f"blocked bloom fpr {fpr:.4f} too high"

    def test_size_bounded(self):
        bloom = BlockedBloom(100_000, bits_per_key=16)
        assert bloom.size_bytes <= 100_000 * 4  # ~2 bytes/key at 16 bits


class TestBuildSummary:
    def test_small_ndv_uses_distinct(self):
        s = summarize_build(np.array([1, 2, 3, 2, 1]), ndv_limit=10)
        assert s.distinct is not None and s.bloom is None
        assert s.min == 1 and s.max == 3

    def test_large_ndv_uses_bloom(self):
        s = summarize_build(np.arange(10_000), ndv_limit=100)
        assert s.bloom is not None and s.distinct is None
        # summary stays a small fraction of the build side (Sec. 6.1)
        assert s.size_bytes < 10_000 * 8 * 0.5

    def test_nulls_excluded(self):
        s = summarize_build(np.array([1, 2, 3]), null_mask=np.array([False, True, False]))
        assert s.count == 2 and s.max == 3

    def test_empty_build_distinct_keeps_key_dtype(self):
        """Regression: the empty distinct set used to be a float64
        np.zeros(0) regardless of the key domain."""
        s = summarize_build(np.zeros(0, dtype=np.int64))
        assert s.empty and s.distinct.dtype == np.int64
        s = summarize_build(np.array([1, 2]), null_mask=np.array([True, True]))
        assert s.empty and s.distinct.dtype == np.int64


def _probe_table(vals, rows_per_partition=4):
    return Table.build("probe", {"k": np.asarray(vals, dtype=np.int64)},
                       rows_per_partition=rows_per_partition)


class TestProbePruning:
    def test_range_pruning(self):
        tbl = _probe_table(np.arange(40))          # partitions of 4: [0..3],[4..7]...
        summary = summarize_build(np.array([9, 10, 11]))
        res = prune_probe(ScanSet.full(tbl.num_partitions), tbl.stats, "k", summary)
        kept = set(res.scan.part_ids.tolist())
        assert kept == {2}  # only partition [8..11] overlaps
        assert res.pruned_by_range + res.pruned_by_distinct == 9

    def test_distinct_pruning_beats_range(self):
        # build keys {0, 39}: range overlap keeps everything, distinct kills middle
        tbl = _probe_table(np.arange(40))
        summary = summarize_build(np.array([0, 39]))
        res = prune_probe(ScanSet.full(tbl.num_partitions), tbl.stats, "k", summary)
        kept = set(res.scan.part_ids.tolist())
        assert kept == {0, 9}
        assert res.pruned_by_distinct == 8

    def test_bloom_pruning_narrow_partitions(self):
        rng = np.random.default_rng(1)
        build = rng.choice(1_000_000, size=20_000, replace=False)
        tbl = _probe_table(np.arange(2_000_000, 2_000_400))  # disjoint from build
        summary = summarize_build(build, ndv_limit=100)      # force bloom
        assert summary.bloom is not None
        res = prune_probe(ScanSet.full(tbl.num_partitions), tbl.stats, "k", summary)
        assert len(res.scan) == 0  # range check already removes everything
        # now overlapping but sparse probe values -> bloom must do the work
        tbl2 = _probe_table(np.arange(500_000, 500_400))
        res2 = prune_probe(ScanSet.full(tbl2.num_partitions), tbl2.stats, "k", summary)
        # partitions whose 4-value ranges miss every build key get pruned
        assert res2.pruned_by_bloom > 0 or len(res2.scan) < tbl2.num_partitions

    def test_empty_build_removes_probe_scan(self):
        tbl = _probe_table(np.arange(40))
        summary = summarize_build(np.zeros(0, dtype=np.int64))
        res = prune_probe(ScanSet.full(tbl.num_partitions), tbl.stats, "k", summary)
        assert len(res.scan) == 0  # the paper's 100%-pruned case

    def test_fractional_probe_range_not_falsely_pruned(self):
        """Regression (ISSUE 3): on a float key column the narrow-range
        enumeration probed only integer offsets from pmin — for the range
        [0.6, 1.4] it tested the single candidate trunc(0.6) = 0 and
        falsely pruned the partition containing the joinable key 1.2.
        Float columns must skip enumeration entirely (skip = keep)."""
        tbl = Table.build("probe", {"k": np.array([0.6, 1.4])},
                          rows_per_partition=2)
        assert tbl.stats.column("k").kind == "float"
        build = np.array([1.2])
        summary = summarize_build(build, ndv_limit=0)       # force Bloom
        assert summary.bloom is not None
        # guard: the regression is only visible if 0 isn't a false positive
        assert not summary.bloom.contains(np.array([0])).any()
        res = prune_probe(ScanSet.full(tbl.num_partitions), tbl.stats, "k",
                          summary)
        assert 0 in res.scan.part_ids.tolist()
        assert res.pruned_by_bloom == 0

    @settings(max_examples=60, deadline=None)
    @given(
        build=st.lists(st.floats(-50, 50).map(lambda x: round(x * 4) / 4),
                       min_size=1, max_size=40),
        probe=st.lists(st.floats(-50, 50).map(lambda x: round(x * 4) / 4),
                       min_size=4, max_size=80),
    )
    def test_never_prunes_joinable_fractional_keys(self, build, probe):
        """Hypothesis regression for the float-domain enumeration bug:
        quarter-step keys (exact in binary, frequently joinable) through
        a forced Bloom summary must never lose a joinable partition."""
        build = np.asarray(build, dtype=np.float64)
        tbl = Table.build("probe", {"k": np.asarray(probe, np.float64)},
                          rows_per_partition=4)
        summary = summarize_build(build, ndv_limit=0)       # force Bloom
        res = prune_probe(ScanSet.full(tbl.num_partitions), tbl.stats, "k",
                          summary)
        kept = set(res.scan.part_ids.tolist())
        for p in range(tbl.num_partitions):
            v, _ = tbl.partition_ctx(p).col("k")
            if np.isin(v, build).any():
                assert p in kept, f"pruned joinable partition {p}"

    def test_extreme_int64_range_width_does_not_overflow(self):
        """Regression (ISSUE 3): width = (pmax - pmin + 1).astype(int64)
        overflowed for int64-extreme ranges (numpy warns/raises on the
        invalid cast).  Width is now compared in float64 first — such
        partitions simply aren't narrow and must be kept."""
        vals = np.array([-2**62, 2**62], dtype=np.int64)
        tbl = Table.build("probe", {"k": vals}, rows_per_partition=2)
        summary = summarize_build(np.arange(5000, dtype=np.int64),
                                  ndv_limit=100)            # force Bloom
        assert summary.bloom is not None
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            res = prune_probe(ScanSet.full(tbl.num_partitions), tbl.stats,
                              "k", summary)
        assert 0 in res.scan.part_ids.tolist()              # range overlaps

    @settings(max_examples=80, deadline=None)
    @given(
        build=st.lists(st.integers(0, 500), min_size=0, max_size=80),
        probe=st.lists(st.integers(0, 500), min_size=4, max_size=200),
        ndv_limit=st.sampled_from([2, 4096]),
    )
    def test_never_prunes_joinable_partition(self, build, probe, ndv_limit):
        """The Sec. 6.2 guarantee: may miss prunable partitions, but never
        prunes one containing a joinable key."""
        build = np.asarray(build, dtype=np.int64)
        tbl = _probe_table(probe)
        summary = summarize_build(build, ndv_limit=ndv_limit)
        res = prune_probe(ScanSet.full(tbl.num_partitions), tbl.stats, "k", summary)
        kept = set(res.scan.part_ids.tolist())
        for p in range(tbl.num_partitions):
            ctx = tbl.partition_ctx(p)
            v, _ = ctx.col("k")
            if np.isin(v, build).any():
                assert p in kept, f"pruned joinable partition {p}"
