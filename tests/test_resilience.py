"""Chaos suite: the pruning service fails prune-less, never wrong.

The resilience layer (PR 6) turns pruning's safe-degraded-answer
property into machinery: a ``DegradationLadder`` that demotes a failing
launch down an ordered rung chain (sharded device -> device -> host
kernel -> host oracle -> no-prune passthrough), a checksum-stamped
plane-integrity protocol in ``DeviceStatsCache``, and a ``FaultInjector``
seam threaded through staging / eviction / getters / launches.  This
suite pins three contracts:

  * **never raise**: ``run_batch`` / ``run_fleet`` return a report per
    query under any injected fault schedule (errors, delays, torn
    planes, eviction faults) interleaved with DML and budget pressure;
  * **never wrong**: every scan set is a superset of the host oracle's
    (a kept partition is always safe), and is *bit-identical* whenever
    the ladder stopped at or above the host-oracle rung (no
    passthroughs, no isolated query errors in the batch's counters);
  * **deterministic timing**: retry/backoff/deadline arithmetic runs
    under an injectable clock — no test ever really sleeps.

Plus the two satellite regressions: ``pin_scope`` exception safety
(zero leaked pins even when eviction callbacks raise mid-cleanup) and
per-query error isolation of malformed specs.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import expr as E
from repro.core.device_stats import (DeviceStatsCache, PlaneIntegrityError,
                                     plane_checksum)
from repro.core.flow import PruningPipeline, Query, TableScanSpec
from repro.serve.prune_service import PruningService
from repro.serve.resilience import (RUNGS, BackoffPolicy, DegradationLadder,
                                    FaultInjector, InjectedFault,
                                    new_resilience_counters)

from test_fleet_parity import (NDV_LIMIT, _plane_mesh_or_none, _rows,
                               assert_reports_equal, build_fleet,
                               fleet_queries, measure_working_set)

NO_SLEEP = lambda d: None  # noqa: E731


class FakeClock:
    """Monotonic clock + sleep pair: sleeping advances the clock."""

    def __init__(self):
        self.t = 0.0
        self.sleeps = []

    def __call__(self):
        return self.t

    def sleep(self, d):
        self.sleeps.append(d)
        self.t += d


def _filter_query(t, lo=0, hi=400):
    return Query(scans={t.name: TableScanSpec(
        t, (E.col("v") >= lo) & (E.col("v") <= hi))})


def assert_scan_superset(qs, got, want, label):
    """Every kept partition of the oracle is kept by the resilient run."""
    for qi, (a, b) in enumerate(zip(got, want)):
        for name in qs[qi].scans:
            dropped = np.setdiff1d(b.scan_sets[name].part_ids,
                                   a.scan_sets[name].part_ids)
            assert dropped.size == 0, \
                f"{label}: q={qi} scan={name} lost partitions {dropped}"


def assert_scan_parity(qs, got, want, label):
    """ids + three-valued match bit-identical (the exact-rung promise)."""
    for qi, (a, b) in enumerate(zip(got, want)):
        for name in qs[qi].scans:
            np.testing.assert_array_equal(
                a.scan_sets[name].part_ids, b.scan_sets[name].part_ids,
                err_msg=f"{label}: q={qi} scan={name} part_ids")
            np.testing.assert_array_equal(
                a.scan_sets[name].match, b.scan_sets[name].match,
                err_msg=f"{label}: q={qi} scan={name} match")


def _apply_dml(op, tables, rng):
    t = tables[int(rng.integers(0, len(tables)))]
    if op == "append":
        n = int(rng.integers(4, 16))
        t.append_partitions(_rows(rng, n),
                            nulls={"v": rng.random(n) < 0.08},
                            rows_per_partition=4)
    elif op == "drop":
        live = np.where(t.live_mask)[0]
        if live.size > 2:
            t.drop_partitions(rng.choice(live, size=1))
    elif op == "rewrite":
        live = np.where(t.live_mask)[0]
        pid = int(live[rng.integers(0, live.size)])
        n = int(np.diff(t.part_bounds)[pid])
        t.rewrite_partitions([pid], _rows(rng, n))
    elif op == "update":
        t.update_column("g", rng.integers(0, 40, t.num_rows)
                        .astype(np.int64))


# ---------------------------------------------------------------------------
# BackoffPolicy: deterministic exponential schedule, no real time involved
# ---------------------------------------------------------------------------

class TestBackoffPolicy:
    def test_exponential_growth_then_cap(self):
        import random
        p = BackoffPolicy(base_delay=0.01, multiplier=2.0, max_delay=0.05)
        rng = random.Random(0)
        delays = [p.delay(i, rng) for i in range(5)]
        assert delays == [0.01, 0.02, 0.04, 0.05, 0.05]

    def test_jitter_bounded_and_deterministic_under_seed(self):
        import random
        p = BackoffPolicy(base_delay=0.01, multiplier=2.0, max_delay=1.0,
                          jitter=0.5)
        a = [p.delay(i, random.Random(7)) for i in range(6)]
        b = [p.delay(i, random.Random(7)) for i in range(6)]
        assert a == b, "same seed must replay the same jittered schedule"
        for i, d in enumerate(a):
            base = 0.01 * 2.0 ** i
            assert base <= d <= min(base * 1.5, 1.0) + 1e-12

    def test_jitter_never_exceeds_cap(self):
        import random
        p = BackoffPolicy(base_delay=0.2, multiplier=2.0, max_delay=0.25,
                          jitter=1.0)
        rng = random.Random(3)
        assert all(p.delay(i, rng) <= 0.25 for i in range(10))


# ---------------------------------------------------------------------------
# DegradationLadder: retry counts, demotion attribution, deadlines
# ---------------------------------------------------------------------------

class TestDegradationLadder:
    def _ladder(self, **kw):
        clock = FakeClock()
        c = new_resilience_counters()
        lad = DegradationLadder(clock=clock, sleep=clock.sleep,
                                counters=c, **kw)
        return lad, clock, c

    def test_first_rung_success_touches_nothing(self):
        lad, clock, c = self._ladder()
        result, rung = lad.execute([("sharded", lambda: 42),
                                    ("device", lambda: 0)])
        assert (result, rung) == (42, "sharded")
        assert c["retries"] == 0 and c["deadline_hits"] == 0
        assert not any(c["demotions"].values()) and not clock.sleeps

    def test_retries_then_demotes_with_backoff_sleeps(self):
        lad, clock, c = self._ladder(
            policy=BackoffPolicy(retries=2, base_delay=1.0, multiplier=2.0,
                                 max_delay=8.0))

        def bad():
            raise RuntimeError("kernel down")

        result, rung = lad.execute([("device", bad), ("host_kernel",
                                                      lambda: "host")])
        assert (result, rung) == ("host", "host_kernel")
        assert c["retries"] == 2           # two re-attempts on the rung
        assert clock.sleeps == [1.0, 2.0]  # deterministic exponential
        assert c["demotions"] == {"sharded_tree": 0, "tree": 0, "sharded": 0,
                                  "device": 0, "host_kernel": 1,
                                  "host_oracle": 0, "passthrough": 0}

    def test_deadline_refuses_to_sleep_into_expiry(self):
        # base delay alone exceeds the stage deadline: abandon the rung
        # (one deadline hit) without sleeping rather than sleeping past it
        lad, clock, c = self._ladder(
            policy=BackoffPolicy(retries=5, base_delay=10.0,
                                 max_delay=10.0),
            deadline_s=5.0)

        def bad():
            raise RuntimeError("down")

        result, rung = lad.execute([("device", bad), ("host_kernel",
                                                      lambda: 1)])
        assert rung == "host_kernel"
        assert c["deadline_hits"] == 1 and c["retries"] == 0
        assert clock.sleeps == []

    def test_deadline_expired_during_attempt(self):
        lad, clock, c = self._ladder(
            policy=BackoffPolicy(retries=5, base_delay=0.001),
            deadline_s=2.0)

        def slow_and_bad():
            clock.t += 3.0                  # the attempt itself blew it
            raise RuntimeError("slow")

        _, rung = lad.execute([("device", slow_and_bad),
                               ("host_oracle", lambda: 1)])
        assert rung == "host_oracle"
        assert c["deadline_hits"] == 1 and c["retries"] == 0

    def test_passthrough_counted(self):
        lad, _clock, c = self._ladder(policy=BackoffPolicy(retries=0))

        def bad():
            raise RuntimeError("down")

        _, rung = lad.execute([("device", bad), ("host_kernel", bad),
                               ("host_oracle", bad),
                               ("passthrough", lambda: None)])
        assert rung == "passthrough"
        assert c["passthroughs"] == 1
        assert c["demotions"]["host_kernel"] == 1
        assert c["demotions"]["host_oracle"] == 1
        assert c["demotions"]["passthrough"] == 1

    def test_all_rungs_failing_raises_last(self):
        lad, _clock, _c = self._ladder(policy=BackoffPolicy(retries=0))

        def bad():
            raise KeyError("no safe bottom")

        with pytest.raises(KeyError):
            lad.execute([("device", bad), ("host_kernel", bad)])

    def test_rung_order_matches_contract(self):
        assert RUNGS == ("verdict", "sharded_tree", "tree", "sharded",
                         "device", "host_kernel", "host_oracle",
                         "passthrough")


# ---------------------------------------------------------------------------
# FaultInjector: named sites, seeded schedules, torn-plane corruption
# ---------------------------------------------------------------------------

class TestFaultInjector:
    def test_prefix_and_exact_site_matching(self):
        inj = FaultInjector()
        inj.add("launch.filter")
        with pytest.raises(InjectedFault):
            inj.fire("launch.filter:sharded")     # prefix match
        with pytest.raises(InjectedFault):
            inj.fire("launch.filter")             # exact match
        inj.fire("launch.join:device")            # different site: silent
        inj.fire("stage.stat")

    def test_after_and_times_schedule(self):
        inj = FaultInjector()
        inj.add("get.stat", after=1, times=2)
        inj.fire("get.stat")                      # skipped (after=1)
        with pytest.raises(InjectedFault):
            inj.fire("get.stat")                  # fires 1/2
        with pytest.raises(InjectedFault):
            inj.fire("get.stat")                  # fires 2/2
        inj.fire("get.stat")                      # exhausted
        assert len(inj.log) == 2

    def test_prob_schedule_replays_under_fixed_seed(self):
        def run(seed):
            inj = FaultInjector(seed=seed)
            inj.add("evict", prob=0.5)
            hits = []
            for i in range(30):
                try:
                    inj.fire("evict")
                    hits.append(0)
                except InjectedFault:
                    hits.append(1)
            return hits

        assert run(11) == run(11), "fixed seed must replay the schedule"
        assert 0 < sum(run(11)) < 30

    def test_delay_kind_uses_injected_sleep(self):
        slept = []
        inj = FaultInjector(sleep=slept.append)
        inj.add("launch.topk", kind="delay", delay=0.5, times=2)
        inj.fire("launch.topk:device")
        inj.fire("launch.topk:device")
        assert slept == [0.5, 0.5]

    def test_custom_exception(self):
        inj = FaultInjector()
        inj.add("stage.stat", exc=TimeoutError("hbm"))
        with pytest.raises(TimeoutError):
            inj.fire("stage.stat")

    def test_corrupt_tears_bytes_but_keeps_shape(self):
        inj = FaultInjector(seed=0)
        inj.add("stage.stat", kind="corrupt", times=1)
        arrays = (np.arange(12, dtype=np.float32).reshape(3, 4),
                  np.arange(6, dtype=np.int8))
        stamp = plane_checksum(arrays)
        torn = inj.corrupt("stage.stat", arrays)
        assert all(a.shape == b.shape and a.dtype == b.dtype
                   for a, b in zip(arrays, torn))
        assert plane_checksum(torn) != stamp
        # schedule exhausted: next call passes arrays through untouched
        again = inj.corrupt("stage.stat", arrays)
        assert plane_checksum(again) == stamp

    def test_disabled_rules_do_not_match_other_kinds(self):
        inj = FaultInjector()
        inj.add("stage.stat", kind="corrupt")
        inj.fire("stage.stat")        # corrupt rules never raise via fire
        out = inj.corrupt("stage.join_key", (np.zeros(3),))
        assert plane_checksum(out) == plane_checksum((np.zeros(3),))


# ---------------------------------------------------------------------------
# Satellite 1: pin_scope exception safety
# ---------------------------------------------------------------------------

class TestPinScopeExceptionSafety:
    def test_body_exception_unpins_everything(self):
        tables, _dim = build_fleet(1, seed=5)
        a = tables[0]
        cache = DeviceStatsCache(budget_bytes=1 << 20)
        with pytest.raises(RuntimeError, match="boom"):
            with cache.pin_scope():
                cache.get(a)
                cache.join_key_plane(a, "k")
                raise RuntimeError("boom")
        assert cache.memory.pinned_bytes == 0
        assert cache.memory.bytes_in_use == cache.resident_bytes

    def test_unpin_failure_still_unpins_the_rest_and_reraises(self):
        """One raising unpin must not strand the frame's other pins."""
        tables, _dim = build_fleet(1, seed=6)
        a = tables[0]
        cache = DeviceStatsCache(budget_bytes=1 << 20)
        mgr = cache.memory
        orig = mgr.unpin
        tripped = []

        def flaky_unpin(family, key):
            orig(family, key)           # the pin itself is released...
            if not tripped:
                tripped.append(1)
                raise RuntimeError("cb")  # ...then bookkeeping blows up

        mgr.unpin = flaky_unpin
        try:
            with pytest.raises(RuntimeError, match="cb"):
                with cache.pin_scope():
                    cache.get(a)
                    cache.join_key_plane(a, "k")
                    cache.block_topk_plane(a, "v", True)
        finally:
            mgr.unpin = orig
        assert mgr.pinned_bytes == 0, "a raising unpin leaked other pins"
        assert not mgr._orphan_pins

    def test_eviction_fault_during_scope_exit_leaks_no_pins(self):
        """reclaim() at scope exit hits an eviction fault: the exception
        propagates, but every pin was already released and the cache /
        manager accounting agree (store entry popped before the fault
        seam fires)."""
        tables, _dim = build_fleet(2, seed=7)
        a, b = tables
        inj = FaultInjector()
        cache = DeviceStatsCache(fault_injector=inj)
        cache.get(a)
        cache.get(b)
        cache.memory.budget_bytes = cache.resident_bytes - 1  # must evict
        inj.add("evict", times=1)
        with pytest.raises(InjectedFault):
            with cache.pin_scope():
                cache.get(a)               # pin a; b is the LRU victim
        assert cache.memory.pinned_bytes == 0
        assert cache.memory.bytes_in_use == cache.resident_bytes
        # the cache recovers: next reclaim (no fault left) gets under
        # budget and serving continues
        cache.memory.reclaim()
        assert cache.memory.bytes_in_use <= cache.memory.budget_bytes
        cache.get(a)

    def test_nested_scope_inner_exception_spares_outer_pins(self):
        tables, _dim = build_fleet(1, seed=8)
        a = tables[0]
        cache = DeviceStatsCache(budget_bytes=1 << 20)
        key = (a.name, a.stats.uid)
        with cache.pin_scope():
            cache.get(a)
            with pytest.raises(RuntimeError):
                with cache.pin_scope():
                    cache.join_key_plane(a, "k")
                    raise RuntimeError("inner")
            # outer frame's pin still held: the stat plane can't evict
            assert cache.memory._resident[("stat", key)].pins == 1
        assert cache.memory.pinned_bytes == 0


# ---------------------------------------------------------------------------
# Plane integrity: stamp, sampled verify, quarantine, forced restage
# ---------------------------------------------------------------------------

class TestPlaneIntegrity:
    def test_clean_planes_verify_clean(self):
        tables, dim = build_fleet(2, seed=9)
        svc = PruningService(mode="ref", integrity_sample=1)
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        qs = fleet_queries(tables, dim, np.random.default_rng(0), 12)
        svc.run_batch(qs, pipe)
        integ = svc.cache.integrity
        assert integ["verifications"] > 0
        assert integ["checksum_failures"] == 0
        assert integ["quarantines"] == 0

    def test_torn_stage_quarantined_then_serves_truth(self):
        """One corrupt staging: the sampled verifier catches it before
        the first verdict, quarantines, and the forced restage serves
        the oracle's answer — a counter, not a wrong prune."""
        tables, _dim = build_fleet(1, seed=10)
        a = tables[0]
        inj = FaultInjector(seed=1)
        inj.add("stage.stat", kind="corrupt", times=1)
        svc = PruningService(mode="ref", fault_injector=inj,
                             integrity_sample=1)
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)
        qs = [_filter_query(a)]
        got = svc.run_batch(qs, pipe)
        assert_reports_equal(qs, got, [host.run(q) for q in qs], "torn")
        integ = svc.cache.integrity
        assert integ["checksum_failures"] == 1
        assert integ["quarantines"] == 1
        assert got[0].counters["integrity"]["quarantines"] == 1
        # resilience untouched: integrity healed below the ladder
        assert not any(got[0].counters["resilience"]["demotions"].values())

    def test_persistent_corruption_demotes_never_raises(self):
        """Every restage torn: the integrity protocol raises
        PlaneIntegrityError internally, the ladder demotes past the
        device rungs, and the batch still returns the exact answer."""
        tables, _dim = build_fleet(1, seed=11)
        a = tables[0]
        inj = FaultInjector(seed=2)
        inj.add("stage.stat", kind="corrupt")        # no times cap
        svc = PruningService(mode="ref", fault_injector=inj,
                             integrity_sample=1, sleep=NO_SLEEP)
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)
        qs = [_filter_query(a)]
        got = svc.run_batch(qs, pipe)
        assert_reports_equal(qs, got, [host.run(q) for q in qs],
                             "persistent-corruption")
        res = got[0].counters["resilience"]
        assert res["demotions"]["host_kernel"] >= 1
        assert res["passthroughs"] == 0
        assert svc.cache.integrity["quarantines"] >= 2

    def test_restage_after_eviction_always_verified(self):
        tables, _dim = build_fleet(2, seed=12)
        a, b = tables
        # default sampling (64): the forced check is what must fire
        cache = DeviceStatsCache()
        cache.get(a)
        cache.memory.budget_bytes = cache.resident_bytes
        cache.get(b)                                  # evicts a
        assert cache.memory.was_evicted("stat", (a.name, a.stats.uid))
        before = cache.integrity["verifications"]
        cache.memory.budget_bytes = None
        cache.get(a)                                  # restage: forced verify
        assert cache.integrity["verifications"] == before + 1
        assert cache.integrity["checksum_failures"] == 0

    def test_direct_checksum_roundtrip(self):
        arrays = (np.arange(10, dtype=np.float32), np.ones(4, np.int8))
        assert plane_checksum(arrays) == plane_checksum(
            tuple(np.array(a, copy=True) for a in arrays))
        other = (np.arange(10, dtype=np.float32) + 1, np.ones(4, np.int8))
        assert plane_checksum(arrays) != plane_checksum(other)


# ---------------------------------------------------------------------------
# Satellite 2: malformed queries isolate, the batch survives
# ---------------------------------------------------------------------------

class TestQueryErrorIsolation:
    def test_bad_column_is_isolated_to_passthrough(self):
        tables, _dim = build_fleet(2, seed=13)
        a, b = tables
        svc = PruningService(mode="ref")
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)
        good = _filter_query(a)
        bad = Query(scans={b.name: TableScanSpec(b, E.col("nope") > 3)})
        reps = svc.run_batch([good, bad, _filter_query(b, 100, 700)], pipe)
        assert len(reps) == 3
        res = reps[0].counters["resilience"]
        assert res["errors"] == 1
        # the malformed query degraded to keep-everything, PARTIAL only
        ss = reps[1].scan_sets[b.name]
        live = np.where(b.live_mask)[0]
        np.testing.assert_array_equal(np.sort(ss.part_ids), live)
        assert set(np.unique(ss.match)) == {1}, \
            "passthrough must never certify FULL"
        # its neighbours still get exact verdicts
        assert_reports_equal([good], [reps[0]], [host.run(good)], "q0")
        q2 = _filter_query(b, 100, 700)
        assert_reports_equal([q2], [reps[2]], [host.run(q2)], "q2")

    def test_bad_order_by_column_isolated(self):
        tables, _dim = build_fleet(1, seed=14)
        a = tables[0]
        svc = PruningService(mode="ref")
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        bad = Query(scans={a.name: TableScanSpec(a, E.col("v") >= 0)},
                    limit=3, order_by=(a.name, "missing", True))
        reps = svc.run_batch([bad], pipe)
        assert reps[0].counters["resilience"]["errors"] == 1
        assert set(np.unique(reps[0].scan_sets[a.name].match)) <= {1}


# ---------------------------------------------------------------------------
# Ladder end-to-end on the real service
# ---------------------------------------------------------------------------

class TestServiceDegradation:
    def test_device_launch_faults_demote_exactly(self):
        """Device launches down, host kernel up: answers bit-identical,
        demotions attributed to the host_kernel rung."""
        tables, dim = build_fleet(2, seed=15)
        inj = FaultInjector()
        inj.add("launch.filter:device")
        inj.add("launch.filter:sharded")
        svc = PruningService(mode="ref", fault_injector=inj,
                             sleep=NO_SLEEP)
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)
        qs = [_filter_query(tables[0]), _filter_query(tables[1], -50, 300)]
        got = svc.run_batch(qs, pipe)
        assert_reports_equal(qs, got, [host.run(q) for q in qs],
                             "device-down")
        res = got[0].counters["resilience"]
        assert res["demotions"]["host_kernel"] >= 1
        assert res["passthroughs"] == 0 and res["errors"] == 0

    def test_total_filter_blackout_passes_through_supersets(self):
        tables, _dim = build_fleet(1, seed=16)
        a = tables[0]
        inj = FaultInjector()
        inj.add("launch.filter")          # every rung with a launch site
        svc = PruningService(mode="ref", fault_injector=inj,
                             sleep=NO_SLEEP)
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)
        qs = [_filter_query(a)]
        got = svc.run_batch(qs, pipe)     # must not raise
        res = got[0].counters["resilience"]
        assert res["passthroughs"] >= 1
        assert res["demotions"]["passthrough"] >= 1
        assert_scan_superset(qs, got, [host.run(q) for q in qs],
                             "blackout")
        ss = got[0].scan_sets[a.name]
        assert set(np.unique(ss.match)) == {1}

    def test_join_and_topk_degrade_to_exact_host(self):
        tables, dim = build_fleet(2, seed=17)
        inj = FaultInjector()
        inj.add("launch.join")
        inj.add("launch.topk")
        svc = PruningService(mode="ref", fault_injector=inj,
                             sleep=NO_SLEEP)
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)
        rng = np.random.default_rng(3)
        qs = fleet_queries(tables, dim, rng, 16)
        got = svc.run_batch(qs, pipe)
        # join/topk host-oracle rungs are exact: scan parity holds
        assert_scan_parity(qs, got, [host.run(q) for q in qs],
                           "join-topk-down")
        res = got[0].counters["resilience"]
        assert res["passthroughs"] == 0 and res["errors"] == 0

    def test_retry_heals_transient_fault_without_demotion(self):
        tables, _dim = build_fleet(1, seed=18)
        a = tables[0]
        inj = FaultInjector()
        inj.add("launch.filter:device", times=1)   # one transient blip
        svc = PruningService(mode="ref", fault_injector=inj,
                             backoff=BackoffPolicy(retries=1,
                                                   base_delay=0.0),
                             sleep=NO_SLEEP)
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)
        qs = [_filter_query(a)]
        got = svc.run_batch(qs, pipe)
        assert_reports_equal(qs, got, [host.run(q) for q in qs], "blip")
        res = got[0].counters["resilience"]
        assert res["retries"] == 1
        assert not any(res["demotions"].values())

    def test_fleet_summary_carries_resilience_and_integrity(self):
        tables, _dim = build_fleet(1, seed=19)
        inj = FaultInjector()
        inj.add("launch.filter:device", times=1)
        svc = PruningService(mode="ref", fault_injector=inj,
                             backoff=BackoffPolicy(retries=0),
                             sleep=NO_SLEEP)
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        svc.run_batch([_filter_query(tables[0])], pipe)
        summary = svc.fleet_summary()
        assert summary["resilience"]["demotions"]["host_kernel"] == 1
        assert "verifications" in summary["integrity"]


# ---------------------------------------------------------------------------
# The chaos harness: randomized fault schedules x DML x budget pressure
# ---------------------------------------------------------------------------

SITES = ("launch.filter:sharded", "launch.filter:device", "launch.filter",
         "launch.join", "launch.join_bloom", "launch.topk",
         "stage.stat", "stage.join_key", "stage.enum", "stage.block_topk",
         "get.stat", "get.join_key", "get.block_topk", "evict")
CORRUPT_SITES = ("stage.stat", "stage.join_key", "stage.block_topk")


@st.composite
def fault_plans(draw):
    rules = []
    for _ in range(draw(st.integers(1, 4))):
        kind = draw(st.sampled_from(["error", "error", "corrupt", "delay"]))
        site = draw(st.sampled_from(
            CORRUPT_SITES if kind == "corrupt" else SITES))
        rules.append(dict(
            site=site, kind=kind,
            prob=draw(st.sampled_from([1.0, 0.5, 0.25])),
            times=draw(st.sampled_from([1, 3, None])),
            after=draw(st.integers(0, 2)),
            delay=0.001 if kind == "delay" else 0.0))
    return rules


class TestChaosHarness:
    """Fault schedules interleaved with DML + budget pressure: never
    raise, never smaller than the oracle's kept set, bit-identical when
    the ladder never fell below the host-oracle rung."""

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2 ** 31),
           plan=fault_plans(),
           dml=st.lists(st.sampled_from(
               ["append", "drop", "rewrite", "update", "noop"]),
               min_size=1, max_size=3),
           budget_frac=st.sampled_from([None, 0.35, 0.6]))
    def test_chaos_rounds(self, seed, plan, dml, budget_frac):
        rng = np.random.default_rng(seed)
        tables, dim = build_fleet(3, seed)
        budget = None
        if budget_frac is not None:
            budget = max(1, int(measure_working_set(tables, dim)
                                * budget_frac))
        inj = FaultInjector(seed=seed, sleep=NO_SLEEP)
        for rule in plan:
            inj.add(**rule)
        svc = PruningService(mode="ref", budget_bytes=budget,
                             shard_mesh=_plane_mesh_or_none(),
                             fault_injector=inj, sleep=NO_SLEEP,
                             integrity_sample=1)
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)
        for rnd, op in enumerate(["noop"] + list(dml)):
            _apply_dml(op, tables, rng)
            qs = fleet_queries(tables, dim, rng, 8)
            got = svc.run_batch(qs, pipe)          # the never-raise claim
            assert len(got) == len(qs)
            want = [host.run(q) for q in qs]
            label = f"round {rnd} ({op})"
            assert_scan_superset(qs, got, want, label)
            res = got[0].counters["resilience"]
            if res["passthroughs"] == 0 and res["errors"] == 0:
                # every rung at or above host_oracle is exact
                assert_scan_parity(qs, got, want, label)
            mem = svc.cache.memory
            assert mem.pinned_bytes == 0
            assert mem.bytes_in_use == svc.cache.resident_bytes

    def test_run_fleet_survives_blackout_storm(self):
        """A fixed worst-case schedule through run_fleet: launches
        erroring, stages torn, evictions faulting, under a budget that
        forces churn — every round returns, every set is a superset."""
        tables, dim = build_fleet(3, seed=23)
        budget = max(1, int(measure_working_set(tables, dim) * 0.4))
        inj = FaultInjector(seed=5, sleep=NO_SLEEP)
        inj.add("launch.filter", prob=0.5)
        inj.add("launch.join", prob=0.5)
        inj.add("launch.topk", prob=0.5)
        inj.add("stage.stat", kind="corrupt", prob=0.3)
        inj.add("evict", prob=0.25)
        inj.add("get.join_key", prob=0.3)
        svc = PruningService(mode="ref", budget_bytes=budget,
                             fault_injector=inj, sleep=NO_SLEEP,
                             integrity_sample=1)
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        host = PruningPipeline(join_ndv_limit=NDV_LIMIT)
        rng = np.random.default_rng(23)
        batches = [fleet_queries(tables, dim, rng, 8) for _ in range(3)]
        rounds = svc.run_fleet(batches, pipe)      # must not raise
        assert len(rounds) == len(batches)
        for rnd, (qs, got) in enumerate(zip(batches, rounds)):
            want = [host.run(q) for q in qs]
            assert_scan_superset(qs, got, want, f"fleet round {rnd}")
        assert svc.cache.memory.pinned_bytes == 0

    def test_no_faults_means_no_resilience_activity(self):
        """The ladder + integrity machinery is pure bookkeeping when
        nothing fails: zero demotions, zero retries, zero passthroughs,
        zero checksum failures — the <5% overhead bench's precondition."""
        tables, dim = build_fleet(2, seed=29)
        svc = PruningService(mode="ref")
        pipe = PruningPipeline(filter_mode="device", service=svc,
                               join_ndv_limit=NDV_LIMIT)
        rng = np.random.default_rng(4)
        for _ in range(2):
            got = svc.run_batch(fleet_queries(tables, dim, rng, 10), pipe)
            res = got[0].counters["resilience"]
            assert res["retries"] == 0 and res["passthroughs"] == 0
            assert not any(res["demotions"].values())
        assert svc.cache.integrity["checksum_failures"] == 0
