"""Pallas kernels: interpret-mode execution vs pure-jnp oracles vs the
host engine (core/*).  Shape sweeps via hypothesis."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import expr as E
from repro.core.metadata import ScanSet
from repro.core.prune_filter import eval_ranges_tv, extract_ranges
from repro.core.prune_topk import run_topk, topk_oracle
from repro.kernels import join_overlap, minmax_prune, ops, ref, topk_boundary

from helpers import small_tables


# ---------------------------------------------------------------------------
# minmax_prune
# ---------------------------------------------------------------------------

@st.composite
def range_problems(draw):
    P = draw(st.integers(1, 300))
    K = draw(st.integers(1, 6))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    mins = rng.uniform(-100, 100, size=(K, P)).astype(np.float32)
    maxs = mins + rng.uniform(0, 50, size=(K, P)).astype(np.float32)
    # sprinkle empty intervals (all-null partitions)
    empty = rng.random((K, P)) < 0.1
    mins = np.where(empty, np.inf, mins).astype(np.float32)
    maxs = np.where(empty, -np.inf, maxs).astype(np.float32)
    nullable = (rng.random((K, P)) < 0.2).astype(np.float32)
    lo = rng.uniform(-120, 120, size=K).astype(np.float32)
    hi = lo + rng.uniform(0, 100, size=K).astype(np.float32)
    return lo, hi, mins, maxs, nullable


class TestMinmaxPruneKernel:
    @settings(max_examples=40, deadline=None)
    @given(problem=range_problems())
    def test_kernel_matches_ref(self, problem):
        lo, hi, mins, maxs, nullable = map(jnp.asarray, problem)
        out_k = minmax_prune(lo, hi, mins, maxs, nullable, interpret=True)
        out_r = ref.minmax_prune_ref(lo, hi, mins, maxs, nullable)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    @settings(max_examples=40, deadline=None)
    @given(tbl=small_tables())
    def test_kernel_matches_host_engine(self, tbl):
        pred = (E.col("x") >= -10) & (E.col("y") < 700)
        ranges = extract_ranges(pred, tbl.stats)
        assert ranges is not None
        host_tv = eval_ranges_tv(ranges, tbl.stats)
        for mode in ("ref", "interpret"):
            dev_tv = ops.prune_ranges_device(ranges, tbl.stats, mode=mode)
            np.testing.assert_array_equal(dev_tv, host_tv)

    @pytest.mark.parametrize("P", [1, 7, 2048, 2049, 5000])
    @pytest.mark.parametrize("K", [1, 3])
    def test_block_boundary_shapes(self, P, K):
        rng = np.random.default_rng(P * 31 + K)
        mins = rng.uniform(-10, 10, (K, P)).astype(np.float32)
        maxs = mins + 1
        nullable = np.zeros((K, P), np.float32)
        lo = np.full(K, -5, np.float32)
        hi = np.full(K, 5, np.float32)
        args = map(jnp.asarray, (lo, hi, mins, maxs, nullable))
        out_k = minmax_prune(*args, interpret=True)
        out_r = ref.minmax_prune_ref(
            *map(jnp.asarray, (lo, hi, mins, maxs, nullable)))
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


# ---------------------------------------------------------------------------
# topk_boundary
# ---------------------------------------------------------------------------

@st.composite
def topk_problems(draw, valid_binit=False):
    P = draw(st.integers(1, 120))
    k = draw(st.sampled_from([1, 2, 4, 8, 16]))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    rows = rng.integers(-1000, 1000, size=(P, k)).astype(np.float32)
    # simulate partially-filled partitions with -inf padding
    fill = rng.integers(0, k + 1, size=P)
    for p in range(P):
        rows[p, fill[p]:] = -np.inf
    rows = -np.sort(-rows, axis=1)  # desc per row
    if valid_binit:
        # Sec. 5.4 boundaries are WITNESSES: k rows >= b_init must exist.
        finite = np.sort(rows[np.isfinite(rows)])[::-1]
        kth = finite[k - 1] if len(finite) >= k else -np.inf
        binit = draw(st.sampled_from([-np.inf, float(kth), float(kth) - 10.0]))
    else:
        binit = draw(st.sampled_from([-np.inf, -500.0, 0.0, 500.0]))
    return rows, np.float32(binit)


class TestTopKBoundaryKernel:
    @settings(max_examples=40, deadline=None)
    @given(problem=topk_problems())
    def test_kernel_matches_ref(self, problem):
        rows, binit = problem
        skip_k, heap_k = topk_boundary(jnp.asarray(rows), jnp.asarray(binit),
                                       interpret=True)
        skip_r, heap_r = ref.topk_boundary_ref(jnp.asarray(rows), binit)
        np.testing.assert_array_equal(np.asarray(skip_k), np.asarray(skip_r))
        np.testing.assert_allclose(np.asarray(heap_k), np.asarray(heap_r))

    @settings(max_examples=40, deadline=None)
    @given(problem=topk_problems(valid_binit=True))
    def test_prefix_formulation_dominates(self, problem):
        """DESIGN.md §6: with a *valid* upfront boundary (a witness, as
        Sec. 5.4 constructs), prefix-merge gives the same heap and a skip
        mask that is a superset of the sequential one."""
        rows, binit = problem
        skip_s, heap_s = ref.topk_boundary_ref(jnp.asarray(rows), binit)
        skip_p, heap_p = ref.topk_boundary_prefix_ref(jnp.asarray(rows), binit)
        np.testing.assert_allclose(np.sort(np.asarray(heap_p)),
                                   np.sort(np.asarray(heap_s)))
        assert (np.asarray(skip_p) >= np.asarray(skip_s)).all()

    @settings(max_examples=30, deadline=None)
    @given(tbl=small_tables(with_nulls=False), k=st.sampled_from([1, 4, 8]))
    def test_device_topk_matches_host_engine(self, tbl, k):
        """End-to-end: block-topk staging + kernel == core.run_topk values."""
        ctx = tbl.global_ctx()
        vals, _ = ctx.col("y")
        # identical processing order for both paths: sorted by block max
        scan = ScanSet.full(tbl.num_partitions)
        host = run_topk(tbl, scan, "y", k, strategy="sort")
        rows = ops.build_block_topk(vals.astype(np.float32),
                                    tbl.part_bounds, k)
        bmax = tbl.stats.col_max("y")
        order = np.argsort(-bmax, kind="stable")
        skip, heap = ops.topk_boundary_device(rows[order], mode="interpret")
        oracle = topk_oracle(tbl, "y", k)
        got = np.sort(heap[heap > -np.inf])[::-1]
        np.testing.assert_allclose(got, oracle.astype(np.float32))
        # identical skip decisions as the host scan loop
        host_skip = np.isin(scan.part_ids[order], host.skipped).astype(np.int32)
        np.testing.assert_array_equal(skip, host_skip)

    def test_padding_rows_harmless(self):
        rows = np.full((300, 4), -np.inf, dtype=np.float32)  # > BLOCK_ROWS
        rows[0] = [5, 4, 3, 2]
        skip, heap = topk_boundary(jnp.asarray(rows), jnp.float32(-np.inf),
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(heap), [5, 4, 3, 2])


# ---------------------------------------------------------------------------
# join_overlap
# ---------------------------------------------------------------------------

@st.composite
def overlap_problems(draw):
    P = draw(st.integers(1, 400))
    D = draw(st.integers(1, 500))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    pmin = rng.integers(0, 10_000, size=P).astype(np.float32)
    pmax = pmin + rng.integers(0, 100, size=P).astype(np.float32)
    empty = rng.random(P) < 0.05
    pmin = np.where(empty, np.inf, pmin).astype(np.float32)
    pmax = np.where(empty, -np.inf, pmax).astype(np.float32)
    distinct = np.unique(rng.integers(0, 10_000, size=D)).astype(np.float32)
    return pmin, pmax, distinct


class TestJoinOverlapKernel:
    @settings(max_examples=40, deadline=None)
    @given(problem=overlap_problems())
    def test_kernel_matches_ref(self, problem):
        pmin, pmax, distinct = map(jnp.asarray, problem)
        out_k = join_overlap(pmin, pmax, distinct, interpret=True)
        out_r = ref.join_overlap_ref(pmin, pmax, distinct)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    @settings(max_examples=40, deadline=None)
    @given(problem=overlap_problems())
    def test_oracle_truth(self, problem):
        """Both implementations vs brute force."""
        pmin, pmax, distinct = problem
        brute = np.array(
            [((distinct >= lo) & (distinct <= hi)).any()
             for lo, hi in zip(pmin, pmax)], dtype=np.int32)
        out_r = ref.join_overlap_ref(*map(jnp.asarray, problem))
        np.testing.assert_array_equal(np.asarray(out_r), brute)

    @pytest.mark.parametrize("P,D", [(1, 1), (1024, 2048), (1025, 2049), (3000, 10)])
    def test_block_boundary_shapes(self, P, D):
        rng = np.random.default_rng(P + D)
        pmin = rng.uniform(0, 1000, P).astype(np.float32)
        pmax = pmin + 5
        distinct = np.sort(rng.choice(max(2000, 2 * D), size=D, replace=False)).astype(np.float32)
        out_k = join_overlap(*map(jnp.asarray, (pmin, pmax, distinct)),
                             interpret=True)
        out_r = ref.join_overlap_ref(*map(jnp.asarray, (pmin, pmax, distinct)))
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
