"""Llama 3.2 3B — small llama3 [hf:meta-llama/Llama-3.2-1B; unverified].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128_256,
    rope_theta=500_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        rope_theta=500_000.0,
        logits_chunk=32,
        attn_chunk=32,
    )
