"""Model configuration schema + registry for the 10 assigned architectures."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    activation: str = "swiglu"   # swiglu | geglu
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    experts_per_tok: int = 0
    capacity_factor: float = 1.25
    moe_seq_chunk: int = 256   # dispatch chunk along S: bounds the [E,C,d]
                               # buffers to O(B*chunk) tokens instead of B*S

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4

    # hybrid (Zamba2-style): one shared attention block applied every
    # `attn_every` SSM layers
    attn_every: int = 0

    # encoder-decoder (Whisper-style)
    n_enc_layers: int = 0

    # modality frontend stub: none | patch (VLM) | frames (audio)
    frontend: str = "none"
    n_prefix: int = 576          # patches / frames prepended (stub output)

    # training-time knobs
    remat: bool = True
    scan_layers: bool = True
    logits_chunk: int = 512      # sequence chunking for the CE loss
    attn_chunk: int = 512        # query-block size for chunked attention
    ssm_chunk: int = 256         # SSD chunk length
    optimizer_state_dtype: str = "float32"  # bf16 for the 1T config

    # which long-context shapes this arch supports (sub-quadratic only)
    supports_long_context: bool = False

    # ---- performance knobs (EXPERIMENTS.md §Perf; defaults = the
    # paper-faithful/naive BASELINE so before/after stays reproducible) ----
    # 'fsdp': expert weights FSDP-sharded over embed and all-gathered per
    #         layer (naive); 'resident': experts sharded over (pod, data) x
    #         d_ff over model, tokens all-to-all to the weights (GShard-
    #         style) — no per-layer weight gather.
    moe_sharding: str = "fsdp"
    # 'scatter': dispatch via a global scatter into the [E, C, d] buffer —
    #            GSPMD lowers it as a dense ALL-REDUCE of the whole buffer
    #            (measured: the dominant collective, §Perf H1 baseline);
    # 'grouped': batch-local dispatch [B, E, C_b, d] via vmapped scatters —
    #            stays shard-local, experts reached by slicing the E dim.
    moe_dispatch: str = "scatter"
    # decode with TP-resident weights (no FSDP gather per token step)
    serve_resident: bool = False
    # pad the vocab to a multiple (0 = off) so the unembedding/CE shards
    # over the model axis (whisper: 51865 -> 51872)
    pad_vocab_to: int = 0
    # disable FSDP weight sharding entirely (small models: replicating
    # 0.5 GB beats per-layer all-gathers — §Perf H3)
    no_fsdp: bool = False

    @property
    def padded_vocab(self) -> int:
        if self.pad_vocab_to <= 0:
            return self.vocab
        return -(-self.vocab // self.pad_vocab_to) * self.pad_vocab_to

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs accounting)."""
        d, v = self.d_model, self.vocab
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.family in ("dense", "vlm"):
            mlp = 3 * d * self.d_ff
            return emb + self.n_layers * (attn + mlp + 2 * d)
        if self.family == "moe":
            mlp = 3 * d * self.d_ff * self.n_experts + d * self.n_experts
            return emb + self.n_layers * (attn + mlp + 2 * d)
        if self.family == "ssm":
            ssm = self._ssm_block_params()
            return emb + self.n_layers * (ssm + d)
        if self.family == "hybrid":
            ssm = self._ssm_block_params()
            shared_attn = attn + 3 * d * self.d_ff + 2 * d
            return emb + self.n_layers * (ssm + d) + shared_attn
        if self.family == "encdec":
            mlp = 3 * d * self.d_ff
            enc = self.n_enc_layers * (attn + mlp + 2 * d)
            dec = self.n_layers * (2 * attn + mlp + 3 * d)
            return emb + enc + dec
        raise ValueError(self.family)

    def _ssm_block_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        h = self.ssm_heads
        in_proj = d * (2 * di + 2 * n + h)
        conv = (di + 2 * n) * self.conv_kernel
        return in_proj + conv + 2 * h + di + di * d

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        hd = self.resolved_head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        mlp = 3 * d * self.d_ff * self.experts_per_tok + d * self.n_experts
        emb = self.vocab * d * 2
        return emb + self.n_layers * (attn + mlp + 2 * d)


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM pool (seq_len, global_batch, kind)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_REGISTRY = (
    "kimi_k2_1t_a32b",
    "qwen3_moe_30b_a3b",
    "zamba2_2p7b",
    "qwen1p5_4b",
    "glm4_9b",
    "llama3p2_3b",
    "gemma_7b",
    "llava_next_34b",
    "whisper_small",
    "mamba2_1p3b",
)

# CLI ids (--arch <id>) -> module names
ARCH_IDS = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "zamba2-2.7b": "zamba2_2p7b",
    "qwen1.5-4b": "qwen1p5_4b",
    "glm4-9b": "glm4_9b",
    "llama3.2-3b": "llama3p2_3b",
    "gemma-7b": "gemma_7b",
    "llava-next-34b": "llava_next_34b",
    "whisper-small": "whisper_small",
    "mamba2-1.3b": "mamba2_1p3b",
}


def _module(name: str):
    mod_name = ARCH_IDS.get(name, name)
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def list_archs() -> Tuple[str, ...]:
    return tuple(ARCH_IDS)


def shape_supported(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    """None if supported, else a human-readable skip reason."""
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention arch: 500k decode needs sub-quadratic "
                "attention (DESIGN.md §5)")
    return None
