"""Qwen1.5 4B — dense with QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab=151_936,
    qkv_bias=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        qkv_bias=True,
        logits_chunk=32,
        attn_chunk=32,
    )
