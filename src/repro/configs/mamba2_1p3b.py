"""Mamba2 1.3B — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified].

48L d_model=2048, ssm_state=128, vocab=50280.  Sub-quadratic: runs
long_500k (constant-size state cache at decode).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50_280,
    ssm_state=128,
    supports_long_context=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=256,
        ssm_state=16,
        ssm_head_dim=32,
        ssm_chunk=16,
        logits_chunk=32,
        supports_long_context=True,
    )
