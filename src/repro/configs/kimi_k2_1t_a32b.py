"""Kimi K2 — trillion-parameter MoE [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048, MoE 384 experts top-8,
vocab 163840.  Optimizer state kept in bf16 so params+Adam fit a 512-chip
v5e slice (EXPERIMENTS.md §Dry-run discusses the memory budget).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163_840,
    n_experts=384,
    experts_per_tok=8,
    optimizer_state_dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=32,
        vocab=256,
        n_experts=8,
        experts_per_tok=2,
        logits_chunk=32,
        attn_chunk=32,
    )
