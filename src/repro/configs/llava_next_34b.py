"""LLaVA-NeXT 34B — VLM backbone, anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Backbone only (the assignment's rule): 60L d_model=7168 56H (GQA kv=8)
d_ff=20480 vocab=64000.  The vision frontend is a STUB — input_specs()
provides precomputed patch embeddings [B, n_prefix, d_model] that the
model prepends to the token embeddings (loss masked over the prefix).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20_480,
    vocab=64_000,
    frontend="patch",
    n_prefix=576,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        frontend="patch",
        n_prefix=8,
        logits_chunk=32,
        attn_chunk=32,
    )
