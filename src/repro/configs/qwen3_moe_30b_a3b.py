"""Qwen3-MoE 30B-A3B — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

48L d_model=2048 32H (GQA kv=4) expert d_ff=768, MoE 128e top-8,
vocab 151936.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab=151_936,
    n_experts=128,
    experts_per_tok=8,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=32,
        vocab=256,
        n_experts=4,
        experts_per_tok=2,
        logits_chunk=32,
        attn_chunk=32,
    )
