"""Whisper small — encoder-decoder ASR backbone [arXiv:2212.04356;
unverified].

12 encoder + 12 decoder layers, d_model=768 12H d_ff=3072 vocab=51865.
The conv frontend is a STUB: input_specs() provides precomputed frame
embeddings [B, n_frames, d_model] (post-conv mel features).  decode_*
shapes exercise the DECODER with cached self- and cross-attention.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    n_enc_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51_865,
    frontend="frames",
    n_prefix=1500,           # 30s of audio at 50 frames/s
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="encdec",
        n_layers=2,
        n_enc_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        frontend="frames",
        n_prefix=16,
        logits_chunk=32,
        attn_chunk=32,
    )
