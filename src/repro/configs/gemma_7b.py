"""Gemma 7B — GeGLU, head_dim=256 [arXiv:2403.08295; hf].

28L d_model=3072 16H (MHA kv=16) d_ff=24576 vocab=256000.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24_576,
    vocab=256_000,
    activation="geglu",
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=128,
        vocab=256,
        activation="geglu",
        tie_embeddings=True,
        logits_chunk=32,
        attn_chunk=32,
    )
