"""Architecture configs: one module per assigned architecture.

``get_config(name)`` returns the full published config;
``get_smoke_config(name)`` a reduced same-family config for CPU tests.
"""

from .base import (ARCH_REGISTRY, ModelConfig, get_config, get_smoke_config,
                   list_archs)

__all__ = ["ModelConfig", "get_config", "get_smoke_config", "list_archs",
           "ARCH_REGISTRY"]
