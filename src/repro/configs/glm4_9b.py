"""GLM-4 9B — dense, RoPE, aggressive GQA [hf:THUDM/glm-4-9b; hf].

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.  kv=2 cannot
shard over 16-way TP -> KV projections replicate (models/sharding.py).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13_696,
    vocab=151_552,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="glm4-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        logits_chunk=32,
        attn_chunk=32,
    )
