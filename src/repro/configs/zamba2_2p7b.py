"""Zamba2 2.7B — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf].

54 Mamba2 layers, d_model=2560, ssm_state=64; one SHARED attention+MLP
block (32H, kv=32, d_ff=10240) applied every 6 SSM layers — Zamba2's
parameter-sharing trick.  vocab 32000.  Sub-quadratic: runs long_500k.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10_240,
    vocab=32_000,
    ssm_state=64,
    attn_every=6,
    supports_long_context=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=256,
        ssm_state=16,
        ssm_head_dim=32,
        attn_every=2,
        ssm_chunk=16,
        logits_chunk=32,
        attn_chunk=32,
        supports_long_context=True,
    )
