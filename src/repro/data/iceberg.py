"""Iceberg/Parquet-style hierarchical metadata (paper Sec. 8.1).

Open-table-format pruning is two-level: manifest FILE stats first, then
ROW-GROUP stats only for files that survive.  Benefits mirrored here:
  * metadata I/O: row-group stats of pruned files are never touched (in
    a data lake, that's an object-store fetch per file);
  * missing metadata: Parquet files without stats cannot be pruned — the
    paper's *backfill* reconstructs stats with one full scan so later
    queries prune (``backfill``).

Three-valued semantics compose across levels: a FULL file certifies all
its row groups FULL; a NO file prunes them unseen; PARTIAL descends.
Tests prove two-level == flat row-group pruning while touching strictly
less metadata.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from ..core import expr as E
from ..core.metadata import (FULL_MATCH, NO_MATCH, PARTIAL_MATCH,
                             PartitionStats)
from ..core.prune_filter import eval_tv
from .table import Table


@dataclasses.dataclass
class IcebergTable:
    """A Table viewed as files of row groups, with manifest-level stats."""

    table: Table                      # row groups = the table's partitions
    file_of_group: np.ndarray         # [G] file id per row group
    file_stats: PartitionStats        # [F] manifest-level stats
    has_metadata: np.ndarray          # [F] bool: files missing stats can't prune

    @property
    def num_files(self) -> int:
        return len(self.has_metadata)

    @staticmethod
    def from_table(table: Table, groups_per_file: int = 8,
                   missing_meta_files: Optional[np.ndarray] = None
                   ) -> "IcebergTable":
        G = table.num_partitions
        file_of_group = np.arange(G) // groups_per_file
        F = int(file_of_group[-1]) + 1 if G else 0
        s = table.stats
        mins = np.full((F, s.num_columns), np.inf)
        maxs = np.full((F, s.num_columns), -np.inf)
        nulls = np.zeros((F, s.num_columns), dtype=np.int64)
        rows = np.zeros(F, dtype=np.int64)
        for f in range(F):
            sel = file_of_group == f
            mins[f] = s.mins[sel].min(axis=0)
            maxs[f] = s.maxs[sel].max(axis=0)
            nulls[f] = s.null_counts[sel].sum(axis=0)
            rows[f] = s.row_counts[sel].sum()
        has_meta = np.ones(F, dtype=bool)
        if missing_meta_files is not None:
            has_meta[missing_meta_files] = False
        return IcebergTable(
            table, file_of_group,
            PartitionStats(s.columns, mins, maxs, nulls, rows), has_meta)

    def backfill(self, file_id: int) -> int:
        """Reconstruct a file's missing metadata with one full read of its
        row groups (the paper's reconstruction path).  Returns the rows
        scanned to pay for it."""
        if self.has_metadata[file_id]:
            return 0
        self.has_metadata[file_id] = True
        sel = np.where(self.file_of_group == file_id)[0]
        return int(self.table.stats.row_counts[sel].sum())


@dataclasses.dataclass
class TwoLevelResult:
    group_tv: np.ndarray          # [G] three-valued result
    files_pruned: int
    file_meta_reads: int          # manifest rows examined
    group_meta_reads: int         # row-group stats examined (saved reads =
                                  # G - this)


def two_level_prune(pred: E.Pred, ice: IcebergTable) -> TwoLevelResult:
    G = ice.table.num_partitions
    file_tv = eval_tv(pred, ice.file_stats)
    # files without metadata can never be pruned (conservative PARTIAL)
    file_tv = np.where(ice.has_metadata, file_tv, PARTIAL_MATCH).astype(np.int8)

    group_tv = np.empty(G, dtype=np.int8)
    descend_groups: List[int] = []
    for f in range(ice.num_files):
        sel = ice.file_of_group == f
        if file_tv[f] == NO_MATCH:
            group_tv[sel] = NO_MATCH
        elif file_tv[f] == FULL_MATCH:
            group_tv[sel] = FULL_MATCH
        else:
            descend_groups.extend(np.where(sel)[0].tolist())

    if descend_groups:
        ids = np.asarray(descend_groups, dtype=np.int64)
        sub = ice.table.stats.select(ids)
        group_tv[ids] = eval_tv(pred, sub)
    return TwoLevelResult(
        group_tv=group_tv,
        files_pruned=int((file_tv == NO_MATCH).sum()),
        file_meta_reads=ice.num_files,
        group_meta_reads=len(descend_groups),
    )
