"""Columnar tables split into micro-partitions (paper Sec. 2).

A ``Table`` is a PAX-style columnar store: each column is one contiguous
encoded array, horizontally sliced into micro-partitions at row boundaries
(``part_bounds``).  String columns are dictionary-encoded with an
order-preserving sorted dictionary (DESIGN.md §2 — code order equals
lexicographic order, so min/max pruning semantics are preserved exactly).

Partition sizing: Snowflake micro-partitions hold 50–500MB uncompressed;
here the row count per partition plays that role and is configurable so
tests stay laptop-sized while benchmarks model realistic partition counts.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.metadata import ColumnMeta, PartitionStats
from ..core.rowval import RowContext


@dataclasses.dataclass
class Table:
    name: str
    columns: Dict[str, ColumnMeta]
    data: Dict[str, np.ndarray]          # encoded float64, full table
    nulls: Dict[str, np.ndarray]         # bool masks (absent = no nulls)
    part_bounds: np.ndarray              # [P+1] row offsets
    stats: PartitionStats

    @property
    def num_rows(self) -> int:
        return int(self.part_bounds[-1])

    @property
    def num_partitions(self) -> int:
        return len(self.part_bounds) - 1

    def partition_rows(self, p: int) -> slice:
        return slice(int(self.part_bounds[p]), int(self.part_bounds[p + 1]))

    def partition_ctx(self, p: int) -> RowContext:
        s = self.partition_rows(p)
        return RowContext(
            self.columns,
            {k: v[s] for k, v in self.data.items()},
            {k: v[s] for k, v in self.nulls.items()},
        )

    def ctx_for(self, part_ids: Sequence[int]) -> RowContext:
        """RowContext over the concatenation of the given partitions."""
        idx = np.concatenate(
            [np.arange(self.part_bounds[p], self.part_bounds[p + 1]) for p in part_ids]
        ) if len(part_ids) else np.zeros(0, dtype=np.int64)
        return RowContext(
            self.columns,
            {k: v[idx] for k, v in self.data.items()},
            {k: v[idx] for k, v in self.nulls.items()},
        )

    def global_ctx(self) -> RowContext:
        return RowContext(self.columns, self.data, self.nulls)

    def decode(self, name: str, codes: np.ndarray):
        cm = self.columns[name]
        if cm.kind != "str":
            return codes
        return cm.dictionary[codes.astype(np.int64)]

    @staticmethod
    def build(
        name: str,
        raw: Dict[str, np.ndarray],
        rows_per_partition: int = 1000,
        nulls: Optional[Dict[str, np.ndarray]] = None,
        part_bounds: Optional[np.ndarray] = None,
    ) -> "Table":
        nulls = {k: np.asarray(v, dtype=bool) for k, v in (nulls or {}).items()}
        n = len(next(iter(raw.values())))
        for k, v in raw.items():
            if len(v) != n:
                raise ValueError(f"column {k!r} length mismatch")
        if part_bounds is None:
            bounds: List[int] = list(range(0, n, rows_per_partition)) + [n]
            if bounds[-2] == n:
                bounds.pop(-2)
            part_bounds = np.asarray(bounds, dtype=np.int64)
        else:
            part_bounds = np.asarray(part_bounds, dtype=np.int64)

        columns: Dict[str, ColumnMeta] = {}
        data: Dict[str, np.ndarray] = {}
        for cname, values in raw.items():
            values = np.asarray(values)
            if values.dtype.kind in ("U", "S", "O"):
                svals = values.astype(str)
                dictionary = np.unique(svals)
                cm = ColumnMeta(cname, "str", dictionary)
                data[cname] = cm.encode(svals)
            elif values.dtype.kind in ("i", "u"):
                cm = ColumnMeta(cname, "int")
                data[cname] = values.astype(np.float64)
            else:
                cm = ColumnMeta(cname, "float")
                data[cname] = values.astype(np.float64)
            columns[cname] = cm

        stats = PartitionStats.from_columns(
            list(columns.values()), data, nulls, part_bounds
        )
        return Table(name, columns, data, nulls, part_bounds, stats)
