"""Columnar tables split into micro-partitions (paper Sec. 2).

A ``Table`` is a PAX-style columnar store: each column is one contiguous
encoded array, horizontally sliced into micro-partitions at row boundaries
(``part_bounds``).  String columns are dictionary-encoded with an
order-preserving sorted dictionary (DESIGN.md §2 — code order equals
lexicographic order, so min/max pruning semantics are preserved exactly).

Partition sizing: Snowflake micro-partitions hold 50–500MB uncompressed;
here the row count per partition plays that role and is configurable so
tests stay laptop-sized while benchmarks model realistic partition counts.

Streaming DML (incremental ingest)
----------------------------------
Micro-partitions are immutable in Snowflake: DML creates and drops whole
partitions.  The same model here:

  * ``append_partitions`` adds new partitions at the end (partition ids
    never shift);
  * ``drop_partitions`` tombstones partitions in place — rows stay in the
    arrays but the partition leaves the ``live`` mask and its stats become
    the empty-interval sentinel, so every pruning path sees it as empty;
  * ``rewrite_partitions`` replaces the rows of live partitions in place
    (same row counts, so ``part_bounds`` is stable);
  * ``update_column`` rewrites one column's values across the table.

Each mutation bumps ``version`` and logs a ``TableDelta`` so resident
device metadata planes (``core.device_stats``) can sync by staging only
the changed partitions instead of restaging ``[C, P]`` from scratch.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.metadata import ColumnMeta, PartitionStats, TableDelta
from ..core.rowval import RowContext

# Replay horizon: deltas older than this are compacted away; a resident
# plane staged before ``delta_floor`` simply full-restages (always safe).
DELTA_LOG_LIMIT = 256


@dataclasses.dataclass
class Table:
    name: str
    columns: Dict[str, ColumnMeta]
    data: Dict[str, np.ndarray]          # encoded float64, full table
    nulls: Dict[str, np.ndarray]         # bool masks (absent = no nulls)
    part_bounds: np.ndarray              # [P+1] row offsets
    stats: PartitionStats
    # -- streaming-DML state (defaults keep static tables zero-cost) -------
    version: int = 0                     # bumped by every DML method
    live: Optional[np.ndarray] = None    # bool [P]; None = all live
    deltas: List[TableDelta] = dataclasses.field(default_factory=list)
    delta_floor: int = 0                 # oldest version replayable from

    @property
    def num_rows(self) -> int:
        return int(self.part_bounds[-1])

    @property
    def num_partitions(self) -> int:
        return len(self.part_bounds) - 1

    @property
    def live_mask(self) -> np.ndarray:
        """bool [P] of live partitions (materialized on first DML)."""
        if self.live is None:
            return np.ones(self.num_partitions, dtype=bool)
        return self.live

    @property
    def num_live_partitions(self) -> int:
        return int(self.live_mask.sum())

    def partition_rows(self, p: int) -> slice:
        return slice(int(self.part_bounds[p]), int(self.part_bounds[p + 1]))

    def partition_ctx(self, p: int) -> RowContext:
        s = self.partition_rows(p)
        return RowContext(
            self.columns,
            {k: v[s] for k, v in self.data.items()},
            {k: v[s] for k, v in self.nulls.items()},
        )

    def ctx_for(self, part_ids: Sequence[int]) -> RowContext:
        """RowContext over the concatenation of the given partitions."""
        idx = np.concatenate(
            [np.arange(self.part_bounds[p], self.part_bounds[p + 1]) for p in part_ids]
        ) if len(part_ids) else np.zeros(0, dtype=np.int64)
        return RowContext(
            self.columns,
            {k: v[idx] for k, v in self.data.items()},
            {k: v[idx] for k, v in self.nulls.items()},
        )

    def global_ctx(self) -> RowContext:
        return RowContext(self.columns, self.data, self.nulls)

    def decode(self, name: str, codes: np.ndarray):
        cm = self.columns[name]
        if cm.kind != "str":
            return codes
        return cm.dictionary[codes.astype(np.int64)]

    @staticmethod
    def build(
        name: str,
        raw: Dict[str, np.ndarray],
        rows_per_partition: int = 1000,
        nulls: Optional[Dict[str, np.ndarray]] = None,
        part_bounds: Optional[np.ndarray] = None,
    ) -> "Table":
        nulls = {k: np.asarray(v, dtype=bool) for k, v in (nulls or {}).items()}
        n = len(next(iter(raw.values())))
        for k, v in raw.items():
            if len(v) != n:
                raise ValueError(f"column {k!r} length mismatch")
        if part_bounds is None:
            bounds: List[int] = list(range(0, n, rows_per_partition)) + [n]
            if bounds[-2] == n:
                bounds.pop(-2)
            part_bounds = np.asarray(bounds, dtype=np.int64)
        else:
            part_bounds = np.asarray(part_bounds, dtype=np.int64)

        columns: Dict[str, ColumnMeta] = {}
        data: Dict[str, np.ndarray] = {}
        for cname, values in raw.items():
            values = np.asarray(values)
            if values.dtype.kind in ("U", "S", "O"):
                svals = values.astype(str)
                dictionary = np.unique(svals)
                cm = ColumnMeta(cname, "str", dictionary)
                data[cname] = cm.encode(svals)
            elif values.dtype.kind in ("i", "u"):
                cm = ColumnMeta(cname, "int")
                data[cname] = values.astype(np.float64)
            else:
                cm = ColumnMeta(cname, "float")
                data[cname] = values.astype(np.float64)
            columns[cname] = cm

        stats = PartitionStats.from_columns(
            list(columns.values()), data, nulls, part_bounds
        )
        return Table(name, columns, data, nulls, part_bounds, stats)

    # ---- streaming micro-partition DML ------------------------------------

    def _log(self, kind: str, **kw) -> None:
        self.version += 1
        self.deltas.append(TableDelta(version=self.version, kind=kind, **kw))
        while len(self.deltas) > DELTA_LOG_LIMIT:
            self.delta_floor = self.deltas.pop(0).version

    def _encode_batch(self, raw: Dict[str, np.ndarray],
                      nulls: Optional[Dict[str, np.ndarray]]):
        """Encode a row batch against the existing schema/dictionaries.

        String values must already be in the column's dictionary (the
        sorted dictionary is immutable — appending unseen strings would
        renumber codes under every resident plane); ``encode`` raises
        KeyError otherwise.
        """
        if set(raw) != set(self.columns):
            raise ValueError(
                f"append columns {sorted(raw)} != schema {sorted(self.columns)}")
        n = len(next(iter(raw.values())))
        enc: Dict[str, np.ndarray] = {}
        for cname, values in raw.items():
            if len(values) != n:
                raise ValueError(f"column {cname!r} length mismatch")
            enc[cname] = self.columns[cname].encode(values)
        nmasks = {k: np.asarray(v, dtype=bool)
                  for k, v in (nulls or {}).items()}
        return n, enc, nmasks

    def append_partitions(
        self,
        raw: Dict[str, np.ndarray],
        nulls: Optional[Dict[str, np.ndarray]] = None,
        rows_per_partition: Optional[int] = None,
    ) -> np.ndarray:
        """Append rows as new micro-partitions; returns the new ids.

        ``rows_per_partition=None`` packs the whole batch into one new
        partition (the streaming-ingest shape: one flush = one
        micro-partition)."""
        n, enc, nmasks = self._encode_batch(raw, nulls)
        if n == 0:
            return np.zeros(0, dtype=np.int64)
        if rows_per_partition is None:
            local_bounds = np.array([0, n], dtype=np.int64)
        else:
            local_bounds = np.asarray(
                list(range(0, n, rows_per_partition)) + [n], dtype=np.int64)
        new_stats = PartitionStats.from_columns(
            list(self.columns.values()), enc, nmasks, local_bounds)

        old_rows = self.num_rows
        old_p = self.num_partitions
        old_live = self.live_mask            # before bounds grow
        for cname in self.columns:
            self.data[cname] = np.concatenate([self.data[cname], enc[cname]])
        for cname in set(self.nulls) | set(nmasks):
            old = self.nulls.get(
                cname, np.zeros(old_rows, dtype=bool))
            new = nmasks.get(cname, np.zeros(n, dtype=bool))
            self.nulls[cname] = np.concatenate([old, new])
        self.part_bounds = np.concatenate(
            [self.part_bounds, old_rows + local_bounds[1:]])
        self.stats.append_rows(new_stats)
        self.live = np.concatenate(
            [old_live, np.ones(len(local_bounds) - 1, dtype=bool)])
        self._log("append", part_lo=old_p, part_hi=self.num_partitions)
        return np.arange(old_p, self.num_partitions, dtype=np.int64)

    def drop_partitions(self, part_ids: Sequence[int]) -> None:
        """Tombstone partitions in place (ids never shift)."""
        ids = np.unique(np.asarray(part_ids, dtype=np.int64))
        if ids.size == 0:
            return
        if ids[0] < 0 or ids[-1] >= self.num_partitions:
            raise IndexError(f"partition ids out of range: {ids}")
        if not self.live_mask[ids].all():
            raise ValueError("dropping an already-dropped partition")
        self.live = self.live_mask.copy()
        self.live[ids] = False
        self.stats.drop_rows(ids)
        self._log("drop", part_ids=tuple(int(i) for i in ids))

    def rewrite_partitions(
        self,
        part_ids: Sequence[int],
        raw: Dict[str, np.ndarray],
        nulls: Optional[Dict[str, np.ndarray]] = None,
    ) -> None:
        """Replace the rows of live partitions in place.

        The replacement batch must carry exactly as many rows as the
        partitions hold (``part_bounds`` stays fixed); rows are assigned
        to partitions in the given ``part_ids`` order.
        """
        ids = np.asarray(part_ids, dtype=np.int64)
        if len(np.unique(ids)) != len(ids):
            raise ValueError("duplicate partition ids in rewrite")
        if ids.size and (ids.min() < 0 or ids.max() >= self.num_partitions):
            raise IndexError(f"partition ids out of range: {ids}")
        if not self.live_mask[ids].all():
            raise ValueError("rewriting a dropped partition")
        sizes = np.diff(self.part_bounds)[ids]
        n, enc, nmasks = self._encode_batch(raw, nulls)
        if n != int(sizes.sum()):
            raise ValueError(
                f"rewrite rows ({n}) != partition rows ({int(sizes.sum())})")
        local_bounds = np.concatenate(
            [[0], np.cumsum(sizes)]).astype(np.int64)
        new_stats = PartitionStats.from_columns(
            list(self.columns.values()), enc, nmasks, local_bounds)
        for bi, pid in enumerate(ids):
            src = slice(int(local_bounds[bi]), int(local_bounds[bi + 1]))
            dst = self.partition_rows(int(pid))
            for cname in self.columns:
                self.data[cname][dst] = enc[cname][src]
            for cname in set(self.nulls) | set(nmasks):
                if cname not in self.nulls:
                    self.nulls[cname] = np.zeros(self.num_rows, dtype=bool)
                self.nulls[cname][dst] = nmasks.get(
                    cname, np.zeros(n, dtype=bool))[src]
        self.stats.rewrite_rows(ids, new_stats)
        self._log("rewrite", part_ids=tuple(int(i) for i in ids))

    def update_column(
        self,
        column: str,
        values: np.ndarray,
        nulls: Optional[np.ndarray] = None,
    ) -> None:
        """Rewrite one column's values across the whole table.

        Column-scoped on purpose: resident per-column device planes of
        *other* columns stay valid, and the ``[C, P]`` stat planes sync
        by restaging only this column's rows.
        """
        cm = self.columns[column]
        if len(values) != self.num_rows:
            raise ValueError("update_column needs one value per row")
        self.data[column] = cm.encode(values)
        if nulls is not None:
            self.nulls[column] = np.asarray(nulls, dtype=bool)
        elif column in self.nulls:
            self.nulls[column] = np.zeros(self.num_rows, dtype=bool)
        ci = self.stats.col_id(column)
        vals = self.data[column]
        nmask = self.nulls.get(column)
        live = self.live_mask
        for p in range(self.num_partitions):
            if not live[p]:
                continue                      # dropped: sentinel stays
            s = self.partition_rows(p)
            v = vals[s]
            if nmask is not None:
                m = nmask[s]
                self.stats.null_counts[p, ci] = int(m.sum())
                v = v[~m]
            else:
                self.stats.null_counts[p, ci] = 0
            if v.size:
                self.stats.mins[p, ci] = v.min()
                self.stats.maxs[p, ci] = v.max()
            else:
                self.stats.mins[p, ci] = np.inf
                self.stats.maxs[p, ci] = -np.inf
        self._log("update", column=column)
