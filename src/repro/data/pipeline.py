"""Pruned pretraining data pipeline (DESIGN.md §2: "training is a pruned
scan").

Pre-training corpora are stored as token shards with per-shard metadata
(quality score, language, source, dedup bucket, ingestion time) — exactly
the micro-partition + min/max metadata shape of the paper.  Data curation
("quality >= t AND lang IN (...) AND NOT duplicate") is filter pruning:
shards whose metadata cannot match are never fetched from storage, and
LIMIT pruning implements token budgets ("take the first 50B curated
tokens") IO-optimally via fully-matching shards.

Distribution: the pruned scan set is split over data-parallel workers;
stragglers are handled by *deterministic work stealing* — every worker
can compute who owns what from (scan_set, worker_count, cursor) alone, so
a restart resumes exactly (the checkpoint stores only cursors).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..core import expr as E
from ..core.metadata import NO_MATCH, ScanSet
from ..core.prune_filter import eval_tv
from .generator import ColumnSpec, gen_table
from .table import Table


def make_corpus_metadata(
    rng: np.random.Generator,
    n_shards: int = 2048,
    docs_per_shard: int = 64,
) -> Table:
    """Shard-level metadata table: one row per document, one partition per
    shard.  Quality/language cluster by source crawl — the correlation
    that makes curation prunable (as in the paper's production data)."""
    n = n_shards * docs_per_shard
    specs = [
        ColumnSpec("ingest_ts", "int", 0, 10_000_000, clustering=0.99),
        ColumnSpec("quality", "float", 0.0, 1.0, clustering=0.85),
        ColumnSpec("lang", "str", n_distinct=16, clustering=0.9,
                   str_groups=("en", "de", "fr", "zh")),
        ColumnSpec("dedup_bucket", "int", 0, 1000, clustering=0.0),
        ColumnSpec("n_tokens", "int", 256, 4096, clustering=0.0),
    ]
    return gen_table("corpus", rng, n, docs_per_shard, specs)


@dataclasses.dataclass
class CurationReport:
    shards_total: int
    shards_selected: int

    @property
    def pruning_ratio(self) -> float:
        return 1.0 - self.shards_selected / max(self.shards_total, 1)


def curate(meta: Table, pred: E.Pred) -> Tuple[ScanSet, CurationReport]:
    """Filter-prune the shard set against a curation predicate."""
    tv = eval_tv(pred, meta.stats)
    keep = tv > NO_MATCH
    scan = ScanSet(np.where(keep)[0], tv[keep])
    return scan, CurationReport(meta.num_partitions, len(scan))


class WorkQueue:
    """Deterministic work stealing over a shard list.

    Shards are round-robin assigned; a worker that drains its own list
    steals the tail of the most-loaded worker's list.  All decisions are
    functions of the shared cursor state, so every worker (and a restore)
    reaches identical conclusions — no coordinator needed beyond the
    cursor array.
    """

    def __init__(self, shard_ids: np.ndarray, n_workers: int):
        self.n_workers = n_workers
        self.lists: List[List[int]] = [
            list(map(int, shard_ids[w::n_workers])) for w in range(n_workers)
        ]
        self.cursor = [0] * n_workers          # next index into own list
        self.stolen: set = set()

    def remaining(self, w: int) -> int:
        return len(self.lists[w]) - self.cursor[w]

    def next_for(self, w: int) -> Optional[int]:
        # own work first
        while self.cursor[w] < len(self.lists[w]):
            sid = self.lists[w][self.cursor[w]]
            self.cursor[w] += 1
            if sid not in self.stolen:
                return sid
        # steal from the most-loaded worker, from the TAIL (the victim
        # works head-first, so collisions are impossible until exhaustion)
        victim = max(range(self.n_workers), key=self.remaining)
        if self.remaining(victim) <= 0:
            return None
        for i in range(len(self.lists[victim]) - 1, self.cursor[victim] - 1, -1):
            sid = self.lists[victim][i]
            if sid not in self.stolen:
                self.stolen.add(sid)
                return sid
        return None

    def state(self) -> dict:
        return {"cursor": list(self.cursor), "stolen": sorted(self.stolen)}

    def restore(self, state: dict) -> None:
        self.cursor = list(state["cursor"])
        self.stolen = set(state["stolen"])


def shard_tokens(shard_id: int, tokens_per_shard: int, vocab: int,
                 seed: int = 0) -> np.ndarray:
    """Deterministic synthetic token stream for a shard (stands in for the
    object-store fetch; keyed by shard id so replays are exact)."""
    rng = np.random.default_rng((seed << 20) ^ shard_id)
    return rng.integers(0, vocab, size=tokens_per_shard, dtype=np.int32)


class PrunedDataLoader:
    """Batches [B, S+1] from the curated shard set for one DP worker."""

    def __init__(
        self,
        scan: ScanSet,
        worker: int,
        n_workers: int,
        batch_size: int,
        seq_len: int,
        vocab: int,
        tokens_per_shard: int = 32_768,
        seed: int = 0,
    ):
        self.queue = WorkQueue(scan.part_ids, n_workers)
        self.worker = worker
        self.batch = batch_size
        self.seq = seq_len
        self.vocab = vocab
        self.tps = tokens_per_shard
        self.seed = seed
        self._buf = np.zeros(0, dtype=np.int32)
        self.shards_consumed: List[int] = []

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        need = self.batch * (self.seq + 1)
        while True:
            while len(self._buf) < need:
                sid = self.queue.next_for(self.worker)
                if sid is None:
                    return
                self.shards_consumed.append(sid)
                self._buf = np.concatenate(
                    [self._buf, shard_tokens(sid, self.tps, self.vocab, self.seed)]
                )
            chunk, self._buf = self._buf[:need], self._buf[need:]
            arr = chunk.reshape(self.batch, self.seq + 1)
            yield {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def state(self) -> dict:
        return {"queue": self.queue.state(),
                "buf_len": int(len(self._buf)),
                "consumed": list(self.shards_consumed)}
