"""Scan + query execution over pruned scan sets.

Executes queries for real (row-level filters, hash joins, LIMIT halt,
top-k) so tests can prove pruning changes *work*, never *results*.  Also
accounts bytes/rows/partitions touched — the cost model standing in for
the network I/O a decoupled-storage system saves (DESIGN.md §2).

The executor halts a LIMIT scan as soon as k rows are produced (the
paper's observation that most engines do this anyway); partition-level
metrics therefore show the parallel-execution catch of Sec. 4.4 — without
pruning, n workers each fetch partitions before the halt propagates.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from ..core import expr as E
from ..core.flow import PruningReport, Query
from ..core.metadata import ScanSet, live_full_scan
from ..core.rowval import matches
from .table import Table

BYTES_PER_VALUE = 8  # encoded columnar width


@dataclasses.dataclass
class ScanMetrics:
    partitions_scanned: int = 0
    rows_scanned: int = 0
    bytes_scanned: int = 0

    def add(self, other: "ScanMetrics") -> None:
        self.partitions_scanned += other.partitions_scanned
        self.rows_scanned += other.rows_scanned
        self.bytes_scanned += other.bytes_scanned


@dataclasses.dataclass
class QueryResult:
    columns: Dict[str, np.ndarray]
    nulls: Dict[str, np.ndarray]
    metrics: Dict[str, ScanMetrics]

    @property
    def num_rows(self) -> int:
        return len(next(iter(self.columns.values()))) if self.columns else 0

    def total_bytes(self) -> int:
        return sum(m.bytes_scanned for m in self.metrics.values())


def scan_partitions(
    table: Table,
    scan: ScanSet,
    pred: Optional[E.Pred],
    stop_after_rows: Optional[int] = None,
) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray], ScanMetrics]:
    """Fetch partitions in scan-set order, filter rows, stop early on LIMIT."""
    metrics = ScanMetrics()
    out_cols: Dict[str, list] = {c: [] for c in table.columns}
    out_nulls: Dict[str, list] = {c: [] for c in table.columns}
    produced = 0
    ncols = len(table.columns)
    for pid in scan.part_ids:
        ctx = table.partition_ctx(int(pid))
        metrics.partitions_scanned += 1
        metrics.rows_scanned += ctx.n
        metrics.bytes_scanned += ctx.n * ncols * BYTES_PER_VALUE
        mask = (
            matches(pred, ctx)
            if pred is not None and not isinstance(pred, E.TruePred)
            else np.ones(ctx.n, dtype=bool)
        )
        for c in table.columns:
            v, nm = ctx.col(c)
            out_cols[c].append(v[mask])
            out_nulls[c].append(nm[mask])
        produced += int(mask.sum())
        if stop_after_rows is not None and produced >= stop_after_rows:
            break
    cols = {c: np.concatenate(v) if v else np.zeros(0) for c, v in out_cols.items()}
    nulls = {c: np.concatenate(v) if v else np.zeros(0, dtype=bool)
             for c, v in out_nulls.items()}
    return cols, nulls, metrics


def _join_indices(
    probe_keys: np.ndarray,
    probe_nulls: np.ndarray,
    build_keys: np.ndarray,
    build_nulls: np.ndarray,
    kind: str,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized hash-join index computation.

    Returns (probe_idx, build_idx, matched_mask_for_probe); build_idx is -1
    for unmatched probe rows under left_outer.
    """
    valid_b = ~build_nulls
    b_idx_valid = np.where(valid_b)[0]
    bk = build_keys[valid_b]
    order = np.argsort(bk, kind="stable")
    sorted_b = bk[order]

    pk = probe_keys.copy()
    n = len(pk)
    lo = np.searchsorted(sorted_b, pk, side="left")
    hi = np.searchsorted(sorted_b, pk, side="right")
    counts = (hi - lo) * (~probe_nulls)  # null keys never join
    total = int(counts.sum())

    probe_idx = np.repeat(np.arange(n), counts)
    within = np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
    build_idx = b_idx_valid[order[np.repeat(lo, counts) + within]]

    matched = counts > 0
    if kind == "left_outer":
        unmatched = np.where(~matched)[0]
        probe_idx = np.concatenate([probe_idx, unmatched])
        build_idx = np.concatenate([build_idx, np.full(len(unmatched), -1, dtype=np.int64)])
    return probe_idx, build_idx, matched


def execute_query(
    q: Query,
    report: Optional[PruningReport] = None,
    halt_on_limit: bool = True,
) -> QueryResult:
    """Execute a query; with ``report`` the pruned scan sets are used,
    otherwise every partition is scanned (the no-pruning baseline)."""
    if q.group_by:
        raise NotImplementedError("aggregation execution not modeled")

    scan_sets = (
        report.scan_sets
        if report is not None
        else {n: live_full_scan(s.table) for n, s in q.scans.items()}
    )
    metrics: Dict[str, ScanMetrics] = {}

    # Plain LIMIT without join: scan in scan-set order, halting early.
    if q.join is None:
        (name, spec), = q.scans.items()
        stop = q.effective_k if (q.is_plain_limit and halt_on_limit) else None
        if q.is_topk and report is not None and report.topk is not None:
            # Execute the top-k via the boundary-pruned runtime directly.
            cols, nulls, m = scan_partitions(
                spec.table,
                ScanSet(report.topk.scanned),
                spec.pred,
            )
            metrics[name] = m
        else:
            cols, nulls, m = scan_partitions(spec.table, scan_sets[name], spec.pred, stop)
            metrics[name] = m
        cols = {f"{name}.{c}": v for c, v in cols.items()}
        nulls = {f"{name}.{c}": v for c, v in nulls.items()}
        return _finalize(q, cols, nulls, metrics)

    # Join path: build side first (always fully scanned), then probe.
    j = q.join
    bspec, pspec = q.scans[j.build], q.scans[j.probe]
    bcols, bnulls, bm = scan_partitions(bspec.table, scan_sets[j.build], bspec.pred)
    metrics[j.build] = bm
    probe_scan = scan_sets[j.probe]
    if q.is_topk and report is not None and report.topk is not None and \
            q.order_by[0] == j.probe:
        probe_scan = ScanSet(report.topk.scanned)
    pcols, pnulls, pm = scan_partitions(pspec.table, probe_scan, pspec.pred)
    metrics[j.probe] = pm

    pi, bi, _ = _join_indices(
        pcols[j.probe_key], pnulls[j.probe_key],
        bcols[j.build_key], bnulls[j.build_key], j.kind,
    )
    cols: Dict[str, np.ndarray] = {}
    nulls: Dict[str, np.ndarray] = {}
    for c, v in pcols.items():
        cols[f"{j.probe}.{c}"] = v[pi]
        nulls[f"{j.probe}.{c}"] = pnulls[c][pi]
    pad = bi < 0
    bi_safe = np.where(pad, 0, bi)
    for c, v in bcols.items():
        cols[f"{j.build}.{c}"] = np.where(pad, np.nan, v[bi_safe])
        nulls[f"{j.build}.{c}"] = np.where(pad, True, bnulls[c][bi_safe])
    return _finalize(q, cols, nulls, metrics)


def _finalize(q: Query, cols, nulls, metrics) -> QueryResult:
    n = len(next(iter(cols.values()))) if cols else 0
    order = np.arange(n)
    if q.is_topk:
        scan_name, col, desc = q.order_by
        key = cols[f"{scan_name}.{col}"].astype(np.float64).copy()
        nm = nulls[f"{scan_name}.{col}"]
        key[nm] = -np.inf if desc else np.inf  # NULLS LAST
        order = np.argsort(-key if desc else key, kind="stable")
    if q.limit is not None:
        order = order[q.offset : q.offset + q.limit]
    cols = {c: v[order] for c, v in cols.items()}
    nulls = {c: v[order] for c, v in nulls.items()}
    return QueryResult(cols, nulls, metrics)
