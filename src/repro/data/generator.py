"""Synthetic table/workload generation, calibrated to the paper's stats.

The paper's central empirical claim (Sec. 8.3) is that real workloads are
far more selective and better clustered than TPC-H.  We therefore generate
two families:

  * *production-like* tables: strongly clustered timestamp/sequence
    columns, categorical columns with prefix structure, highly selective
    predicates; LIMIT k drawn from the Figure 6 distribution.
  * *TPC-H-like* tables (Fig. 13 setup): LINEITEM/ORDERS shapes clustered
    on l_shipdate/o_orderdate, with the benchmark's characteristically
    low-selectivity predicates.

The ``clustering`` knob (0 = random, 1 = perfectly sorted) displaces each
row of a sorted column by Normal(0, (1-clustering) * n) positions — a
smooth interpolation between a clustered and a shuffled layout that
controls min/max overlap between partitions, the quantity pruning
effectiveness depends on ("regardless of the implemented pruning
techniques, the number of partitions that can be skipped primarily
depends on how data is distributed", Sec. 1).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from .table import Table


@dataclasses.dataclass
class ColumnSpec:
    name: str
    kind: str = "float"              # 'int' | 'float' | 'str'
    low: float = 0.0
    high: float = 1_000_000.0
    clustering: float = 0.0
    null_frac: float = 0.0
    n_distinct: Optional[int] = None  # categorical domain size
    str_groups: Sequence[str] = ("Alpine", "Boreal", "Coastal", "Desert")


def _displace(sorted_vals: np.ndarray, clustering: float, rng: np.random.Generator):
    n = len(sorted_vals)
    if clustering >= 1.0 or n <= 1:
        return sorted_vals
    sigma = (1.0 - clustering) * n
    keys = np.arange(n) + rng.normal(0.0, sigma, size=n)
    return sorted_vals[np.argsort(keys, kind="stable")]


def gen_column(rng: np.random.Generator, n: int, spec: ColumnSpec):
    """Returns (raw_values, null_mask)."""
    if spec.kind == "str":
        nd = spec.n_distinct or 64
        per_group = max(nd // len(spec.str_groups), 1)
        domain = np.array(
            [f"{g}-{i:05d}" for g in spec.str_groups for i in range(per_group)]
        )
        idx = np.sort(rng.integers(0, len(domain), size=n))
        vals = domain[_displace_codes(idx, spec.clustering, rng)]
    elif spec.n_distinct is not None:
        idx = np.sort(rng.integers(int(spec.low), int(spec.low) + spec.n_distinct, size=n))
        vals = _displace(idx.astype(np.int64), spec.clustering, rng)
    elif spec.kind == "int":
        vals = np.sort(rng.integers(int(spec.low), int(spec.high), size=n))
        vals = _displace(vals.astype(np.int64), spec.clustering, rng)
    else:
        vals = np.sort(rng.uniform(spec.low, spec.high, size=n))
        vals = _displace(vals, spec.clustering, rng)
    nulls = rng.random(n) < spec.null_frac if spec.null_frac > 0 else None
    return vals, nulls


def _displace_codes(sorted_codes: np.ndarray, clustering: float, rng):
    return _displace(sorted_codes, clustering, rng)


def gen_table(
    name: str,
    rng: np.random.Generator,
    n_rows: int,
    rows_per_partition: int,
    specs: Sequence[ColumnSpec],
) -> Table:
    raw: Dict[str, np.ndarray] = {}
    nulls: Dict[str, np.ndarray] = {}
    for spec in specs:
        v, nm = gen_column(rng, n_rows, spec)
        raw[spec.name] = v
        if nm is not None:
            nulls[spec.name] = nm
    return Table.build(name, raw, rows_per_partition, nulls)


# ---------------------------------------------------------------------------
# Figure 6: the LIMIT-k distribution observed across Snowflake.
# 97% of queries have k <= 10,000; 99.9% k <= 2,000,000; the bulk is 0/1.
# ---------------------------------------------------------------------------

def sample_limit_k(rng: np.random.Generator) -> int:
    u = rng.random()
    if u < 0.28:
        return 0            # BI tools fetching schemas with LIMIT 0
    if u < 0.48:
        return 1
    if u < 0.62:
        return int(rng.choice([10, 25, 50, 100]))
    if u < 0.97:
        return int(np.exp(rng.uniform(np.log(2), np.log(10_000))))
    if u < 0.999:
        return int(np.exp(rng.uniform(np.log(10_000), np.log(2_000_000))))
    return int(np.exp(rng.uniform(np.log(2_000_000), np.log(20_000_000))))


# ---------------------------------------------------------------------------
# Production-like tables (events fact table + users dimension)
# ---------------------------------------------------------------------------

def make_events_table(
    rng: np.random.Generator,
    n_rows: int = 200_000,
    rows_per_partition: int = 1000,
    ts_clustering: float = 0.98,
    user_clustering: float = 0.55,
) -> Table:
    """A production-shaped fact table: events clustered by ingestion time.

    Real warehouse tables arrive roughly time-ordered, which is what makes
    min/max pruning on date predicates so effective (the 99%+ filter
    pruning ratios of Fig. 4).
    """
    specs = [
        ColumnSpec("ts", "int", 0, 10_000_000, clustering=ts_clustering),
        ColumnSpec("user_id", "int", 0, 500_000, clustering=user_clustering),
        ColumnSpec("score", "float", 0.0, 1.0, clustering=0.0),
        # counters correlate with ingestion order in production tables
        ColumnSpec("num_sightings", "int", 0, 100_000, clustering=0.55),
        ColumnSpec("status", "str", n_distinct=32, clustering=0.92,
                   str_groups=("ok", "warn", "err", "crit")),
        ColumnSpec("region", "str", n_distinct=16, clustering=0.3,
                   str_groups=("eu", "us", "ap", "sa")),
    ]
    return gen_table("events", rng, n_rows, rows_per_partition, specs)


def make_users_table(
    rng: np.random.Generator,
    n_rows: int = 20_000,
    rows_per_partition: int = 1000,
) -> Table:
    """Dimension table with a *correlated* attribute: user ids are assigned
    chronologically, so age anti-correlates with id.  Column correlation is
    what gives join pruning its bite on real data (Sec. 8.3 / Dreseler et
    al.): a selective predicate on age concentrates the build-side keys in
    a narrow id range, which probe-side min/max metadata can exclude."""
    ids = np.sort(rng.choice(500_000, size=n_rows, replace=False))
    age = np.clip(
        90.0 - ids * (70.0 / 500_000.0) + rng.normal(0, 4.0, n_rows), 10, 90
    ).astype(np.int64)
    country_spec = ColumnSpec("country", "str", n_distinct=32, clustering=0.1,
                              str_groups=("EU", "US", "AP", "SA"))
    country, _ = gen_column(rng, n_rows, country_spec)
    return Table.build(
        "users",
        {"id": ids.astype(np.int64), "age": age, "country": country},
        rows_per_partition,
    )


# ---------------------------------------------------------------------------
# TPC-H-like tables (Fig. 13: clustered by l_shipdate / o_orderdate)
# ---------------------------------------------------------------------------

DATE_LO, DATE_HI = 8766, 11322  # days: 1992-01-01 .. 1998-12-31, TPC-H range


def make_lineitem(
    rng: np.random.Generator,
    n_rows: int = 300_000,
    rows_per_partition: int = 1000,
) -> Table:
    specs = [
        ColumnSpec("l_shipdate", "int", DATE_LO, DATE_HI, clustering=0.995),
        ColumnSpec("l_orderkey", "int", 0, n_rows // 4, clustering=0.97),
        ColumnSpec("l_quantity", "int", 1, 51, clustering=0.0),
        ColumnSpec("l_discount", "float", 0.0, 0.11, clustering=0.0),
        ColumnSpec("l_extendedprice", "float", 900.0, 105_000.0, clustering=0.0),
        ColumnSpec("l_returnflag", "str", n_distinct=3, clustering=0.0,
                   str_groups=("A", "N", "R")),
    ]
    return gen_table("lineitem", rng, n_rows, rows_per_partition, specs)


def make_orders(
    rng: np.random.Generator,
    n_rows: int = 75_000,
    rows_per_partition: int = 1000,
) -> Table:
    specs = [
        ColumnSpec("o_orderdate", "int", DATE_LO, DATE_HI - 151, clustering=0.995),
        ColumnSpec("o_orderkey", "int", 0, n_rows, clustering=0.97),
        ColumnSpec("o_totalprice", "float", 850.0, 560_000.0, clustering=0.0),
        ColumnSpec("o_orderpriority", "str", n_distinct=5, clustering=0.0,
                   str_groups=("1-URGENT", "2-HIGH", "3-MEDIUM", "4-LOW")),
    ]
    return gen_table("orders", rng, n_rows, rows_per_partition, specs)
