"""Pallas TPU kernel: top-k boundary-value scan (paper Sec. 5).

The WAND-style runtime pruning loop as a TPU kernel.  Input is the
per-partition *block top-k table*: ``rows[P, k]`` where row p holds
partition p's k largest (signed) order-column values sorted descending,
padded with -inf (rows are pre-arranged in processing order — Sec. 5.3 —
and pre-masked by the scan's filter predicate).  The kernel walks the
partitions sequentially, carrying the global top-k heap, and emits

  * ``skip[P]``  — 1 where the partition would be pruned by the boundary
                   (these partitions would never be fetched from storage),
  * ``heap[k]``  — the final top-k values.

Skip rule (proved in core/prune_topk.py and hypothesis-tested):
  with B = upfront boundary (Sec. 5.4) and H = current heap k-th value,
  skip iff  block_max < max(B, H)  or  (heap full and block_max <= H).

TPU mapping: the heap/row merge is *rank-selection* — an all-pairs
comparison of the 2k candidates followed by a one-hot combine — which is
branch-free VPU work (2k <= 256 lanes), instead of the CPU heap's
branchy sift-down.  The partition dimension is blocked (BLOCK_ROWS rows
per grid step) with the heap carried across grid steps in VMEM scratch.
The sequential carry is the paper's semantics; a fully parallel
formulation (associative prefix merge) is discussed in DESIGN.md §6 and
validated in the ref oracle.

Values must be finite (the wrapper uses -inf as padding / null encoding).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_ROWS = 256


def _merge_topk(heap: jax.Array, row: jax.Array, k: int) -> jax.Array:
    """Top-k of two descending-sorted length-k vectors via rank selection."""
    cand = jnp.concatenate([heap, row])                     # [2k]
    n = 2 * k
    ci = cand[:, None]                                      # value of i
    cj = cand[None, :]                                      # value of j
    ii = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    rank = jnp.sum((cj > ci) | ((cj == ci) & (jj < ii)), axis=1)  # [2k]
    tgt = jax.lax.broadcasted_iota(jnp.int32, (k, n), 0)
    sel = (rank[None, :] == tgt).astype(cand.dtype)         # one-hot [k, 2k]
    return jnp.sum(sel * cand[None, :], axis=1)             # [k]


def _topk_boundary_kernel(binit_ref, rows_ref, skip_ref, heap_ref, scratch):
    k = rows_ref.shape[1]
    bp = rows_ref.shape[0]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        scratch[...] = jnp.full_like(scratch, -jnp.inf)

    b_init = binit_ref[0, 0]
    heap0 = scratch[0, :]

    def body(j, carry):
        heap, skips = carry
        row = rows_ref[j, :]
        h_kth = heap[k - 1]
        heap_full = h_kth > -jnp.inf
        bm = row[0]
        eff = jnp.maximum(b_init, jnp.where(heap_full, h_kth, -jnp.inf))
        skip = (bm < eff) | (heap_full & (bm <= h_kth))
        merged = _merge_topk(heap, row, k)
        heap = jnp.where(skip, heap, merged)
        skips = skips.at[j].set(skip.astype(jnp.int32))
        return heap, skips

    heap, skips = jax.lax.fori_loop(
        0, bp, body, (heap0, jnp.zeros((bp,), jnp.int32))
    )
    scratch[0, :] = heap
    skip_ref[...] = skips[None, :]
    heap_ref[...] = heap[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def topk_boundary(
    rows: jax.Array,      # [P, k] f32, desc-sorted rows, -inf padded
    b_init: jax.Array,    # scalar f32 upfront boundary (-inf if none)
    interpret: bool = False,
):
    """Returns (skip [P] int32, heap [k] f32)."""
    P, k = rows.shape
    pad = (-P) % BLOCK_ROWS
    if pad:
        rows = jnp.pad(rows, ((0, pad), (0, 0)), constant_values=-jnp.inf)
    Pp = P + pad
    grid = (Pp // BLOCK_ROWS,)
    skip, heap = pl.pallas_call(
        _topk_boundary_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_ROWS, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, BLOCK_ROWS), lambda i: (0, i)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, Pp), jnp.int32),
            jax.ShapeDtypeStruct((1, k), rows.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, k), rows.dtype)],
        interpret=interpret,
    )(jnp.asarray(b_init, rows.dtype).reshape(1, 1), rows)
    # padding rows can never un-skip; slice them off
    return skip[0, :P], heap[0]
