"""Pallas TPU kernel: top-k boundary-value scan (paper Sec. 5).

The WAND-style runtime pruning loop as a TPU kernel.  Input is the
per-partition *block top-k table*: ``rows[P, k]`` where row p holds
partition p's k largest (signed) order-column values sorted descending,
padded with -inf (rows are pre-arranged in processing order — Sec. 5.3 —
and pre-masked by the scan's filter predicate).  The kernel walks the
partitions sequentially, carrying the global top-k heap, and emits

  * ``skip[P]``  — 1 where the partition would be pruned by the boundary
                   (these partitions would never be fetched from storage),
  * ``heap[k]``  — the final top-k values.

Skip rule (proved in core/prune_topk.py and hypothesis-tested):
  with B = upfront boundary (Sec. 5.4) and H = current heap k-th value,
  skip iff  block_max < max(B, H)  or  (heap full and block_max <= H).

TPU mapping: the heap/row merge is *rank-selection* — an all-pairs
comparison of the 2k candidates followed by a one-hot combine — which is
branch-free VPU work (2k <= 256 lanes), instead of the CPU heap's
branchy sift-down.  The partition dimension is blocked (BLOCK_ROWS rows
per grid step) with the heap carried across grid steps in VMEM scratch.
The sequential carry is the paper's semantics; a fully parallel
formulation (associative prefix merge) is discussed in DESIGN.md §6 and
validated in the ref oracle.

Values must be finite (the wrapper uses -inf as padding / null encoding).

``topk_init_batched`` is the workload-scale boundary *initializer* (Sec.
5.4): against the table's resident block-top-k plane (core/device_stats.py
— [P, K] per-partition top-K rows, staged once per table version), one
launch computes every query's upfront boundary as the k-th largest value
over its fully-matching partitions' resident rows.  No per-query staging:
only the [Q, P] candidate masks cross to the device per batch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_ROWS = 256


def _merge_topk(heap: jax.Array, row: jax.Array, k: int) -> jax.Array:
    """Top-k of two descending-sorted length-k vectors via rank selection."""
    cand = jnp.concatenate([heap, row])                     # [2k]
    n = 2 * k
    ci = cand[:, None]                                      # value of i
    cj = cand[None, :]                                      # value of j
    ii = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    rank = jnp.sum((cj > ci) | ((cj == ci) & (jj < ii)), axis=1)  # [2k]
    tgt = jax.lax.broadcasted_iota(jnp.int32, (k, n), 0)
    sel = (rank[None, :] == tgt).astype(cand.dtype)         # one-hot [k, 2k]
    return jnp.sum(sel * cand[None, :], axis=1)             # [k]


def _topk_boundary_kernel(binit_ref, rows_ref, skip_ref, heap_ref, scratch):
    k = rows_ref.shape[1]
    bp = rows_ref.shape[0]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        scratch[...] = jnp.full_like(scratch, -jnp.inf)

    b_init = binit_ref[0, 0]
    heap0 = scratch[0, :]

    def body(j, carry):
        heap, skips = carry
        row = rows_ref[j, :]
        h_kth = heap[k - 1]
        heap_full = h_kth > -jnp.inf
        bm = row[0]
        eff = jnp.maximum(b_init, jnp.where(heap_full, h_kth, -jnp.inf))
        skip = (bm < eff) | (heap_full & (bm <= h_kth))
        merged = _merge_topk(heap, row, k)
        heap = jnp.where(skip, heap, merged)
        skips = skips.at[j].set(skip.astype(jnp.int32))
        return heap, skips

    heap, skips = jax.lax.fori_loop(
        0, bp, body, (heap0, jnp.zeros((bp,), jnp.int32))
    )
    scratch[0, :] = heap
    skip_ref[...] = skips[None, :]
    heap_ref[...] = heap[None, :]


BLOCK_QI = 8     # queries per tile in the batched init kernel
BLOCK_PI = 128   # partitions folded into the heaps per grid step


def _merge_topk_rows(heap: jax.Array, rows: jax.Array, k: int) -> jax.Array:
    """Row-wise top-k merge: heap [BQ, k] desc + rows [BQ, m] -> [BQ, k].

    The batched analogue of ``_merge_topk``: rank selection via an
    all-pairs comparison per query row, branch-free VPU work."""
    cand = jnp.concatenate([heap, rows], axis=1)            # [BQ, n]
    n = cand.shape[1]
    ci = cand[:, :, None]                                   # value of i
    cj = cand[:, None, :]                                   # value of j
    ii = jax.lax.broadcasted_iota(jnp.int32, (1, n, n), 1)
    jj = jax.lax.broadcasted_iota(jnp.int32, (1, n, n), 2)
    rank = jnp.sum(((cj > ci) | ((cj == ci) & (jj < ii))).astype(jnp.int32),
                   axis=2)                                  # [BQ, n]
    tgt = jax.lax.broadcasted_iota(jnp.int32, (1, k, n), 1)
    sel = rank[:, None, :] == tgt                           # [BQ, k, n]
    # where, not sel * cand: candidates are -inf-padded and 0 * -inf = NaN
    # in eager IEEE semantics (jit happens to fold the one-hot away).
    picked = jnp.where(sel, cand[:, None, :], jnp.zeros_like(cand)[:, None, :])
    return jnp.sum(picked, axis=2)                          # [BQ, k]


def _topk_init_kernel(plane_ref, mask_ref, heap_ref, scratch, *, k):
    BP, K = plane_ref.shape
    BQ = mask_ref.shape[1]

    @pl.when(pl.program_id(1) == 0)
    def _init():
        scratch[...] = jnp.full_like(scratch, -jnp.inf)

    def body(j, heap):
        prow = plane_ref[j, :]                              # [K]
        m = mask_ref[j, :]                                  # [BQ]
        rows = jnp.where(m[:, None] > 0, prow[None, :], -jnp.inf)
        return _merge_topk_rows(heap, rows, k)

    heap = jax.lax.fori_loop(0, BP, body, scratch[...])
    scratch[...] = heap
    heap_ref[...] = heap


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_init_batched(
    plane: jax.Array,     # [P, K] f32 resident block-top-k rows, -inf padded
    mask: jax.Array,      # [P, Q] f32, 1.0 = candidate partition for query q
    k: int,
    interpret: bool = False,
) -> jax.Array:
    """Per-query top-k over masked unions of resident block-top-k rows.

    Returns heap [Q, k] f32 descending (-inf padded): row q holds the k
    largest plane values among partitions with ``mask[p, q] == 1`` — the
    Sec. 5.4 upfront boundary for query q is ``heap[q, kq - 1]`` for any
    kq <= k (a prefix of a larger heap is the exact smaller-k answer, so
    one launch serves a whole group of queries with mixed k).

    The partition dimension is blocked with the heaps carried across grid
    steps in VMEM scratch, like ``topk_boundary``; queries ride the
    sublane dim like ``minmax_prune_batched``.
    """
    P, K = plane.shape
    Q = mask.shape[1]
    pad_q = (-Q) % BLOCK_QI
    if pad_q:
        mask = jnp.pad(mask, ((0, 0), (0, pad_q)))
    pad_p = (-P) % BLOCK_PI
    if pad_p:
        plane = jnp.pad(plane, ((0, pad_p), (0, 0)), constant_values=-jnp.inf)
        mask = jnp.pad(mask, ((0, pad_p), (0, 0)))
    Qp, Pp = Q + pad_q, P + pad_p
    grid = (Qp // BLOCK_QI, Pp // BLOCK_PI)
    heap = pl.pallas_call(
        functools.partial(_topk_init_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_PI, K), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_PI, BLOCK_QI), lambda i, j: (j, i)),
        ],
        out_specs=pl.BlockSpec((BLOCK_QI, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Qp, k), plane.dtype),
        scratch_shapes=[pltpu.VMEM((BLOCK_QI, k), plane.dtype)],
        interpret=interpret,
    )(plane, mask)
    return heap[:Q]


@functools.partial(jax.jit, static_argnames=("interpret",))
def topk_boundary(
    rows: jax.Array,      # [P, k] f32, desc-sorted rows, -inf padded
    b_init: jax.Array,    # scalar f32 upfront boundary (-inf if none)
    interpret: bool = False,
):
    """Returns (skip [P] int32, heap [k] f32)."""
    P, k = rows.shape
    pad = (-P) % BLOCK_ROWS
    if pad:
        rows = jnp.pad(rows, ((0, pad), (0, 0)), constant_values=-jnp.inf)
    Pp = P + pad
    grid = (Pp // BLOCK_ROWS,)
    skip, heap = pl.pallas_call(
        _topk_boundary_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_ROWS, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, BLOCK_ROWS), lambda i: (0, i)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, Pp), jnp.int32),
            jax.ShapeDtypeStruct((1, k), rows.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, k), rows.dtype)],
        interpret=interpret,
    )(jnp.asarray(b_init, rows.dtype).reshape(1, 1), rows)
    # padding rows can never un-skip; slice them off
    return skip[0, :P], heap[0]
