"""Pallas TPU kernel: batched blocked-Bloom JOIN pruning (paper Sec. 6).

Large-NDV build sides ship a blocked Bloom filter instead of an exact
distinct set; the probe side then prunes *narrow* partitions — ranges
spanning at most ``enum_limit`` integer/dictionary-code values — by
enumerating every possible value against the filter.  PR 2 left this half
of JOIN pruning on the host; this kernel closes it: **Q Bloom filters x P
probe partitions in one launch** against the table's resident enumeration
plane (core/device_stats.py — integer-snapped pmin/width int32 rows).

TPU adaptation (everything branch-free int32 lane work):

  * the murmur probe pipeline is the shared 32-bit mixer (``ref.mix32`` ==
    ``core.prune_join._mix32`` bit-for-bit; logical shifts emulated by
    masking the arithmetic shift's sign fill);
  * enumeration is vectorized over an ``enum_pad``-wide **lane dim**: one
    [1, E] iota row enumerates a partition's candidate values, hashes
    them, and tests all of them against the filter at once (E is the
    power-of-two bucket of the batch's max width, so recompiles stay
    bounded);
  * the per-candidate 16-word Bloom block is fetched with the engine's
    one-hot **matmul gather** ([16, Bb] words @ [Bb, E] one-hot — MXU
    work, no dynamic addressing).  Word values don't fit f32, so filters
    are packed as exact 16-bit f32 halves and reassembled in int32;
  * each candidate's 4 probe bits are folded into a per-word *required
    signature* [16, E]; membership is ``(word & sig) == sig`` over the 16
    words — same-word probe collisions OR together exactly like the host;
  * filters are padded to power-of-two block-count buckets by *periodic
    tiling* (``ops.pack_blooms``): block selection is ``h & (blocks-1)``,
    so a tiled filter probes identical words under the larger mask and
    every query in a launch shares one block count.

Partitions ride the grid (BLOCK_PB per cell) with a sequential fori per
partition; non-enumerable partitions (width 0: too wide, float-snapped
empty, or outside int32) short-circuit to hit=1 — skip = keep, so the
kernel is false-positive-only by construction, like the host matcher it
must match bit-for-bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.prune_join import BLOCK_WORDS, K_PROBES
from .ref import H1_SALT, H2_SALT, lsr32, mix32

BLOCK_PB = 128   # partitions per grid cell (sequential fori within)


def _bloom_probe_kernel(pmin_ref, width_ref, lo_ref, hi_ref, hit_ref, *,
                        enum_pad):
    BP = pmin_ref.shape[0]
    Bb = lo_ref.shape[2]
    E = enum_pad
    lo_t = lo_ref[0]                                    # [16, Bb] f32
    hi_t = hi_ref[0]
    jidx = jax.lax.broadcasted_iota(jnp.int32, (1, E), 1)
    biota = jax.lax.broadcasted_iota(jnp.int32, (Bb, E), 0)
    wiota = jax.lax.broadcasted_iota(jnp.int32, (BLOCK_WORDS, E), 0)

    def body(p, hit):
        pmin_p = pmin_ref[p, 0]
        w_p = width_ref[p, 0]

        def probe(_):
            cand = pmin_p + jidx                        # [1, E] int32
            # int64 fold: the high word of an int32-domain key is its
            # sign extension (cand >> 31 == 0 or -1 == 0xFFFFFFFF).
            h0 = mix32(cand ^ mix32(cand >> 31))
            h1 = mix32(h0 ^ jnp.int32(H1_SALT))
            h2 = mix32(h1 ^ jnp.int32(H2_SALT))
            block = h0 & jnp.int32(Bb - 1)
            onehot = (biota == block).astype(jnp.float32)       # [Bb, E]
            # Exact gather: one 1.0 per column; halves are <= 0xFFFF so
            # the f32 dot is an exact row select, reassembled in int32.
            glo = jnp.dot(lo_t, onehot, preferred_element_type=jnp.float32)
            ghi = jnp.dot(hi_t, onehot, preferred_element_type=jnp.float32)
            word = (ghi.astype(jnp.int32) << 16) | glo.astype(jnp.int32)
            sig = jnp.zeros((BLOCK_WORDS, E), jnp.int32)
            for i in range(K_PROBES):
                wi = lsr32(h1, 8 * i) & jnp.int32(BLOCK_WORDS - 1)
                bi = lsr32(h2, 8 * i) & jnp.int32(31)
                sig |= jnp.where(wiota == wi,
                                 jnp.left_shift(jnp.int32(1), bi), 0)
            ok = jnp.all((word & sig) == sig, axis=0, keepdims=True)
            return jnp.any(ok & (jidx < w_p)).astype(jnp.int32)

        h = jax.lax.cond(w_p > 0, probe, lambda _: jnp.int32(1), None)
        return hit.at[p].set(h)

    hit = jax.lax.fori_loop(0, BP, body, jnp.zeros((BP,), jnp.int32))
    hit_ref[...] = hit[:, None]


@functools.partial(jax.jit, static_argnames=("enum_pad", "interpret"))
def bloom_probe_batched(
    lo_t: jax.Array,     # [Q, 16, Bb] f32 low 16-bit filter-word halves
    hi_t: jax.Array,     # [Q, 16, Bb] f32 high halves (ops.pack_blooms)
    pmin: jax.Array,     # [P] int32 resident integer-snapped minima
    width: jax.Array,    # [P] int32 candidate counts; 0 = keep (no enum)
    enum_pad: int,       # lane bucket >= every width (pow2, ops.enum_bucket)
    interpret: bool = False,
) -> jax.Array:
    """Batched Bloom probe: Q build filters x P probe partitions.

    Returns hit [Q, P] int32 — 0 only where partition p is enumerable
    (0 < width[p] <= enum_pad) and none of its candidate values is in
    query q's filter.  Row q is bit-identical to the host matcher's
    narrow-range enumeration for the same filter.
    """
    P = pmin.shape[0]
    Q = lo_t.shape[0]
    pad_p = (-P) % BLOCK_PB
    if pad_p:
        # width 0 -> hit 1 without probing; sliced off below.
        pmin = jnp.pad(pmin, (0, pad_p))
        width = jnp.pad(width, (0, pad_p))
    Pp = P + pad_p
    Bb = lo_t.shape[2]
    grid = (Q, Pp // BLOCK_PB)
    hit = pl.pallas_call(
        functools.partial(_bloom_probe_kernel, enum_pad=enum_pad),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_PB, 1), lambda q, p: (p, 0)),
            pl.BlockSpec((BLOCK_PB, 1), lambda q, p: (p, 0)),
            pl.BlockSpec((1, BLOCK_WORDS, Bb), lambda q, p: (q, 0, 0)),
            pl.BlockSpec((1, BLOCK_WORDS, Bb), lambda q, p: (q, 0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_PB, 1), lambda q, p: (p, q)),
        out_shape=jax.ShapeDtypeStruct((Pp, Q), jnp.int32),
        interpret=interpret,
    )(pmin[:, None], width[:, None], lo_t, hi_t)
    return hit[:P].T
