"""Pallas TPU kernel: causal flash attention (forward).

The LM-side compute hot spot: the prefill_32k shapes spend most of their
FLOPs here.  Classic flash algorithm — online softmax with running
(max, sum, accumulator) carried in VMEM scratch across KV blocks — tiled
for the MXU: Q blocks of BLOCK_Q x D against KV blocks of BLOCK_K x D,
grid (batch*heads, nQ, nK) with the KV dimension innermost (sequential,
accumulating).

Fully-masked blocks (k-block strictly after the q-block under causality)
are skipped with pl.when — the causal schedule does ~half the block work.

Distribution: under pjit the kernel runs per-shard inside shard_map with
heads already sharded over `model` (each device sees its local [B, S,
H_local, D] slice); ops.flash_attention is the single-device entry the
tests validate in interpret mode against the jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, nk: int, sq: int, sk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = iq * BLOCK_Q
    k_lo = ik * BLOCK_K
    # causal: the whole k-block is masked iff k_lo > q_hi
    live = (not causal) or (k_lo <= q_lo + BLOCK_Q - 1)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)            # [BQ, D]
        k = k_ref[0].astype(jnp.float32)            # [BK, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [BQ, BK]
        q_ids = q_lo + jax.lax.broadcasted_iota(jnp.int32, (BLOCK_Q, BLOCK_K), 0)
        k_ids = k_lo + jax.lax.broadcasted_iota(jnp.int32, (BLOCK_Q, BLOCK_K), 1)
        mask = k_ids < sk                           # strip K padding
        if causal:
            mask &= k_ids <= q_ids
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                         # [BQ]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(
    q: jax.Array,   # [BH, Sq, D]
    k: jax.Array,   # [BH, Sk, D]
    v: jax.Array,
    causal: bool = True,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    scale = D ** -0.5
    pad_q = (-Sq) % BLOCK_Q
    pad_k = (-Sk) % BLOCK_K
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    nq = (Sq + pad_q) // BLOCK_Q
    nk = (Sk + pad_k) // BLOCK_K

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          nk=nk, sq=Sq, sk=Sk),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, BLOCK_Q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BLOCK_K, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, BLOCK_K, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_Q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq + pad_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BLOCK_Q,), jnp.float32),
            pltpu.VMEM((BLOCK_Q,), jnp.float32),
            pltpu.VMEM((BLOCK_Q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
