"""Pure-jnp oracles for the Pallas kernels (no pallas imports).

Each function implements the identical contract with straightforward
jax.numpy, serving as the allclose reference in tests and as the
fallback implementation on backends without Pallas.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def minmax_prune_ref(lo, hi, mins, maxs, nullable) -> jax.Array:
    """tv [P] int32 for a conjunction of K ranges over [K, P] stats."""
    lo = lo[:, None]
    hi = hi[:, None]
    empty = mins > maxs
    no = (maxs < lo) | (mins > hi) | empty
    full = (mins >= lo) & (maxs <= hi) & (nullable == 0.0) & ~empty
    tv_k = jnp.where(no, 0, jnp.where(full, 2, 1)).astype(jnp.int32)
    return jnp.min(tv_k, axis=0)


def minmax_prune_batched_ref(cids, lo, hi, mins, maxs, demote) -> jax.Array:
    """tv [Q, P] int32 for Q queries of Kb ranges over resident [C, P] stats.

    Mirrors kernels/minmax_prune_batched.py: per-constraint stat rows are
    gathered from the resident planes by column id; ``(-inf, +inf)``
    constraints are padding no-ops (tv=2, the AND identity).  The K loop
    is a static Python unroll so peak memory stays O(Q*P), never O(Q*K*P).
    """
    Q, Kb = lo.shape
    P = mins.shape[1]
    tv = jnp.full((Q, P), 2, dtype=jnp.int32)
    for k in range(Kb):
        pmin = jnp.take(mins, cids[:, k], axis=0)       # [Q, P]
        pmax = jnp.take(maxs, cids[:, k], axis=0)
        pdem = jnp.take(demote, cids[:, k], axis=0)
        lo_k = lo[:, k][:, None]
        hi_k = hi[:, k][:, None]
        empty = pmin > pmax
        no = (pmax < lo_k) | (pmin > hi_k) | empty
        full = (pmin >= lo_k) & (pmax <= hi_k) & (pdem == 0.0) & ~empty
        tv_k = jnp.where(no, 0, jnp.where(full, 2, 1)).astype(jnp.int32)
        noop = (lo_k == -jnp.inf) & (hi_k == jnp.inf)
        tv_k = jnp.where(noop, 2, tv_k)
        tv = jnp.minimum(tv, tv_k)
    return tv


def minmax_prune_gathered_ref(cids, lo, hi, mins, maxs, demote, pos
                              ) -> jax.Array:
    """tv [Q, W] int32 over per-query *gathered* plane positions.

    The tree path's survivor-restricted evaluator: column w of row q is
    plane position ``pos[q, w]`` (an index into the flattened partition
    dim — used both for the fine group planes and the leaf planes), so
    entry (q, w) equals ``minmax_prune_batched_ref(...)[q, pos[q, w]]``
    bit-for-bit — the gather commutes with every elementwise step of the
    tri-valued conjunction.  Duplicate or padding positions simply
    recompute the same truthful verdict.
    """
    Q, Kb = lo.shape
    stride = mins.shape[1]
    fm = mins.reshape(-1)
    fx = maxs.reshape(-1)
    fd = demote.reshape(-1)
    tv = jnp.full(pos.shape, 2, dtype=jnp.int32)
    for k in range(Kb):
        idx = cids[:, k][:, None] * stride + pos        # [Q, W] flat index
        pmin = jnp.take(fm, idx)
        pmax = jnp.take(fx, idx)
        pdem = jnp.take(fd, idx)
        lo_k = lo[:, k][:, None]
        hi_k = hi[:, k][:, None]
        empty = pmin > pmax
        no = (pmax < lo_k) | (pmin > hi_k) | empty
        full = (pmin >= lo_k) & (pmax <= hi_k) & (pdem == 0.0) & ~empty
        tv_k = jnp.where(no, 0, jnp.where(full, 2, 1)).astype(jnp.int32)
        noop = (lo_k == -jnp.inf) & (hi_k == jnp.inf)
        tv_k = jnp.where(noop, 2, tv_k)
        tv = jnp.minimum(tv, tv_k)
    return tv


def topk_boundary_ref(rows: jax.Array, b_init) -> tuple:
    """(skip [P] int32, heap [k]) — sequential lax.scan with jnp.sort."""
    P, k = rows.shape
    b_init = jnp.asarray(b_init, rows.dtype)

    def step(heap, row):
        h_kth = heap[k - 1]
        heap_full = h_kth > -jnp.inf
        bm = row[0]
        eff = jnp.maximum(b_init, jnp.where(heap_full, h_kth, -jnp.inf))
        skip = (bm < eff) | (heap_full & (bm <= h_kth))
        merged = jnp.sort(jnp.concatenate([heap, row]))[::-1][:k]
        heap = jnp.where(skip, heap, merged)
        return heap, skip.astype(jnp.int32)

    heap0 = jnp.full((k,), -jnp.inf, rows.dtype)
    heap, skips = jax.lax.scan(step, heap0, rows)
    return skips, heap


def topk_boundary_prefix_ref(rows: jax.Array, b_init) -> tuple:
    """DESIGN.md §6: the *associative prefix-merge* formulation.

    top-k-merge is associative, so the evolving heap is an exclusive
    prefix-scan over block top-k rows — parallelizable in log depth with
    jax.lax.associative_scan, unlike the sequential heap.  Because the
    prefix heap merges every row (including ones the sequential algorithm
    skipped — all of which sit at or below the running k-th value), its
    k-th value is always >= the sequential heap's.  Consequences (tested):
      * the final top-k value multiset is IDENTICAL, and
      * the skip mask is a SUPERSET of the sequential one — the parallel
        formulation prunes at least as much.  A beyond-paper improvement.
    """
    P, k = rows.shape
    b_init = jnp.asarray(b_init, rows.dtype)

    def merge(a, b):
        return jnp.sort(jnp.concatenate([a, b], axis=-1), axis=-1)[..., ::-1][..., :k]

    inclusive = jax.lax.associative_scan(merge, rows, axis=0)      # [P, k]
    prev = jnp.concatenate(
        [jnp.full((1, k), -jnp.inf, rows.dtype), inclusive[:-1]], axis=0
    )
    h_kth = prev[:, k - 1]
    heap_full = h_kth > -jnp.inf
    bm = rows[:, 0]
    eff = jnp.maximum(b_init, jnp.where(heap_full, h_kth, -jnp.inf))
    skip = (bm < eff) | (heap_full & (bm <= h_kth))
    return skip.astype(jnp.int32), inclusive[-1]


# ---------------------------------------------------------------------------
# Blocked-Bloom probe primitives (shared by the oracle and the Pallas kernel)
# ---------------------------------------------------------------------------

# Murmur3 finalizer constants as int32 bit patterns (the host mixer in
# core.prune_join works in uint32; two's-complement wraparound is the same
# mod-2^32 arithmetic, so int32 lanes produce identical bits).
MURMUR_C1 = 0x85EBCA6B - (1 << 32)
MURMUR_C2 = 0xC2B2AE35 - (1 << 32)
H1_SALT = 0x9E3779B9 - (1 << 32)
H2_SALT = 0x7F4A7C15


def lsr32(x: jax.Array, s: int) -> jax.Array:
    """Logical right shift of int32 lanes by a constant: the arithmetic
    shift's sign fill is masked off (TPU has no unsigned shift)."""
    if s == 0:
        return x
    return (x >> s) & jnp.int32((1 << (32 - s)) - 1)


def mix32(x: jax.Array) -> jax.Array:
    """Murmur3 finalizer on int32 lanes — bit-identical to the uint32
    host mixer ``core.prune_join._mix32``."""
    x = x ^ lsr32(x, 16)
    x = x * jnp.int32(MURMUR_C1)
    x = x ^ lsr32(x, 13)
    x = x * jnp.int32(MURMUR_C2)
    x = x ^ lsr32(x, 16)
    return x


def bloom_probe_batched_ref(lo_t, hi_t, pmin, width, enum_pad: int) -> jax.Array:
    """hit [Q, P] int32 — jnp oracle for kernels/bloom_probe.py.

    ``lo_t``/``hi_t`` are the packed filters (ops.pack_blooms): [Q, 16, Bb]
    f32 halves of each query's filter words, tiled to the common Bb block
    bucket.  ``pmin``/``width`` are the int32 enumeration rows (width 0 =
    not enumerable = keep).  Dense gather formulation — peak memory is
    O(Q*P*E), so this is the small-shape test oracle; the production
    no-Pallas fallback (ops.bloom_probe_batched_device) instead exploits
    narrowness sparsity with the host BlockedBloom probe.
    """
    Q, _w16, Bb = lo_t.shape
    words = (hi_t.astype(jnp.int32) << 16) | lo_t.astype(jnp.int32)
    flat = words.reshape(Q, -1)                        # [Q, 16 * Bb]
    pmin = pmin.astype(jnp.int32)
    width = width.astype(jnp.int32)
    j = jnp.arange(enum_pad, dtype=jnp.int32)
    cand = pmin[:, None] + j[None, :]                  # [P, E]
    h0 = mix32(cand ^ mix32(cand >> 31))               # >> 31: int64 hi word
    h1 = mix32(h0 ^ jnp.int32(H1_SALT))
    h2 = mix32(h1 ^ jnp.int32(H2_SALT))
    block = h0 & jnp.int32(Bb - 1)
    ok = jnp.ones((Q,) + cand.shape, dtype=bool)
    for i in range(4):
        wi = lsr32(h1, 8 * i) & 15
        bi = lsr32(h2, 8 * i) & 31
        idx = wi * Bb + block                          # [P, E] word index
        w = jnp.take(flat, idx.reshape(-1), axis=1).reshape(ok.shape)
        ok &= ((w >> bi[None]) & 1) == 1
    valid = j[None, :] < width[:, None]                # [P, E]
    hit = jnp.any(ok & valid[None], axis=2) | (width == 0)[None, :]
    return hit.astype(jnp.int32)


def join_overlap_ref(pmin, pmax, distinct) -> jax.Array:
    """hit [P] int32 via searchsorted (the CPU engine's formulation)."""
    lo = jnp.searchsorted(distinct, pmin, side="left")
    hi = jnp.searchsorted(distinct, pmax, side="right")
    return (hi > lo).astype(jnp.int32)


def join_overlap_batched_ref(dist, pmin, pmax) -> jax.Array:
    """hit [Q, P] int32 for Q queries' distinct lists vs one key plane.

    Mirrors kernels/join_overlap.py::join_overlap_batched: ``dist`` is
    [Db, Q] with each query's *sorted* distinct keys on the sublane dim,
    padded with +inf — which sorts last and, with the plane clamped to
    finite f32 (pmax <= f32max), can never land inside a range, so the
    searchsorted counts are untouched by padding."""
    def one(d):
        lo = jnp.searchsorted(d, pmin, side="left")
        hi = jnp.searchsorted(d, pmax, side="right")
        return (hi > lo).astype(jnp.int32)

    return jax.vmap(one, in_axes=1)(dist)


def topk_init_batched_ref(plane, mask, k: int) -> jax.Array:
    """heap [Q, k] — dense masked broadcast + lax.top_k.

    Mirrors kernels/topk_boundary.py::topk_init_batched; peak memory is
    O(Q*P*K), so it serves as the small-shape test oracle.  The
    production no-Pallas fallback (ops.topk_init_batched_device) instead
    exploits mask sparsity with a per-query numpy gather + partition —
    top-k is a pure selection, so both return identical values."""
    Q = mask.shape[1]
    vals = jnp.where(mask.T[:, :, None] > 0, plane[None, :, :], -jnp.inf)
    flat = vals.reshape(Q, -1)
    if flat.shape[1] < k:
        flat = jnp.pad(flat, ((0, 0), (0, k - flat.shape[1])),
                       constant_values=-jnp.inf)
    return jax.lax.top_k(flat, k)[0]


def flash_attention_ref(q, k, v, causal: bool = True) -> jax.Array:
    """Naive softmax attention oracle: q/k/v [BH, S, D]."""
    D = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
