"""Pallas TPU kernel: build-side distinct keys vs probe partition ranges.

The exact path of JOIN pruning (paper Sec. 6): given the build side's
sorted distinct join keys and every probe partition's [min, max] key
range, decide per partition whether ANY build key falls inside its range
— partitions with no hit are pruned before they are fetched.

TPU adaptation: a CPU engine binary-searches each partition's bounds in
the distinct list (branchy, gather-heavy).  Here it becomes an all-pairs
compare ``[BLOCK_P, BLOCK_D]`` with an any-reduction — dense, branch-free
VPU work with perfect locality: distinct-key blocks stream through VMEM
while the partition block's accumulator is revisited (grid is
(P_blocks, D_blocks) with accumulation over the inner D dimension).

Pad value for the distinct list is NaN: NaN compares false against every
bound, so padding never produces a hit.

``join_overlap_batched`` is the workload-scale variant: Q queries' distinct
lists (packed into power-of-two buckets, +inf padded) against the table's
*resident* join-key plane (core/device_stats.py) in one launch — queries on
the sublane dim like minmax_prune_batched, so a table group's JOIN pruning
costs one launch regardless of the number of queries.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 1024
BLOCK_D = 2048
BLOCK_QB = 8     # queries per tile in the batched kernel (f32 sublane height)


def _join_overlap_kernel(pmin_ref, pmax_ref, dist_ref, hit_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        hit_ref[...] = jnp.zeros_like(hit_ref)

    pmin = pmin_ref[0, :]          # [BP]
    pmax = pmax_ref[0, :]          # [BP]
    d = dist_ref[0, :]             # [BD]
    inside = (d[None, :] >= pmin[:, None]) & (d[None, :] <= pmax[:, None])
    hit_ref[...] |= jnp.any(inside, axis=1).astype(jnp.int32)[None, :]


def _join_overlap_batched_kernel(dist_ref, pmin_ref, pmax_ref, hit_ref):
    Db = dist_ref.shape[0]
    BQ = dist_ref.shape[1]
    pmin = pmin_ref[0, :]          # [BP]
    pmax = pmax_ref[0, :]          # [BP]
    BP = pmin.shape[0]

    def body(d, hit):
        dk = dist_ref[d, :][:, None]                       # [BQ, 1]
        inside = (dk >= pmin[None, :]) & (dk <= pmax[None, :])
        return hit | inside.astype(jnp.int32)

    hit = jax.lax.fori_loop(0, Db, body, jnp.zeros((BQ, BP), jnp.int32))
    hit_ref[...] = hit


@functools.partial(jax.jit, static_argnames=("interpret",))
def join_overlap_batched(
    dist: jax.Array,     # [Db, Q] f32 distinct build keys per query,
                         #         +inf padded (keys on the sublane dim)
    pmin: jax.Array,     # [P] f32 resident probe key-column minima (widened,
                         #         FINITE — core.device_stats clamps ±inf)
    pmax: jax.Array,     # [P] f32 resident probe key-column maxima (widened)
    interpret: bool = False,
) -> jax.Array:
    """Batched JOIN overlap: Q build summaries x P probe partitions.

    One launch answers every query of a table group against the resident
    join-key plane — the multi-query analogue of ``join_overlap``, with
    distinct keys packed into power-of-two Db buckets (ops.d_bucket, like
    the K-bucket scheme of minmax_prune_batched) so jit recompiles stay
    bounded.  Padding is ``+inf``: with the plane clamped to finite f32,
    ``+inf <= pmax`` is always False, so a pad key never produces a hit
    (and an all-pad query row yields an all-zero hit row, sliced off).

    Returns hit [Q, P] int32 (0 -> partition is prunable for that query).
    """
    Db, Q = dist.shape
    P = pmin.shape[0]
    pad_q = (-Q) % BLOCK_QB
    if pad_q:
        dist = jnp.pad(dist, ((0, 0), (0, pad_q)), constant_values=jnp.inf)
    pad_p = (-P) % BLOCK_P
    if pad_p:
        # Empty finite intervals, like minmax_prune_batched's P padding.
        fmax = float(jnp.finfo(jnp.float32).max)
        pmin = jnp.pad(pmin, (0, pad_p), constant_values=fmax)
        pmax = jnp.pad(pmax, (0, pad_p), constant_values=-fmax)
    Qp, Pp = Q + pad_q, P + pad_p
    grid = (Qp // BLOCK_QB, Pp // BLOCK_P)
    hit = pl.pallas_call(
        _join_overlap_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Db, BLOCK_QB), lambda i, j: (0, i)),
            pl.BlockSpec((1, BLOCK_P), lambda i, j: (0, j)),
            pl.BlockSpec((1, BLOCK_P), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_QB, BLOCK_P), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Qp, Pp), jnp.int32),
        interpret=interpret,
    )(dist, pmin[None, :], pmax[None, :])
    return hit[:Q, :P]


@functools.partial(jax.jit, static_argnames=("interpret",))
def join_overlap(
    pmin: jax.Array,     # [P] f32 probe partition minima of the key column
    pmax: jax.Array,     # [P] f32 probe partition maxima
    distinct: jax.Array, # [D] f32 sorted distinct build keys
    interpret: bool = False,
) -> jax.Array:
    """Returns hit [P] int32 (0 -> partition is prunable)."""
    P = pmin.shape[0]
    D = distinct.shape[0]
    pad_p = (-P) % BLOCK_P
    pad_d = (-D) % BLOCK_D
    if pad_p:
        pmin = jnp.pad(pmin, (0, pad_p), constant_values=jnp.inf)
        pmax = jnp.pad(pmax, (0, pad_p), constant_values=-jnp.inf)
    if pad_d:
        distinct = jnp.pad(distinct, (0, pad_d), constant_values=jnp.nan)
    Pp, Dp = P + pad_p, D + pad_d
    grid = (Pp // BLOCK_P, Dp // BLOCK_D)
    hit = pl.pallas_call(
        _join_overlap_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_P), lambda i, j: (0, i)),
            pl.BlockSpec((1, BLOCK_P), lambda i, j: (0, i)),
            pl.BlockSpec((1, BLOCK_D), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_P), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Pp), jnp.int32),
        interpret=interpret,
    )(pmin[None, :], pmax[None, :], distinct[None, :])
    return hit[0, :P]
