"""Pallas TPU kernel: build-side distinct keys vs probe partition ranges.

The exact path of JOIN pruning (paper Sec. 6): given the build side's
sorted distinct join keys and every probe partition's [min, max] key
range, decide per partition whether ANY build key falls inside its range
— partitions with no hit are pruned before they are fetched.

TPU adaptation: a CPU engine binary-searches each partition's bounds in
the distinct list (branchy, gather-heavy).  Here it becomes an all-pairs
compare ``[BLOCK_P, BLOCK_D]`` with an any-reduction — dense, branch-free
VPU work with perfect locality: distinct-key blocks stream through VMEM
while the partition block's accumulator is revisited (grid is
(P_blocks, D_blocks) with accumulation over the inner D dimension).

Pad value for the distinct list is NaN: NaN compares false against every
bound, so padding never produces a hit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 1024
BLOCK_D = 2048


def _join_overlap_kernel(pmin_ref, pmax_ref, dist_ref, hit_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        hit_ref[...] = jnp.zeros_like(hit_ref)

    pmin = pmin_ref[0, :]          # [BP]
    pmax = pmax_ref[0, :]          # [BP]
    d = dist_ref[0, :]             # [BD]
    inside = (d[None, :] >= pmin[:, None]) & (d[None, :] <= pmax[:, None])
    hit_ref[...] |= jnp.any(inside, axis=1).astype(jnp.int32)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def join_overlap(
    pmin: jax.Array,     # [P] f32 probe partition minima of the key column
    pmax: jax.Array,     # [P] f32 probe partition maxima
    distinct: jax.Array, # [D] f32 sorted distinct build keys
    interpret: bool = False,
) -> jax.Array:
    """Returns hit [P] int32 (0 -> partition is prunable)."""
    P = pmin.shape[0]
    D = distinct.shape[0]
    pad_p = (-P) % BLOCK_P
    pad_d = (-D) % BLOCK_D
    if pad_p:
        pmin = jnp.pad(pmin, (0, pad_p), constant_values=jnp.inf)
        pmax = jnp.pad(pmax, (0, pad_p), constant_values=-jnp.inf)
    if pad_d:
        distinct = jnp.pad(distinct, (0, pad_d), constant_values=jnp.nan)
    Pp, Dp = P + pad_p, D + pad_d
    grid = (Pp // BLOCK_P, Dp // BLOCK_D)
    hit = pl.pallas_call(
        _join_overlap_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_P), lambda i, j: (0, i)),
            pl.BlockSpec((1, BLOCK_P), lambda i, j: (0, i)),
            pl.BlockSpec((1, BLOCK_D), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_P), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Pp), jnp.int32),
        interpret=interpret,
    )(pmin[None, :], pmax[None, :], distinct[None, :])
    return hit[0, :P]
