"""Pallas TPU kernels (+ ops wrappers, ref oracles).

Pruning hot spots (the paper's engine):
  minmax_prune         — conjunctive-range three-valued filter pruning (Sec. 3)
  minmax_prune_batched — Q queries x K ranges x P partitions in one launch,
                         against the resident metadata plane (device_stats)
  topk_boundary        — WAND-style boundary scan over block top-k rows (Sec. 5)
  topk_init_batched    — Q queries' upfront boundaries (Sec. 5.4) over the
                         resident block-top-k plane in one launch
  join_overlap         — distinct-keys vs partition-range overlap (Sec. 6)
  join_overlap_batched — Q build summaries x P probe partitions against the
                         resident join-key plane in one launch
  bloom_probe_batched  — Q blocked-Bloom filters x P probe partitions in one
                         launch: narrow-range enumeration against the
                         resident enumeration plane (Sec. 6, large-NDV path)
LM hot spot:
  flash_attention      — causal online-softmax attention (prefill compute)

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with interpret=True against the pure-jnp oracles in
ref.py.
"""

from . import ops, ref
from .bloom_probe import bloom_probe_batched
from .flash_attention import flash_attention
from .join_overlap import join_overlap, join_overlap_batched
from .minmax_prune import minmax_prune
from .minmax_prune_batched import minmax_prune_batched
from .topk_boundary import topk_boundary, topk_init_batched

__all__ = ["ops", "ref", "minmax_prune", "minmax_prune_batched",
           "topk_boundary", "topk_init_batched", "join_overlap",
           "join_overlap_batched", "bloom_probe_batched", "flash_attention"]
