"""Pallas TPU kernels (+ ops wrappers, ref oracles).

Pruning hot spots (the paper's engine):
  minmax_prune         — conjunctive-range three-valued filter pruning (Sec. 3)
  minmax_prune_batched — Q queries x K ranges x P partitions in one launch,
                         against the resident metadata plane (device_stats)
  topk_boundary        — WAND-style boundary scan over block top-k rows (Sec. 5)
  join_overlap         — distinct-keys vs partition-range overlap (Sec. 6)
LM hot spot:
  flash_attention      — causal online-softmax attention (prefill compute)

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with interpret=True against the pure-jnp oracles in
ref.py.
"""

from . import ops, ref
from .flash_attention import flash_attention
from .join_overlap import join_overlap
from .minmax_prune import minmax_prune
from .minmax_prune_batched import minmax_prune_batched
from .topk_boundary import topk_boundary

__all__ = ["ops", "ref", "minmax_prune", "minmax_prune_batched",
           "topk_boundary", "join_overlap", "flash_attention"]
