"""jit'd wrappers wiring the Pallas kernels into the pruning engine.

Each op auto-selects the Pallas kernel on TPU, the interpret-mode kernel
when ``interpret=True`` (CPU validation), or the pure-jnp ref as fallback.
Host-side NumPy metadata is staged to device arrays here; the core engine
(core/*) stays NumPy-pure so compile-time pruning never touches a device.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.metadata import PartitionStats
from . import ref
from .join_overlap import join_overlap
from .minmax_prune import minmax_prune
from .topk_boundary import topk_boundary


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def stage_ranges(
    ranges: List[Tuple[int, float, float]], stats: PartitionStats
):
    """Gather per-constraint stat rows into the kernel's [K, P] layout."""
    cids = np.array([c for c, _, _ in ranges], dtype=np.int64)
    lo = jnp.asarray(np.array([l for _, l, _ in ranges], dtype=np.float32))
    hi = jnp.asarray(np.array([h for _, _, h in ranges], dtype=np.float32))
    mins = jnp.asarray(stats.mins.T[cids].astype(np.float32))
    maxs = jnp.asarray(stats.maxs.T[cids].astype(np.float32))
    nullable = jnp.asarray((stats.null_counts.T[cids] > 0).astype(np.float32))
    return lo, hi, mins, maxs, nullable


def prune_ranges_device(
    ranges: List[Tuple[int, float, float]],
    stats: PartitionStats,
    mode: str = "auto",          # 'auto' | 'pallas' | 'interpret' | 'ref'
) -> np.ndarray:
    """Three-valued conjunctive-range pruning on device; returns tv [P]."""
    lo, hi, mins, maxs, nullable = stage_ranges(ranges, stats)
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        tv = ref.minmax_prune_ref(lo, hi, mins, maxs, nullable)
    else:
        tv = minmax_prune(lo, hi, mins, maxs, nullable,
                          interpret=(mode == "interpret") or not _on_tpu())
    return np.asarray(tv)


def build_block_topk(
    values: np.ndarray,
    part_bounds: np.ndarray,
    k: int,
    mask: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Per-partition block top-k table [P, k] (desc, -inf padded).

    This is the metadata-sketch the TPU top-k path consumes; masked-out
    rows (filter misses, nulls) are excluded.
    """
    P = len(part_bounds) - 1
    out = np.full((P, k), -np.inf, dtype=np.float32)
    for p in range(P):
        s, e = int(part_bounds[p]), int(part_bounds[p + 1])
        v = values[s:e]
        if mask is not None:
            v = v[mask[s:e]]
        if v.size:
            top = np.sort(v)[::-1][:k]
            out[p, : len(top)] = top
    return out


def topk_boundary_device(
    rows: np.ndarray,
    b_init: float = -np.inf,
    mode: str = "auto",
) -> Tuple[np.ndarray, np.ndarray]:
    """(skip [P], heap [k]) for pre-ordered block top-k rows."""
    rows_j = jnp.asarray(rows, dtype=jnp.float32)
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        skip, heap = ref.topk_boundary_ref(rows_j, b_init)
    elif mode == "prefix":
        skip, heap = ref.topk_boundary_prefix_ref(rows_j, b_init)
    else:
        skip, heap = topk_boundary(rows_j, jnp.float32(b_init),
                                   interpret=(mode == "interpret") or not _on_tpu())
    return np.asarray(skip), np.asarray(heap)


def join_overlap_device(
    stats: PartitionStats,
    key_col: str,
    distinct: np.ndarray,
    mode: str = "auto",
) -> np.ndarray:
    """hit [P] int32: 1 where a build key may live in the partition."""
    pmin = jnp.asarray(stats.col_min(key_col).astype(np.float32))
    pmax = jnp.asarray(stats.col_max(key_col).astype(np.float32))
    d = jnp.asarray(np.asarray(distinct, dtype=np.float32))
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        hit = ref.join_overlap_ref(pmin, pmax, d)
    else:
        hit = join_overlap(pmin, pmax, d,
                           interpret=(mode == "interpret") or not _on_tpu())
    return np.asarray(hit)
