"""jit'd wrappers wiring the Pallas kernels into the pruning engine.

Each op auto-selects the Pallas kernel on TPU, the interpret-mode kernel
when ``interpret=True`` (CPU validation), or the pure-jnp ref as fallback.
Host-side NumPy metadata is staged to device arrays here; the core engine
(core/*) stays NumPy-pure so compile-time pruning never touches a device.

Device pruning plane (architecture note)
----------------------------------------
Two staging regimes coexist:

  * **Per-query** (``stage_ranges`` / ``prune_ranges_device``): gather the
    ``[K, P]`` stat slice for one query's constraints and launch the
    single-query kernel.  Simple, but every query pays a host transpose +
    H2D copy + launch — fine for one-off queries, wrong for a workload.
  * **Resident + batched** (``prune_ranges_batched_device``): the table's
    full ``[C, P]`` planes live on device in a
    ``core.device_stats.DeviceStatsCache`` (staged once per table
    version); a *batch* of queries is packed into ``[Q, Kb]`` constraint
    tables (Kb a power-of-two bucket, ``(-inf, +inf)`` no-op padding) and
    evaluated by ``minmax_prune_batched`` in one launch, queries on the
    sublane dim.  ``serve.prune_service.PruningService`` is the entry
    point that groups a workload by table and drives this path.

All f32 downcasts go through ``core.device_stats`` (widening + demotion;
see its precision contract).  Integral columns (int / dictionary codes)
get their query bounds snapped to integers first, so the f32 path stays
exactly equal to the f64 host oracle on the paper's workloads.
"""

from __future__ import annotations

import functools
import threading
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PSpec

try:
    from jax.experimental.shard_map import shard_map
except ImportError:              # pragma: no cover - very old jax
    shard_map = None

from ..core.device_stats import (TREE_MIN_GROUPS, DeviceStats,
                                 cast_bounds_f32, cast_stats_f32,
                                 round_down_f32, round_up_f32,
                                 snap_bounds_integral)
from ..core.metadata import PartitionStats
from ..core.prune_join import BLOCK_WORDS
from . import ref
from .bloom_probe import bloom_probe_batched
from .join_overlap import join_overlap, join_overlap_batched
from .minmax_prune import minmax_prune
from .minmax_prune_batched import BLOCK_Q, minmax_prune_batched
from .topk_boundary import topk_boundary, topk_init_batched

# Peak elements per gathered [Q, P_slab] plane on the jnp ref path; keeps
# the no-Pallas fallback memory-bounded for huge P without touching the
# kernel (whose grid already tiles P).
_REF_SLAB_ELEMS = 1 << 25


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Partition-dim sharding (fleet-scale planes; launch/mesh.make_plane_mesh)
# ---------------------------------------------------------------------------
#
# Every batched kernel evaluates queries x partitions with no cross-
# partition coupling except the top-k heap (a pure selection, mergeable by
# rank).  A 1-D ``parts`` mesh therefore shards the resident planes on the
# partition (capacity) dim via shard_map: each device runs the identical
# kernel on its [*, cap/n] shard, verdict rows concatenate, and per-shard
# top-k heaps reduce with the rank-selection merge.  Capacity padding and
# dead-partition sentinels are position-independent no-ops, so a sentinel
# landing on a shard edge behaves exactly as it does mid-plane
# (tests/test_kernel_sentinels.py pins that for all four kernels).

PLANE_AXIS = "parts"


def mesh_shards(mesh, cap: int) -> int:
    """Usable partition-shard count for a capacity-``cap`` plane.

    The mesh's device count when it has a ``parts`` axis dividing ``cap``
    (plane capacities and plane-mesh sizes are both powers of two, so
    this holds for every plane at least as wide as the mesh); otherwise 1
    — the launch simply stays unsharded, same math, one device.
    """
    if mesh is None or shard_map is None:
        return 1
    if PLANE_AXIS not in getattr(mesh, "axis_names", ()):
        return 1
    n = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    return n if (n > 1 and cap % n == 0) else 1


def _use_kernel(mode: str) -> bool:
    """Kernel vs jnp-oracle body inside a sharded launch — the same
    mode policy as the unsharded wrappers (``auto`` off-TPU -> oracle)."""
    return mode != "ref" and (mode != "auto" or _on_tpu())


# Shard count the most recent batched launch on THIS thread actually
# used (1 = unsharded) — the wrappers can demote a mesh-eligible launch
# back to unsharded when the jnp-oracle body's dense footprint exceeds
# the slab bound, and the service's sharded_launches counter must report
# what really ran, not mesh eligibility.  Thread-local so concurrent
# services (the supported multi-threaded serving regime) cannot
# cross-attribute each other's launches.
_shard_note = threading.local()


def last_launch_shards() -> int:
    return getattr(_shard_note, "n", 1)


def _note_shards(n: int) -> int:
    _shard_note.n = int(n)
    return n


# The sharded callables are built once per (mesh, static config) and
# jit-wrapped, so repeated launches hit the jit cache instead of
# re-tracing shard_map eagerly per call — a fleet issues thousands of
# launches over a handful of shape buckets.

@functools.lru_cache(maxsize=None)
def _sharded_minmax(mesh, use_kernel: bool, interp: bool):
    def body(c, l, h, m, x, d):
        if use_kernel:
            return minmax_prune_batched(c, l, h, m, x, d, interpret=interp)
        return ref.minmax_prune_batched_ref(c, l, h, m, x, d)

    rep, sp = PSpec(), PSpec(None, PLANE_AXIS)
    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(rep, rep, rep, sp, sp, sp),
                             out_specs=sp, check_rep=False))


@functools.lru_cache(maxsize=None)
def _sharded_join(mesh, use_kernel: bool, interp: bool):
    def body(d, a, b):
        if use_kernel:
            return join_overlap_batched(d, a, b, interpret=interp)
        return ref.join_overlap_batched_ref(d, a, b)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(PSpec(), PSpec(PLANE_AXIS), PSpec(PLANE_AXIS)),
        out_specs=PSpec(None, PLANE_AXIS), check_rep=False))


@functools.lru_cache(maxsize=None)
def _sharded_bloom(mesh, use_kernel: bool, interp: bool, enum_pad: int):
    def body(l, h, pm, w):
        if use_kernel:
            return bloom_probe_batched(l, h, pm, w, enum_pad=enum_pad,
                                       interpret=interp)
        return ref.bloom_probe_batched_ref(l, h, pm, w, enum_pad)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(PSpec(), PSpec(), PSpec(PLANE_AXIS), PSpec(PLANE_AXIS)),
        out_specs=PSpec(None, PLANE_AXIS), check_rep=False))


@functools.lru_cache(maxsize=None)
def _sharded_topk(mesh, use_kernel: bool, interp: bool, k: int):
    def body(pl, m):
        if use_kernel:
            heap = topk_init_batched(pl, m, k, interpret=interp)
        else:
            heap = ref.topk_init_batched_ref(pl, m, k)
        return heap[None]

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(PSpec(PLANE_AXIS, None), PSpec(PLANE_AXIS, None)),
        out_specs=PSpec(PLANE_AXIS, None, None), check_rep=False))


def _pow2_at_least(n: int, floor: int = 1) -> int:
    b = floor
    while b < n:
        b *= 2
    return b


def k_bucket(k: int) -> int:
    """Constraint-count bucket: next power of two >= max(k, 1).

    Batches are padded up to the bucket with no-op ranges so the batched
    kernel sees a handful of static Kb values, bounding jit recompiles.
    """
    return _pow2_at_least(max(k, 1))


def q_bucket(q: int) -> int:
    """Query-count bucket: next power of two >= max(q, BLOCK_Q)."""
    return _pow2_at_least(max(q, 1), floor=BLOCK_Q)


def d_bucket(d: int) -> int:
    """Distinct-key-count bucket: next power of two >= max(d, 8).

    Batched join overlap pads each query's distinct list up to the bucket
    with +inf no-op keys, so jit recompiles stay bounded — the same scheme
    as ``k_bucket`` for constraint counts.
    """
    return _pow2_at_least(max(d, 1), floor=8)


def bloom_bucket(n_blocks: int) -> int:
    """Bloom block-count bucket: next power of two >= max(n_blocks, 8).

    Filters are *tiled* (not zero-padded) up to the bucket — block
    selection is ``h & (blocks - 1)``, so a periodically repeated filter
    probes identical words under the larger mask (see pack_blooms) —
    and the floor keeps the packed [16, Bb] word planes at full sublane
    height.
    """
    return _pow2_at_least(max(n_blocks, 1), floor=8)


def enum_bucket(w: int) -> int:
    """Enumeration-lane bucket: next power of two >= max(w, 128).

    The Bloom kernel enumerates a partition's candidate values on the
    lane dim; the bucket keeps lanes full (128) and recompiles bounded.
    """
    return _pow2_at_least(max(w, 1), floor=128)


# Kernel-path cap on blocks per Bloom filter: the in-kernel one-hot gather
# materializes a [Bb, E] f32 tile per probe step (4MB at 1024 x 1024 —
# comfortably inside VMEM next to the [16, Bb] word planes).  Bigger
# filters (build NDV > ~32k at 16 bits/key) fall back to the host
# matcher, counted per technique.
BLOOM_MAX_BLOCKS = 1024


# ---------------------------------------------------------------------------
# Per-query staging (single-launch path)
# ---------------------------------------------------------------------------

def _stage_ranges(ranges, stats: PartitionStats):
    """One staging pass: kernel inputs + whether FULL is provable.

    Returns ((lo, hi, mins, maxs, demote) device arrays, full_safe bool).
    The f32 downcast is centralized in core.device_stats: stat intervals
    are widened (mins down, maxs up) and partitions whose cast was inexact
    are FULL-demoted via the nullable/demote plane; full_safe is False
    when any query bound's own cast was inexact.
    """
    cids = np.array([c for c, _, _ in ranges], dtype=np.int64)
    lo64 = np.array([l for _, l, _ in ranges], dtype=np.float64)
    hi64 = np.array([h for _, _, h in ranges], dtype=np.float64)
    integral = np.array([c.kind != "float" for c in stats.columns], dtype=bool)
    lo64, hi64 = snap_bounds_integral(lo64, hi64, integral[cids])
    lo32, hi32, exact = cast_bounds_f32(lo64, hi64)
    mins32, maxs32, inexact = cast_stats_f32(stats.mins.T[cids],
                                             stats.maxs.T[cids])
    demote = ((stats.null_counts.T[cids] > 0) | inexact).astype(np.float32)
    staged = (jnp.asarray(lo32), jnp.asarray(hi32), jnp.asarray(mins32),
              jnp.asarray(maxs32), jnp.asarray(demote))
    return staged, bool(exact.all())


def stage_ranges(
    ranges: List[Tuple[int, float, float]],
    stats: PartitionStats,
):
    """Gather per-constraint stat rows into the kernel's [K, P] layout."""
    staged, _ = _stage_ranges(ranges, stats)
    return staged


def prune_ranges_device(
    ranges: List[Tuple[int, float, float]],
    stats: PartitionStats,
    mode: str = "auto",          # 'auto' | 'pallas' | 'interpret' | 'ref'
) -> np.ndarray:
    """Three-valued conjunctive-range pruning on device; returns tv [P]."""
    if not ranges:   # empty conjunction == TruePred: everything FULL
        return np.full(stats.num_partitions, 2, dtype=np.int8)
    (lo, hi, mins, maxs, nullable), full_safe = _stage_ranges(ranges, stats)
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        tv = ref.minmax_prune_ref(lo, hi, mins, maxs, nullable)
    else:
        tv = minmax_prune(lo, hi, mins, maxs, nullable,
                          interpret=(mode == "interpret") or not _on_tpu())
    tv = np.asarray(tv)
    if not full_safe:
        tv = np.minimum(tv, 1)   # inexact f32 bounds: FULL is not provable
    return tv


# ---------------------------------------------------------------------------
# Batched multi-query path (resident metadata plane)
# ---------------------------------------------------------------------------

def pack_ranges(
    range_lists: Sequence[List[Tuple[int, float, float]]],
    dstats: DeviceStats,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Pack per-query constraint lists into [Qb, Kb] kernel inputs.

    Returns (cids int32, lo f32, hi f32, full_safe bool[Q]).  Constraint
    slots beyond a query's K and query rows beyond Q are ``(-inf, +inf)``
    no-ops; Kb/Qb are power-of-two buckets so recompiles stay bounded.
    """
    Q = len(range_lists)
    Kb = k_bucket(max((len(r) for r in range_lists), default=1))
    Qb = q_bucket(Q)
    cids = np.zeros((Qb, Kb), dtype=np.int32)
    valid = np.zeros((Qb, Kb), dtype=bool)
    lo64 = np.full((Qb, Kb), -np.inf, dtype=np.float64)
    hi64 = np.full((Qb, Kb), np.inf, dtype=np.float64)
    for qi, ranges in enumerate(range_lists):
        for ki, (cid, lo_v, hi_v) in enumerate(ranges):
            cids[qi, ki] = cid
            valid[qi, ki] = True
            lo64[qi, ki] = lo_v
            hi64[qi, ki] = hi_v
    lo64, hi64 = snap_bounds_integral(lo64, hi64, dstats.integral[cids])
    lo32, hi32, exact = cast_bounds_f32(lo64, hi64)
    # cast_bounds_f32 clamps to finite f32; re-impose the (-inf, +inf)
    # sentinel on padding slots so the kernel's no-op detection fires.
    lo32 = np.where(valid, lo32, np.float32(-np.inf))
    hi32 = np.where(valid, hi32, np.float32(np.inf))
    full_safe = (exact | ~valid).all(axis=1)[:Q]
    return cids, lo32, hi32, full_safe


_batched_ref_jit = jax.jit(ref.minmax_prune_batched_ref)


def prune_ranges_batched_device(
    range_lists: Sequence[List[Tuple[int, float, float]]],
    dstats: DeviceStats,
    mode: str = "auto",          # 'auto' | 'pallas' | 'interpret' | 'ref'
    mesh=None,                   # 1-D 'parts' mesh: shard the partition dim
) -> np.ndarray:
    """Evaluate Q queries' conjunctive ranges in one batched launch.

    Returns tv ``[Q, P]`` int8 — row q is identical to the per-query
    device path for query q's ranges, and to the f64 host oracle on
    int/dictionary workloads (bounds snap to integers and cast exactly).
    Bounds that are inexact in f32 demote FULL to PARTIAL — never a false
    NO_MATCH or false FULL (core.device_stats precision contract).

    With ``mesh`` (``launch.mesh.make_plane_mesh``) the resident planes
    shard on the capacity dim: each device evaluates its partition slice
    and the verdict rows concatenate — bit-identical to the unsharded
    launch (partitions are independent).
    """
    Q = len(range_lists)
    # one consistent snapshot: a concurrent delta replay swaps the whole
    # (planes, logical P) pair atomically, so a single read here can
    # never mix post-DML planes with a pre-DML partition count (or
    # vice versa)
    planes, P = dstats.planes_state
    mins, maxs, demote = planes
    Pc = int(mins.shape[1])            # staged capacity (>= P; sentinel tail)
    cids, lo, hi, full_safe = pack_ranges(range_lists, dstats)
    Qb = cids.shape[0]
    cids_d = jnp.asarray(cids)
    lo_d = jnp.asarray(lo)
    hi_d = jnp.asarray(hi)
    shards = mesh_shards(mesh, Pc)
    if (shards > 1 and not _use_kernel(mode)
            and Qb * Pc // shards > _REF_SLAB_ELEMS):
        shards = 1     # per-shard jnp body would exceed the slab bound;
                       # the unsharded path below slabs instead
    _note_shards(shards)
    if shards > 1:
        fn = _sharded_minmax(mesh, _use_kernel(mode),
                             (mode == "interpret") or not _on_tpu())
        tv = np.asarray(fn(cids_d, lo_d, hi_d, mins, maxs, demote))
    elif mode == "ref" or (mode == "auto" and not _on_tpu()):
        slab = max(1024, _REF_SLAB_ELEMS // Qb)
        if slab >= Pc:
            tv = np.asarray(_batched_ref_jit(
                cids_d, lo_d, hi_d, mins, maxs, demote))
        else:
            tv = np.empty((Qb, Pc), dtype=np.int32)
            for s in range(0, Pc, slab):
                e = min(s + slab, Pc)
                tv[:, s:e] = np.asarray(_batched_ref_jit(
                    cids_d, lo_d, hi_d,
                    jax.lax.slice_in_dim(mins, s, e, axis=1),
                    jax.lax.slice_in_dim(maxs, s, e, axis=1),
                    jax.lax.slice_in_dim(demote, s, e, axis=1)))
    else:
        tv = np.asarray(minmax_prune_batched(
            cids_d, lo_d, hi_d, mins, maxs, demote,
            interpret=(mode == "interpret") or not _on_tpu()))
    tv = tv[:Q, :P].astype(np.int8)
    if not full_safe.all():
        tv[~full_safe] = np.minimum(tv[~full_safe], 1)
    return tv


def prune_ranges_batched_host(
    range_lists: Sequence[List[Tuple[int, float, float]]],
    stats: PartitionStats,
) -> np.ndarray:
    """Pure-numpy host fallback for the batched range kernel.

    The degradation ladder's third rung: same ``[Q, P]`` int8 verdict
    contract as ``prune_ranges_batched_device`` but evaluated directly
    on the host f64 stats — no device, no staged planes, no f32 cast, so
    it is bit-identical to the per-query ``eval_tv`` host oracle on
    every predicate whose ranges lowered (the closed-interval semantics:
    NO when the partition interval misses [lo, hi], FULL when it sits
    inside with no nulls, PARTIAL otherwise; constraints AND via min).
    An empty range list is the TruePred lowering: everything FULL.
    """
    P = stats.num_partitions
    tv = np.full((len(range_lists), P), 2, dtype=np.int8)
    mins, maxs = stats.mins, stats.maxs            # [P, C] float64
    has_nulls = stats.null_counts > 0
    for qi, ranges in enumerate(range_lists):
        row = np.full(P, 2, dtype=np.int8)
        for cid, lo, hi in ranges:
            pmin, pmax = mins[:, cid], maxs[:, cid]
            no = (pmax < lo) | (pmin > hi)
            full = (pmin >= lo) & (pmax <= hi) & ~has_nulls[:, cid]
            row = np.minimum(
                row, np.where(no, 0, np.where(full, 2, 1)).astype(np.int8))
        tv[qi] = row
    return tv


# ---------------------------------------------------------------------------
# Hierarchical (tree) pruning path: group pre-pass + gathered leaf eval
# ---------------------------------------------------------------------------
#
# The flat batched path is linear in P — every query touches every
# partition slot.  The tree path makes the device work proportional to
# *survivors* instead, in three levels (core.device_stats stages the
# aggregated planes; see its tree-geometry note):
#
#   0. host coarse: the [C, G2] root hulls (G2 <= 64) evaluate in numpy —
#      this both restricts level 1 and *prices* the pre-pass before any
#      launch.  Coarse survivors bound fine survivors from above (a dead
#      root kills all its children), so a coarse density over the cutoff
#      proves the fine pre-pass can't win and the flat launch runs with
#      ZERO extra launches — the stale-selectivity guarantee.
#   1. fine group pre-pass: the [C, G] group planes evaluate only at
#      coarse-survivor children, per-query, via the gathered oracle.
#   2. leaf: the flat [C, cap] planes evaluate only at surviving groups'
#      member positions; verdicts scatter into the [Q, P] output.  Every
#      unlisted live partition sits in a group whose hull missed the
#      query, and group NO_MATCH implies member NO_MATCH, so the
#      scattered rows are bit-identical to the flat evaluation.
#
# FULL is never decided above the leaves: sentinel members don't widen a
# hull, so a hull inside [lo, hi] proves nothing about its members — the
# pre-pass only ever decides NO_MATCH vs survive (over-approximation is
# structural, exactly the Extensible-Data-Skipping safety argument).

TREE_DENSE_CUTOFF = 0.5

# What the most recent tree-path launch on THIS thread actually did
# (path taken, group counts, survivor densities) — benches and parity
# tests read it; thread-local like the shard note.
_tree_note = threading.local()


def last_tree_stats() -> dict:
    return getattr(_tree_note, "d", {})


def _note_tree(**kw) -> None:
    _tree_note.d = dict(kw)


_gathered_ref_jit = jax.jit(ref.minmax_prune_gathered_ref)


def _coarse_survivors(cids, lo, hi, cmins, cmaxs) -> np.ndarray:
    """surv [Q, G2] bool — host evaluation of the coarse root level.

    Mirrors the NO_MATCH term of the batched oracle (empty-hull and
    range-miss tests); padding no-op slots keep everything."""
    surv = np.ones((cids.shape[0], cmins.shape[1]), dtype=bool)
    for k in range(cids.shape[1]):
        pm = cmins[cids[:, k]]                        # [Q, G2]
        px = cmaxs[cids[:, k]]
        lo_k = lo[:, k][:, None]
        hi_k = hi[:, k][:, None]
        noop = (lo_k == -np.inf) & (hi_k == np.inf)
        no = ((pm > px) | (px < lo_k) | (pm > hi_k)) & ~noop
        surv &= ~no
    return surv


def _survivor_positions(surv: np.ndarray, span: int) -> np.ndarray:
    """pos [Q, Sb * span] int32 — each row's surviving ids expanded to
    their ``span`` child positions (id * span + j), right-padded with id
    0's children up to the pow-2 bucket Sb of the max per-row survivor
    count (bounded jit shapes).  Padding is *exact*, not a sentinel: the
    gathered evaluator computes the true verdict at every listed
    position, and scattering a truthful verdict twice — or for a
    non-surviving group, whose members are provably NO — changes
    nothing."""
    Q = surv.shape[0]
    counts = surv.sum(axis=1)
    sb = _pow2_at_least(max(int(counts.max()), 1))
    ids = np.zeros((Q, sb), dtype=np.int64)
    qs, gs = np.nonzero(surv)
    col = np.arange(len(qs)) - np.repeat(np.cumsum(counts) - counts, counts)
    ids[qs, col] = gs
    pos = (ids[:, :, None] * span
           + np.arange(span, dtype=np.int64)[None, None, :])
    return pos.reshape(Q, sb * span).astype(np.int32)


def prune_ranges_batched_tree(
    range_lists: Sequence[List[Tuple[int, float, float]]],
    dstats: DeviceStats,
    tree_entry,                  # DeviceStatsCache.tree_plane(...) entry
    mode: str = "auto",
    mesh=None,
    dense_cutoff: float = TREE_DENSE_CUTOFF,
) -> np.ndarray:
    """tv [Q, P] int8 via the hierarchical group pre-pass.

    Bit-identical to ``prune_ranges_batched_device`` row for row (and so
    to the f64 host oracle wherever the flat path is): the pre-pass only
    removes positions whose group hull *proves* NO_MATCH.  Falls back to
    the flat launch when the table is too small for the tree geometry or
    the coarse survivor density exceeds ``dense_cutoff`` — the density
    check runs on the host coarse level, so the dense-workload fallback
    never pays a pre-pass launch.  The gathered evaluations use the jnp
    oracle on every backend (XLA-native gathers; the Pallas kernel
    remains the flat path's dense evaluator), and are unsharded — a mesh
    is forwarded to the flat fallback only.
    """
    Q = len(range_lists)
    planes, P = dstats.planes_state
    mins, maxs, demote = planes
    Pc = int(mins.shape[1])
    gm, gx, gd = tree_entry.arrays[:3]
    cmins, cmaxs = (np.asarray(a) for a in tree_entry.arrays[3:])
    fanout = int(tree_entry.meta["fanout"])
    G = int(gm.shape[1])
    if Q == 0 or Pc != G * fanout or P < fanout * TREE_MIN_GROUPS:
        _note_tree(path="flat_small", groups=G)
        return prune_ranges_batched_device(range_lists, dstats, mode,
                                           mesh=mesh)
    cids, lo, hi, full_safe = pack_ranges(range_lists, dstats)
    Qb = cids.shape[0]
    # Level 0 — padding rows beyond Q are all-no-op and survive
    # everything; the density must price only the real rows.
    csurv = _coarse_survivors(cids[:Q], lo[:Q], hi[:Q], cmins, cmaxs)
    G2 = csurv.shape[1]
    cdens = csurv.sum(axis=1).max() / G2
    if cdens > dense_cutoff:
        _note_tree(path="flat_dense", groups=G, coarse_density=float(cdens))
        return prune_ranges_batched_device(range_lists, dstats, mode,
                                           mesh=mesh)
    cids_d = jnp.asarray(cids)
    lo_d = jnp.asarray(lo)
    hi_d = jnp.asarray(hi)

    def pad_rows(a):
        return np.concatenate(
            [a, np.zeros((Qb - Q, a.shape[1]), dtype=a.dtype)], axis=0)

    # Level 1 — fine group pre-pass over coarse-survivor children only.
    gpos = _survivor_positions(csurv, G // G2)            # [Q, S2b * f2]
    tvg = np.asarray(_gathered_ref_jit(
        cids_d, lo_d, hi_d, gm, gx, gd, jnp.asarray(pad_rows(gpos))))[:Q]
    gsurv = np.zeros((Q, G), dtype=bool)
    qrow = np.repeat(np.arange(Q), gpos.shape[1])
    gsurv[qrow, gpos.reshape(-1)] = (tvg > 0).reshape(-1)
    fdens = gsurv.sum(axis=1).max() / G
    # Level 2 — gathered leaf evaluation over surviving groups' members,
    # slabbed like the flat ref path (slab and W are both pow-2 multiples
    # of fanout, so chunk widths repeat and recompiles stay bounded).
    pos = _survivor_positions(gsurv, fanout)              # [Q, Sb * fanout]
    W = pos.shape[1]
    groups_per_slab = max(1, (_REF_SLAB_ELEMS // max(Qb, 1)) // fanout)
    slab = fanout * (1 << (groups_per_slab.bit_length() - 1))
    pos_d = jnp.asarray(pad_rows(pos))
    if W <= slab:
        tvl = np.asarray(_gathered_ref_jit(
            cids_d, lo_d, hi_d, mins, maxs, demote, pos_d))[:Q]
    else:
        tvl = np.empty((Q, W), dtype=np.int32)
        for s in range(0, W, slab):
            e = min(s + slab, W)
            tvl[:, s:e] = np.asarray(_gathered_ref_jit(
                cids_d, lo_d, hi_d, mins, maxs, demote,
                jax.lax.slice_in_dim(pos_d, s, e, axis=1)))[:Q]
    _note_shards(1)
    # Scatter — unlisted positions stay 0 (NO): every unlisted live
    # partition sits in a pruned group, and group NO implies member NO.
    tv = np.zeros((Q, P), dtype=np.int8)
    ps = pos.reshape(-1)
    live = ps < P                    # capacity-tail sentinel slots
    qs = np.repeat(np.arange(Q), W)[live]
    tv[qs, ps[live]] = tvl.reshape(-1)[live].astype(np.int8)
    if not full_safe.all():
        tv[~full_safe] = np.minimum(tv[~full_safe], 1)
    _note_tree(path="tree", groups=G, coarse_density=float(cdens),
               fine_density=float(fdens), leaf_cols=int(W))
    return tv


def join_overlap_batched_tree(
    distinct_lists: Sequence[np.ndarray],
    pmin: jnp.ndarray,
    pmax: jnp.ndarray,
    tree_entry,
    key_ci: int,
    mode: str = "auto",
    part_ids_lists: Optional[Sequence[np.ndarray]] = None,
    mesh=None,
    dense_cutoff: float = TREE_DENSE_CUTOFF,
) -> np.ndarray:
    """hit [Q, P] — group pre-pass wrapper over the batched join overlap.

    The stat tree's ``key_ci`` row is a hull over the same widened f32
    member intervals as the join-key plane (both derive from the same
    ``round_down/round_up + clamp`` of the same f64 column stats), so a
    distinct list that misses group g's hull misses every member: those
    members' hits are provably 0 and drop out of the part-id restriction
    handed to the flat evaluator.  Bit-identical either way; the kernel
    path ignores part-id restrictions by design (dense resident
    evaluation), so the win lands on the no-Pallas fallback.
    """
    Q = len(distinct_lists)
    P = int(pmin.shape[0])
    fanout = int(tree_entry.meta["fanout"])
    G = int(tree_entry.meta["groups"])
    if Q == 0 or P > G * fanout:
        _note_tree(path="flat_small", groups=G)
        return join_overlap_batched_device(distinct_lists, pmin, pmax, mode,
                                           part_ids_lists, mesh)
    hg_lo = np.asarray(tree_entry.arrays[0])[key_ci]      # [G] group hulls
    hg_hi = np.asarray(tree_entry.arrays[1])[key_ci]
    restricted = []
    dens = 0.0
    for qi, d in enumerate(distinct_lists):
        d32 = np.asarray(d, dtype=np.float32)
        # group g may hit iff some distinct key lands in its hull; an
        # empty hull (all-sentinel group) brackets nothing.
        ghit = (np.searchsorted(d32, hg_hi, side="right")
                > np.searchsorted(d32, hg_lo, side="left"))
        dens = max(dens, ghit.sum() / G)
        ids = (np.arange(P) if part_ids_lists is None
               else np.asarray(part_ids_lists[qi]))
        restricted.append(ids[ghit[ids // fanout]])
    if dens > dense_cutoff:
        _note_tree(path="flat_dense", groups=G, fine_density=float(dens))
        return join_overlap_batched_device(distinct_lists, pmin, pmax, mode,
                                           part_ids_lists, mesh)
    _note_tree(path="tree", groups=G, fine_density=float(dens))
    return join_overlap_batched_device(distinct_lists, pmin, pmax, mode,
                                       restricted, mesh)


def bloom_probe_batched_tree(
    blooms: Sequence,
    pmin: jnp.ndarray,
    width: jnp.ndarray,
    wmax: int,
    enum_limit: int,
    tree_entry,
    mode: str = "auto",
    part_ids_lists: Optional[Sequence[np.ndarray]] = None,
    mesh=None,
) -> np.ndarray:
    """hit [Q, P] — group pre-pass wrapper over the batched Bloom probe.

    Bloom pruning only ever decides partitions that are *enumerable*
    (0 < width <= enum_limit); everything else is an unconditional keep.
    The group pre-pass aggregates enumerability over the width plane
    (one host reshape over the resident view — no launch) and restricts
    the part-id lists to members of groups with at least one enumerable
    member.  The restriction covers every enumerable partition, so the
    excluded rows are exactly the flat path's unconditional keeps —
    bit-identical.
    """
    Q = len(blooms)
    P = int(pmin.shape[0])
    fanout = int(tree_entry.meta["fanout"])
    G = int(tree_entry.meta["groups"])
    w = np.asarray(width)
    if Q == 0 or int(w.shape[0]) != G * fanout:
        _note_tree(path="flat_small", groups=G)
        return bloom_probe_batched_device(blooms, pmin, width, wmax,
                                          enum_limit, mode, part_ids_lists,
                                          mesh)
    genum = ((w > 0) & (w <= enum_limit)).reshape(G, fanout).any(axis=1)
    restricted = []
    for qi in range(Q):
        ids = (np.arange(P) if part_ids_lists is None
               else np.asarray(part_ids_lists[qi]))
        restricted.append(ids[genum[ids // fanout]])
    _note_tree(path="tree", groups=G, fine_density=float(genum.mean()))
    return bloom_probe_batched_device(blooms, pmin, width, wmax, enum_limit,
                                      mode, restricted, mesh)


def topk_init_batched_tree(
    plane: jnp.ndarray,
    mask: np.ndarray,
    k: int,
    tree_entry,
    mode: str = "auto",
    mesh=None,
    dense_cutoff: float = TREE_DENSE_CUTOFF,
) -> np.ndarray:
    """heap [Q, k] — group-compacted wrapper over the batched top-k init.

    The union of the candidate masks' groups names every plane row any
    query can select from, so evaluating the compacted [S * fanout, K]
    plane slice with compacted masks returns identical value multisets
    (top-k is a pure selection; masked-out rows contribute nothing).
    Dense unions fall back flat; the compacted capacity rarely divides a
    plane mesh, so the compacted launch runs unsharded.
    """
    mask = np.asarray(mask)
    Q = int(mask.shape[0])
    fanout = int(tree_entry.meta["fanout"])
    G = int(tree_entry.meta["groups"])
    Pp = int(plane.shape[0])
    if Q == 0 or Pp != G * fanout:
        _note_tree(path="flat_small", groups=G)
        return topk_init_batched_device(plane, mask, k, mode, mesh)
    m = mask
    if m.shape[1] < Pp:
        m = np.pad(m, ((0, 0), (0, Pp - m.shape[1])))
    gunion = m.reshape(Q, G, fanout).any(axis=(0, 2))      # [G]
    dens = gunion.sum() / G
    if dens > dense_cutoff:
        _note_tree(path="flat_dense", groups=G, fine_density=float(dens))
        return topk_init_batched_device(plane, mask, k, mode, mesh)
    gids = np.nonzero(gunion)[0]
    _note_tree(path="tree", groups=G, fine_density=float(dens))
    if not gids.size:
        return np.full((Q, k), -np.inf, dtype=np.float32)
    pos = (gids[:, None] * fanout
           + np.arange(fanout)[None, :]).reshape(-1).astype(np.int32)
    cplane = jnp.take(plane, jnp.asarray(pos), axis=0)
    return topk_init_batched_device(cplane, m[:, pos], k, mode, mesh)


# ---------------------------------------------------------------------------
# Top-k / join staging
# ---------------------------------------------------------------------------

def build_block_topk(
    values: np.ndarray,
    part_bounds: np.ndarray,
    k: int,
    mask: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Per-partition block top-k table [P, k] (desc, -inf padded).

    This is the metadata-sketch the TPU top-k path consumes; masked-out
    rows (filter misses, nulls) are excluded.  Segmented formulation: one
    lexsort by (partition, -value) then a rank-within-partition select —
    O(N log N) total with no Python loop over P.

    part_bounds must be non-decreasing row offsets (they are cumulative
    by construction everywhere in the engine).  NaN values are dropped
    (a NaN in a sketch row would corrupt topk_boundary's comparisons).
    """
    part_bounds = np.asarray(part_bounds)
    if np.any(np.diff(part_bounds) < 0):
        raise ValueError("part_bounds must be non-decreasing row offsets")
    P = len(part_bounds) - 1
    out = np.full((P, k), -np.inf, dtype=np.float32)
    values = np.asarray(values)
    # Clamp like the slice values[s:e] would: bounds may overrun values.
    cb = np.clip(part_bounds, 0, len(values))
    lo_row, hi_row = int(cb[0]), int(cb[-1])
    # Widen, don't round-to-nearest: a plane value must never understate
    # the block's potential, or the boundary test could skip a match.
    vals = round_up_f32(values[lo_row:hi_row])
    pid = np.repeat(np.arange(P), np.diff(cb))
    if mask is not None:
        sel = np.asarray(mask, dtype=bool)[lo_row:hi_row]
        vals = vals[sel]
        pid = pid[sel]
    finite = ~np.isnan(vals)
    if not finite.all():
        vals = vals[finite]
        pid = pid[finite]
    if vals.size == 0:
        return out
    order = np.lexsort((-vals, pid))        # partition-major, value desc
    pid_s = pid[order]
    vals_s = vals[order]
    starts = np.searchsorted(pid_s, np.arange(P), side="left")
    rank = np.arange(len(vals_s)) - starts[pid_s]
    keep = rank < k
    out[pid_s[keep], rank[keep]] = vals_s[keep]
    return out


def topk_boundary_device(
    rows: np.ndarray,
    b_init: float = -np.inf,
    mode: str = "auto",
) -> Tuple[np.ndarray, np.ndarray]:
    """(skip [P], heap [k]) for pre-ordered block top-k rows."""
    rows_j = jnp.asarray(rows, dtype=jnp.float32)
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        skip, heap = ref.topk_boundary_ref(rows_j, b_init)
    elif mode == "prefix":
        skip, heap = ref.topk_boundary_prefix_ref(rows_j, b_init)
    else:
        # round the upfront boundary down so a narrowed b_init can never
        # skip a block the f64 boundary would have kept
        b32 = jnp.asarray(round_down_f32(b_init))
        skip, heap = topk_boundary(rows_j, b32,
                                   interpret=(mode == "interpret") or not _on_tpu())
    return np.asarray(skip), np.asarray(heap)


def join_overlap_device(
    stats: PartitionStats,
    key_col: str,
    distinct: np.ndarray,
    mode: str = "auto",
) -> np.ndarray:
    """hit [P] int32: 1 where a build key may live in the partition."""
    pmin = jnp.asarray(round_down_f32(stats.col_min(key_col)))
    pmax = jnp.asarray(round_up_f32(stats.col_max(key_col)))
    d = jnp.asarray(np.asarray(distinct, dtype=np.float32))
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        hit = ref.join_overlap_ref(pmin, pmax, d)
    else:
        hit = join_overlap(pmin, pmax, d,
                           interpret=(mode == "interpret") or not _on_tpu())
    return np.asarray(hit)


# ---------------------------------------------------------------------------
# Batched runtime-technique paths (resident join-key / block-top-k planes)
# ---------------------------------------------------------------------------

def pack_distinct(
    distinct_lists: Sequence[np.ndarray],
) -> np.ndarray:
    """Pack per-query sorted distinct keys into the [Db, Qb] kernel layout.

    Db/Qb are power-of-two buckets (``d_bucket`` / ``q_bucket``); padding
    is +inf — sorted last (the ref path binary-searches each column) and
    never inside a finite range (the kernel path compares directly).
    """
    Q = len(distinct_lists)
    Db = d_bucket(max((len(d) for d in distinct_lists), default=1))
    Qb = q_bucket(Q)
    dist = np.full((Db, Qb), np.inf, dtype=np.float32)
    for qi, d in enumerate(distinct_lists):
        dist[: len(d), qi] = np.asarray(d, dtype=np.float32)
    return dist


def join_overlap_batched_device(
    distinct_lists: Sequence[np.ndarray],
    pmin: jnp.ndarray,       # [P] resident f32 key-column minima (widened)
    pmax: jnp.ndarray,       # [P] resident f32 key-column maxima (widened)
    mode: str = "auto",
    part_ids_lists: Optional[Sequence[np.ndarray]] = None,
    mesh=None,
) -> np.ndarray:
    """hit [Q, P] int32 — Q build summaries vs the resident key plane.

    Row q equals ``join_overlap_device`` for query q's distinct list; one
    launch covers the whole table group.  The f32 key cast is round-to-
    nearest, which is monotone, so a key inside a partition's true f64
    range is always inside the *widened* resident range — the device path
    can keep extra partitions (degrading pruning) but never prunes a
    partition containing a joinable key.

    ``part_ids_lists`` optionally names the partitions each query will
    actually consult (its current scan set).  The kernel path ignores it —
    the resident plane is evaluated dense, that is the batched design —
    but the no-Pallas fallback restricts its C-speed searchsorted to those
    positions (other entries are 0 and must not be read).
    """
    Q = len(distinct_lists)
    P = int(pmin.shape[0])
    shards = mesh_shards(mesh, P)
    if (shards > 1 and not _use_kernel(mode)
            and q_bucket(Q) * P // shards > _REF_SLAB_ELEMS):
        shards = 1     # keep the C-speed searchsorted fallback below
    _note_shards(shards)
    if shards > 1:
        fn = _sharded_join(mesh, _use_kernel(mode),
                           (mode == "interpret") or not _on_tpu())
        hit = np.asarray(fn(jnp.asarray(pack_distinct(distinct_lists)),
                            pmin, pmax))
        return hit[:Q]
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        # np.asarray of a CPU-backed jax array is a view — the resident
        # plane is not copied.  A key k32 hits [pmin, pmax] iff
        # searchsorted brackets it: identical counts to the jnp oracle.
        pmin_h = np.asarray(pmin)
        pmax_h = np.asarray(pmax)
        hit = np.zeros((Q, P), dtype=np.int32)
        for qi, d in enumerate(distinct_lists):
            d32 = np.asarray(d, dtype=np.float32)
            ids = None if part_ids_lists is None else part_ids_lists[qi]
            lo_q = pmin_h if ids is None else pmin_h[ids]
            hi_q = pmax_h if ids is None else pmax_h[ids]
            lo = np.searchsorted(d32, lo_q, side="left")
            hi = np.searchsorted(d32, hi_q, side="right")
            row = (hi > lo).astype(np.int32)
            if ids is None:
                hit[qi] = row
            else:
                hit[qi, ids] = row
        return hit
    dist_d = jnp.asarray(pack_distinct(distinct_lists))
    hit = np.asarray(join_overlap_batched(
        dist_d, pmin, pmax,
        interpret=(mode == "interpret") or not _on_tpu()))
    return hit[:Q]


def pack_blooms(blooms: Sequence) -> Tuple[np.ndarray, np.ndarray]:
    """Pack Q blocked-Bloom filters into the kernel's [Qb, 16, Bb] layout.

    Returns (lo, hi): exact f32 16-bit halves of the filter words, word
    index on the sublane dim (pre-transposed for the kernel's one-hot
    matmul gather).  Each filter is tiled periodically up to the common
    power-of-two Bb bucket: blocked-Bloom block selection is
    ``h & (n_blocks - 1)``, and ``tiled[h & (Bb - 1)] == words[h & (nb - 1)]``
    for any pow-2 multiple Bb, so every query in a launch shares one
    block mask and recompiles stay bounded by |buckets|.  Query rows
    beyond Q are all-zero filters (never a hit; sliced off).
    """
    Q = len(blooms)
    Bb = bloom_bucket(max(b.n_blocks for b in blooms))
    Qb = q_bucket(Q)
    lo = np.zeros((Qb, BLOCK_WORDS, Bb), dtype=np.float32)
    hi = np.zeros((Qb, BLOCK_WORDS, Bb), dtype=np.float32)
    for qi, b in enumerate(blooms):
        w = b.words.reshape(b.n_blocks, BLOCK_WORDS).T        # [16, nb]
        w = np.tile(w, (1, Bb // b.n_blocks))                 # [16, Bb]
        lo[qi] = (w & np.uint32(0xFFFF)).astype(np.float32)
        hi[qi] = (w >> np.uint32(16)).astype(np.float32)
    return lo, hi


def bloom_probe_batched_device(
    blooms: Sequence,        # Q core.prune_join.BlockedBloom filters
    pmin: jnp.ndarray,       # [P] int32 resident enumeration minima
    width: jnp.ndarray,      # [P] int32 resident candidate counts (0=keep)
    wmax: int,               # host-side max raw width (plane metadata)
    enum_limit: int,
    mode: str = "auto",
    part_ids_lists: Optional[Sequence[np.ndarray]] = None,
    mesh=None,
) -> np.ndarray:
    """hit [Q, P] int32 — Q Bloom summaries vs the resident enumeration
    plane; row q equals the (fixed) host matcher's narrow-range
    enumeration for query q's filter, false-positive-only by construction
    (hit is 0 only where 0 < width <= enum_limit and no candidate value
    is in the filter).

    The no-Pallas fallback exploits narrowness *sparsity*: only
    enumerable partitions — restricted to each query's scan set when
    ``part_ids_lists`` names it (other entries are 1 and must not be
    read) — go through the host BlockedBloom probe at C speed.  The
    kernel path evaluates the resident plane dense (the batched design)
    with a per-partition dynamic trip count.
    """
    Q = len(blooms)
    P = int(pmin.shape[0])
    shards = mesh_shards(mesh, P)
    eb = enum_bucket(max(1, min(int(wmax), int(enum_limit))))
    if (shards > 1 and not _use_kernel(mode)
            and q_bucket(Q) * P * eb // shards > _REF_SLAB_ELEMS):
        # the jnp oracle body is dense O(Q*P*E) — at fleet shapes the
        # sparsity-aware host BlockedBloom fallback below wins (and the
        # dense body could exhaust memory); only the kernel path shards
        # unconditionally
        shards = 1
    _note_shards(shards)
    if shards > 1:
        lo, hi = pack_blooms(blooms)
        width_eff = jnp.where(width <= enum_limit, width, 0).astype(jnp.int32)
        fn = _sharded_bloom(mesh, _use_kernel(mode),
                            (mode == "interpret") or not _on_tpu(), eb)
        hit = np.asarray(fn(jnp.asarray(lo), jnp.asarray(hi),
                            pmin, width_eff))
        return hit[:Q]
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        # np.asarray of a CPU-backed jax array is a view — no copy.
        pmin_h = np.asarray(pmin)
        width_h = np.asarray(width)
        hit = np.ones((Q, P), dtype=np.int32)
        for qi, bloom in enumerate(blooms):
            ids = (np.arange(P) if part_ids_lists is None
                   else np.asarray(part_ids_lists[qi]))
            w = width_h[ids]
            nids = ids[(w > 0) & (w <= enum_limit)]
            if not nids.size:
                continue
            wq = width_h[nids]
            span = int(wq.max())
            cand = (pmin_h[nids][:, None].astype(np.int64)
                    + np.arange(span)[None, :])
            valid = np.arange(span)[None, :] < wq[:, None]
            hits = bloom.contains(cand.reshape(-1)).reshape(cand.shape)
            hit[qi, nids[~(hits & valid).any(axis=1)]] = 0
        return hit
    lo, hi = pack_blooms(blooms)
    width_eff = jnp.where(width <= enum_limit, width, 0).astype(jnp.int32)
    eb = enum_bucket(max(1, min(int(wmax), int(enum_limit))))
    hit = np.asarray(bloom_probe_batched(
        jnp.asarray(lo), jnp.asarray(hi), pmin, width_eff, enum_pad=eb,
        interpret=(mode == "interpret") or not _on_tpu()))
    return hit[:Q]


def topk_init_batched_device(
    plane: jnp.ndarray,      # [P, K] resident block-top-k rows (signed f32)
    mask: np.ndarray,        # [Q, P] 1 where partition p is a candidate
    k: int,
    mode: str = "auto",
    mesh=None,
) -> np.ndarray:
    """heap [Q, k] f32 — per-query top-k over masked resident plane rows.

    Query q's Sec. 5.4 upfront boundary for any effective kq <= k is
    ``heap[q, kq - 1]`` (-inf when fewer than kq candidates exist).

    The no-Pallas fallback exploits the masks' sparsity — candidate sets
    (fully-matching partitions of selective queries) are tiny fractions
    of P, so a gather + partition per query beats the kernel's dense
    formulation on CPU (np.asarray of a CPU-backed jax array is a view,
    so the resident plane is not copied).  Top-k is a pure selection, so
    every path returns the identical value multiset per query.
    """
    mask = np.asarray(mask)
    Q = int(mask.shape[0])
    # Delta-staged planes carry sentinel capacity slots past the table's
    # logical P; widen the mask with zeros so shapes line up (the slots
    # are all -inf and masked out — they contribute nothing either way).
    Pp = int(plane.shape[0])
    if mask.shape[1] < Pp:
        mask = np.pad(mask, ((0, 0), (0, Pp - mask.shape[1])))
    shards = mesh_shards(mesh, Pp)
    if (shards > 1 and not _use_kernel(mode)
            and Q * Pp * int(plane.shape[1]) // shards > _REF_SLAB_ELEMS):
        shards = 1     # dense O(Q*P*K) oracle body: the sparse numpy
                       # gather below wins at fleet shapes
    _note_shards(shards)
    if shards > 1:
        mask_d = jnp.asarray(mask.astype(np.float32).T)   # [Pp, Q]
        fn = _sharded_topk(mesh, _use_kernel(mode),
                           (mode == "interpret") or not _on_tpu(), k)
        heaps = np.asarray(fn(plane, mask_d))             # [n, Q, k]
        # Rank-selection merge of the per-shard heaps: top-k is a pure
        # selection, so selecting k from the union of shard-local top-k
        # heaps is exactly the global top-k (same value multiset).
        allv = np.concatenate(list(heaps), axis=1)        # [Q, n*k]
        return -np.sort(-allv, axis=1)[:, :k]
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        plane_np = np.asarray(plane)
        heap = np.full((Q, k), -np.inf, dtype=np.float32)
        for qi in range(Q):
            ids = np.nonzero(mask[qi])[0]
            if not ids.size:
                continue
            vals = plane_np[ids].ravel()
            vals = vals[vals > -np.inf]
            if not vals.size:
                continue
            if vals.size > k:
                vals = np.partition(vals, vals.size - k)[-k:]
            top = np.sort(vals)[::-1]
            heap[qi, : top.size] = top
        return heap
    mask_d = jnp.asarray(mask.astype(np.float32).T)   # [P, Q]
    heap = topk_init_batched(
        plane, mask_d, k,
        interpret=(mode == "interpret") or not _on_tpu())
    return np.asarray(heap)
