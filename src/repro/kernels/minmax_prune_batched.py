"""Pallas TPU kernel: multi-query conjunctive-range pruning, one launch.

The single-query kernel (minmax_prune.py) amortizes nothing across a
workload: Q queries mean Q stagings and Q launches.  This kernel evaluates
**Q queries x Kb constraints x P partitions** in one launch against the
table's *resident* ``[C, P]`` metadata planes (core/device_stats.py), so a
heavy workload's pruning decisions ride a single grid.

Layout (DESIGN.md §2 conventions):
  * queries are packed on the **sublane** dimension (BLOCK_Q = 8, the f32
    tile height); partitions stay on the 128-wide lane dimension;
  * each query brings a ``[Kb]`` row of (cid, lo, hi) constraints.  Kb is
    the query batch's constraint count padded to a power-of-two bucket
    (ops.k_bucket) with ``(-inf, +inf)`` no-op ranges, so jit recompiles
    are bounded by |buckets| x |tables| instead of per-batch shapes;
  * the per-constraint stat row is gathered **in-kernel** from the
    ``[C, BLOCK_P]`` stats tile via a one-hot matmul
    (``onehot(cid) [BQ, C] @ stats [C, BP]``) — an MXU-native gather that
    never materializes a ``[Q, K, P]`` intermediate anywhere.

Per (query, constraint, partition) the three-valued lattice is the same
as minmax_prune.py; no-op padding rows contribute tv=2 (the AND identity).
A padded query row (all no-ops) therefore yields tv=2 and is sliced off.

Block layout per grid step (i over query blocks, j over partition blocks):
  cids/lo/hi:        [BLOCK_Q, Kb]  (i, 0)
  mins/maxs/demote:  [C, BLOCK_P]   (0, j)   — revisited, stays in VMEM
  tv out:            [BLOCK_Q, BLOCK_P] int32 (i, j)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 8      # queries per tile: the f32 sublane height
BLOCK_P = 2048   # partitions per tile: C*BLOCK_P*4B*3 stays << VMEM

_NEG = float("-inf")
_POS = float("inf")


def _batched_kernel(cids_ref, lo_ref, hi_ref, mins_ref, maxs_ref, dem_ref,
                    tv_ref):
    C = mins_ref.shape[0]
    BQ, Kb = lo_ref.shape
    BP = mins_ref.shape[1]
    mins = mins_ref[...]          # [C, BP]
    maxs = maxs_ref[...]
    dem = dem_ref[...]
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (BQ, C), 1)

    tv = jnp.full((BQ, BP), 2, dtype=jnp.int32)
    for k in range(Kb):           # static unroll: Kb is a small power of two
        cid = cids_ref[:, k]                       # [BQ] int32
        onehot = (cid[:, None] == col_iota).astype(jnp.float32)
        # One-hot gather: exactly one 1.0 per row, so the matmul is an
        # exact row select (no rounding), executed on the MXU.
        pmin = jnp.dot(onehot, mins, preferred_element_type=jnp.float32)
        pmax = jnp.dot(onehot, maxs, preferred_element_type=jnp.float32)
        pdem = jnp.dot(onehot, dem, preferred_element_type=jnp.float32)
        lo = lo_ref[:, k][:, None]                 # [BQ, 1]
        hi = hi_ref[:, k][:, None]

        empty = pmin > pmax
        no = (pmax < lo) | (pmin > hi) | empty
        full = (pmin >= lo) & (pmax <= hi) & (pdem == 0.0) & ~empty
        tv_k = jnp.where(no, 0, jnp.where(full, 2, 1)).astype(jnp.int32)
        # (-inf, +inf) is the padding sentinel: the AND identity regardless
        # of the gathered stats (extract_ranges never emits it for a real
        # constraint — strict bounds go through nextafter/snapping).
        noop = (lo == _NEG) & (hi == _POS)
        tv_k = jnp.where(noop, 2, tv_k)
        tv = jnp.minimum(tv, tv_k)
    tv_ref[...] = tv


@functools.partial(jax.jit, static_argnames=("interpret",))
def minmax_prune_batched(
    cids: jax.Array,      # [Q, Kb] int32 constraint column ids
    lo: jax.Array,        # [Q, Kb] f32 range lows  (inclusive; -inf pad)
    hi: jax.Array,        # [Q, Kb] f32 range highs (inclusive; +inf pad)
    mins: jax.Array,      # [C, P] f32 resident partition minima (widened)
    maxs: jax.Array,      # [C, P] f32 resident partition maxima (widened)
    demote: jax.Array,    # [C, P] f32 1.0 where FULL must be suppressed
    interpret: bool = False,
) -> jax.Array:
    """Returns tv [Q, P] int32 in {0, 1, 2}.

    mins/maxs must be FINITE (core.device_stats.cast_stats_f32 clamps
    ±inf to ±f32max): the one-hot matmul gather multiplies every stat by
    0 or 1, and 0 x inf = NaN would silently corrupt the lattice.
    """
    Q, Kb = lo.shape
    C, P = mins.shape

    pad_q = (-Q) % BLOCK_Q
    if pad_q:
        # Padded queries are all no-op constraints -> tv 2; sliced off.
        cids = jnp.pad(cids, ((0, pad_q), (0, 0)))
        lo = jnp.pad(lo, ((0, pad_q), (0, 0)), constant_values=_NEG)
        hi = jnp.pad(hi, ((0, pad_q), (0, 0)), constant_values=_POS)
    pad_p = (-P) % BLOCK_P
    if pad_p:
        # Padded partitions get an empty interval -> tv 0; sliced off.
        # Finite extremes, not ±inf: a 0-weight x inf product in the
        # one-hot gather matmul would poison gathered rows with NaN —
        # core.device_stats clamps the real planes for the same reason.
        fmax = float(jnp.finfo(jnp.float32).max)
        mins = jnp.pad(mins, ((0, 0), (0, pad_p)), constant_values=fmax)
        maxs = jnp.pad(maxs, ((0, 0), (0, pad_p)), constant_values=-fmax)
        demote = jnp.pad(demote, ((0, 0), (0, pad_p)))
    Qp, Pp = Q + pad_q, P + pad_p

    grid = (Qp // BLOCK_Q, Pp // BLOCK_P)
    tv = pl.pallas_call(
        _batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_Q, Kb), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_Q, Kb), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_Q, Kb), lambda i, j: (i, 0)),
            pl.BlockSpec((C, BLOCK_P), lambda i, j: (0, j)),
            pl.BlockSpec((C, BLOCK_P), lambda i, j: (0, j)),
            pl.BlockSpec((C, BLOCK_P), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_Q, BLOCK_P), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Qp, Pp), jnp.int32),
        interpret=interpret,
    )(cids, lo, hi, mins, maxs, demote)
    return tv[:Q, :P]
