"""Pallas TPU kernel: conjunctive-range three-valued pruning.

The hot path of compile-/run-time filter pruning (paper Sec. 3): evaluate a
conjunction of K closed column ranges against per-partition min/max/null
metadata for P partitions and emit the three-valued match lattice
(NO=0 / PARTIAL=1 / FULL=2).

TPU adaptation (DESIGN.md §2): Snowflake evaluates pruning predicates
partition-at-a-time on CPUs; here the metadata is packed ``[K, P]``
(constraint-major, partitions on the 128-wide lane dimension) so one VPU
op processes 8x128 partitions per constraint.  The caller pre-gathers the
per-constraint stat columns (an XLA gather), so the kernel body is pure
branch-free elementwise work plus a min-reduction over K.

Block layout:
  mins/maxs/nullable: [K, P] f32 tiles of shape (K, BLOCK_P) in VMEM
  lo/hi:              [K, 1]  f32, the same block every grid step
  tv out:             [1, P] int32 tiles of shape (1, BLOCK_P)

P is padded to a multiple of BLOCK_P by the ops.py wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 2048  # partitions per grid step: K*BLOCK_P*4B*3 stays << VMEM


def _minmax_prune_kernel(lo_ref, hi_ref, mins_ref, maxs_ref, null_ref, tv_ref):
    lo = lo_ref[...]            # [K, 1]
    hi = hi_ref[...]            # [K, 1]
    pmin = mins_ref[...]        # [K, BP]
    pmax = maxs_ref[...]        # [K, BP]
    nullable = null_ref[...]    # [K, BP] (0.0 / 1.0)

    empty = pmin > pmax  # all-null column in partition: empty interval
    no = (pmax < lo) | (pmin > hi) | empty
    full = (pmin >= lo) & (pmax <= hi) & (nullable == 0.0) & ~empty
    tv_k = jnp.where(no, 0, jnp.where(full, 2, 1)).astype(jnp.int32)
    tv_ref[...] = jnp.min(tv_k, axis=0, keepdims=True)  # AND = min over K


@functools.partial(jax.jit, static_argnames=("interpret",))
def minmax_prune(
    lo: jax.Array,        # [K] f32 range lows  (inclusive)
    hi: jax.Array,        # [K] f32 range highs (inclusive)
    mins: jax.Array,      # [K, P] f32 per-constraint partition minima
    maxs: jax.Array,      # [K, P] f32 per-constraint partition maxima
    nullable: jax.Array,  # [K, P] f32 (1.0 where the column has nulls)
    interpret: bool = False,
) -> jax.Array:
    """Returns tv [P] int32 in {0, 1, 2}."""
    K, P = mins.shape
    pad = (-P) % BLOCK_P
    if pad:
        # Padding partitions get an empty interval -> tv 0; sliced off below.
        mins = jnp.pad(mins, ((0, 0), (0, pad)), constant_values=jnp.inf)
        maxs = jnp.pad(maxs, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        nullable = jnp.pad(nullable, ((0, 0), (0, pad)))
    Pp = P + pad
    grid = (Pp // BLOCK_P,)
    tv = pl.pallas_call(
        _minmax_prune_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, BLOCK_P), lambda i: (0, i)),
            pl.BlockSpec((K, BLOCK_P), lambda i: (0, i)),
            pl.BlockSpec((K, BLOCK_P), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_P), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Pp), jnp.int32),
        interpret=interpret,
    )(lo[:, None], hi[:, None], mins, maxs, nullable)
    return tv[0, :P]
