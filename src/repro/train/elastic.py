"""Elastic scaling: re-mesh + state resharding after topology changes.

Scenario at 1000+ nodes: a pod (or a slice of one) fails mid-run.  The
job restarts on the surviving devices; ``plan_mesh`` builds the largest
valid (data, model) mesh from what is left (model-parallel degree is
preserved — TP re-sharding would change matmul partitioning — while the
data axis absorbs the loss), and ``reshard`` device_puts the restored
checkpoint onto the new shardings.  Data-parallel batch bookkeeping
(`scale_batch`) keeps the *global* batch constant when possible by
raising the per-replica microbatch count.

Straggler mitigation lives in data/pipeline.py (deterministic work
stealing over the pruned scan set); this module owns topology changes.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

from ..models.sharding import tree_shardings


def plan_mesh(
    devices: Optional[Sequence] = None,
    model_parallel: int = 16,
    axis_names: Tuple[str, str] = ("data", "model"),
) -> Mesh:
    """Largest (data, model) mesh from the surviving devices."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    while model_parallel > 1 and (n % model_parallel or n < model_parallel):
        model_parallel //= 2
    data = n // model_parallel
    usable = devices[: data * model_parallel]
    import numpy as np
    return Mesh(
        np.array(usable).reshape(data, model_parallel), axis_names
    )


def reshard(state: Any, specs: Any, new_mesh: Mesh, rules=None) -> Any:
    """device_put every leaf onto the new mesh's shardings.

    ``specs`` is the ParamSpec tree for the params; optimizer-state leaves
    reuse the matching param shardings (same logical axes).
    """
    from .train_step import TrainState

    param_sh = tree_shardings(specs, new_mesh, rules)
    if isinstance(state, TrainState):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return TrainState(
            params=jax.device_put(state.params, param_sh),
            opt=type(state.opt)(
                step=jax.device_put(state.opt.step, NamedSharding(new_mesh, P())),
                m=jax.device_put(state.opt.m, param_sh),
                v=jax.device_put(state.opt.v, param_sh),
            ),
            error=None if state.error is None
            else jax.device_put(state.error, param_sh),
        )
    return jax.device_put(state, param_sh)


def scale_batch(global_batch: int, old_data: int, new_data: int,
                microbatches: int) -> Tuple[int, int]:
    """Keep the global batch when the data-parallel degree shrinks by
    raising the microbatch count; otherwise shrink to the nearest valid.

    Returns (global_batch, microbatches).
    """
    if new_data == old_data:
        return global_batch, microbatches
    if global_batch % new_data == 0:
        factor = max(old_data // max(new_data, 1), 1)
        return global_batch, microbatches * factor
    per = max(global_batch // new_data, 1)
    return per * new_data, microbatches
