"""AdamW with configurable state dtype + global-norm clipping.

Hand-rolled (no optax dependency).  ``state_dtype='bfloat16'`` halves the
optimizer-state HBM footprint — required for the 1T-parameter config to
fit a 512-chip v5e slice (2B params + 2B m + 2B v = 6 bytes/param; fp32
states would need 10).  Optimizer states inherit the parameters'
(FSDP x TP) shardings, so the update is fully sharded elementwise work.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]        # schedule: step -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: Any = jnp.float32

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.state_dtype)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def init_abstract(self, param_shapes) -> AdamWState:
        """ShapeDtypeStruct state for the dry-run (no allocation)."""
        zeros = lambda p: jax.ShapeDtypeStruct(p.shape, self.state_dtype)
        return AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=jax.tree.map(zeros, param_shapes),
            v=jax.tree.map(zeros, param_shapes),
        )

    def update(self, grads, state: AdamWState, params) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads))
            )
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self.lr(step)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m_new.astype(self.state_dtype), v_new.astype(self.state_dtype)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = jax.tree.leaves(state.m)
        flat_v = jax.tree.leaves(state.v)
        flat_p = jax.tree.leaves(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        return new_p, AdamWState(step=step, m=new_m, v=new_v)


def cosine_schedule(peak: float, warmup: int = 100, total: int = 10_000,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup, warm, cos)

    return lr
