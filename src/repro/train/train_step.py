"""Training step: microbatched grad accumulation + optimizer update.

The step function is pure (params, opt_state, batch) -> (params,
opt_state, metrics) and jit-compiles under any mesh; sharding comes
entirely from in_shardings/out_shardings at jit time (launch/dryrun.py,
launch/train.py), so the same function serves the CPU examples and the
512-chip dry-run.

Microbatching: the global batch is reshaped to [n_micro, B/n_micro, ...]
and scanned, accumulating f32 gradients.  On TPU the backward of
microbatch i overlaps the gradient reduce-scatter of microbatch i-1 (XLA
latency-hiding scheduler) — the compute/comm overlap trick at scale.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.model import Model
from .compress import compress_grads, init_error
from .optimizer import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    error: Optional[Any] = None     # error-feedback state (compression)


def make_train_step(
    model: Model,
    optimizer: AdamW,
    microbatches: int = 1,
    compress: bool = False,
) -> Callable:
    """Returns step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step(state: TrainState, batch) -> Tuple[TrainState, dict]:
        params = state.params
        if microbatches == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])

            mb = jax.tree.map(split, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mbatch):
                gsum, lsum = carry
                loss, _, g = grads_of(params, mbatch)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            (grads, loss_sum), _ = jax.lax.scan(acc, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = {}

        new_error = state.error
        if compress:
            grads, new_error = compress_grads(grads, state.error)

        new_params, new_opt = optimizer.update(grads, state.opt, params)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        out_metrics = {"loss": loss, "grad_norm": gnorm,
                       "lr": optimizer.lr(new_opt.step), **metrics}
        return TrainState(new_params, new_opt, new_error), out_metrics

    return step


def init_state(model: Model, optimizer: AdamW, key, compress: bool = False
               ) -> TrainState:
    from ..models.sharding import init_params
    params = init_params(model.specs, key)
    return TrainState(
        params=params,
        opt=optimizer.init(params),
        error=init_error(params) if compress else None,
    )


def abstract_state(model: Model, optimizer: AdamW, compress: bool = False
                   ) -> TrainState:
    """ShapeDtypeStruct state for AOT lowering (dry-run: no allocation)."""
    from ..models.sharding import tree_abstract
    shapes = tree_abstract(model.specs)
    return TrainState(
        params=shapes,
        opt=optimizer.init_abstract(shapes),
        error=jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), shapes
        ) if compress else None,
    )
