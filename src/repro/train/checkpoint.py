"""Fault-tolerant checkpointing: sharded npz + manifest, atomic commit.

Layout:  <dir>/step_<N>/
             manifest.json     tree structure, shapes, dtypes, mesh
                               fingerprint, step — written LAST
             shard_<proc>.npz  this process's param/opt leaves

Atomicity: everything is written into ``step_<N>.tmp`` and renamed after
the manifest is in place; a crash mid-save can never leave a directory
that ``latest_step`` would pick up.  Restore accepts a DIFFERENT mesh
than the one that saved (elastic.py re-device_puts onto the new
shardings), which is what turns a node failure into "reshard + resume"
instead of "lose the run".
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# npz cannot represent ml_dtypes (bfloat16 etc.): store as a same-width
# integer view and record the true dtype in the manifest.
_VIEW_FOR = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
             "float8_e5m2": np.uint8}


def _to_savable(arr: np.ndarray) -> np.ndarray:
    view = _VIEW_FOR.get(str(arr.dtype))
    return arr.view(view) if view is not None else arr


def _from_savable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_FOR:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


def save(directory: str, step: int, state, extra: Optional[dict] = None) -> str:
    """Save a pytree state; returns the committed checkpoint path."""
    tmp = os.path.join(directory, f"step_{step:08d}.tmp")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    flat = _flatten_with_paths(state)
    arrays = {k: np.asarray(v) for k, v in flat.items() if v is not None}
    np.savez(os.path.join(tmp, "shard_0.npz"),
             **{k: _to_savable(v) for k, v in arrays.items()})

    treedef = jax.tree.structure(state)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "treedef": str(treedef),
        "n_processes": jax.process_count(),
        "n_devices": jax.device_count(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int, like, shardings=None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  With ``shardings``, leaves are device_put onto
    them — the elastic-resume path."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))

    flat_like = _flatten_with_paths(like)
    flat_sh = _flatten_with_paths(shardings) if shardings is not None else {}
    out = {}
    for key, leaf in flat_like.items():
        if leaf is None:
            out[key] = None
            continue
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = _from_savable(data[key], manifest["dtypes"][key])
        expect = tuple(leaf.shape) if hasattr(leaf, "shape") else None
        if expect is not None and tuple(arr.shape) != expect:
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs {expect}")
        sh = flat_sh.get(key)
        out[key] = jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr)

    # rebuild the tree in `like`'s structure
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree.structure(like)
    ordered = []
    for pth, leaf in leaves_paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in pth
        )
        ordered.append(out[key])
    return jax.tree.unflatten(treedef, ordered), manifest


def restore_latest(directory: str, like, shardings=None):
    step = latest_step(directory)
    if step is None:
        return None, None
    state, manifest = restore(directory, step, like, shardings)
    return state, manifest
