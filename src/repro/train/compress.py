"""Error-feedback int8 gradient compression for the cross-pod hop.

At 512+ chips the gradient all-reduce crosses the slow DCN between pods.
Quantizing the cross-pod summand to int8 (per-tensor absmax scale) cuts
those bytes 4x (vs f32 master grads; 2x vs bf16) at the cost of
quantization noise, which *error feedback* (Karimireddy et al., 2019)
re-injects next step so the optimizer sees an unbiased long-run signal.

Two entry points:
  * ``compress_grads``  — pytree-level quantize->dequantize with carried
    error state; applied before the optimizer in train_step when enabled.
    This simulates the wire format exactly and is what the convergence
    test exercises.
  * ``compressed_psum`` — the shard_map building block that performs the
    actual quantized all-reduce over a named axis (used on real multi-pod
    meshes; unit-tested on a host mesh).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads: Any, error: Any) -> Tuple[Any, Any]:
    """Returns (dequantized grads as seen after the wire, new error state)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-quantized all-reduce over ``axis_name`` (inside shard_map).

    Protocol: agree on a shared scale (max over the axis), send int8,
    accumulate in int32, rescale.  Bytes on the wire: 1/axis of the
    f32 volume + one scalar round.
    """
    local_scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    scale = jax.lax.pmax(local_scale, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale
