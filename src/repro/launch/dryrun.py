import os

_DEVS = os.environ.get("REPRO_DRYRUN_DEVICES", "512")
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_DEVS} "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each
architecture and input shape, the train/prefill/decode step is lowered
with the production shardings and compiled AOT on 512 virtual devices
(single-pod 16x16 and multi-pod 2x16x16).  Outputs per cell:

  * memory_analysis()  — per-device argument/output/temp bytes (fits HBM?)
  * cost_analysis()    — HLO FLOPs / bytes for EXPERIMENTS.md §Roofline
  * collective bytes   — parsed from the optimized HLO

Results append to a JSON file so the 34-cell sweep is resumable.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only] [--out results.json]
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.configs.base import SHAPES, shape_supported
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (arch_rules, cache_shardings, decode_specs,
                                prefill_batch_specs, train_batch_specs)
from repro.models import build_model
from repro.models.sharding import tree_abstract, tree_shardings, use_mesh
from repro.train.optimizer import AdamW, cosine_schedule
from repro.train.train_step import abstract_state, make_train_step


def _sharding_tree_for_state(model, optimizer, mesh, rules):
    param_sh = tree_shardings(model.specs, mesh, rules)
    from repro.train.train_step import TrainState
    from repro.train.optimizer import AdamWState
    return TrainState(
        params=param_sh,
        opt=AdamWState(
            step=NamedSharding(mesh, P()),
            m=param_sh,
            v=param_sh,
        ),
        error=None,
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: Optional[dict] = None, smoke: bool = False):
    """Returns (compiled, lowered, mesh, meta) for one cell."""
    import dataclasses as dc

    from repro.configs import get_smoke_config

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if overrides:
        cfg = dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    if smoke:
        shape = dc.replace(shape, seq_len=min(shape.seq_len, 128),
                           global_batch=min(shape.global_batch, 16))
        if cfg.family == "vlm":
            shape = dc.replace(shape, seq_len=max(shape.seq_len, cfg.n_prefix * 2))
    skip = shape_supported(cfg, shape_name)
    if skip is not None:
        return None, None, None, {"skipped": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = arch_rules(cfg, mesh, kind=shape.kind)
    model = build_model(cfg)
    optimizer = AdamW(lr=cosine_schedule(3e-4),
                      state_dtype=jnp.dtype(cfg.optimizer_state_dtype))

    with use_mesh(mesh, rules):
        if shape.kind == "train":
            step = make_train_step(model, optimizer)
            state = abstract_state(model, optimizer)
            state_sh = _sharding_tree_for_state(model, optimizer, mesh, rules)
            batch, batch_sh = train_batch_specs(cfg, shape, mesh)
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state, batch)
        elif shape.kind == "prefill":
            params = tree_abstract(model.specs)
            params_sh = tree_shardings(model.specs, mesh, rules)
            batch, batch_sh = prefill_batch_specs(cfg, shape, mesh)
            cache_sh = cache_shardings(
                cfg, model.init_cache(shape.global_batch, shape.seq_len), mesh)
            fn = lambda p, b: model.prefill_fn(p, b, shape.seq_len)
            jitted = jax.jit(
                fn,
                in_shardings=(params_sh, batch_sh),
                out_shardings=(None, cache_sh),
            )
            lowered = jitted.lower(params, batch)
        else:  # decode
            params = tree_abstract(model.specs)
            params_sh = tree_shardings(model.specs, mesh, rules)
            (cache, tokens, position), (cache_sh, tok_sh, pos_sh) = decode_specs(
                cfg, shape, mesh, model)
            jitted = jax.jit(
                model.decode_fn,
                in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params, cache, tokens, position)

        compiled = lowered.compile()
    return compiled, lowered, mesh, {"skipped": None}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: Optional[dict] = None, hlo_roofline: bool = True,
             smoke: bool = False) -> dict:
    t0 = time.time()
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
    }
    try:
        compiled, lowered, mesh, meta = lower_cell(
            arch, shape_name, multi_pod, overrides, smoke=smoke)
        if meta["skipped"]:
            rec.update(status="SKIP", reason=meta["skipped"])
            return rec
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        rec["status"] = "OK"
        rec["compile_s"] = round(time.time() - t0, 1)
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes"):
                rec[k] = getattr(mem, k, None)
            args = rec.get("argument_size_in_bytes") or 0
            alias = rec.get("alias_size_in_bytes") or 0
            out = rec.get("output_size_in_bytes") or 0
            temp = rec.get("temp_size_in_bytes") or 0
            rec["peak_bytes_per_device"] = args + out + temp - alias
        if hlo_roofline:
            hlo = compiled.as_text()
            rl = RL.derive(cfg, shape, hlo, rec["chips"], cost)
            rec["roofline"] = rl.to_dict()
        return rec
    except Exception as e:  # noqa: BLE001 — a failed cell is a result
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 mesh (default: single-pod 16x16)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs/shapes: validates the code path")
    ap.add_argument("--opt", action="store_true",
                    help="§Perf optimizations: resident-MoE sharding, "
                         "TP-resident decode weights, vocab padding")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()
    opt_overrides = dict(moe_dispatch="grouped", moe_sharding="expert_only",
                         serve_resident=True,
                         pad_vocab_to=128) if args.opt else None

    cells = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for arch, shape, mp in cells:
        key = (arch, shape, "2x16x16" if mp else "16x16")
        if key in done:
            print(f"[dryrun] {key} cached", flush=True)
            continue
        print(f"[dryrun] {key} ...", flush=True)
        rec = run_cell(arch, shape, mp, overrides=opt_overrides,
                       smoke=args.smoke)
        status = rec["status"]
        extra = rec.get("reason") or rec.get("error") or ""
        peak = rec.get("peak_bytes_per_device")
        peak_s = f" peak={peak/2**30:.2f}GiB" if peak else ""
        rl = rec.get("roofline") or {}
        bn = f" bottleneck={rl.get('bottleneck')}" if rl else ""
        print(f"[dryrun] {key} -> {status}{peak_s}{bn} {extra}", flush=True)
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)

    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"[dryrun] total={len(results)} ok={n_ok} skip={n_skip} fail={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
