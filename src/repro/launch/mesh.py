"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax use).

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis composes
with `data` into the DP/FSDP dimension (hierarchical gradient reduction:
reduce-scatter over ICI first, cross-pod all-reduce over DCN last).
"""

from __future__ import annotations

import os

import jax


def make_production_mesh(*, multi_pod: bool = False):
    # REPRO_MESH_SCALE=n shrinks every axis by n for CI-scale validation
    # of the identical code path (tests/test_dryrun.py uses 8 devices).
    scale = int(os.environ.get("REPRO_MESH_SCALE", "1"))
    d, m = 16 // scale, 16 // scale
    shape = (2, d, m) if multi_pod else (d, m)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host offers, as a (data, model) mesh — used by tests
    and CPU-scale examples."""
    n = len(jax.devices())
    model = 1
    for m in (4, 2):
        if n % m == 0 and n > m:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_plane_mesh():
    """The host's devices as one 1-D ``parts`` axis: the partition-shard
    mesh of the metadata-plane kernels.

    The resident ``[C, P]`` planes split their partition (capacity) dim
    over this axis via ``shard_map``, so a table's P can grow past one
    device's memory.  Plane capacities are powers of two, so the axis is
    the largest power-of-two prefix of ``make_host_mesh()``'s device set
    — every capacity >= the axis size divides evenly and shards.  On a
    single-device host the mesh is size 1 and the launch path stays
    unsharded (same code, no shard_map).
    """
    import numpy as np

    devs = jax.devices()
    n = 1
    while n * 2 <= len(devs):
        n *= 2
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("parts",))
