"""Roofline-term derivation for the dry-run cells.

Three terms per (arch x shape x mesh), in seconds — the dominant one is
the bottleneck the §Perf loop works on:

  compute    = analytic_flops / (chips * peak_FLOPs)
  memory     = analytic_hbm_bytes / (chips * HBM_bw)
  collective = hlo_collective_bytes_per_device / link_bw

Why analytic compute/memory instead of cost_analysis(): XLA's
HloCostAnalysis counts while-loop bodies ONCE, and this codebase runs
layers, attention chunks, MoE dispatch chunks, SSD chunks and the CE loss
under lax.scan — the measured flops under-count by the product of trip
counts (verified empirically in EXPERIMENTS.md §Dry-run).  Analytic
matmul-exact accounting (PaLM-appendix style MFU math) is the standard
production practice and is what we report; raw cost_analysis numbers are
kept in the results JSON for transparency.

Collective bytes ARE taken from the optimized per-device HLO (they are
not in cost_analysis): every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction contributes its largest shape
(per-device bytes under SPMD).  Instructions inside non-ENTRY computations
(loop bodies — in this codebase, the layer scan) are multiplied by the
layer trip count; ENTRY-level collectives (gradient reduce-scatter, logit
reductions) count once.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (values from the assignment).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
    r"\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str, loop_trip: int = 1) -> Dict[str, float]:
    """Per-collective-kind bytes over the optimized per-device HLO.

    The HLO module lists computations; ENTRY holds top-level instructions,
    every other computation is a fusion / loop body / remat region.
    Collectives never live inside fusions, so non-ENTRY collectives are in
    loop bodies and are scaled by ``loop_trip`` (the layer-scan count).
    """
    out: Dict[str, float] = {}
    in_entry = False
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            in_entry = True
        elif line and not line[0].isspace() and "{" in line:
            in_entry = False
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        sizes = [shape_bytes(d, s) for d, s in _SHAPE_RE.findall(line)]
        if sizes:
            mult = 1 if in_entry else loop_trip
            out[kind] = out.get(kind, 0.0) + float(max(sizes)) * mult
    return out


# ---------------------------------------------------------------------------
# Analytic FLOPs / HBM models (documented in EXPERIMENTS.md §Roofline)
# ---------------------------------------------------------------------------

def _attention_layers(cfg) -> int:
    if cfg.family in ("dense", "moe", "vlm"):
        return cfg.n_layers
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every     # shared block applications
    if cfg.family == "encdec":
        return cfg.n_enc_layers + 2 * cfg.n_layers  # self + cross
    return 0


def _matmul_params(cfg) -> int:
    """Active parameters that participate in matmuls (embedding gather
    excluded; unembedding projection included)."""
    n = cfg.active_param_count()
    emb_factor = 1 if cfg.tie_embeddings else 2
    n -= cfg.vocab * cfg.d_model * emb_factor     # remove both tables
    n += cfg.vocab * cfg.d_model                  # unembed matmul is real
    return n


def _ssd_extra_flops_per_token(cfg) -> float:
    """SSD state-path flops/token beyond the projections (per layer):
    intra-chunk dual form ~ 2*q*(n + p) per token-pair column + state
    update/output ~ 6*p*n per head."""
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    h, p, n, q = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_chunk
    intra = 2.0 * q * (n + p) * h / 2.0           # causal half
    inter = 6.0 * p * n * h
    return (intra + inter) * cfg.n_layers


def analytic_flops(cfg, shape) -> float:
    """Global FLOPs for one step of this cell."""
    B, S = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    tokens = B if decode else B * S
    kv_len = S if decode else S / 2               # causal average

    base = 2.0 * _matmul_params(cfg) * tokens
    hd = cfg.resolved_head_dim
    attn = 4.0 * kv_len * cfg.n_heads * hd * _attention_layers(cfg) * tokens
    ssd = _ssd_extra_flops_per_token(cfg) * tokens
    fwd = base + attn + ssd
    if shape.kind == "train":
        # 1 fwd + 2 bwd (+1 remat recompute of the fwd)
        return fwd * (4.0 if cfg.remat else 3.0)
    return fwd


def analytic_hbm_bytes(cfg, shape, chips: int) -> float:
    """Global HBM traffic model for one step.

    train:   weights bf16 read fwd+bwd (2x) + grad write/read (f32) +
             optimizer m,v read+write (state dtype) + activation traffic
             ~ 12 bf16 touches per token per layer-equivalent.
    prefill: weights read + activations + KV-cache write.
    decode:  weights read + KV/state cache read (+tiny writes) — the
             classic decode bound.
    Per-device weight traffic never drops below the full shard (weights
    are read wherever they live); activation traffic scales with tokens.
    """
    B, S = shape.global_batch, shape.seq_len
    p_bytes = cfg.param_count() * 2.0
    opt_bytes = cfg.param_count() * (4.0 if cfg.optimizer_state_dtype ==
                                     "float32" else 2.0) * 2.0
    layers_eq = max(cfg.n_layers, 1)
    act_per_tok_layer = 12.0 * cfg.d_model * 2.0
    kv_heads = max(cfg.n_kv_heads, 0)
    hd = cfg.resolved_head_dim

    if shape.kind == "train":
        tokens = B * S
        acts = tokens * layers_eq * act_per_tok_layer * (1.5 if cfg.remat else 1.0)
        grads = cfg.param_count() * 4.0 * 2.0
        return 2.0 * p_bytes + grads + 2.0 * opt_bytes + acts
    if shape.kind == "prefill":
        tokens = B * S
        acts = tokens * layers_eq * act_per_tok_layer / 2.0
        kv = tokens * _attention_layers(cfg) * kv_heads * hd * 2 * 2.0
        return p_bytes + acts + kv
    # decode: read all weights + the whole KV/state cache once per step
    kv = B * S * _attention_layers(cfg) * kv_heads * hd * 2 * 2.0
    if cfg.family in ("ssm", "hybrid"):
        kv += B * cfg.n_layers * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4.0
        if cfg.family == "ssm":
            kv = B * cfg.n_layers * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4.0
    acts = B * layers_eq * act_per_tok_layer
    return p_bytes + kv + acts


@dataclasses.dataclass
class Roofline:
    flops: float                 # global analytic flops
    hbm_bytes: float             # global analytic bytes
    coll_bytes: float            # per-device HLO collective bytes
    coll_breakdown: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float           # 6*N_active*D (train) — the MFU numerator
    useful_ratio: float          # model_flops / analytic flops
    chips: int
    raw_cost_flops: float = 0.0  # XLA cost_analysis (loop bodies once)
    raw_cost_bytes: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def model_flops_for(cfg, shape) -> float:
    """MFU numerator: 6*N_active*tokens (train) or 2*N_active*tokens."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n_active * tokens


def derive(cfg, shape, hlo_text: str, chips: int,
           cost: Optional[Dict[str, float]] = None) -> Roofline:
    loop_trip = cfg.n_layers // cfg.attn_every if cfg.family == "hybrid" \
        else max(cfg.n_layers, 1)
    coll = collective_bytes(hlo_text, loop_trip=loop_trip)
    coll_total = sum(coll.values())

    flops = analytic_flops(cfg, shape)
    hbm = analytic_hbm_bytes(cfg, shape, chips)
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = hbm / (chips * HBM_BW)
    collective_s = coll_total / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_for(cfg, shape)
    return Roofline(
        flops=flops, hbm_bytes=hbm, coll_bytes=coll_total,
        coll_breakdown=coll, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, bottleneck=bottleneck,
        model_flops=mf, useful_ratio=mf / flops if flops else 0.0,
        chips=chips,
        raw_cost_flops=float((cost or {}).get("flops", 0.0)),
        raw_cost_bytes=float((cost or {}).get("bytes accessed", 0.0)),
    )
