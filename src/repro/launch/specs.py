"""Abstract input specs + sharding assignments for every (arch x shape).

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins (weak-type
correct, shardable, no allocation) for each step function's inputs, plus
the matching PartitionSpecs.

Sharding policy (DESIGN.md §4):
  tokens/labels  [B, S]         -> (('pod','data'), None); B=1 replicates
  prefix embeds  [B, T, d]      -> (dp, None, None)
  KV caches      [L, B, S, KV, D]: heads over `model` when divisible,
                 otherwise the SEQUENCE dim over `model` (context
                 parallelism) — decided per arch (e.g. GLM-4 kv=2, Kimi
                 kv=8 -> sequence-sharded caches).
  params/opt     from ParamSpec logical axes (FSDP over ('pod','data')
                 via the 'embed' rule + TP over 'model').
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import InputShape, ModelConfig
from ..models.model import Model
from ..models.sharding import resolve_axis


def arch_rules(cfg: ModelConfig, mesh: Mesh, kind: str = "train") -> Dict[str, Any]:
    """Per-arch rule overrides.

    * context-parallel KV caches when the KV heads can't TP-shard;
    * §Perf H2: decode with TP-resident weights — the per-step FSDP
      all-gather of every parameter is the decode bottleneck, so the
      'embed' (FSDP) dim replicates and weights live sharded over `model`
      (+ experts over the DP axes in resident-MoE mode).
    """
    tp = mesh.shape.get("model", 1)
    rules: Dict[str, Any] = {}
    if cfg.n_kv_heads and tp > 1 and cfg.n_kv_heads % tp != 0:
        rules["kv_seq"] = "model"
    if cfg.no_fsdp or (kind == "decode" and cfg.serve_resident):
        rules["embed"] = None
    return rules


def batch_pspec(mesh: Mesh, global_batch: int) -> Any:
    dp = resolve_axis(global_batch, ("pod", "data"), mesh)
    return dp


def train_batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    B, S = shape.global_batch, shape.seq_len
    dp = batch_pspec(mesh, B)
    n_tok = S - (cfg.n_prefix if cfg.family == "vlm" else 0)
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, n_tok), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, n_tok), jnp.int32),
    }
    pspecs = {
        "tokens": NamedSharding(mesh, P(dp, None)),
        "labels": NamedSharding(mesh, P(dp, None)),
    }
    if cfg.frontend != "none":
        batch["prefix"] = jax.ShapeDtypeStruct(
            (B, cfg.n_prefix, cfg.d_model), jnp.float32)
        pspecs["prefix"] = NamedSharding(mesh, P(dp, None, None))
    return batch, pspecs


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    return train_batch_specs(cfg, shape, mesh)


def cache_shardings(cfg: ModelConfig, cache_shapes, mesh: Mesh):
    """PartitionSpecs for the decode cache pytree, per family."""
    tp = mesh.shape.get("model", 1)
    kv_on_heads = cfg.n_kv_heads and tp > 1 and cfg.n_kv_heads % tp == 0

    def kv_spec(ndim_prefix: int, batch: int, seq: int, kv: int):
        dp = batch_pspec(mesh, batch)
        if kv_on_heads:
            return P(*([None] * ndim_prefix), dp, None,
                     resolve_axis(kv, "model", mesh), None)
        # context parallelism — but only if the cache length divides
        # (e.g. whisper's 1500-frame cross-attention K/V replicates)
        return P(*([None] * ndim_prefix), dp,
                 resolve_axis(seq, "model", mesh), None, None)

    def leaf_spec(path: str, s: jax.ShapeDtypeStruct):
        nd = len(s.shape)
        if path in ("k", "v", "xk", "xv"):
            batch, seq, kv = s.shape[nd - 4], s.shape[nd - 3], s.shape[nd - 2]
            return kv_spec(nd - 4, batch, seq, kv)
        if path == "s":       # SSM state [..., B, H, P, N]
            dp = batch_pspec(mesh, s.shape[nd - 4])
            h_ax = resolve_axis(s.shape[nd - 3], "model", mesh)
            return P(*([None] * (nd - 4)), dp, h_ax, None, None)
        if path == "conv":    # [..., B, K-1, C]
            dp = batch_pspec(mesh, s.shape[nd - 3])
            return P(*([None] * (nd - 3)), dp, None, None)
        return P()

    return {k: NamedSharding(mesh, leaf_spec(k, v))
            for k, v in cache_shapes.items()}


def decode_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh, model: Model):
    """(cache, tokens, position) abstract values + shardings for decode."""
    B, S = shape.global_batch, shape.seq_len
    dp = batch_pspec(mesh, B)
    cache_shapes = model.init_cache(B, S)
    cache_sh = cache_shardings(cfg, cache_shapes, mesh)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    position = jax.ShapeDtypeStruct((B,), jnp.int32)
    return (
        (cache_shapes, tokens, position),
        (cache_sh, NamedSharding(mesh, P(dp, None)), NamedSharding(mesh, P(dp))),
    )
