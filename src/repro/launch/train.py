"""End-to-end training driver: pruned data pipeline -> train loop with
checkpoint/restart.

CPU-scale by default (a ~20M-param llama-family model for a few hundred
steps finishes in minutes); pass a real --arch for the full config (on a
TPU slice the same driver runs under make_production_mesh()).

Fault tolerance exercised here:
  * periodic atomic checkpoints (params, optimizer, data cursors),
  * --simulate-failure N kills the process state at step N; re-running
    the same command resumes from the last checkpoint (the restart test
    drives this),
  * data-pipeline work stealing (n_workers > 1 interleaves shard lists).

Usage:
  PYTHONPATH=src python -m repro.launch.train --steps 200
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ModelConfig
from repro.core import expr as E
from repro.data.pipeline import (PrunedDataLoader, curate,
                                 make_corpus_metadata)
from repro.models import build_model
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamW, cosine_schedule
from repro.train.train_step import init_state, make_train_step


def default_config(vocab: int = 8192) -> ModelConfig:
    """~20M-param dense model that trains at CPU speed."""
    return ModelConfig(
        name="cpu-20m", family="dense", n_layers=4, d_model=256,
        n_heads=8, n_kv_heads=4, d_ff=1024, vocab=vocab,
        logits_chunk=128, attn_chunk=128,
    )


CURATION_PRED = (
    (E.col("quality") >= 0.35)
    & E.in_(E.col("lang"), ["en-00000", "en-00001", "en-00002", "en-00003"])
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--simulate-failure", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.arch:
        cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    else:
        cfg = default_config()
    if cfg.frontend != "none":
        raise SystemExit("train driver covers LM archs; use examples/ for "
                         "frontend-stub archs")

    model = build_model(cfg)
    optimizer = AdamW(
        lr=cosine_schedule(3e-4, warmup=20, total=max(args.steps, 100)),
        state_dtype=jnp.dtype(cfg.optimizer_state_dtype),
    )
    step_fn = jax.jit(make_train_step(
        model, optimizer, microbatches=args.microbatches,
        compress=args.compress), donate_argnums=(0,))

    # --- pruned data pipeline (the paper's engine in the loop) ---
    rng = np.random.default_rng(args.seed)
    meta = make_corpus_metadata(rng, n_shards=512, docs_per_shard=16)
    scan, report = curate(meta, CURATION_PRED)
    print(f"[train] curation pruned {report.pruning_ratio:.1%} of shards "
          f"({report.shards_selected}/{report.shards_total} fetched)")
    loader = PrunedDataLoader(
        scan, worker=0, n_workers=1, batch_size=args.batch,
        seq_len=args.seq, vocab=cfg.vocab, seed=args.seed)

    # --- init or resume ---
    state = None
    start = 0
    latest = ckpt.latest_step(args.ckpt_dir)
    if latest is not None:
        like = init_state(model, optimizer, jax.random.PRNGKey(args.seed),
                          compress=args.compress)
        state, manifest = ckpt.restore(args.ckpt_dir, latest, like)
        start = manifest["step"]
        print(f"[train] resumed from step {start}")
    else:
        state = init_state(model, optimizer, jax.random.PRNGKey(args.seed),
                           compress=args.compress)

    it = iter(loader)
    # replay the loader to the resume point (deterministic shards)
    for _ in range(start):
        next(it)

    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        batch = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            dt = time.time() - t0
            print(f"[train] step {step+1} loss={losses[-1]:.4f} "
                  f"({dt/args.log_every:.2f}s/step)", flush=True)
            t0 = time.time()
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            path = ckpt.save(args.ckpt_dir, step + 1, state,
                             extra={"loader": loader.state()})
            print(f"[train] checkpoint -> {path}", flush=True)
        if args.simulate_failure and step + 1 == args.simulate_failure:
            print("[train] simulated failure (SIGKILL semantics)", flush=True)
            raise SystemExit(42)

    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
