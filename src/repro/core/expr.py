"""Typed expression trees for predicates and scalar expressions.

The pruning engine never sees SQL text; queries are built from these nodes
(the paper's guiding example becomes
``(col('altit') * 0.3048).if_(col('unit') == 'feet', col('altit')) > 1500``
— see ``If`` below — combined with ``like(col('name'), 'Marked-%-Ridge')``).

Scalar nodes produce value intervals (intervals.py); predicate nodes
produce three-valued match results (prune_filter.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence, Tuple, Union


class Expr:
    """Base class for scalar-valued expressions."""

    def _wrap(self, other) -> "Expr":
        return other if isinstance(other, Expr) else Lit(other)

    # -- arithmetic -------------------------------------------------------
    def __add__(self, o): return Arith("+", self, self._wrap(o))
    def __radd__(self, o): return Arith("+", self._wrap(o), self)
    def __sub__(self, o): return Arith("-", self, self._wrap(o))
    def __rsub__(self, o): return Arith("-", self._wrap(o), self)
    def __mul__(self, o): return Arith("*", self, self._wrap(o))
    def __rmul__(self, o): return Arith("*", self._wrap(o), self)
    def __truediv__(self, o): return Arith("/", self, self._wrap(o))
    def __neg__(self): return Arith("-", Lit(0.0), self)

    # -- comparisons ------------------------------------------------------
    def __gt__(self, o): return Cmp(">", self, self._wrap(o))
    def __ge__(self, o): return Cmp(">=", self, self._wrap(o))
    def __lt__(self, o): return Cmp("<", self, self._wrap(o))
    def __le__(self, o): return Cmp("<=", self, self._wrap(o))
    def __eq__(self, o): return Cmp("==", self, self._wrap(o))  # type: ignore[override]
    def __ne__(self, o): return Cmp("!=", self, self._wrap(o))  # type: ignore[override]

    __hash__ = object.__hash__

    def columns(self) -> Tuple[str, ...]:
        """All column names referenced by this (sub)expression."""
        out: list = []
        _collect_columns(self, out)
        return tuple(dict.fromkeys(out))


@dataclasses.dataclass(frozen=True, eq=False)
class Col(Expr):
    name: str

    def __repr__(self):
        return f"col({self.name!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class Lit(Expr):
    value: Any  # float/int or str (encoded lazily against the dictionary)

    def __repr__(self):
        return f"lit({self.value!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class Arith(Expr):
    op: str  # '+', '-', '*', '/'
    lhs: Expr
    rhs: Expr

    def __repr__(self):
        return f"({self.lhs!r} {self.op} {self.rhs!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class If(Expr):
    """IF(cond, then, else) — the paper's Sec. 3.1 derived-range example."""

    cond: "Pred"
    then: Expr
    other: Expr

    def __repr__(self):
        return f"if_({self.cond!r}, {self.then!r}, {self.other!r})"


class Pred:
    """Base class for boolean-valued predicate nodes."""

    def __and__(self, o): return And((self, o))
    def __or__(self, o): return Or((self, o))
    def __invert__(self): return Not(self)

    __hash__ = object.__hash__

    def columns(self) -> Tuple[str, ...]:
        out: list = []
        _collect_columns(self, out)
        return tuple(dict.fromkeys(out))


@dataclasses.dataclass(frozen=True, eq=False)
class Cmp(Pred):
    op: str  # '>', '>=', '<', '<=', '==', '!='
    lhs: Expr
    rhs: Expr

    def __repr__(self):
        return f"({self.lhs!r} {self.op} {self.rhs!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class And(Pred):
    children: Tuple[Pred, ...]

    def __post_init__(self):
        object.__setattr__(self, "children", tuple(self.children))

    def __repr__(self):
        return "(" + " & ".join(map(repr, self.children)) + ")"


@dataclasses.dataclass(frozen=True, eq=False)
class Or(Pred):
    children: Tuple[Pred, ...]

    def __post_init__(self):
        object.__setattr__(self, "children", tuple(self.children))

    def __repr__(self):
        return "(" + " | ".join(map(repr, self.children)) + ")"


@dataclasses.dataclass(frozen=True, eq=False)
class Not(Pred):
    child: Pred

    def __repr__(self):
        return f"~{self.child!r}"


@dataclasses.dataclass(frozen=True, eq=False)
class Like(Pred):
    """SQL LIKE with '%' wildcards (no '_' support needed for the paper)."""

    col: Col
    pattern: str

    def __repr__(self):
        return f"like({self.col!r}, {self.pattern!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class StartsWith(Pred):
    col: Col
    prefix: str

    def __repr__(self):
        return f"startswith({self.col!r}, {self.prefix!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class InSet(Pred):
    col: Col
    values: Tuple[Any, ...]

    def __post_init__(self):
        object.__setattr__(self, "values", tuple(self.values))

    def __repr__(self):
        return f"in_({self.col!r}, {self.values!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class IsNull(Pred):
    col: Col
    negated: bool = False

    def __repr__(self):
        return f"is_{'not_' if self.negated else ''}null({self.col!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class TruePred(Pred):
    """WHERE true — matches everything (paper's Sec. 6 example query)."""

    def __repr__(self):
        return "true"


def _collect_columns(node, out: list) -> None:
    if isinstance(node, Col):
        out.append(node.name)
    elif isinstance(node, (Like, StartsWith, InSet, IsNull)):
        out.append(node.col.name)
    elif isinstance(node, Arith):
        _collect_columns(node.lhs, out)
        _collect_columns(node.rhs, out)
    elif isinstance(node, Cmp):
        _collect_columns(node.lhs, out)
        _collect_columns(node.rhs, out)
    elif isinstance(node, If):
        _collect_columns(node.cond, out)
        _collect_columns(node.then, out)
        _collect_columns(node.other, out)
    elif isinstance(node, (And, Or)):
        for c in node.children:
            _collect_columns(c, out)
    elif isinstance(node, Not):
        _collect_columns(node.child, out)


# ---------------------------------------------------------------------------
# Builder API
# ---------------------------------------------------------------------------

def col(name: str) -> Col:
    return Col(name)


def lit(value) -> Lit:
    return Lit(value)


def if_(cond: Pred, then: Union[Expr, float], other: Union[Expr, float]) -> If:
    w = lambda e: e if isinstance(e, Expr) else Lit(e)
    return If(cond, w(then), w(other))


def like(c: Col, pattern: str) -> Like:
    return Like(c, pattern)


def startswith(c: Col, prefix: str) -> StartsWith:
    return StartsWith(c, prefix)


def in_(c: Col, values: Sequence) -> InSet:
    return InSet(c, tuple(values))


def is_null(c: Col) -> IsNull:
    return IsNull(c)


def is_not_null(c: Col) -> IsNull:
    return IsNull(c, negated=True)


def true() -> TruePred:
    return TruePred()


def and_(*preds: Pred) -> Pred:
    preds = tuple(p for p in preds if not isinstance(p, TruePred))
    if not preds:
        return TruePred()
    return preds[0] if len(preds) == 1 else And(preds)


def or_(*preds: Pred) -> Pred:
    return preds[0] if len(preds) == 1 else Or(tuple(preds))


# ---------------------------------------------------------------------------
# Canonical predicate keys (predicate cache + batch dedupe)
# ---------------------------------------------------------------------------

# Comparison orientation flips for lit-on-left normalization.
_CMP_FLIP = {">": "<", ">=": "<=", "<": ">", "<=": ">=", "==": "==",
             "!=": "!="}


def _canon_value(v) -> str:
    """Normalize a literal so numerically equal constants collide.

    ``1`` and ``1.0`` canonicalize identically; an int too wide for an
    exact f64 keeps its integer spelling (folding it into a float would
    merge *distinct* predicates, which is unsound for a cache key).
    """
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return repr(v)
    f = float(v)
    return repr(f) if f == v else repr(v)


def _canon(node) -> str:
    if isinstance(node, Lit):
        return f"lit({_canon_value(node.value)})"
    if isinstance(node, Col):
        return repr(node)
    if isinstance(node, Arith):
        return f"({_canon(node.lhs)} {node.op} {_canon(node.rhs)})"
    if isinstance(node, If):
        return (f"if_({_canon(node.cond)}, {_canon(node.then)}, "
                f"{_canon(node.other)})")
    if isinstance(node, Cmp):
        lhs, rhs, op = node.lhs, node.rhs, node.op
        if isinstance(lhs, Lit) and not isinstance(rhs, Lit):
            lhs, rhs, op = rhs, lhs, _CMP_FLIP[op]
        return f"({_canon(lhs)} {op} {_canon(rhs)})"
    if isinstance(node, (And, Or)):
        # Commutative + associative + idempotent: flatten same-kind
        # nesting, canonicalize children, then sort and dedupe.
        kind = type(node)
        parts: list = []
        for c in node.children:
            if isinstance(c, kind):
                parts.extend(c.children)
            else:
                parts.append(c)
        keys = sorted(dict.fromkeys(_canon(c) for c in parts))
        if len(keys) == 1:
            return keys[0]
        sep = " & " if kind is And else " | "
        return "(" + sep.join(keys) + ")"
    if isinstance(node, Not):
        return f"~{_canon(node.child)}"
    if isinstance(node, InSet):
        vals = sorted(dict.fromkeys(_canon_value(v) for v in node.values))
        return f"in_({_canon(node.col)}, ({', '.join(vals)}))"
    if isinstance(node, (Like, StartsWith, IsNull, TruePred)):
        return repr(node)
    return repr(node)


def canonical_key(pred) -> str:
    """Canonical string key for a predicate: equal keys imply equivalent
    predicates, and the common syntactic variants of one predicate —
    commutative ``AND``/``OR`` orderings, ``1`` vs ``1.0`` literals,
    lit-on-left comparisons, duplicate conjuncts — collide.

    This is both the ``plan_key`` cache key (Sec. 8.2) and the
    within-batch dedupe key for the device-resident verdict plane.
    Non-predicate inputs (None, prebuilt repr strings from benchmarks)
    fall back to ``repr``.
    """
    if not isinstance(pred, (Pred, Expr)):
        return repr(pred)
    return _canon(pred)


def invert(pred: Pred) -> Pred:
    """Logical negation used for the Sec. 4.2 inverted-predicate pass."""
    if isinstance(pred, Not):
        return pred.child
    if isinstance(pred, And):
        return Or(tuple(invert(c) for c in pred.children))
    if isinstance(pred, Or):
        return And(tuple(invert(c) for c in pred.children))
    if isinstance(pred, Cmp):
        flip = {">": "<=", ">=": "<", "<": ">=", "<=": ">", "==": "!=", "!=": "=="}
        return Cmp(flip[pred.op], pred.lhs, pred.rhs)
    if isinstance(pred, IsNull):
        return IsNull(pred.col, negated=not pred.negated)
    return Not(pred)
