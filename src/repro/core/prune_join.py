"""JOIN pruning: coarse-grained sideways information passing (paper Sec. 6).

Four steps, exactly the paper's:
  (1) summarize the build side's join-key values during the build phase,
  (2) ship the summary to the probe side (size-bounded — it crosses the
      network in a distributed setting),
  (3) match the summary against probe-side partitions' min/max metadata,
  (4) prune partitions that provably contain no joinable tuples.

Summary structure ("balance between accuracy and storage cost"):
  * global min/max of the build keys — free, prunes by range overlap;
  * if the build NDV is small, the exact sorted distinct-value set;
  * otherwise a *blocked Bloom filter* (512-bit blocks = 16 x int32 words,
    4 probe bits), which additionally prunes narrow-range partitions by
    enumerating their possible integer/dictionary-code values against the
    filter.  Enumeration is only sound on integer-domain columns (int /
    dictionary codes): fractional keys are invisible to the integer
    enumeration, so float key columns skip it (skip = keep, never prune).
    Blocked layout + 32-bit mixing is the TPU adaptation: probes are
    branch-free int32 lane ops — ``kernels/bloom_probe.py`` runs the same
    enumeration batched (Q filters x P partitions) against the resident
    enumeration plane, and ``prune_probe`` accepts its result via
    ``bloom_hit`` exactly like ``distinct_hit``.

The technique is probabilistic in the paper's sense: it may *miss* a
prunable partition (Bloom false positives) but never prunes a partition
containing joinable rows — hypothesis tests assert exactly this.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .metadata import PartitionStats, ScanSet

BLOCK_WORDS = 16          # 16 x 32-bit words = 512-bit blocks
K_PROBES = 4
DEFAULT_ENUM_LIMIT = 1024  # max values enumerated per narrow partition


def _mix32(x: np.ndarray) -> np.ndarray:
    """Murmur3 finalizer — the shared 32-bit mixer (numpy + Pallas)."""
    x = x.astype(np.uint32)
    x ^= x >> np.uint32(16)
    x *= np.uint32(0x85EBCA6B)
    x ^= x >> np.uint32(13)
    x *= np.uint32(0xC2B2AE35)
    x ^= x >> np.uint32(16)
    return x


def _fold_key(keys: np.ndarray) -> np.ndarray:
    """int64-domain keys -> uint32 hash seed."""
    k = keys.astype(np.int64)
    lo = (k & np.int64(0xFFFFFFFF)).astype(np.uint32)
    hi = ((k >> np.int64(32)) & np.int64(0xFFFFFFFF)).astype(np.uint32)
    return _mix32(lo ^ _mix32(hi))


def _probe_coords(keys: np.ndarray, n_blocks: int):
    """(block, word[4], bit[4]) coordinates for each key."""
    h0 = _fold_key(keys)
    block = h0 & np.uint32(n_blocks - 1)
    h1 = _mix32(h0 ^ np.uint32(0x9E3779B9))
    h2 = _mix32(h1 ^ np.uint32(0x7F4A7C15))
    words = np.stack([(h1 >> np.uint32(8 * i)) & np.uint32(BLOCK_WORDS - 1)
                      for i in range(K_PROBES)], axis=-1)
    bits = np.stack([(h2 >> np.uint32(8 * i)) & np.uint32(31)
                     for i in range(K_PROBES)], axis=-1)
    return block, words, bits


class BlockedBloom:
    """Register-blocked Bloom filter over int-domain keys."""

    def __init__(self, n_keys: int, bits_per_key: int = 16):
        want_bits = max(n_keys, 1) * bits_per_key
        n_blocks = 1
        while n_blocks * BLOCK_WORDS * 32 < want_bits:
            n_blocks *= 2
        self.n_blocks = n_blocks
        self.words = np.zeros(n_blocks * BLOCK_WORDS, dtype=np.uint32)

    @property
    def size_bytes(self) -> int:
        return self.words.nbytes

    def add(self, keys: np.ndarray) -> None:
        block, words, bits = _probe_coords(keys, self.n_blocks)
        for i in range(K_PROBES):
            idx = block * np.uint32(BLOCK_WORDS) + words[:, i]
            np.bitwise_or.at(self.words, idx.astype(np.int64),
                             np.uint32(1) << bits[:, i])

    def contains(self, keys: np.ndarray) -> np.ndarray:
        block, words, bits = _probe_coords(keys, self.n_blocks)
        ok = np.ones(len(keys), dtype=bool)
        for i in range(K_PROBES):
            idx = (block * np.uint32(BLOCK_WORDS) + words[:, i]).astype(np.int64)
            ok &= (self.words[idx] >> bits[:, i]) & np.uint32(1) == 1
        return ok


@dataclasses.dataclass
class BuildSummary:
    """What ships from build to probe side (step 2)."""

    min: float
    max: float
    count: int
    distinct: Optional[np.ndarray]      # sorted distinct keys, if NDV small
    bloom: Optional[BlockedBloom]
    size_bytes: int

    @property
    def empty(self) -> bool:
        return self.count == 0


def summarize_build(
    keys: np.ndarray,
    null_mask: Optional[np.ndarray] = None,
    ndv_limit: int = 4096,
    bits_per_key: int = 16,
) -> BuildSummary:
    """Step 1: summarize build-side join-key values (nulls never join)."""
    if null_mask is not None:
        keys = keys[~null_mask]
    if keys.size == 0:
        # The empty distinct set keeps the key column's dtype: callers
        # (device eligibility, np.isin masks) see the real key domain, not
        # an accidental float64.
        return BuildSummary(np.inf, -np.inf, 0,
                            np.zeros(0, dtype=keys.dtype), None, 16)
    uniq = np.unique(keys)
    if uniq.size <= ndv_limit:
        return BuildSummary(
            float(uniq[0]), float(uniq[-1]), int(keys.size),
            uniq, None, int(uniq.nbytes) + 16,
        )
    bloom = BlockedBloom(uniq.size, bits_per_key)
    bloom.add(uniq)
    return BuildSummary(
        float(uniq[0]), float(uniq[-1]), int(keys.size),
        None, bloom, bloom.size_bytes + 16,
    )


@dataclasses.dataclass
class JoinPruneResult:
    scan: ScanSet
    pruned_by_range: int
    pruned_by_distinct: int
    pruned_by_bloom: int
    partitions_before: int
    partitions_after: int


def prune_probe(
    scan: ScanSet,
    stats: PartitionStats,
    key_col: str,
    summary: BuildSummary,
    enum_limit: int = DEFAULT_ENUM_LIMIT,
    distinct_hit: Optional[np.ndarray] = None,
    bloom_hit: Optional[np.ndarray] = None,
) -> JoinPruneResult:
    """Steps 3+4: overlap the summary with probe partitions' min/max.

    ``distinct_hit`` injects a precomputed distinct-key overlap result
    (bool per scan entry) in place of the host searchsorted — the device
    engine computes it with the batched ``join_overlap_batched`` kernel
    over the resident join-key plane.  ``bloom_hit`` is its Bloom-summary
    analogue: the narrow-range enumeration result (bool per scan entry)
    from ``bloom_probe_batched`` over the resident enumeration plane,
    True for every non-enumerable partition.  Either injection must be
    superset-safe (never False for a partition that may hold a build key).
    """
    before = len(scan)
    pmin = stats.col_min(key_col)[scan.part_ids]
    pmax = stats.col_max(key_col)[scan.part_ids]
    empty_part = pmin > pmax  # all-null key column: no row can join

    if summary.empty:
        # Empty build side: the probe scan is eliminated entirely (the
        # paper's "13% of queries see a pruning ratio of 100%").
        return JoinPruneResult(scan.keep(np.zeros(before, dtype=bool)),
                               before, 0, 0, before, 0)

    keep = (pmax >= summary.min) & (pmin <= summary.max) & ~empty_part
    n_range = int(before - keep.sum())
    n_distinct = n_bloom = 0

    if summary.distinct is not None:
        if distinct_hit is not None:
            hit = np.asarray(distinct_hit, dtype=bool)
        else:
            d = summary.distinct
            lo = np.searchsorted(d, pmin, side="left")
            hi = np.searchsorted(d, pmax, side="right")
            hit = hi > lo
        n_distinct = int((keep & ~hit).sum())
        keep &= hit
    elif summary.bloom is not None:
        if bloom_hit is not None:
            hit = np.asarray(bloom_hit, dtype=bool)
            n_bloom = int((keep & ~hit).sum())
            keep &= hit
        elif stats.column(key_col).kind != "float":
            # Integer/dictionary domains only: fractional build keys are
            # invisible to the integer enumeration, so float columns skip
            # it entirely (skip = keep — the technique may only miss
            # prunable partitions, never prune joinable ones).  Width is
            # compared in float64 before any integer cast: int64-extreme
            # or huge-float ranges would overflow the cast (and can raise)
            # but simply aren't narrow.
            widthf = pmax - pmin + 1.0
            narrow = keep & (widthf > 0) & (widthf <= enum_limit)
            idx = np.where(narrow)[0]
            if idx.size:
                width = widthf[idx].astype(np.int64)
                cand = (pmin[idx, None].astype(np.int64)
                        + np.arange(enum_limit)[None, :])
                valid = np.arange(enum_limit)[None, :] < width[:, None]
                hits = summary.bloom.contains(
                    cand.reshape(-1)).reshape(cand.shape)
                any_hit = (hits & valid).any(axis=1)
                n_bloom = int((~any_hit).sum())
                keep[idx[~any_hit]] = False

    pruned = scan.keep(keep)
    return JoinPruneResult(pruned, n_range, n_distinct, n_bloom, before, len(pruned))
