"""The combined pruning flow (paper Sec. 7) as a technique-executor engine.

Techniques execute in Snowflake's order:
    filter pruning (compile time, Sec. 3)
      -> LIMIT pruning (compile time, extends filter pruning, Sec. 4)
      -> JOIN pruning  (runtime, Sec. 6)
      -> top-k pruning (runtime, Sec. 5)

Technique-executor contract
---------------------------
Each stage is a ``Technique``.  An executor reads the query's per-scan
``ScanSet``s out of a ``PruneState``, refines them, and records a
``TechniqueReport`` — per scan it is a ``(ScanSet, report) ->
(ScanSet, report)`` transformer, and the pipeline is nothing but the
ordered composition of the four executors (cf. Extensible Data
Skipping's pluggable technique interface over shared metadata).

The same executors run in two regimes:

  * ``PruningPipeline.run`` drives the sequence for ONE query — each
    executor's ``run(pipeline, state)``;
  * ``serve.prune_service.PruningService.run_batch`` drives the sequence
    over a whole workload — each executor's ``run_batch(pipeline,
    states, service)``, where device-eligible stages (filter, join
    overlap, top-k boundary init) group their kernel work **per table**
    so launches are bounded by the number of distinct tables, not the
    number of queries.

Both regimes produce bit-identical ``PruningReport``s: the batched path
evaluates exactly the same per-query math, only packed into shared
launches against the resident metadata planes (core/device_stats.py).

``PruningPipeline.run`` returns a per-scan, per-technique report — the
data source for the Figure 1 / Figure 11 benchmarks — together with the
final scan sets that the executor (data/scan.py) consumes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import expr as E
from .metadata import (NO_MATCH, PARTIAL_MATCH, ScanSet, live_full_scan,
                       mask_dead_partitions, pruning_ratio)
from .prune_filter import eval_tv
from .prune_join import BuildSummary, prune_probe, summarize_build
from .prune_limit import limit_prune
from .prune_topk import TopKResult, run_topk
from .prune_tree import AdaptivePruner
from .rowval import matches


@dataclasses.dataclass
class TableScanSpec:
    table: object                     # data.table.Table
    pred: E.Pred = dataclasses.field(default_factory=E.true)


@dataclasses.dataclass
class JoinSpec:
    build: str                        # scan name (small side, hashed)
    probe: str                        # scan name (large side, pruned)
    build_key: str
    probe_key: str
    kind: str = "inner"               # 'inner' | 'left_outer' (probe side preserved)


@dataclasses.dataclass
class Query:
    scans: Dict[str, TableScanSpec]
    join: Optional[JoinSpec] = None
    limit: Optional[int] = None
    offset: int = 0
    order_by: Optional[Tuple[str, str, bool]] = None  # (scan, column, desc)
    group_by: Tuple[str, ...] = ()
    order_by_is_aggregate: bool = False

    @property
    def effective_k(self) -> Optional[int]:
        # Fig. 6: OFFSET counts toward the rows that must be produced.
        return None if self.limit is None else self.limit + self.offset

    @property
    def is_topk(self) -> bool:
        return self.limit is not None and self.order_by is not None

    @property
    def is_plain_limit(self) -> bool:
        return self.limit is not None and self.order_by is None


@dataclasses.dataclass
class TechniqueReport:
    before: int
    after: int
    applied: bool
    detail: dict = dataclasses.field(default_factory=dict)

    @property
    def ratio(self) -> float:
        return pruning_ratio(self.before, self.after)


@dataclasses.dataclass
class PruningReport:
    per_scan: Dict[str, Dict[str, TechniqueReport]]
    scan_sets: Dict[str, ScanSet]
    topk: Optional[TopKResult] = None
    topk_scan: Optional[str] = None   # scan name the top-k technique targeted
    counters: Optional[dict] = None   # this batch's ServiceCounters delta
                                      # (attached by PruningService.run_batch)

    def technique_totals(self) -> Dict[str, Tuple[int, int]]:
        out: Dict[str, Tuple[int, int]] = {}
        for scans in self.per_scan.values():
            for tech, rep in scans.items():
                b, a = out.get(tech, (0, 0))
                out[tech] = (b + rep.before, a + rep.after)
        return out

    @property
    def overall_ratio(self) -> float:
        """Partitions removed by ANY technique / total partitions touched
        by the query — the paper's whole-query pruning ratio (Fig. 4
        'relative to the total number of partitions to be processed').

        ``topk.skipped`` partitions are not removed from ``scan_sets`` by
        the engine, so they are subtracted here — but only those still
        *present* in the target scan set, guarding against a caller that
        already removed them (double subtraction would overstate the
        ratio, even past 1.0)."""
        total = sum(s.table.num_partitions for s in self._scan_specs.values())
        remaining = sum(len(ss) for ss in self.scan_sets.values())
        if self.topk is not None and len(self.topk.skipped):
            if self.topk_scan is not None:
                target = self.scan_sets.get(self.topk_scan)
                present = (int(np.isin(self.topk.skipped,
                                       target.part_ids).sum())
                           if target is not None else 0)
            else:
                # Legacy reports without a recorded target scan: the
                # skipped ids all belong to ONE (unknown) table, so take
                # the largest single-scan intersection — partition ids
                # are table-local and comparing against a concatenation
                # of every scan would let another table's ids collide.
                present = max((int(np.isin(self.topk.skipped,
                                           ss.part_ids).sum())
                               for ss in self.scan_sets.values()),
                              default=0)
            remaining -= present
        return pruning_ratio(total, remaining)

    _scan_specs: Dict[str, TableScanSpec] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class PruneState:
    """Mutable per-query state threaded through the technique sequence."""

    query: Query
    scan_sets: Dict[str, ScanSet] = dataclasses.field(default_factory=dict)
    per_scan: Dict[str, Dict[str, TechniqueReport]] = dataclasses.field(
        default_factory=dict)
    filter_sets: Optional[Dict[str, ScanSet]] = None  # injected filter results
    build_keys: Optional[np.ndarray] = None           # join build-side keys
    topk: Optional[TopKResult] = None
    topk_scan: Optional[str] = None


class Technique:
    """One pruning stage.  ``run`` executes it for a single query;
    ``run_batch`` executes it across a workload, and device-eligible
    subclasses override it to batch kernel work per table group via the
    ``service`` (a ``serve.prune_service.PruningService``)."""

    name = "?"

    def run(self, pipe: "PruningPipeline", state: PruneState) -> None:
        raise NotImplementedError

    def run_batch(self, pipe: "PruningPipeline", states: List[PruneState],
                  service=None) -> None:
        for st in states:
            self.run(pipe, st)


class FilterTechnique(Technique):
    """Sec. 3 filter pruning (+ Sec. 4.2 fully-matching, one pass)."""

    name = "filter"

    def run(self, pipe, state):
        q = state.query
        for name, spec in q.scans.items():
            if state.filter_sets is not None and name in state.filter_sets:
                ss = state.filter_sets[name]
                P = spec.table.num_partitions
                rep = TechniqueReport(
                    P, len(ss),
                    applied=pipe.enable_filter
                    and not isinstance(spec.pred, E.TruePred))
            else:
                ss, rep = self._prune_scan(pipe, spec)
            state.scan_sets[name] = ss
            state.per_scan[name]["filter"] = rep

    def _prune_scan(self, pipe, spec: TableScanSpec
                    ) -> Tuple[ScanSet, TechniqueReport]:
        table = spec.table
        P = table.num_partitions
        if not pipe.enable_filter or isinstance(spec.pred, E.TruePred):
            ss = live_full_scan(table)
            if not isinstance(spec.pred, E.TruePred):
                # Filter disabled but a predicate exists: no partition is
                # *certified* fully matching — FULL here would let the
                # LIMIT cutter and the Sec. 5.4 boundary initializers
                # (host and device) trust uncertified rows and drop true
                # results.
                ss = ScanSet(ss.part_ids,
                             np.full(len(ss), PARTIAL_MATCH, dtype=np.int8))
            return ss, TechniqueReport(P, len(ss), applied=False)
        if pipe.adaptive:
            res = AdaptivePruner(spec.pred).run(table.stats,
                                               batch_size=max(P // 8, 1))
            tv = res.tv
        else:
            tv = None
            if pipe.filter_mode == "device":
                # Delegate to the PruningService: resident device stats
                # (staged once, delta-synced on DML) + the batched kernel.
                # The plane's PlaneEpoch (version/live/capacity) is
                # surfaced batch-level via PruningReport.counters.
                tv = pipe.device_service().scan_tv(spec)
            if tv is None:
                tv = eval_tv(spec.pred, table.stats)
        # Dropped partitions never enter a scan set, on any path — the
        # same mask the device plane encodes as sentinel slots.
        tv = mask_dead_partitions(tv, table)
        keep = tv > NO_MATCH
        ss = ScanSet(np.where(keep)[0], tv[keep])
        return ss, TechniqueReport(P, len(ss), applied=True)

    def run_batch(self, pipe, states, service=None):
        if (service is not None and pipe.enable_filter and not pipe.adaptive
                and pipe.filter_mode == "device"):
            batch_sets = service.prune_batch([st.query for st in states])
            for st, fs in zip(states, batch_sets):
                if st.filter_sets:       # caller-injected sets win
                    fs = {**fs, **st.filter_sets}
                st.filter_sets = fs
        for st in states:
            self.run(pipe, st)


class LimitTechnique(Technique):
    """Sec. 4 LIMIT pruning over fully-matching partitions (host-only:
    compile-time metadata arithmetic, never a kernel launch)."""

    name = "limit"

    def run(self, pipe, state):
        q = state.query
        if not (pipe.enable_limit and q.is_plain_limit):
            return
        for name, spec in q.scans.items():
            res = limit_prune(
                state.scan_sets[name],
                spec.table.stats,
                q.effective_k,
                supported_shape=pipe._limit_supported(q, name),
            )
            state.scan_sets[name] = res.scan
            state.per_scan[name]["limit"] = TechniqueReport(
                res.partitions_before, res.partitions_after,
                res.applied, detail=dict(category=res.category),
            )


class JoinTechnique(Technique):
    """Sec. 6 JOIN pruning.  The build side is summarized on the host
    (runtime values); in device mode the probe-side matching runs on the
    resident planes — the distinct-key overlap via ``join_overlap_batched``
    over the join-key plane, the Bloom narrow-range enumeration via
    ``bloom_probe_batched`` over the enumeration plane — one launch per
    (table, key column, summary kind) group in ``run_batch``.
    Non-castable distinct keys and non-integer Bloom key domains fall
    back to the host matcher (counted per technique, never wrong)."""

    name = "join"

    def _build_keys(self, state: PruneState) -> np.ndarray:
        q = state.query
        bspec = q.scans[q.join.build]
        bctx = bspec.table.ctx_for(state.scan_sets[q.join.build].part_ids)
        bmask = matches(bspec.pred, bctx)
        keys, knulls = bctx.col(q.join.build_key)
        return keys[bmask & ~knulls]

    def _summarize(self, pipe, state) -> Optional[BuildSummary]:
        """Host part of the stage: build keys + summary (also feeds the
        top-k technique's extra mask).  None when the stage is disabled."""
        if state.query.join is None:
            return None
        state.build_keys = self._build_keys(state)
        if not pipe.enable_join:
            return None
        return summarize_build(state.build_keys,
                               ndv_limit=pipe.join_ndv_limit)

    def _apply(self, pipe, state, summary: BuildSummary,
               hit: Optional[np.ndarray]) -> None:
        """Overlap + prune the probe scan; ``hit`` is the device result
        [P] — distinct-key overlap or Bloom enumeration, per the summary
        kind (None -> host matcher)."""
        q = state.query
        scan = state.scan_sets[q.join.probe]
        over = None if hit is None else np.asarray(hit)[scan.part_ids] > 0
        res = prune_probe(
            scan, q.scans[q.join.probe].table.stats,
            q.join.probe_key, summary,
            distinct_hit=over if summary.distinct is not None else None,
            bloom_hit=over if summary.bloom is not None else None,
        )
        state.scan_sets[q.join.probe] = res.scan
        state.per_scan[q.join.probe]["join"] = TechniqueReport(
            res.partitions_before, res.partitions_after,
            applied=True,
            detail=dict(
                by_range=res.pruned_by_range,
                by_distinct=res.pruned_by_distinct,
                by_bloom=res.pruned_by_bloom,
                summary_bytes=summary.size_bytes,
                summary_kind=(
                    "distinct" if summary.distinct is not None
                    else "bloom" if summary.bloom is not None else "empty"
                ),
                path="device" if hit is not None else "host",
            ),
        )

    def run(self, pipe, state):
        summary = self._summarize(pipe, state)
        if summary is None:
            return
        hit = None
        if pipe.filter_mode == "device" and not pipe.adaptive:
            q = state.query
            hit = pipe.device_service().join_hit(
                q.scans[q.join.probe].table, q.join.probe_key, summary,
                part_ids=state.scan_sets[q.join.probe].part_ids)
        self._apply(pipe, state, summary, hit)

    def run_batch(self, pipe, states, service=None):
        if service is None:
            return super().run_batch(pipe, states, service)
        # (table id, probe key) -> (table, key_col, [(state, summary)]),
        # one group dict per summary kind: distinct overlaps and Bloom
        # enumerations are different kernels, each one launch per group.
        groups: Dict[Tuple, Tuple] = {}
        bloom_groups: Dict[Tuple, Tuple] = {}
        host_jobs = []
        for st in states:
            summary = self._summarize(pipe, st)
            if summary is None:
                continue
            q = st.query
            table = q.scans[q.join.probe].table
            if not service.join_device_eligible(summary, table,
                                                q.join.probe_key):
                host_jobs.append((st, summary))
                continue
            g = groups if summary.distinct is not None else bloom_groups
            g.setdefault(
                (id(table), q.join.probe_key),
                (table, q.join.probe_key, []))[2].append((st, summary))
        for table, key_col, members in groups.values():
            hits = service.join_hit_batch(
                table, key_col, [s for _, s in members],
                part_ids=[st.scan_sets[st.query.join.probe].part_ids
                          for st, _ in members])
            if hits is None:
                # the service's ladder degraded this group past the
                # device rungs: the host matcher (hit=None per member)
                # is the stage's exact terminal rung
                hits = [None] * len(members)
            for (st, summary), hit in zip(members, hits):
                self._apply(pipe, st, summary, hit)
        for table, key_col, members in bloom_groups.values():
            hits = service.bloom_hit_batch(
                table, key_col, [s for _, s in members],
                part_ids=[st.scan_sets[st.query.join.probe].part_ids
                          for st, _ in members])
            if hits is None:
                hits = [None] * len(members)
            for (st, summary), hit in zip(members, hits):
                self._apply(pipe, st, summary, hit)
        for st, summary in host_jobs:
            if not summary.empty:
                service.counters.bump(
                    "join_bloom" if summary.bloom is not None else self.name,
                    fallbacks=1)
            self._apply(pipe, st, summary, None)


class TopKTechnique(Technique):
    """Sec. 5 top-k boundary pruning.  The scan loop stays on the host
    (it fetches real rows); in device mode the Sec. 5.4 upfront boundary
    is *initialized from the resident block-top-k plane* — the k-th
    largest value over the fully-matching partitions' resident top-k
    rows, a strictly stronger (still witnessed) boundary than the
    stats-only candidates — via one batched ``topk_init_batched`` launch
    per (table, order column, direction) group in ``run_batch``."""

    name = "topk"

    def _extra_mask(self, state: PruneState):
        q = state.query
        scan_name, _col, _desc = q.order_by
        if (q.join is not None and scan_name == q.join.probe
                and q.join.kind == "inner"):
            key_col = q.join.probe_key
            bk = (np.unique(state.build_keys)
                  if state.build_keys is not None else np.zeros(0))

            def extra(ctx, _bk=bk, _kc=key_col):
                v, nm = ctx.col(_kc)
                return np.isin(v, _bk) & ~nm

            return extra
        return None

    def _device_eligible(self, pipe, state, extra) -> bool:
        # Upfront boundaries are only valid without interposed operators
        # (Sec. 5.4) — mirroring run_topk's own use_upfront_init gate.
        # Adaptive pipelines keep their own (host) semantics throughout,
        # like the filter stage.
        q = state.query
        return (pipe.filter_mode == "device" and not pipe.adaptive
                and pipe.topk_upfront_init
                and extra is None and q.effective_k > 0)

    def _apply(self, pipe, state, extra, b_floor: float, path: str) -> None:
        q = state.query
        scan_name, order_col, desc = q.order_by
        spec = q.scans[scan_name]
        topk_res = run_topk(
            spec.table, state.scan_sets[scan_name], order_col, q.effective_k,
            pred=spec.pred if not isinstance(spec.pred, E.TruePred) else None,
            desc=desc, strategy=pipe.topk_strategy,
            use_upfront_init=pipe.topk_upfront_init,
            extra_mask_fn=extra, b_init_floor=b_floor,
        )
        before = len(state.scan_sets[scan_name])
        state.per_scan[scan_name]["topk"] = TechniqueReport(
            before, before - len(topk_res.skipped), applied=True,
            detail=dict(rows_scanned=topk_res.rows_scanned, path=path,
                        b_init_floor=b_floor),
        )
        state.topk = topk_res
        state.topk_scan = scan_name

    def run(self, pipe, state):
        q = state.query
        target = pipe._topk_supported(q)
        if not (pipe.enable_topk and target is not None):
            return
        extra = self._extra_mask(state)
        b_floor, path = -np.inf, "host"
        if self._device_eligible(pipe, state, extra):
            scan_name, order_col, desc = q.order_by
            b_floor = pipe.device_service().topk_init(
                q.scans[scan_name].table, state.scan_sets[scan_name],
                order_col, bool(desc), q.effective_k)
            path = "device"
        elif pipe.filter_mode == "device" and not pipe.adaptive:
            pipe.device_service().counters.bump(self.name, fallbacks=1)
        self._apply(pipe, state, extra, b_floor, path)

    def run_batch(self, pipe, states, service=None):
        if service is None:
            return super().run_batch(pipe, states, service)
        # (table id, order col, desc) -> (table, col, desc, [(state, extra, k)])
        groups: Dict[Tuple, Tuple] = {}
        host_jobs = []
        for st in states:
            q = st.query
            target = pipe._topk_supported(q)
            if not (pipe.enable_topk and target is not None):
                continue
            extra = self._extra_mask(st)
            if not self._device_eligible(pipe, st, extra):
                host_jobs.append((st, extra))
                continue
            scan_name, order_col, desc = q.order_by
            table = q.scans[scan_name].table
            groups.setdefault(
                (id(table), order_col, bool(desc)),
                (table, order_col, bool(desc), []))[3].append(
                    (st, extra, q.effective_k))
        for table, col, desc, members in groups.values():
            floors = service.topk_init_batch(
                table, col, desc,
                [(st.scan_sets[st.query.order_by[0]], k)
                 for st, _, k in members])
            for (st, extra, _k), floor in zip(members, floors):
                self._apply(pipe, st, extra, floor, "device")
        for st, extra in host_jobs:
            service.counters.bump(self.name, fallbacks=1)
            self._apply(pipe, st, extra, -np.inf, "host")


class PruningPipeline:
    def __init__(
        self,
        adaptive: bool = False,
        topk_strategy: str = "sort",
        topk_upfront_init: bool = True,
        enable_filter: bool = True,
        enable_limit: bool = True,
        enable_join: bool = True,
        enable_topk: bool = True,
        join_ndv_limit: int = 4096,
        filter_mode: str = "host",   # 'host' | 'device': the pipeline's
                                     # execution mode.  'device' routes every
                                     # device-eligible stage (filter ranges,
                                     # join overlap, top-k boundary init)
                                     # through the PruningService's resident
                                     # metadata planes and batched kernels.
        service=None,                # serve.prune_service.PruningService;
                                     # built lazily for filter_mode='device'
        budget_bytes: Optional[int] = None,
                                     # HBM budget for the lazily-built
                                     # service's plane manager: every plane
                                     # getter routes through it (LRU
                                     # eviction + in-flight pinning); None
                                     # keeps the planes unbounded.
        shard_planes: bool = False,  # partition-shard the lazily-built
                                     # service's batched launches over the
                                     # host plane mesh (shard_map on
                                     # launch.mesh.make_plane_mesh()).
        tree_fanout: Optional[int] = None,
                                     # hierarchical-plane group size for the
                                     # lazily-built service (None keeps the
                                     # cache default; tests shrink it so
                                     # small tables take the tree rungs).
    ):
        self.adaptive = adaptive
        self.topk_strategy = topk_strategy
        self.topk_upfront_init = topk_upfront_init
        self.enable_filter = enable_filter
        self.enable_limit = enable_limit
        self.enable_join = enable_join
        self.enable_topk = enable_topk
        self.join_ndv_limit = join_ndv_limit
        self.filter_mode = filter_mode
        if service is not None and (budget_bytes is not None or shard_planes
                                    or tree_fanout is not None):
            # Silently dropping these would run the fleet unbounded /
            # unsharded — the exact failure they exist to prevent.
            raise ValueError(
                "budget_bytes / shard_planes / tree_fanout configure the "
                "lazily-built service; pass them to the PruningService "
                "itself when providing one")
        self._service = service
        self._budget_bytes = budget_bytes
        self._shard_planes = shard_planes
        self._tree_fanout = tree_fanout
        self.techniques: List[Technique] = [
            FilterTechnique(), LimitTechnique(),
            JoinTechnique(), TopKTechnique(),
        ]

    def device_service(self):
        """The PruningService backing filter_mode='device' (lazy).

        Sharing one service across pipelines shares its DeviceStatsCache —
        tables are staged once per version, not once per pipeline.  Every
        plane getter the techniques reach through this service routes
        through the cache's ``PlaneMemoryManager`` (LRU under
        ``budget_bytes``, pinned while a launch is in flight); with
        ``shard_planes`` the batched launches partition-shard over the
        host plane mesh.
        """
        if self._service is None:
            from ..serve.prune_service import PruningService
            self._service = PruningService(
                budget_bytes=self._budget_bytes,
                shard_mesh=True if self._shard_planes else None,
                tree_fanout=self._tree_fanout)
        return self._service

    # -- shape gates shared by executors -------------------------------------

    def _limit_supported(self, q: Query, name: str) -> bool:
        """Sec. 4.3 pushdown rules: row-reducing operators block LIMIT
        pushdown, except through the preserved side of a LEFT OUTER join."""
        if q.group_by or q.order_by is not None:
            return False
        if q.join is None:
            return True
        return q.join.kind == "left_outer" and name == q.join.probe

    def _topk_supported(self, q: Query) -> Optional[str]:
        """Fig. 7 shapes: which scan can the TopK boundary prune?"""
        if not q.is_topk:
            return None
        scan_name, _col, _desc = q.order_by
        if q.group_by:
            # Fig. 7d: ORDER BY must be a subset of GROUP BY keys.
            return scan_name if not q.order_by_is_aggregate else None
        if q.join is None:
            return scan_name
        if scan_name == q.join.probe:
            return scan_name                     # Fig. 7b
        if q.join.kind == "left_outer" and scan_name == q.join.build:
            return scan_name                     # Fig. 7c: replicate to build
        return None

    # -- driver --------------------------------------------------------------

    def make_state(self, q: Query,
                   filter_sets: Optional[Dict[str, ScanSet]] = None
                   ) -> PruneState:
        return PruneState(query=q, per_scan={n: {} for n in q.scans},
                          filter_sets=filter_sets)

    def finish(self, state: PruneState) -> PruningReport:
        report = PruningReport(state.per_scan, state.scan_sets,
                               state.topk, state.topk_scan)
        report._scan_specs = dict(state.query.scans)
        return report

    def run(self, q: Query, filter_sets: Optional[Dict[str, ScanSet]] = None
            ) -> PruningReport:
        """Run the technique sequence for one query; ``filter_sets``
        injects precomputed filter scan sets (PruningService.run_batch
        batches that stage across a workload) — later techniques run
        unchanged on top of them."""
        state = self.make_state(q, filter_sets)
        for tech in self.techniques:
            tech.run(self, state)
        return self.finish(state)
