"""The combined pruning flow (paper Sec. 7).

Techniques execute in Snowflake's order:
    filter pruning (compile time, Sec. 3)
      -> LIMIT pruning (compile time, extends filter pruning, Sec. 4)
      -> JOIN pruning  (runtime, Sec. 6)
      -> top-k pruning (runtime, Sec. 5)

``PruningPipeline.run`` returns a per-scan, per-technique report — the
data source for the Figure 1 / Figure 11 benchmarks — together with the
final scan sets that the executor (data/scan.py) consumes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import expr as E
from .metadata import NO_MATCH, ScanSet, pruning_ratio
from .prune_filter import eval_tv
from .prune_join import BuildSummary, prune_probe, summarize_build
from .prune_limit import (ALREADY_MINIMAL, NO_FULLY_MATCHING, UNSUPPORTED_SHAPE,
                          limit_prune)
from .prune_topk import TopKResult, run_topk
from .prune_tree import AdaptivePruner
from .rowval import matches


@dataclasses.dataclass
class TableScanSpec:
    table: object                     # data.table.Table
    pred: E.Pred = dataclasses.field(default_factory=E.true)


@dataclasses.dataclass
class JoinSpec:
    build: str                        # scan name (small side, hashed)
    probe: str                        # scan name (large side, pruned)
    build_key: str
    probe_key: str
    kind: str = "inner"               # 'inner' | 'left_outer' (probe side preserved)


@dataclasses.dataclass
class Query:
    scans: Dict[str, TableScanSpec]
    join: Optional[JoinSpec] = None
    limit: Optional[int] = None
    offset: int = 0
    order_by: Optional[Tuple[str, str, bool]] = None  # (scan, column, desc)
    group_by: Tuple[str, ...] = ()
    order_by_is_aggregate: bool = False

    @property
    def effective_k(self) -> Optional[int]:
        # Fig. 6: OFFSET counts toward the rows that must be produced.
        return None if self.limit is None else self.limit + self.offset

    @property
    def is_topk(self) -> bool:
        return self.limit is not None and self.order_by is not None

    @property
    def is_plain_limit(self) -> bool:
        return self.limit is not None and self.order_by is None


@dataclasses.dataclass
class TechniqueReport:
    before: int
    after: int
    applied: bool
    detail: dict = dataclasses.field(default_factory=dict)

    @property
    def ratio(self) -> float:
        return pruning_ratio(self.before, self.after)


@dataclasses.dataclass
class PruningReport:
    per_scan: Dict[str, Dict[str, TechniqueReport]]
    scan_sets: Dict[str, ScanSet]
    topk: Optional[TopKResult] = None

    def technique_totals(self) -> Dict[str, Tuple[int, int]]:
        out: Dict[str, Tuple[int, int]] = {}
        for scans in self.per_scan.values():
            for tech, rep in scans.items():
                b, a = out.get(tech, (0, 0))
                out[tech] = (b + rep.before, a + rep.after)
        return out

    @property
    def overall_ratio(self) -> float:
        """Partitions removed by ANY technique / total partitions touched
        by the query — the paper's whole-query pruning ratio (Fig. 4
        'relative to the total number of partitions to be processed')."""
        total = sum(s.table.num_partitions for s in self._scan_specs.values())
        remaining = sum(len(ss) for ss in self.scan_sets.values())
        if self.topk is not None:
            remaining -= len(self.topk.skipped)
        return pruning_ratio(total, remaining)

    _scan_specs: Dict[str, TableScanSpec] = dataclasses.field(default_factory=dict)


class PruningPipeline:
    def __init__(
        self,
        adaptive: bool = False,
        topk_strategy: str = "sort",
        topk_upfront_init: bool = True,
        enable_filter: bool = True,
        enable_limit: bool = True,
        enable_join: bool = True,
        enable_topk: bool = True,
        join_ndv_limit: int = 4096,
        filter_mode: str = "host",   # 'host' | 'device' (runtime pruning on
                                     # accelerator via kernels/, when the
                                     # predicate lowers to conj. ranges)
        service=None,                # serve.prune_service.PruningService;
                                     # built lazily for filter_mode='device'
    ):
        self.adaptive = adaptive
        self.topk_strategy = topk_strategy
        self.topk_upfront_init = topk_upfront_init
        self.enable_filter = enable_filter
        self.enable_limit = enable_limit
        self.enable_join = enable_join
        self.enable_topk = enable_topk
        self.join_ndv_limit = join_ndv_limit
        self.filter_mode = filter_mode
        self._service = service

    def device_service(self):
        """The PruningService backing filter_mode='device' (lazy).

        Sharing one service across pipelines shares its DeviceStatsCache —
        tables are staged once per version, not once per pipeline.
        """
        if self._service is None:
            from ..serve.prune_service import PruningService
            self._service = PruningService()
        return self._service

    # -- steps -------------------------------------------------------------

    def _filter_prune(self, spec: TableScanSpec) -> Tuple[ScanSet, TechniqueReport]:
        table = spec.table
        P = table.num_partitions
        if not self.enable_filter or isinstance(spec.pred, E.TruePred):
            ss = ScanSet.full(P)
            return ss, TechniqueReport(P, P, applied=False)
        if self.adaptive:
            res = AdaptivePruner(spec.pred).run(table.stats, batch_size=max(P // 8, 1))
            tv = res.tv
        else:
            tv = None
            if self.filter_mode == "device":
                # Delegate to the PruningService: resident device stats
                # (staged once per table version) + the batched kernel.
                tv = self.device_service().scan_tv(spec)
            if tv is None:
                tv = eval_tv(spec.pred, table.stats)
        keep = tv > NO_MATCH
        ss = ScanSet(np.where(keep)[0], tv[keep])
        return ss, TechniqueReport(P, len(ss), applied=True)

    def _limit_supported(self, q: Query, name: str) -> bool:
        """Sec. 4.3 pushdown rules: row-reducing operators block LIMIT
        pushdown, except through the preserved side of a LEFT OUTER join."""
        if q.group_by or q.order_by is not None:
            return False
        if q.join is None:
            return True
        return q.join.kind == "left_outer" and name == q.join.probe

    def _topk_supported(self, q: Query) -> Optional[str]:
        """Fig. 7 shapes: which scan can the TopK boundary prune?"""
        if not q.is_topk:
            return None
        scan_name, _col, _desc = q.order_by
        if q.group_by:
            # Fig. 7d: ORDER BY must be a subset of GROUP BY keys.
            return scan_name if not q.order_by_is_aggregate else None
        if q.join is None:
            return scan_name
        if scan_name == q.join.probe:
            return scan_name                     # Fig. 7b
        if q.join.kind == "left_outer" and scan_name == q.join.build:
            return scan_name                     # Fig. 7c: replicate to build
        return None

    # -- driver --------------------------------------------------------------

    def run(self, q: Query, filter_sets: Optional[Dict[str, ScanSet]] = None
            ) -> PruningReport:
        """Run the pruning flow; ``filter_sets`` injects precomputed filter
        scan sets (PruningService.run_batch batches that stage across a
        workload) — later techniques run unchanged on top of them."""
        per_scan: Dict[str, Dict[str, TechniqueReport]] = {n: {} for n in q.scans}
        scan_sets: Dict[str, ScanSet] = {}

        # 1. filter pruning (+ fully-matching detection, one pass)
        for name, spec in q.scans.items():
            if filter_sets is not None and name in filter_sets:
                ss = filter_sets[name]
                P = spec.table.num_partitions
                rep = TechniqueReport(
                    P, len(ss),
                    applied=self.enable_filter
                    and not isinstance(spec.pred, E.TruePred))
            else:
                ss, rep = self._filter_prune(spec)
            scan_sets[name] = ss
            per_scan[name]["filter"] = rep

        # 2. LIMIT pruning
        if self.enable_limit and q.is_plain_limit:
            for name, spec in q.scans.items():
                res = limit_prune(
                    scan_sets[name],
                    spec.table.stats,
                    q.effective_k,
                    supported_shape=self._limit_supported(q, name),
                )
                scan_sets[name] = res.scan
                per_scan[name]["limit"] = TechniqueReport(
                    res.partitions_before, res.partitions_after,
                    res.applied, detail=dict(category=res.category),
                )

        # 3. JOIN pruning (runtime: build side values are now available)
        build_keys: Optional[np.ndarray] = None
        if q.join is not None:
            bspec = q.scans[q.join.build]
            bctx = bspec.table.ctx_for(scan_sets[q.join.build].part_ids)
            bmask = matches(bspec.pred, bctx)
            keys, knulls = bctx.col(q.join.build_key)
            build_keys = keys[bmask & ~knulls]
            if self.enable_join:
                summary = summarize_build(build_keys, ndv_limit=self.join_ndv_limit)
                res = prune_probe(
                    scan_sets[q.join.probe], q.scans[q.join.probe].table.stats,
                    q.join.probe_key, summary,
                )
                scan_sets[q.join.probe] = res.scan
                per_scan[q.join.probe]["join"] = TechniqueReport(
                    res.partitions_before, res.partitions_after,
                    applied=True,
                    detail=dict(
                        by_range=res.pruned_by_range,
                        by_distinct=res.pruned_by_distinct,
                        by_bloom=res.pruned_by_bloom,
                        summary_bytes=summary.size_bytes,
                        summary_kind=(
                            "distinct" if summary.distinct is not None
                            else "bloom" if summary.bloom is not None else "empty"
                        ),
                    ),
                )

        # 4. top-k pruning (runtime boundary values)
        topk_res: Optional[TopKResult] = None
        target = self._topk_supported(q)
        if self.enable_topk and target is not None:
            scan_name, order_col, desc = q.order_by
            spec = q.scans[scan_name]
            extra = None
            if q.join is not None and scan_name == q.join.probe and q.join.kind == "inner":
                key_col = q.join.probe_key
                bk = np.unique(build_keys) if build_keys is not None else np.zeros(0)

                def extra(ctx, _bk=bk, _kc=key_col):
                    v, nm = ctx.col(_kc)
                    return np.isin(v, _bk) & ~nm

            topk_res = run_topk(
                spec.table, scan_sets[scan_name], order_col, q.effective_k,
                pred=spec.pred if not isinstance(spec.pred, E.TruePred) else None,
                desc=desc, strategy=self.topk_strategy,
                use_upfront_init=self.topk_upfront_init,
                extra_mask_fn=extra,
            )
            before = len(scan_sets[scan_name])
            per_scan[scan_name]["topk"] = TechniqueReport(
                before, before - len(topk_res.skipped), applied=True,
                detail=dict(rows_scanned=topk_res.rows_scanned),
            )

        report = PruningReport(per_scan, scan_sets, topk_res)
        report._scan_specs = dict(q.scans)
        return report
