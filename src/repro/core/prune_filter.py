"""Filter pruning: three-valued predicate evaluation over partition stats.

This is the paper's Sec. 3 engine, with the DESIGN.md §2 improvement that a
*single* metadata pass yields both classic pruning (NO_MATCH -> drop) and
Sec. 4.2 fully-matching detection (FULL_MATCH), instead of the paper's
second pass with inverted predicates.  ``tests/test_prune_filter.py``
proves the lattice result equals the paper's two-pass formulation.

Semantics per partition p and predicate q:
  NO_MATCH       no row of p can satisfy q          (safe to prune)
  PARTIAL_MATCH  some row may satisfy q             (must scan)
  FULL_MATCH     every row of p satisfies q         (Sec. 4 fully-matching)

SQL NULL handling: a NULL never satisfies a comparison, so FULL_MATCH is
demoted to PARTIAL wherever an involved column has nulls in the partition;
NO_MATCH decisions are unaffected (null rows fail the predicate too).
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from . import expr as E
from . import intervals as I
from .metadata import FULL_MATCH, NO_MATCH, PARTIAL_MATCH, ColumnMeta, PartitionStats
from .rewrite import Widened, rewrite_for_pruning

_SENTINEL_HI = "￿"


def _find_str_hint(node, stats: PartitionStats) -> Optional[ColumnMeta]:
    """Locate a string-typed column in an expression to give a dictionary
    context for encoding string literals."""
    for name in node.columns() if hasattr(node, "columns") else ():
        cm = stats.column(name)
        if cm.kind == "str":
            return cm
    return None


def encode_literal(value, hint: Optional[ColumnMeta]) -> float:
    """Encode a literal into the numeric metadata domain.

    Unseen string literals map to fractional positions between dictionary
    codes (order-preserving), so range comparisons against them stay exact.
    """
    if not isinstance(value, str):
        return float(value)
    if hint is None or hint.dictionary is None:
        raise TypeError(f"string literal {value!r} needs a str column context")
    d = hint.dictionary
    idx = int(np.searchsorted(d, value, side="left"))
    if idx < len(d) and d[idx] == value:
        return float(idx)
    return float(idx) - 0.5


def derive(expr, stats: PartitionStats, hint: Optional[ColumnMeta] = None) -> I.Interval:
    """Per-partition value interval of a scalar expression (Sec. 3.1)."""
    P = stats.num_partitions
    if isinstance(expr, E.Col):
        c = stats.col_id(expr.name)
        return I.Interval(stats.mins[:, c].copy(), stats.maxs[:, c].copy())
    if isinstance(expr, E.Lit):
        return I.Interval.point(encode_literal(expr.value, hint), P)
    if isinstance(expr, E.Arith):
        a = derive(expr.lhs, stats, hint)
        b = derive(expr.rhs, stats, hint)
        return {"+": I.add, "-": I.sub, "*": I.mul, "/": I.div}[expr.op](a, b)
    if isinstance(expr, E.If):
        tv = eval_tv(expr.cond, stats, _rewrite=False)
        then = derive(expr.then, stats, hint)
        other = derive(expr.other, stats, hint)
        return I.select(tv == FULL_MATCH, tv == NO_MATCH, then, other)
    raise TypeError(f"cannot derive interval for {expr!r}")


def _nullable_mask(node, stats: PartitionStats) -> np.ndarray:
    """True where any column involved in ``node`` has nulls in the partition."""
    m = np.zeros(stats.num_partitions, dtype=bool)
    for name in node.columns():
        m |= stats.col_has_nulls(name)
    return m


def _demote_full(tv: np.ndarray, nullable: np.ndarray) -> np.ndarray:
    return np.where(nullable & (tv == FULL_MATCH), PARTIAL_MATCH, tv).astype(np.int8)


def _cmp_tv(op: str, a: I.Interval, b: I.Interval) -> np.ndarray:
    """Three-valued comparison of two interval batches."""
    P = a.lo.shape[0]
    no = np.zeros(P, dtype=bool)
    full = np.zeros(P, dtype=bool)
    if op == ">":
        full = a.lo > b.hi
        no = a.hi <= b.lo
    elif op == ">=":
        full = a.lo >= b.hi
        no = a.hi < b.lo
    elif op == "<":
        full = a.hi < b.lo
        no = a.lo >= b.hi
    elif op == "<=":
        full = a.hi <= b.lo
        no = a.lo > b.hi
    elif op == "==":
        no = (a.hi < b.lo) | (a.lo > b.hi)
        full = (a.lo == a.hi) & (b.lo == b.hi) & (a.lo == b.lo)
    elif op == "!=":
        full = (a.hi < b.lo) | (a.lo > b.hi)
        no = (a.lo == a.hi) & (b.lo == b.hi) & (a.lo == b.lo)
    else:
        raise ValueError(f"unknown comparison {op!r}")
    # Empty interval (all-null column in partition): nothing matches.
    empty = a.empty | b.empty
    tv = np.where(full, FULL_MATCH, PARTIAL_MATCH).astype(np.int8)
    tv = np.where(no, NO_MATCH, tv).astype(np.int8)
    tv = np.where(empty, NO_MATCH, tv).astype(np.int8)
    return tv


def eval_tv(pred: E.Pred, stats: PartitionStats, _rewrite: bool = True) -> np.ndarray:
    """Evaluate predicate -> int8 ``[P]`` in {NO, PARTIAL, FULL}_MATCH."""
    if _rewrite:
        pred = rewrite_for_pruning(pred)
    P = stats.num_partitions

    if isinstance(pred, E.TruePred):
        return np.full(P, FULL_MATCH, dtype=np.int8)

    if isinstance(pred, Widened):
        tv = eval_tv(pred.child, stats, _rewrite=False)
        return np.minimum(tv, PARTIAL_MATCH).astype(np.int8)  # never FULL

    if isinstance(pred, E.Cmp):
        hint = _find_str_hint(pred, stats)
        a = derive(pred.lhs, stats, hint)
        b = derive(pred.rhs, stats, hint)
        return _demote_full(_cmp_tv(pred.op, a, b), _nullable_mask(pred, stats))

    if isinstance(pred, E.And):
        tv = np.full(P, FULL_MATCH, dtype=np.int8)
        for c in pred.children:
            tv = np.minimum(tv, eval_tv(c, stats, _rewrite=False))
        return tv

    if isinstance(pred, E.Or):
        tv = np.full(P, NO_MATCH, dtype=np.int8)
        for c in pred.children:
            tv = np.maximum(tv, eval_tv(c, stats, _rewrite=False))
        return tv

    if isinstance(pred, E.Not):
        tv = (FULL_MATCH - eval_tv(pred.child, stats, _rewrite=False)).astype(np.int8)
        return _demote_full(tv, _nullable_mask(pred, stats))

    if isinstance(pred, E.StartsWith):
        cm = stats.column(pred.col.name)
        rng = cm.prefix_code_range(pred.prefix)
        pmin, pmax = stats.col_min(pred.col.name), stats.col_max(pred.col.name)
        if rng is None:  # no dictionary value has this prefix
            return np.full(P, NO_MATCH, dtype=np.int8)
        lo, hi = rng
        no = (pmax < lo) | (pmin > hi)
        full = (pmin >= lo) & (pmax <= hi)
        tv = np.where(full, FULL_MATCH, PARTIAL_MATCH).astype(np.int8)
        tv = np.where(no | (pmin > pmax), NO_MATCH, tv).astype(np.int8)
        return _demote_full(tv, _nullable_mask(pred, stats))

    if isinstance(pred, E.InSet):
        cm = stats.column(pred.col.name)
        hint = cm if cm.kind == "str" else None
        vals = np.array(sorted(encode_literal(v, hint) for v in pred.values))
        pmin, pmax = stats.col_min(pred.col.name), stats.col_max(pred.col.name)
        # any set value inside [pmin, pmax]?
        pos_lo = np.searchsorted(vals, pmin, side="left")
        pos_hi = np.searchsorted(vals, pmax, side="right")
        any_in = pos_hi > pos_lo
        full = (pmin == pmax) & any_in
        tv = np.where(full, FULL_MATCH, PARTIAL_MATCH).astype(np.int8)
        tv = np.where(~any_in | (pmin > pmax), NO_MATCH, tv).astype(np.int8)
        return _demote_full(tv, _nullable_mask(pred, stats))

    if isinstance(pred, E.IsNull):
        nc = stats.null_counts[:, stats.col_id(pred.col.name)]
        rc = stats.row_counts
        all_null, none_null = nc == rc, nc == 0
        if pred.negated:
            all_null, none_null = none_null, all_null
        tv = np.full(P, PARTIAL_MATCH, dtype=np.int8)
        tv = np.where(all_null, FULL_MATCH, tv).astype(np.int8)
        tv = np.where(none_null, NO_MATCH, tv).astype(np.int8)
        return tv

    if isinstance(pred, E.Like):  # only reachable with _rewrite=False
        return eval_tv(rewrite_for_pruning(pred), stats, _rewrite=False)

    raise TypeError(f"cannot evaluate predicate {pred!r}")


# ---------------------------------------------------------------------------
# Conjunctive-range fast path (feeds the Pallas minmax_prune kernel)
# ---------------------------------------------------------------------------

def extract_ranges(
    pred: E.Pred, stats: PartitionStats
) -> Optional[List[Tuple[int, float, float]]]:
    """Try to lower a predicate to a conjunction of closed column ranges
    ``[(col_id, lo, hi), ...]`` — the hot path in production pruning, which
    the TPU kernel evaluates branch-free.  Returns None when the predicate
    does not have that shape (the general evaluator handles it instead).
    """
    pred = rewrite_for_pruning(pred)
    out: List[Tuple[int, float, float]] = []
    if not _extract(pred, stats, out):
        return None
    return out


def _extract(pred, stats: PartitionStats, out: list) -> bool:
    if isinstance(pred, E.TruePred):
        return True
    if isinstance(pred, E.And):
        return all(_extract(c, stats, out) for c in pred.children)
    if isinstance(pred, E.StartsWith):
        cm = stats.column(pred.col.name)
        rng = cm.prefix_code_range(pred.prefix)
        if rng is None:
            out.append((stats.col_id(pred.col.name), np.inf, -np.inf))
        else:
            out.append((stats.col_id(pred.col.name), rng[0], rng[1]))
        return True
    if isinstance(pred, E.Cmp):
        # col <op> literal  or  literal <op> col
        lhs, rhs, op = pred.lhs, pred.rhs, pred.op
        if isinstance(rhs, E.Col) and isinstance(lhs, E.Lit):
            lhs, rhs = rhs, lhs
            op = {">": "<", ">=": "<=", "<": ">", "<=": ">=", "==": "==", "!=": "!="}[op]
        if not (isinstance(lhs, E.Col) and isinstance(rhs, E.Lit)):
            return False
        cm = stats.column(lhs.name)
        hint = cm if cm.kind == "str" else None
        try:
            v = encode_literal(rhs.value, hint)
        except TypeError:
            return False
        cid = stats.col_id(lhs.name)
        if op == ">":
            out.append((cid, np.nextafter(v, np.inf), np.inf))
        elif op == ">=":
            out.append((cid, v, np.inf))
        elif op == "<":
            out.append((cid, -np.inf, np.nextafter(v, -np.inf)))
        elif op == "<=":
            out.append((cid, -np.inf, v))
        elif op == "==":
            out.append((cid, v, v))
        else:  # '!=' is not a single range
            return False
        return True
    return False


def eval_ranges_tv(
    ranges: List[Tuple[int, float, float]], stats: PartitionStats
) -> np.ndarray:
    """NumPy oracle for the conjunctive-range fast path (kernel ref)."""
    P = stats.num_partitions
    tv = np.full(P, FULL_MATCH, dtype=np.int8)
    for cid, lo, hi in ranges:
        pmin, pmax = stats.mins[:, cid], stats.maxs[:, cid]
        nullable = stats.null_counts[:, cid] > 0
        no = (pmax < lo) | (pmin > hi) | (pmin > pmax)
        full = (pmin >= lo) & (pmax <= hi) & ~nullable & ~(pmin > pmax)
        k = np.where(full, FULL_MATCH, PARTIAL_MATCH).astype(np.int8)
        k = np.where(no, NO_MATCH, k).astype(np.int8)
        tv = np.minimum(tv, k)
    return tv


def fully_matching_two_pass(pred: E.Pred, stats: PartitionStats) -> np.ndarray:
    """The paper's Sec. 4.2 formulation: a second pruning pass with the
    *inverted* predicate; partitions pruned by it are fully matching.
    Kept as the reference/oracle for the one-pass lattice (DESIGN.md §6.1).

    NULL guard: logically inverting a predicate only complements it over
    non-null rows (a NULL satisfies neither ``p`` nor ``NOT p``), so a
    partition with nulls in an involved column can never be declared fully
    matching.  The paper does not spell this out; the one-pass lattice
    handles it via FULL-demotion at comparison nodes.
    """
    rewritten = rewrite_for_pruning(pred)
    inv = E.invert(rewritten)
    tv_inv = eval_tv(inv, stats, _rewrite=False)
    return (tv_inv == NO_MATCH) & ~_nullable_mask(rewritten, stats)
