"""Row-level predicate evaluation (the ground-truth oracle).

Used by the scan executor (after pruning, surviving partitions are filtered
row-wise) and by the tests that prove the no-false-negative invariant:
``eval_tv == NO_MATCH`` must imply "no row matches", and ``FULL_MATCH``
must imply "every row matches".

SQL three-valued (Kleene) row semantics: comparisons with NULL are
UNKNOWN; WHERE keeps rows whose predicate is exactly TRUE.
"""

from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

import numpy as np

from . import expr as E
from .metadata import ColumnMeta
from .rewrite import Widened

K_FALSE, K_UNKNOWN, K_TRUE = 0, 1, 2


def _like_regex(pattern: str) -> "re.Pattern":
    return re.compile("^" + ".*".join(re.escape(p) for p in pattern.split("%")) + "$")


class RowContext:
    """Column data for one partition (or a whole table) in encoded form."""

    def __init__(
        self,
        columns: Dict[str, ColumnMeta],
        data: Dict[str, np.ndarray],
        nulls: Optional[Dict[str, np.ndarray]] = None,
    ):
        self.columns = columns
        self.data = data
        self.nulls = nulls or {}
        self.n = len(next(iter(data.values()))) if data else 0

    def col(self, name: str) -> Tuple[np.ndarray, np.ndarray]:
        v = self.data[name]
        nm = self.nulls.get(name)
        if nm is None:
            nm = np.zeros(self.n, dtype=bool)
        return v, nm

    def _hint_for(self, node) -> Optional[ColumnMeta]:
        for name in node.columns():
            cm = self.columns.get(name)
            if cm is not None and cm.kind == "str":
                return cm
        return None


def eval_expr(node, ctx: RowContext, hint=None) -> Tuple[np.ndarray, np.ndarray]:
    """Scalar expression -> (values, null_mask), both ``[n]``."""
    from .prune_filter import encode_literal

    if isinstance(node, E.Col):
        return ctx.col(node.name)
    if isinstance(node, E.Lit):
        v = encode_literal(node.value, hint)
        return np.full(ctx.n, v), np.zeros(ctx.n, dtype=bool)
    if isinstance(node, E.Arith):
        a, an = eval_expr(node.lhs, ctx, hint)
        b, bn = eval_expr(node.rhs, ctx, hint)
        with np.errstate(divide="ignore", invalid="ignore"):
            v = {"+": np.add, "-": np.subtract, "*": np.multiply,
                 "/": np.divide}[node.op](a, b)
        return v, an | bn
    if isinstance(node, E.If):
        k = eval_pred(node.cond, ctx)
        a, an = eval_expr(node.then, ctx, hint)
        b, bn = eval_expr(node.other, ctx, hint)
        take_then = k == K_TRUE  # UNKNOWN falls through to ELSE (SQL CASE)
        return np.where(take_then, a, b), np.where(take_then, an, bn)
    raise TypeError(f"cannot row-evaluate {node!r}")


def eval_pred(pred, ctx: RowContext) -> np.ndarray:
    """Predicate -> Kleene ``[n]`` in {K_FALSE, K_UNKNOWN, K_TRUE}."""
    from .prune_filter import encode_literal

    if isinstance(pred, E.TruePred):
        return np.full(ctx.n, K_TRUE, dtype=np.int8)
    if isinstance(pred, Widened):
        # Row-level evaluation must use the ORIGINAL semantics; a widened
        # node only exists in pruning trees.  Evaluate the widened child —
        # callers comparing against pruning decisions want the superset.
        return eval_pred(pred.child, ctx)
    if isinstance(pred, E.Cmp):
        hint = ctx._hint_for(pred)
        a, an = eval_expr(pred.lhs, ctx, hint)
        b, bn = eval_expr(pred.rhs, ctx, hint)
        op = {
            ">": np.greater, ">=": np.greater_equal,
            "<": np.less, "<=": np.less_equal,
            "==": np.equal, "!=": np.not_equal,
        }[pred.op]
        k = np.where(op(a, b), K_TRUE, K_FALSE).astype(np.int8)
        return np.where(an | bn, K_UNKNOWN, k).astype(np.int8)
    if isinstance(pred, E.And):
        k = np.full(ctx.n, K_TRUE, dtype=np.int8)
        for c in pred.children:
            k = np.minimum(k, eval_pred(c, ctx))
        return k
    if isinstance(pred, E.Or):
        k = np.full(ctx.n, K_FALSE, dtype=np.int8)
        for c in pred.children:
            k = np.maximum(k, eval_pred(c, ctx))
        return k
    if isinstance(pred, E.Not):
        return (K_TRUE - eval_pred(pred.child, ctx)).astype(np.int8)
    if isinstance(pred, E.StartsWith):
        cm = ctx.columns[pred.col.name]
        v, nm = ctx.col(pred.col.name)
        rng = cm.prefix_code_range(pred.prefix)
        if rng is None:
            k = np.full(ctx.n, K_FALSE, dtype=np.int8)
        else:
            k = np.where((v >= rng[0]) & (v <= rng[1]), K_TRUE, K_FALSE).astype(np.int8)
        return np.where(nm, K_UNKNOWN, k).astype(np.int8)
    if isinstance(pred, E.Like):
        cm = ctx.columns[pred.col.name]
        v, nm = ctx.col(pred.col.name)
        rx = _like_regex(pred.pattern)
        strings = cm.dictionary[v.astype(np.int64)]
        hit = np.fromiter((bool(rx.match(s)) for s in strings), dtype=bool, count=ctx.n)
        k = np.where(hit, K_TRUE, K_FALSE).astype(np.int8)
        return np.where(nm, K_UNKNOWN, k).astype(np.int8)
    if isinstance(pred, E.InSet):
        cm = ctx.columns[pred.col.name]
        hint = cm if cm.kind == "str" else None
        vals = np.array(sorted(encode_literal(x, hint) for x in pred.values))
        v, nm = ctx.col(pred.col.name)
        hit = np.isin(v, vals)
        k = np.where(hit, K_TRUE, K_FALSE).astype(np.int8)
        return np.where(nm, K_UNKNOWN, k).astype(np.int8)
    if isinstance(pred, E.IsNull):
        _, nm = ctx.col(pred.col.name)
        hit = ~nm if pred.negated else nm
        return np.where(hit, K_TRUE, K_FALSE).astype(np.int8)
    raise TypeError(f"cannot row-evaluate predicate {pred!r}")


def matches(pred, ctx: RowContext) -> np.ndarray:
    """Boolean row mask: rows the query's WHERE clause keeps."""
    return eval_pred(pred, ctx) == K_TRUE
