"""Imprecise filter rewrites (paper Sec. 3.1).

Predicates that cannot be evaluated against min/max metadata directly are
*widened* into prunable forms.  Widening is only superset-preserving, so a
widened node may never report FULL_MATCH (that would poison the Sec. 4.2
fully-matching detection); ``Widened`` marks this and the evaluator clamps
FULL -> PARTIAL underneath it.

``LIKE 'Alpine%'`` (single trailing ``%``) is *exactly* a prefix test, so it
rewrites to a non-widened ``StartsWith`` — this is what lets Figure 5's
partition 3 be identified as fully matching.
"""

from __future__ import annotations

import dataclasses

from . import expr as E


@dataclasses.dataclass(frozen=True, eq=False)
class Widened(E.Pred):
    """Marks a pruning predicate that over-approximates the original."""

    child: E.Pred

    def columns(self):
        return self.child.columns()

    def __repr__(self):
        return f"widened({self.child!r})"


def rewrite_like(node: E.Like) -> E.Pred:
    """Rewrite LIKE into a prunable (possibly widened) predicate."""
    pattern = node.pattern
    if "%" not in pattern:
        return E.Cmp("==", node.col, E.Lit(pattern))
    first = pattern.index("%")
    prefix = pattern[:first]
    exact = pattern.endswith("%") and "%" not in pattern[:-1]
    if exact:
        # 'abc%'  <=>  STARTSWITH('abc') — equivalence-preserving.
        return E.StartsWith(node.col, prefix)
    if prefix:
        # 'abc%def' -> widen to STARTSWITH('abc'): drops the suffix
        # constraint, exactly the paper's 'Marked-%-Ridge' example.
        return Widened(E.StartsWith(node.col, prefix))
    # '%abc' — no usable prefix; unprunable.
    return Widened(E.TruePred())


def rewrite_for_pruning(pred: E.Pred) -> E.Pred:
    """Recursively rewrite a predicate tree into its pruning form."""
    if isinstance(pred, E.Like):
        return rewrite_like(pred)
    if isinstance(pred, E.And):
        return E.And(tuple(rewrite_for_pruning(c) for c in pred.children))
    if isinstance(pred, E.Or):
        return E.Or(tuple(rewrite_for_pruning(c) for c in pred.children))
    if isinstance(pred, E.Not):
        return E.Not(rewrite_for_pruning(pred.child))
    if isinstance(pred, Widened):
        return Widened(rewrite_for_pruning(pred.child))
    return pred
