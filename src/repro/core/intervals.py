"""Vectorized interval arithmetic over per-partition metadata.

Implements the paper's Sec. 3.1 "Deriving Min/Max Ranges": every scalar
expression is mapped to a per-partition value interval ``[lo, hi]`` derived
from the partition's column min/max stats.  All operations are conservative
(the derived interval always contains every value the expression can take
on rows of that partition) — the property the no-false-negative guarantee
rests on, and the one our hypothesis tests check.

Intervals are *empty* (lo > hi, encoded +inf/-inf) when the partition has
no non-null value for an involved column; comparisons on empty intervals
evaluate to NO_MATCH (a NULL never satisfies a comparison).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Interval:
    """A batch of per-partition intervals: lo/hi are ``[P]`` float64."""

    lo: np.ndarray
    hi: np.ndarray

    @property
    def empty(self) -> np.ndarray:
        return self.lo > self.hi

    @staticmethod
    def point(value: float, P: int) -> "Interval":
        v = np.full(P, float(value))
        return Interval(v.copy(), v.copy())

    @staticmethod
    def empty_like(P: int) -> "Interval":
        return Interval(np.full(P, np.inf), np.full(P, -np.inf))


def _mask_empty(result: Interval, *inputs: Interval) -> Interval:
    """Any arithmetic involving an empty interval is empty."""
    empty = np.zeros_like(result.lo, dtype=bool)
    for i in inputs:
        empty |= i.empty
    result.lo = np.where(empty, np.inf, result.lo)
    result.hi = np.where(empty, -np.inf, result.hi)
    return result


def add(a: Interval, b: Interval) -> Interval:
    return _mask_empty(Interval(a.lo + b.lo, a.hi + b.hi), a, b)


def sub(a: Interval, b: Interval) -> Interval:
    return _mask_empty(Interval(a.lo - b.hi, a.hi - b.lo), a, b)


def mul(a: Interval, b: Interval) -> Interval:
    # Evaluate the four corner products; NaNs (inf * 0 from empty inputs)
    # are masked out afterwards by _mask_empty.
    with np.errstate(invalid="ignore"):
        p1, p2 = a.lo * b.lo, a.lo * b.hi
        p3, p4 = a.hi * b.lo, a.hi * b.hi
        stack = np.stack([p1, p2, p3, p4])
        stack = np.nan_to_num(stack, nan=0.0)
        return _mask_empty(Interval(stack.min(axis=0), stack.max(axis=0)), a, b)


def div(a: Interval, b: Interval) -> Interval:
    """Conservative division: any divisor interval containing 0 widens the
    result to (-inf, +inf) — cannot prune, never incorrect."""
    contains_zero = (b.lo <= 0.0) & (b.hi >= 0.0)
    safe_b = Interval(
        np.where(contains_zero, 1.0, b.lo), np.where(contains_zero, 1.0, b.hi)
    )
    with np.errstate(invalid="ignore", divide="ignore"):
        q = np.stack(
            [a.lo / safe_b.lo, a.lo / safe_b.hi, a.hi / safe_b.lo, a.hi / safe_b.hi]
        )
        q = np.nan_to_num(q, nan=0.0)
    lo, hi = q.min(axis=0), q.max(axis=0)
    lo = np.where(contains_zero, -np.inf, lo)
    hi = np.where(contains_zero, np.inf, hi)
    return _mask_empty(Interval(lo, hi), a, b)


def hull(a: Interval, b: Interval) -> Interval:
    """Union hull — the paper's conservative IF(...) treatment.  An empty
    branch contributes nothing (min/max against +inf/-inf is identity)."""
    return Interval(np.minimum(a.lo, b.lo), np.maximum(a.hi, b.hi))


def select(cond_full: np.ndarray, cond_no: np.ndarray,
           then: Interval, other: Interval) -> Interval:
    """Interval of IF(c, then, other) given three-valued condition masks.

    Where the condition is conclusively FULL/NO the respective branch's
    interval is used exactly (the paper's "ranges can be adjusted
    accordingly"); elsewhere the hull.
    """
    h = hull(then, other)
    lo = np.where(cond_full, then.lo, np.where(cond_no, other.lo, h.lo))
    hi = np.where(cond_full, then.hi, np.where(cond_no, other.hi, h.hi))
    return Interval(lo, hi)
