"""Device-resident metadata plane: stage partition stats once, prune forever.

The per-query device path used to re-gather and re-upload a fresh ``[K, P]``
stat slice for every query (a host transpose + H2D copy per launch).  At
fleet scale the pruning *decision* must be as cheap as the paper's headline
makes it look, so the metadata becomes a persistent, index-like device
structure instead of per-query scaffolding (cf. Extensible Data Skipping's
metadata indexes):

  * ``DeviceStatsCache.get`` stages a table's full ``[C, P]`` mins / maxs /
    demote planes to device **once per table version** (keyed like
    ``predicate_cache.TableVersion``) — after that, per-query staging is an
    on-device row gather of the resident arrays, no host work.
  * DML invalidates: ``insert_partitions`` / any version bump produces a
    different key, and the stale entry for the same table is dropped.
  * Eviction is always safe (a miss simply re-stages).
  * Runtime techniques ride the same cache: per-column **join-key planes**
    (``join_key_plane``) and **block-top-k planes** (``block_topk_plane``)
    are staged once per table identity and column, with column-granular
    ``notify_update`` invalidation — see the ``DeviceStatsCache`` class
    docstring.

Precision contract (the single place stats are downcast to f32)
---------------------------------------------------------------
Host metadata is float64; kernels evaluate in float32 for VPU throughput.
Values outside f32's 24-bit mantissa (e.g. int64 keys > 2**24) cannot be
represented exactly, so the cast is *widening* and *demoting*:

  * partition mins are rounded toward -inf, maxs toward +inf, and query
    lows/highs likewise (lo down, hi up).  Every interval only grows, so
    the kernel can never declare a false NO_MATCH — a pruned partition is
    always truly empty of matches (the correctness-critical direction);
  * wherever a min/max cast was inexact the partition's ``demote`` plane is
    set (same mechanism as nullability), suppressing FULL_MATCH for that
    partition.  Constraints whose lo/hi cast inexactly report
    ``bounds_exact=False`` and the wrapper demotes FULL host-side.

Net effect: int64 keys > 2**24 can only *false-negative* FULL (degrade to
PARTIAL, costing a scan) and can never *false-positive* NO_MATCH or FULL.
``tests/test_device_plane.py`` holds the regression test.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .metadata import PartitionStats
from .predicate_cache import TableVersion

_F32_NEG = np.float32(-np.inf)
_F32_POS = np.float32(np.inf)
_F32_MAX = np.float32(np.finfo(np.float32).max)


def round_down_f32(x: np.ndarray) -> np.ndarray:
    """f64 -> f32 rounding toward -inf (result <= x always)."""
    x = np.asarray(x, dtype=np.float64)
    f = x.astype(np.float32)
    with np.errstate(over="ignore", invalid="ignore"):
        return np.where(f.astype(np.float64) > x, np.nextafter(f, _F32_NEG), f)


def round_up_f32(x: np.ndarray) -> np.ndarray:
    """f64 -> f32 rounding toward +inf (result >= x always)."""
    x = np.asarray(x, dtype=np.float64)
    f = x.astype(np.float32)
    with np.errstate(over="ignore", invalid="ignore"):
        return np.where(f.astype(np.float64) < x, np.nextafter(f, _F32_POS), f)


def cast_stats_f32(
    mins: np.ndarray, maxs: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Widening downcast of stat planes; returns (mins32, maxs32, inexact).

    ``inexact`` is True wherever either bound moved — those partitions must
    never be declared FULL (fed into the demote plane alongside nulls).

    The planes are additionally clamped to the finite f32 extremes: the
    batched kernel gathers stat rows via a one-hot matmul, and a 0-weight
    x inf product would poison the row with NaN.  Clamping ±inf narrows
    the interval, so clamped entries are marked inexact (FULL-demoted);
    NO_MATCH stays safe because ``cast_bounds_f32`` clamps query bounds
    with the same monotone map, keeping every comparison's two sides
    consistent.  All-null partitions' empty intervals survive as
    (+f32max, -f32max) — still empty.
    """
    mins32 = round_down_f32(mins).astype(np.float32)
    maxs32 = round_up_f32(maxs).astype(np.float32)
    inexact = (mins32.astype(np.float64) != mins) | (
        maxs32.astype(np.float64) != maxs)
    mins_c = np.clip(mins32, -_F32_MAX, _F32_MAX)
    maxs_c = np.clip(maxs32, -_F32_MAX, _F32_MAX)
    inexact |= (mins_c != mins32) | (maxs_c != maxs32)
    return mins_c, maxs_c, inexact


def cast_bounds_f32(
    los: np.ndarray, his: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Widening downcast of query range bounds (lo down, hi up).

    Returns (lo32, hi32, exact) where ``exact`` is per-constraint; a False
    entry means FULL must be demoted to PARTIAL for the whole query (the
    widened range may admit rows the true range excludes).

    Bounds are clamped to the finite f32 extremes to match the stat
    planes (see cast_stats_f32).  One-sided infinite bounds lose nothing:
    every clamped stat satisfies ``>= -f32max`` exactly as it satisfied
    ``>= -inf``.  Degenerate lo=+inf / hi=-inf bounds can no longer
    *prove* FULL in the clamped domain, so they are flagged not exact.
    """
    los = np.asarray(los, dtype=np.float64)
    his = np.asarray(his, dtype=np.float64)
    lo32 = round_down_f32(los).astype(np.float32)
    hi32 = round_up_f32(his).astype(np.float32)
    exact = (lo32.astype(np.float64) == los) & (hi32.astype(np.float64) == his)
    exact &= ~np.isposinf(los) & ~np.isneginf(his)
    lo32 = np.clip(lo32, -_F32_MAX, _F32_MAX).astype(np.float32)
    hi32 = np.clip(hi32, -_F32_MAX, _F32_MAX).astype(np.float32)
    return lo32, hi32, exact


def snap_bounds_integral(
    los: np.ndarray, his: np.ndarray, integral: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Tighten range bounds on integral-domain columns: lo -> ceil, hi -> floor.

    Int columns and dictionary codes only take integer (or, for unseen
    string literals, never-attained half-integer) values, so ``x > 5``
    lowered to ``lo = nextafter(5)`` is exactly ``lo = 6`` — an integer
    that (below 2**24) casts to f32 exactly, keeping the device path
    identical to the f64 host oracle on the paper's workloads instead of
    conservatively demoting FULL.  No-op on float columns and on the
    infinite padding sentinels.
    """
    los = np.asarray(los, dtype=np.float64)
    his = np.asarray(his, dtype=np.float64)
    integral = np.asarray(integral, dtype=bool)
    los = np.where(integral & np.isfinite(los), np.ceil(los), los)
    his = np.where(integral & np.isfinite(his), np.floor(his), his)
    return los, his


@dataclasses.dataclass
class DeviceStats:
    """A table's resident metadata plane: [C, P] device arrays, f32."""

    table_name: str
    version: int
    mins: jnp.ndarray      # [C, P] widened (rounded toward -inf)
    maxs: jnp.ndarray      # [C, P] widened (rounded toward +inf)
    demote: jnp.ndarray    # [C, P] 1.0 where nulls or inexact cast: no FULL
    integral: np.ndarray   # [C] bool, host-side: int/dictionary-code column

    @property
    def num_columns(self) -> int:
        return self.mins.shape[0]

    @property
    def num_partitions(self) -> int:
        return self.mins.shape[1]

    @property
    def nbytes(self) -> int:
        return int(self.mins.nbytes + self.maxs.nbytes + self.demote.nbytes)

    def gather(self, cids: np.ndarray):
        """On-device row gather -> per-constraint [K, P] planes.

        This replaces the old host transpose + H2D copy per query; the
        resident [C, P] arrays never leave the device.
        """
        cids = jnp.asarray(np.asarray(cids, dtype=np.int32))
        return (jnp.take(self.mins, cids, axis=0),
                jnp.take(self.maxs, cids, axis=0),
                jnp.take(self.demote, cids, axis=0))

    @staticmethod
    def stage(stats: PartitionStats, table_name: str = "",
              version: int = 0) -> "DeviceStats":
        """Host [P, C] f64 stats -> device [C, P] f32 planes (one H2D copy)."""
        mins32, maxs32, inexact = cast_stats_f32(stats.mins.T, stats.maxs.T)
        demote = ((stats.null_counts.T > 0) | inexact).astype(np.float32)
        integral = np.array([c.kind != "float" for c in stats.columns],
                            dtype=bool)
        return DeviceStats(
            table_name=table_name,
            version=version,
            mins=jnp.asarray(mins32),
            maxs=jnp.asarray(maxs32),
            demote=jnp.asarray(demote),
            integral=integral,
        )


KPLANE = 64   # block-top-k plane width: values kept per partition


class DeviceStatsCache:
    """Once-per-table-version staging of metadata planes, LRU-bounded.

    Keys are ``(table_name, version, stats.uid)``: the version is the DML
    identity ``predicate_cache.TableVersion`` tracks (insert_partitions,
    delete, order-column update bump it and naturally miss), and the
    stats uid distinguishes a *rebuilt* table — same name, same shape,
    new data — from the object that was staged, so a stale plane can
    never serve it.  Superseded same-table (same-uid) entries are dropped
    eagerly; entries of dead rebuilt tables age out via the LRU bound.

    Runtime-technique planes (PR 2)
    -------------------------------
    Alongside the [C, P] min/max/demote planes the cache stages two
    *per-column* plane families for the runtime techniques:

      * **join-key planes** (``join_key_plane``): the key column's widened
        f32 [P] min/max rows, consumed by ``join_overlap_batched``;
      * **enumeration planes** (``enum_plane``): the key column's
        integer-snapped [P] int32 pmin/width rows (width 0 = never
        enumerate), consumed by ``bloom_probe_batched`` for the Bloom
        half of JOIN pruning;
      * **block-top-k planes** (``block_topk_plane``): [P, KPLANE] rows of
        the column's per-partition top-K *signed* values (sign = +1 DESC /
        -1 ASC, nulls excluded, f64 -> f32 rounded toward -inf so every
        stored value is <= the true row value — a boundary derived from
        them is always witnessed), consumed by ``topk_init_batched``.

    Both follow the same TableVersion invalidation discipline through the
    DML hooks, with one refinement: ``on_update(table, column)`` drops the
    [C, P] planes (they carry every column) but only the *matching
    column's* join-key / block-top-k planes — an update to column X cannot
    change column Y's values, so Y's planes stay resident.
    """

    def __init__(self, max_entries: int = 16, max_planes: int = 64):
        self.entries: "OrderedDict[Tuple, DeviceStats]" = OrderedDict()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        # (name, uid, col) -> (pmin [P], pmax [P]) widened f32 device rows
        self.key_planes: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        # (name, uid, col) -> (pmin [P] i32, width [P] i32, wmax int)
        self.enum_planes: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        # (name, uid, col, desc, k) -> [P, k] signed block-top-k device rows
        self.topk_planes: "OrderedDict[Tuple, jnp.ndarray]" = OrderedDict()
        self.max_planes = max_planes
        self.plane_hits = 0
        self.plane_misses = 0

    @staticmethod
    def _key(table, tv: Optional[TableVersion]) -> Tuple:
        # stats.uid guards against a rebuilt table (same name, same shape,
        # new data) silently hitting the stale staged plane — stale stats
        # would break NO_MATCH safety, the one direction that loses rows.
        version = tv.version if tv is not None else 0
        return (table.name, version, table.stats.uid)

    def get(self, table, tv: Optional[TableVersion] = None) -> DeviceStats:
        """The table's resident DeviceStats, staging on first touch."""
        key = self._key(table, tv)
        e = self.entries.get(key)
        if e is not None:
            self.hits += 1
            self.entries.move_to_end(key)
            return e
        self.misses += 1
        # A version bump supersedes older stagings of the same table
        # object (same uid).  Same-name entries with a different uid are
        # other live tables sharing the name — left alone (LRU bounds
        # them), so alternating tables don't thrash each other.
        stale = [k for k in self.entries
                 if k[0] == table.name and k[2] == table.stats.uid]
        for k in stale:
            del self.entries[k]
        e = DeviceStats.stage(table.stats, table.name, key[1])
        self.entries[key] = e
        while len(self.entries) > self.max_entries:
            self.entries.popitem(last=False)
        return e

    # ---- runtime-technique planes --------------------------------------

    def _plane_get(self, store: "OrderedDict", key: Tuple):
        e = store.get(key)
        if e is not None:
            self.plane_hits += 1
            store.move_to_end(key)
        return e

    def _plane_put(self, store: "OrderedDict", key: Tuple, entry):
        self.plane_misses += 1
        store[key] = entry
        while len(store) > self.max_planes:
            store.popitem(last=False)
        return entry

    def join_key_plane(self, table, key_col: str) -> Tuple:
        """The key column's resident (pmin, pmax) [P] f32 rows (widened).

        Staged once per (table identity, column); consumed by the batched
        join-overlap kernel.  Clamped to finite f32 like the [C, P]
        planes, so +inf distinct-key padding can never produce a hit.
        """
        key = (table.name, table.stats.uid, key_col)
        e = self._plane_get(self.key_planes, key)
        if e is not None:
            return e
        pmin = np.clip(round_down_f32(table.stats.col_min(key_col)),
                       -_F32_MAX, _F32_MAX).astype(np.float32)
        pmax = np.clip(round_up_f32(table.stats.col_max(key_col)),
                       -_F32_MAX, _F32_MAX).astype(np.float32)
        return self._plane_put(self.key_planes, key,
                               (jnp.asarray(pmin), jnp.asarray(pmax)))

    def enum_plane(self, table, key_col: str) -> Tuple:
        """The key column's resident enumeration rows:
        (pmin, width, wmax, domain_ok).

        pmin/width are [P] int32 device rows feeding the Bloom probe
        kernel's narrow-range enumeration: integer-snapped partition
        minima (``ceil(col_min)``) and candidate counts
        (``floor(col_max) - ceil(col_min) + 1``, compared in float64
        before any integer cast so extreme ranges can't overflow).
        width 0 marks partitions that must never be enumerated — empty
        interval, non-finite bounds, or outside int32 (the kernel hashes
        int32 candidates) — and means *keep*: skipping enumeration can
        only miss prunable partitions, never prune joinable ones.  wmax
        (host int) is the plane's max width, used to bucket the kernel's
        enumeration lane dim without a device round-trip.  domain_ok
        (host bool) records whether every non-empty partition's bounds
        sit inside int32 — the device-vs-host parity gate
        (``PruningService.join_device_eligible``), computed once here so
        eligibility never rescans [P] stats per query.

        Same (table identity, column) keying and column-granular
        ``notify_update`` invalidation as ``join_key_plane``.
        """
        key = (table.name, table.stats.uid, key_col)
        e = self._plane_get(self.enum_planes, key)
        if e is not None:
            return e
        lo = np.ceil(np.asarray(table.stats.col_min(key_col), np.float64))
        hi = np.floor(np.asarray(table.stats.col_max(key_col), np.float64))
        with np.errstate(invalid="ignore", over="ignore"):
            wf = hi - lo + 1.0
            in32 = (lo >= -2.0 ** 31) & (hi < 2.0 ** 31)
            live = np.isfinite(lo) & np.isfinite(hi) & (lo <= hi)
            ok = live & in32 & (wf > 0) & (wf < 2.0 ** 31)
        domain_ok = not bool(np.any(live & ~in32))
        pmin = np.where(ok, lo, 0.0).astype(np.int32)
        width = np.where(ok, wf, 0.0).astype(np.int32)
        wmax = int(width.max()) if width.size else 0
        return self._plane_put(self.enum_planes, key,
                               (jnp.asarray(pmin), jnp.asarray(width), wmax,
                                domain_ok))

    def block_topk_plane(self, table, order_col: str, desc: bool,
                         k_plane: int = KPLANE) -> jnp.ndarray:
        """The column's resident [P, k_plane] signed block-top-k rows.

        Row p holds partition p's k_plane largest ``sign * value`` entries
        (desc per row, -inf padded, nulls excluded).  Values are rounded
        toward -inf in the signed domain, so every stored entry is <= the
        true value of an actual non-null row — any boundary taken from
        these rows is a *witnessed* Sec. 5.4 boundary.
        """
        key = (table.name, table.stats.uid, order_col, bool(desc),
               int(k_plane))
        e = self._plane_get(self.topk_planes, key)
        if e is not None:
            return e
        from ..kernels.ops import build_block_topk  # lazy: ops imports us
        sign = 1.0 if desc else -1.0
        sv = round_down_f32(sign * np.asarray(table.data[order_col],
                                              dtype=np.float64))
        nm = table.nulls.get(order_col)
        mask = None if nm is None else ~np.asarray(nm, dtype=bool)
        rows = build_block_topk(sv.astype(np.float32), table.part_bounds,
                                int(k_plane), mask=mask)
        return self._plane_put(self.topk_planes, key, jnp.asarray(rows))

    def invalidate(self, table_name: str, column: Optional[str] = None
                   ) -> None:
        """Drop staged planes for a table.

        ``column=None`` drops everything (insert/delete semantics); a
        column drops the [C, P] planes (they carry every column's stats)
        plus only that column's join-key / enumeration / block-top-k
        planes.
        """
        stale = [k for k in self.entries if k[0] == table_name]
        for k in stale:
            del self.entries[k]
        for store in (self.key_planes, self.enum_planes, self.topk_planes):
            stale = [k for k in store
                     if k[0] == table_name
                     and (column is None or k[2] == column)]
            for k in stale:
                del store[k]

    # ---- DML hooks (mirror predicate_cache's safety analysis; staging a
    # stale stats plane is never *unsafe* for NO_MATCH only if stats were
    # still valid, which DML breaks — so every mutation invalidates) ------

    def on_insert(self, table_name: str) -> None:
        self.invalidate(table_name)

    def on_delete(self, table_name: str) -> None:
        self.invalidate(table_name)

    def on_update(self, table_name: str, column: str) -> None:
        # Updates are column-scoped: the [C, P] stat planes must re-stage
        # (they include the updated column), while the other columns'
        # join-key / enumeration / block-top-k planes remain valid and
        # stay resident.
        self.invalidate(table_name, column=column)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def resident_bytes(self) -> int:
        total = sum(e.nbytes for e in self.entries.values())
        total += sum(int(a.nbytes) + int(b.nbytes)
                     for a, b in self.key_planes.values())
        total += sum(int(a.nbytes) + int(b.nbytes)
                     for a, b, _w in self.enum_planes.values())
        total += sum(int(r.nbytes) for r in self.topk_planes.values())
        return total
