"""Device-resident metadata plane: stage partition stats once, prune forever.

The per-query device path used to re-gather and re-upload a fresh ``[K, P]``
stat slice for every query (a host transpose + H2D copy per launch).  At
fleet scale the pruning *decision* must be as cheap as the paper's headline
makes it look, so the metadata becomes a persistent, index-like device
structure instead of per-query scaffolding (cf. Extensible Data Skipping's
metadata indexes):

  * ``DeviceStatsCache.get`` stages a table's full ``[C, P]`` mins / maxs /
    demote planes to device **once per table version** (keyed like
    ``predicate_cache.TableVersion``) — after that, per-query staging is an
    on-device row gather of the resident arrays, no host work.
  * DML invalidates: ``insert_partitions`` / any version bump produces a
    different key, and the stale entry for the same table is dropped.
  * Eviction is always safe (a miss simply re-stages).
  * Runtime techniques ride the same cache: per-column **join-key planes**
    (``join_key_plane``) and **block-top-k planes** (``block_topk_plane``)
    are staged once per table identity and column, with column-granular
    ``notify_update`` invalidation — see the ``DeviceStatsCache`` class
    docstring.

Precision contract (the single place stats are downcast to f32)
---------------------------------------------------------------
Host metadata is float64; kernels evaluate in float32 for VPU throughput.
Values outside f32's 24-bit mantissa (e.g. int64 keys > 2**24) cannot be
represented exactly, so the cast is *widening* and *demoting*:

  * partition mins are rounded toward -inf, maxs toward +inf, and query
    lows/highs likewise (lo down, hi up).  Every interval only grows, so
    the kernel can never declare a false NO_MATCH — a pruned partition is
    always truly empty of matches (the correctness-critical direction);
  * wherever a min/max cast was inexact the partition's ``demote`` plane is
    set (same mechanism as nullability), suppressing FULL_MATCH for that
    partition.  Constraints whose lo/hi cast inexactly report
    ``bounds_exact=False`` and the wrapper demotes FULL host-side.

Net effect: int64 keys > 2**24 can only *false-negative* FULL (degrade to
PARTIAL, costing a scan) and can never *false-positive* NO_MATCH or FULL.
``tests/test_device_plane.py`` holds the regression test.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import zlib
from collections import OrderedDict
from typing import Callable, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .metadata import NO_MATCH, PartitionStats
from .predicate_cache import TableVersion


class PlaneIntegrityError(RuntimeError):
    """A restaged plane failed checksum verification again.

    Raised only after the quarantine protocol exhausted its one restage:
    a resident plane's checksum mismatched, the plane was dropped and
    restaged from host truth, and the fresh plane mismatched too (i.e.
    the corruption source is persistent).  The serving layer's
    degradation ladder treats this like any launch failure and demotes —
    a wrong verdict is never served from a plane that failed its stamp.
    """


def plane_checksum(arrays) -> int:
    """Cheap integrity stamp over a plane chunk's bytes (crc32).

    Works identically on host numpy and device arrays (device arrays are
    copied back to host — callers stamp from the *host* arrays at stage
    time for free and only pay the D2H on the sampled verify schedule).
    f32/i32 values round-trip the H2D copy bit-exactly, so a clean plane
    always verifies.
    """
    c = 0
    for a in arrays:
        c = zlib.crc32(np.ascontiguousarray(np.asarray(a)).tobytes(), c)
    return c

_F32_NEG = np.float32(-np.inf)
_F32_POS = np.float32(np.inf)
_F32_MAX = np.float32(np.finfo(np.float32).max)


def round_down_f32(x: np.ndarray) -> np.ndarray:
    """f64 -> f32 rounding toward -inf (result <= x always)."""
    x = np.asarray(x, dtype=np.float64)
    f = x.astype(np.float32)
    with np.errstate(over="ignore", invalid="ignore"):
        return np.where(f.astype(np.float64) > x, np.nextafter(f, _F32_NEG), f)


def round_up_f32(x: np.ndarray) -> np.ndarray:
    """f64 -> f32 rounding toward +inf (result >= x always)."""
    x = np.asarray(x, dtype=np.float64)
    f = x.astype(np.float32)
    with np.errstate(over="ignore", invalid="ignore"):
        return np.where(f.astype(np.float64) < x, np.nextafter(f, _F32_POS), f)


def cast_stats_f32(
    mins: np.ndarray, maxs: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Widening downcast of stat planes; returns (mins32, maxs32, inexact).

    ``inexact`` is True wherever either bound moved — those partitions must
    never be declared FULL (fed into the demote plane alongside nulls).

    The planes are additionally clamped to the finite f32 extremes: the
    batched kernel gathers stat rows via a one-hot matmul, and a 0-weight
    x inf product would poison the row with NaN.  Clamping ±inf narrows
    the interval, so clamped entries are marked inexact (FULL-demoted);
    NO_MATCH stays safe because ``cast_bounds_f32`` clamps query bounds
    with the same monotone map, keeping every comparison's two sides
    consistent.  All-null partitions' empty intervals survive as
    (+f32max, -f32max) — still empty.
    """
    mins32 = round_down_f32(mins).astype(np.float32)
    maxs32 = round_up_f32(maxs).astype(np.float32)
    inexact = (mins32.astype(np.float64) != mins) | (
        maxs32.astype(np.float64) != maxs)
    mins_c = np.clip(mins32, -_F32_MAX, _F32_MAX)
    maxs_c = np.clip(maxs32, -_F32_MAX, _F32_MAX)
    inexact |= (mins_c != mins32) | (maxs_c != maxs32)
    return mins_c, maxs_c, inexact


def cast_bounds_f32(
    los: np.ndarray, his: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Widening downcast of query range bounds (lo down, hi up).

    Returns (lo32, hi32, exact) where ``exact`` is per-constraint; a False
    entry means FULL must be demoted to PARTIAL for the whole query (the
    widened range may admit rows the true range excludes).

    Bounds are clamped to the finite f32 extremes to match the stat
    planes (see cast_stats_f32).  One-sided infinite bounds lose nothing:
    every clamped stat satisfies ``>= -f32max`` exactly as it satisfied
    ``>= -inf``.  Degenerate lo=+inf / hi=-inf bounds can no longer
    *prove* FULL in the clamped domain, so they are flagged not exact.
    """
    los = np.asarray(los, dtype=np.float64)
    his = np.asarray(his, dtype=np.float64)
    lo32 = round_down_f32(los).astype(np.float32)
    hi32 = round_up_f32(his).astype(np.float32)
    exact = (lo32.astype(np.float64) == los) & (hi32.astype(np.float64) == his)
    exact &= ~np.isposinf(los) & ~np.isneginf(his)
    lo32 = np.clip(lo32, -_F32_MAX, _F32_MAX).astype(np.float32)
    hi32 = np.clip(hi32, -_F32_MAX, _F32_MAX).astype(np.float32)
    return lo32, hi32, exact


def snap_bounds_integral(
    los: np.ndarray, his: np.ndarray, integral: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Tighten range bounds on integral-domain columns: lo -> ceil, hi -> floor.

    Int columns and dictionary codes only take integer (or, for unseen
    string literals, never-attained half-integer) values, so ``x > 5``
    lowered to ``lo = nextafter(5)`` is exactly ``lo = 6`` — an integer
    that (below 2**24) casts to f32 exactly, keeping the device path
    identical to the f64 host oracle on the paper's workloads instead of
    conservatively demoting FULL.  No-op on float columns and on the
    infinite padding sentinels.
    """
    los = np.asarray(los, dtype=np.float64)
    his = np.asarray(his, dtype=np.float64)
    integral = np.asarray(integral, dtype=bool)
    los = np.where(integral & np.isfinite(los), np.ceil(los), los)
    his = np.where(integral & np.isfinite(his), np.floor(his), his)
    return los, his


def plane_capacity(p: int) -> int:
    """Padded partition capacity for delta-staged planes.

    Next power of two with at least 25% append headroom over ``p``, so a
    streaming table absorbs many appends before a capacity overflow
    forces a full restage.  Capacity slots beyond the logical partition
    count hold drop sentinels — every batched kernel treats them as
    never-matching, so no reshape is needed when partitions arrive.
    """
    want = max(8, p + max(p // 4, 1))
    cap = 8
    while cap < want:
        cap *= 2
    return cap


@dataclasses.dataclass(frozen=True)
class PlaneEpoch:
    """What a resident plane reflects: (table version, live count, capacity).

    The service and the technique executors carry this alongside batched
    launches so a delta-staged launch is checkable against (and stays
    bit-identical to) a fresh host restage of the same table version.
    """

    version: int
    live: int
    capacity: int


@dataclasses.dataclass
class DeviceStats:
    """A table's resident metadata plane: [C, cap] device arrays, f32.

    ``capacity >= logical_p``; columns ``logical_p..capacity`` (and
    dropped partitions inside ``logical_p``) hold the drop sentinel
    ``(+f32max, -f32max, demote=1)`` — an empty interval that every
    batched kernel evaluates as NO_MATCH / no-hit / no contribution.

    The three arrays live in ONE ``planes`` tuple swapped atomically by
    delta replay (single attribute store under the GIL), so a launch
    that unpacked the tuple once can never see post-DML mins next to
    pre-DML maxs — the same discipline ``_PlaneEntry.arrays`` follows.
    """

    table_name: str
    version: int           # table DML version the planes reflect
    # ((mins, maxs, demote), logical_p): the three [C, cap] f32 arrays —
    # mins widened toward -inf, maxs toward +inf, demote 1.0 where
    # nulls/inexact cast (no FULL) — bundled with the logical partition
    # count they reflect.  Launch code must read THIS field once
    # (``planes, P = dstats.planes_state; mins, maxs, demote = planes``)
    # rather than the per-array / num_partitions properties, which are
    # separate reads a concurrent replay could tear across.
    planes_state: Tuple
    integral: np.ndarray   # [C] bool, host-side: int/dictionary-code column
    live_count: int = -1
    tv_version: Optional[int] = None   # service TableVersion seen at staging
    # integrity stamp over the planes' bytes, computed host-side at stage
    # time and re-stamped after every delta replay; the cache verifies it
    # on a sampled read schedule and always after an eviction-restage
    checksum: Optional[int] = None

    def __post_init__(self):
        planes, p = self.planes_state
        if p < 0:          # dense staging: infer logical P from the arrays
            self.planes_state = (planes, int(planes[0].shape[1]))
        if self.live_count < 0:
            self.live_count = self.logical_p

    @property
    def planes(self) -> Tuple:
        return self.planes_state[0]

    @property
    def logical_p(self) -> int:
        return self.planes_state[1]

    @property
    def mins(self) -> jnp.ndarray:
        return self.planes[0]

    @property
    def maxs(self) -> jnp.ndarray:
        return self.planes[1]

    @property
    def demote(self) -> jnp.ndarray:
        return self.planes[2]

    @property
    def num_columns(self) -> int:
        return self.mins.shape[0]

    @property
    def num_partitions(self) -> int:
        return self.logical_p

    @property
    def capacity(self) -> int:
        return int(self.mins.shape[1])

    @property
    def epoch(self) -> PlaneEpoch:
        return PlaneEpoch(self.version, self.live_count, self.capacity)

    @property
    def nbytes(self) -> int:
        return int(sum(int(a.nbytes) for a in self.planes))

    def gather(self, cids: np.ndarray):
        """On-device row gather -> per-constraint [K, cap] planes.

        This replaces the old host transpose + H2D copy per query; the
        resident [C, cap] arrays never leave the device.
        """
        cids = jnp.asarray(np.asarray(cids, dtype=np.int32))
        mins, maxs, demote = self.planes
        return (jnp.take(mins, cids, axis=0),
                jnp.take(maxs, cids, axis=0),
                jnp.take(demote, cids, axis=0))

    @staticmethod
    def stage(stats: PartitionStats, table_name: str = "",
              version: int = 0, capacity: Optional[int] = None,
              live: Optional[np.ndarray] = None) -> "DeviceStats":
        """Host [P, C] f64 stats -> device [C, cap] f32 planes (one H2D copy).

        ``capacity=None`` stages dense (exact [C, P] — the classic
        one-shot path); the cache passes ``plane_capacity(P)`` so the
        staged planes absorb appended partitions in place.
        """
        P = stats.num_partitions
        cap = P if capacity is None else max(int(capacity), P)
        mins32, maxs32, inexact = cast_stats_f32(stats.mins.T, stats.maxs.T)
        demote = ((stats.null_counts.T > 0) | inexact).astype(np.float32)
        if cap > P:
            C = len(stats.columns)
            pad = cap - P
            mins32 = np.concatenate(
                [mins32, np.full((C, pad), _F32_MAX, np.float32)], axis=1)
            maxs32 = np.concatenate(
                [maxs32, np.full((C, pad), -_F32_MAX, np.float32)], axis=1)
            demote = np.concatenate(
                [demote, np.ones((C, pad), np.float32)], axis=1)
        integral = np.array([c.kind != "float" for c in stats.columns],
                            dtype=bool)
        live_count = P if live is None else int(np.asarray(live, bool).sum())
        return DeviceStats(
            table_name=table_name,
            version=version,
            planes_state=((jnp.asarray(mins32), jnp.asarray(maxs32),
                           jnp.asarray(demote)), P),
            integral=integral,
            live_count=live_count,
            # stamped from the host arrays pre-H2D: free at stage time
            checksum=plane_checksum((mins32, maxs32, demote)),
        )


KPLANE = 64   # block-top-k plane width: values kept per partition

# Hierarchical (tree) plane geometry.  The flat [C, cap] planes aggregate
# into [C, G] *group* planes (G = cap / fanout; both powers of two, so the
# division is exact) — group g's interval is the min/max hull of its
# members, so a query range that misses the hull misses every member: the
# batched kernels can prune whole groups before touching leaves (the
# paper's Sec. 3.2/4.3 adaptive tree, device-resident).  A second, tiny
# *coarse* level (at most TREE_COARSE_MAX root groups) lives host-side in
# the same plane entry: it both restricts the fine pre-pass (log-depth
# refinement) and prices the pre-pass before launching it (the >50%-dense
# fallback).  Below fanout * TREE_MIN_GROUPS partitions the flat launch
# wins and the tree path is skipped entirely.
TREE_FANOUT = 256
TREE_MIN_GROUPS = 4
TREE_COARSE_MAX = 64

# Registry of plane families under the integrity protocol.  Every family
# in DeviceStatsCache._stores MUST be declared here and vice versa — the
# contract linter (tools/contract_lint, rule CL002) enforces the parity,
# so a new family cannot ship without joining checksum stamping and byte
# accounting.  ``verdict`` is the Sec. 8.2 predicate/verdict cache: one
# int8 [cap] three-valued verdict row per (table, canonical predicate).
PLANE_FAMILIES = ("stat", "join_key", "enum", "block_topk", "tree_stat",
                  "verdict")


def coarse_from_groups(gmins, gmaxs) -> Tuple[np.ndarray, np.ndarray]:
    """Host [C, G2] root hull of the [C, G] group planes (G2 <= 64)."""
    gm = np.asarray(gmins)
    gx = np.asarray(gmaxs)
    C, G = gm.shape
    g2 = min(G, TREE_COARSE_MAX)
    f2 = G // g2
    cmins = gm.reshape(C, g2, f2).min(axis=2)
    cmaxs = gx.reshape(C, g2, f2).max(axis=2)
    return cmins, cmaxs


def aggregate_tree_planes(mins, maxs, demote, fanout: int) -> Tuple:
    """Aggregate flat [C, cap] planes into the tree plane arrays.

    Returns ``(gmins, gmaxs, gdem, cmins, cmaxs)``: device [C, G] group
    hulls (min of member mins / max of member maxs / max of member
    demotes) plus the host coarse root level.  Sentinel slots
    (+f32max, -f32max) aggregate to an empty hull only when the whole
    group is sentinels — a live member's interval always widens the hull,
    so group NO_MATCH implies member NO_MATCH with no special-casing.
    """
    C, cap = mins.shape
    if fanout <= 0 or cap % fanout:
        raise ValueError(f"fanout {fanout} must divide plane capacity {cap}")
    G = cap // fanout
    gmins = mins.reshape(C, G, fanout).min(axis=2)
    gmaxs = maxs.reshape(C, G, fanout).max(axis=2)
    gdem = demote.reshape(C, G, fanout).max(axis=2)
    cmins, cmaxs = coarse_from_groups(gmins, gmaxs)
    return gmins, gmaxs, gdem, cmins, cmaxs


@dataclasses.dataclass
class _PlaneEntry:
    """A resident per-column plane: device arrays + the version staged.

    ``arrays`` are capacity-padded along the partition axis (axis 0);
    slots beyond ``logical_p`` and dropped partitions hold the family's
    sentinel.  ``meta`` carries host-side extras (enum wmax/domain_ok).
    """

    version: int
    logical_p: int
    arrays: Tuple
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def capacity(self) -> int:
        return int(self.arrays[0].shape[0])

    @property
    def nbytes(self) -> int:
        return int(sum(int(a.nbytes) for a in self.arrays))


def tree_entry_for(dstats: "DeviceStats", fanout: int = TREE_FANOUT,
                   version: int = 0,
                   logical_p: Optional[int] = None) -> _PlaneEntry:
    """Build a standalone hierarchical plane entry from a flat entry.

    Benchmarks and tests that stage ``DeviceStats`` directly (no table /
    cache) use this to get the same entry shape ``tree_plane`` serves:
    group + coarse arrays in ``arrays``, geometry in ``meta``.  The
    cache's build path delegates here so the two can never drift.
    """
    arrays = aggregate_tree_planes(*dstats.planes, fanout=fanout)
    return _PlaneEntry(
        version,
        dstats.num_partitions if logical_p is None else int(logical_p),
        arrays,
        meta=dict(fanout=fanout, cap=dstats.capacity,
                  groups=int(arrays[0].shape[1])))


@dataclasses.dataclass
class _Resident:
    """A plane the memory manager accounts for: device bytes + pin count."""

    nbytes: int
    pins: int = 0


class PlaneMemoryManager:
    """HBM accountant for every resident plane family, LRU under a budget.

    The paper's fleet serves *thousands* of tables; planes staged
    unboundedly run device memory out long before that.  The manager
    enforces one byte budget across all four plane families (stat,
    join-key, enum, block-top-k) with per-(table, plane) LRU eviction —
    the skewed, shifting table popularity of real fleets (cf.
    Workload-Aware Incremental Reclustering) is exactly the regime LRU
    serves well — plus in-flight pinning so a batched launch can never
    have a plane it is about to consume evicted from under it.

    Contract (the eviction invariants the fleet suite pins):

      * entries with ``pins > 0`` are never selected for eviction;
      * an admit first evicts LRU unpinned entries until the new entry
        fits, so ``bytes_in_use`` exceeds the budget only when the
        *pinned* set alone forces it (counted: ``over_budget_events``,
        ``pin_denied``) — with a sane budget both stay 0;
      * re-admitting a key that was previously evicted counts a
        ``restage_storm`` — the thrash signal for budget sizing;
      * eviction is always *safe*: the owning cache drops the entry (a
        later miss re-stages from host truth), and in-flight launches
        keep their device arrays alive via ordinary references.

    ``budget_bytes=None`` disables eviction but keeps the accounting —
    the unbounded engine reports the same counters, all zeros but
    ``bytes_in_use``/``hits``/``misses``.
    """

    MONOTONIC = ("hits", "misses", "evictions", "evicted_bytes",
                 "restage_storms", "over_budget_events", "pin_denied")
    GAUGES = ("bytes_in_use", "peak_bytes", "pinned_bytes", "budget_bytes",
              "resident_planes")

    def __init__(self, budget_bytes: Optional[int] = None):
        self.budget_bytes = budget_bytes
        # (family, key) -> _Resident, LRU order (oldest first)
        self._resident: "OrderedDict[Tuple, _Resident]" = OrderedDict()
        self._evict_cb: Optional[Callable[[str, Tuple], None]] = None
        self._ever_evicted: set = set()
        # pins owed by scopes whose entry was released (invalidate) and
        # possibly re-admitted under the same key: their unpins consume
        # this debt instead of stripping a NEW scope's pin on the fresh
        # record (which would let it be evicted mid-launch)
        self._orphan_pins: dict = {}
        self.bytes_in_use = 0
        self.peak_bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.evicted_bytes = 0
        self.restage_storms = 0
        self.over_budget_events = 0   # admits that left use > budget (pins)
        self.pin_denied = 0           # evictions blocked: all-pinned tail

    def bind(self, evict_cb: Callable[[str, Tuple], None]) -> None:
        """Register the owning cache's store-removal callback."""
        self._evict_cb = evict_cb

    # -- accounting ------------------------------------------------------

    def touch(self, family: str, key: Tuple) -> None:
        """A getter served this resident plane: LRU refresh + hit."""
        fk = (family, key)
        if fk in self._resident:
            self.hits += 1
            self._resident.move_to_end(fk)

    def admit(self, family: str, key: Tuple, nbytes: int) -> None:
        """Account a freshly staged plane, evicting LRU unpinned entries
        first so the budget holds wherever pins allow it to."""
        fk = (family, key)
        old = self._resident.pop(fk, None)
        if old is not None:
            self.bytes_in_use -= old.nbytes
        self.misses += 1
        if fk in self._ever_evicted:
            self.restage_storms += 1
        self._make_room(int(nbytes))
        self._resident[fk] = _Resident(int(nbytes),
                                       pins=old.pins if old else 0)
        self.bytes_in_use += int(nbytes)
        self.peak_bytes = max(self.peak_bytes, self.bytes_in_use)
        if self.budget_bytes is not None \
                and self.bytes_in_use > self.budget_bytes:
            self.over_budget_events += 1

    def _make_room(self, incoming: int) -> None:
        if self.budget_bytes is None:
            return
        if incoming > self.budget_bytes:
            # A plane that can never fit: evicting the whole fleet's
            # residency first would buy nothing — admit over budget
            # (counted by the caller) and leave everyone else resident.
            return
        while self.bytes_in_use + incoming > self.budget_bytes:
            victim = next((fk for fk, r in self._resident.items()
                           if r.pins == 0), None)
            if victim is None:
                # blocked by pins — or, with nothing resident at all, by
                # a single plane larger than the budget (that is an
                # over_budget_event, not pin pressure)
                if self._resident:
                    self.pin_denied += 1
                return
            self._evict_one(victim)

    def _evict_one(self, fk: Tuple) -> None:
        r = self._resident.pop(fk)
        assert r.pins == 0, f"evicting pinned plane {fk}"
        self.bytes_in_use -= r.nbytes
        self.evictions += 1
        self.evicted_bytes += r.nbytes
        self._ever_evicted.add(fk)
        if self._evict_cb is not None:
            self._evict_cb(*fk)

    def was_evicted(self, family: str, key: Tuple) -> bool:
        """Whether this key has ever been budget-evicted — the cache
        force-verifies the checksum on every restage of such a key."""
        return (family, key) in self._ever_evicted

    def release(self, family: str, key: Tuple) -> None:
        """The cache dropped this entry itself (invalidate / restage)."""
        fk = (family, key)
        r = self._resident.pop(fk, None)
        if r is not None:
            self.bytes_in_use -= r.nbytes
            if r.pins:
                # the pinning scopes still owe their unpins — park them
                # as debt so they cannot strip a later scope's pin on a
                # re-admitted record under the same key
                self._orphan_pins[fk] = self._orphan_pins.get(fk, 0) + r.pins

    def reclaim(self) -> None:
        """Evict back under budget once pins release (pin-scope exit).

        A launch whose pinned working set forced an over-budget admit
        leaves ``bytes_in_use > budget`` behind; the owning scope calls
        this on exit so the overshoot lasts exactly as long as the
        launch.  Silent when everything left is pinned by other scopes.
        """
        if self.budget_bytes is None \
                or self.bytes_in_use <= self.budget_bytes:
            return      # common case: every launch exits a scope — O(1)
        # Planes larger than the whole budget can never legally stay:
        # drop them first rather than flushing the rest of the fleet
        # around them (admit leaves them resident only while pinned /
        # until this runs).
        for fk, r in list(self._resident.items()):
            if r.pins == 0 and r.nbytes > self.budget_bytes:
                self._evict_one(fk)
        while self.bytes_in_use > self.budget_bytes:
            victim = next((fk for fk, r in self._resident.items()
                           if r.pins == 0), None)
            if victim is None:
                return
            self._evict_one(victim)

    # -- pinning ---------------------------------------------------------

    def pin(self, family: str, key: Tuple) -> bool:
        r = self._resident.get((family, key))
        if r is None:
            return False
        r.pins += 1
        return True

    def unpin(self, family: str, key: Tuple) -> None:
        fk = (family, key)
        debt = self._orphan_pins.get(fk)
        if debt:                        # our pinned record was released
            if debt == 1:
                del self._orphan_pins[fk]
            else:
                self._orphan_pins[fk] = debt - 1
            return
        r = self._resident.get(fk)
        if r is not None and r.pins > 0:
            r.pins -= 1

    @property
    def pinned_bytes(self) -> int:
        return sum(r.nbytes for r in self._resident.values() if r.pins)

    @property
    def resident_planes(self) -> int:
        return len(self._resident)

    def snapshot(self) -> dict:
        out = {k: getattr(self, k) for k in self.MONOTONIC}
        out.update({k: getattr(self, k) for k in self.GAUGES})
        return out

    @staticmethod
    def delta(before: dict, after: dict) -> dict:
        """Monotonic counters differenced, gauges taken from ``after``."""
        out = {k: after[k] - before[k] for k in PlaneMemoryManager.MONOTONIC}
        out.update({k: after[k] for k in PlaneMemoryManager.GAUGES})
        return out


class DeviceStatsCache:
    """Once-per-table staging of metadata planes, delta-synced, LRU-bounded.

    Keys are ``(table_name, stats.uid)``: the stats uid distinguishes a
    *rebuilt* table — same name, same shape, new data — from the object
    that was staged, so a stale plane can never serve it.  Entries of
    dead rebuilt tables age out via the LRU bound.

    Delta staging (incremental ingest)
    ----------------------------------
    Resident entries record the table DML ``version`` they reflect (and
    the service ``TableVersion`` seen at staging).  When a table's
    version advances through its own DML methods (``append_partitions``
    / ``drop_partitions`` / ``update_column``), ``get`` and the
    per-column plane getters *replay* the table's ``TableDelta`` log
    instead of restaging:

      * **append**: planes were allocated with ``plane_capacity`` slack,
        so only the new ``[C, ΔP]`` columns are staged in place;
      * **drop**: dropped partitions are scattered with the no-op
        sentinel ``(+f32max, -f32max, demote=1)`` — all batched kernels
        skip them without any reshape;
      * **update(column)**: the [C, P] planes restage only that column's
        three rows; per-column planes of *other* columns advance their
        version with zero staging work (the satellite-3 guarantee);
      * **rewrite** (or a log gap / capacity overflow): full restage —
        the only cases that pay O(table) again.

    ``staged_bytes`` / ``delta_stages`` / ``full_restages`` count the
    work; ``PruningService.run_batch`` surfaces the per-batch delta via
    ``PruningReport.counters['staging']``.  A version bump *without* a
    covering delta log (legacy ``TableVersion`` bumps) always full
    restages — never wrong, just slower.

    Runtime-technique planes (PR 2)
    -------------------------------
    Alongside the [C, P] min/max/demote planes the cache stages two
    *per-column* plane families for the runtime techniques:

      * **join-key planes** (``join_key_plane``): the key column's widened
        f32 [P] min/max rows, consumed by ``join_overlap_batched``;
      * **enumeration planes** (``enum_plane``): the key column's
        integer-snapped [P] int32 pmin/width rows (width 0 = never
        enumerate), consumed by ``bloom_probe_batched`` for the Bloom
        half of JOIN pruning;
      * **block-top-k planes** (``block_topk_plane``): [P, KPLANE] rows of
        the column's per-partition top-K *signed* values (sign = +1 DESC /
        -1 ASC, nulls excluded, f64 -> f32 rounded toward -inf so every
        stored value is <= the true row value — a boundary derived from
        them is always witnessed), consumed by ``topk_init_batched``.

    Both follow the same TableVersion invalidation discipline through the
    DML hooks, with one refinement: ``on_update(table, column)`` drops the
    [C, P] planes (they carry every column) but only the *matching
    column's* join-key / block-top-k planes — an update to column X cannot
    change column Y's values, so Y's planes stay resident.

    Memory budget (PR 5)
    --------------------
    ``budget_bytes`` hands residency to a ``PlaneMemoryManager``: one
    HBM byte budget across all four plane families, per-(table, plane)
    LRU eviction, and in-flight pinning via ``pin_scope`` so a batched
    launch can never lose a plane it is consuming.  Eviction is always
    safe — a later getter simply restages (and, the plane being gone,
    pays the full-restage cost; the fleet counters make that thrash
    visible as ``restage_storms``).  Without a budget the legacy
    ``max_entries`` / ``max_planes`` count caps apply unchanged.  Every
    getter is atomic under one reentrant lock: the table-version check,
    the delta replay, the manager accounting, and the returned-plane
    read cannot interleave with a concurrent DML invalidation (the
    eviction-path race the fleet suite regression-tests).
    """

    def __init__(self, max_entries: int = 16, max_planes: int = 64,
                 budget_bytes: Optional[int] = None,
                 fault_injector=None, integrity_sample: int = 64,
                 tree_fanout: int = TREE_FANOUT):
        if tree_fanout < 2 or tree_fanout & (tree_fanout - 1):
            raise ValueError(
                f"tree_fanout must be a power of two >= 2, got {tree_fanout}")
        # Leaf partitions per tree-plane group; plane capacities are
        # powers of two with >= 25% headroom, so any pow-2 fanout <= cap
        # divides a table's capacity exactly.
        self.tree_fanout = int(tree_fanout)
        # (name, uid) -> DeviceStats ([C, cap] planes + epoch)
        self.entries: "OrderedDict[Tuple, DeviceStats]" = OrderedDict()  # guarded-by: _lock
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        # (name, uid, col) -> _PlaneEntry((pmin, pmax) [cap] f32 rows)
        self.key_planes: "OrderedDict[Tuple, _PlaneEntry]" = OrderedDict()  # guarded-by: _lock
        # (name, uid, col) -> _PlaneEntry((pmin, width) [cap] i32 rows,
        #                                 meta: wmax, domain_ok)
        self.enum_planes: "OrderedDict[Tuple, _PlaneEntry]" = OrderedDict()  # guarded-by: _lock
        # (name, uid, col, desc, k) -> _PlaneEntry(([cap, k] signed rows,))
        self.topk_planes: "OrderedDict[Tuple, _PlaneEntry]" = OrderedDict()  # guarded-by: _lock
        # (name, uid) -> _PlaneEntry((gmins, gmaxs, gdem) [C, G] device
        # group hulls + (cmins, cmaxs) host coarse root — all five arrays
        # under one CRC stamp; meta: fanout, cap, groups)
        self.tree_planes: "OrderedDict[Tuple, _PlaneEntry]" = OrderedDict()  # guarded-by: _lock
        # (name, uid, canonical predicate key) -> _PlaneEntry((verdicts,))
        # — one int8 [cap] three-valued row; meta: cols (predicate's
        # column reads, for UPDATE invalidation)
        self.verdict_planes: "OrderedDict[Tuple, _PlaneEntry]" = OrderedDict()  # guarded-by: _lock
        self.max_planes = max_planes
        self.plane_hits = 0
        self.plane_misses = 0
        # staging-work counters (H2D bytes; delta vs full attribution)
        self.staged_bytes = 0
        self.delta_stages = 0      # successful delta replays (any family)
        self.full_restages = 0     # full restagings of previously-resident
                                   # planes (rewrite / log gap / overflow)
        self.prefetch_stages = 0   # prefetch() calls that actually staged
                                   # bytes (serving front-end overlap)
        # HBM budget across all plane families.  With a budget set, the
        # byte-LRU memory manager governs residency and the legacy
        # count caps (max_entries / max_planes) are inactive; without
        # one the counts cap as before and the manager only accounts.
        self.memory = PlaneMemoryManager(budget_bytes)
        self._stores = {"stat": self.entries, "join_key": self.key_planes,
                        "enum": self.enum_planes,
                        "block_topk": self.topk_planes,
                        "tree_stat": self.tree_planes,
                        "verdict": self.verdict_planes}
        self.memory.bind(self._evict_family)
        # Epoch check + plane read must be atomic per getter: under the
        # eviction path a concurrent version bump / invalidate between
        # the check and the read could hand out a plane whose manager
        # record is already gone (accounting drift) or mix two versions'
        # arrays.  One reentrant lock serializes getters, DML hooks, and
        # manager mutation; pin scopes are tracked per thread.
        self._lock = threading.RLock()
        self._pin_local = threading.local()
        # Plane integrity (resilience layer): every staged chunk carries
        # a crc32 stamp; reads verify it every ``integrity_sample``-th
        # getter hit (1 = every read — what the chaos suite uses so a
        # corrupted plane can never serve a verdict; 0 = never sample)
        # and ALWAYS right after a quarantine- or eviction-restage.  A
        # mismatch quarantines the plane (drop + one restage from host
        # truth); a second mismatch raises PlaneIntegrityError, which the
        # serving ladder demotes past.  ``fault_injector`` is the chaos
        # seam (serve.resilience.FaultInjector) — None costs one
        # attribute load per site, nothing else.
        self.fault_injector = fault_injector
        self.integrity_sample = int(integrity_sample)
        self._integrity_tick = 0        # guarded-by: _lock
        self._quarantined: set = set()  # guarded-by: _lock
        self.integrity = dict(verifications=0, checksum_failures=0,  # guarded-by: _lock
                              quarantines=0, verdict_repairs=0)

    # ---- memory-manager plumbing ---------------------------------------

    def _evict_family(self, family: str, key: Tuple) -> None:
        """Manager-initiated eviction: drop the entry from its store
        (the manager already removed its own record)."""
        # pop before the fault seam: an injected eviction fault must not
        # leave a store entry whose manager record is already gone
        self._stores[family].pop(key, None)
        if self.fault_injector is not None:
            self.fault_injector.fire("evict")

    # ---- integrity plumbing --------------------------------------------

    def _fire(self, site: str) -> None:
        if self.fault_injector is not None:
            self.fault_injector.fire(site)

    def _corrupt(self, site: str, arrays: Tuple) -> Tuple:
        if self.fault_injector is not None:
            return self.fault_injector.corrupt(site, arrays)
        return arrays

    def _verify_due(self) -> bool:
        s = self.integrity_sample
        if s <= 0:
            return False
        self._integrity_tick += 1
        return self._integrity_tick % s == 0

    def _verify(self, arrays, stamp: Optional[int]) -> bool:
        self.integrity["verifications"] += 1
        return stamp is None or plane_checksum(arrays) == stamp

    def _quarantine(self, family: str, key: Tuple) -> None:
        """A resident plane's bytes no longer match its stamp: count it,
        drop the plane, and mark the key so the restage is verified."""
        self.integrity["checksum_failures"] += 1
        self.integrity["quarantines"] += 1
        self._stores[family].pop(key, None)
        self.memory.release(family, key)
        self._quarantined.add((family, key))

    def integrity_snapshot(self) -> dict:
        with self._lock:
            return dict(self.integrity)

    def _pin_frames(self):
        frames = getattr(self._pin_local, "frames", None)
        if frames is None:
            frames = self._pin_local.frames = []
        return frames

    @contextlib.contextmanager
    def pin_scope(self):
        """Pin every plane a getter returns inside this scope.

        Batched launches wrap their getter + kernel call in a scope so
        the planes they are about to consume cannot be evicted mid-
        launch (and the budget accounting stays honest about in-flight
        HBM).  Scopes nest; pins are reference counts per entry.
        """
        frame: list = []
        with self._lock:
            self._pin_frames().append(frame)
        try:
            yield
        finally:
            # Exception safety is load-bearing here: a raise anywhere in
            # the scope body (failed launch, injected staging fault, an
            # eviction callback blowing up inside reclaim) must still
            # release every pin this frame took, or the leaked refcounts
            # permanently shrink the evictable set under the HBM budget.
            # Every unpin is attempted even if one raises, and reclaim
            # always runs; a reclaim failure (eviction-path fault) may
            # propagate — with zero pins leaked — where the serving
            # ladder treats it like any launch failure.
            with self._lock:
                frames = self._pin_frames()
                # remove by identity: nested scopes can hold equal-content
                # frames, and list.remove's equality match would pop the
                # wrong one, leaking the outer scope's pins forever
                for i in range(len(frames) - 1, -1, -1):
                    if frames[i] is frame:
                        del frames[i]
                        break
                cleanup_exc = None
                for fk in frame:
                    try:
                        self.memory.unpin(*fk)
                    except Exception as exc:  # pragma: no cover - defensive
                        cleanup_exc = exc
                try:
                    self.memory.reclaim()
                except Exception as exc:
                    cleanup_exc = exc
                if cleanup_exc is not None:
                    raise cleanup_exc

    def _scope_pin(self, family: str, key: Tuple) -> None:
        frames = self._pin_frames()
        if frames and self.memory.pin(family, key):
            frames[-1].append((family, key))

    def _touch(self, family: str, key: Tuple) -> None:
        self.memory.touch(family, key)
        self._scope_pin(family, key)

    def _admit(self, family: str, key: Tuple, nbytes: int) -> None:
        self.memory.admit(family, key, nbytes)
        self._scope_pin(family, key)

    # ---- version / delta-log plumbing ----------------------------------

    @staticmethod
    def _table_version(table) -> int:
        return int(getattr(table, "version", 0))

    @staticmethod
    def _deltas_since(table, version: int):
        """Ordered TableDeltas in (version, table.version], or None when
        the log has been compacted past ``version`` (full restage)."""
        deltas = getattr(table, "deltas", None)
        if deltas is None:
            return None
        if version < int(getattr(table, "delta_floor", 0)):
            return None
        return [d for d in deltas if d.version > version]

    @staticmethod
    def _live_count(table) -> int:
        return int(getattr(table, "num_live_partitions",
                           table.stats.num_partitions))

    def staging_snapshot(self) -> dict:
        return dict(staged_bytes=self.staged_bytes,
                    delta_stages=self.delta_stages,
                    full_restages=self.full_restages,
                    prefetch_stages=self.prefetch_stages)

    def prefetch(self, table, tv: Optional[TableVersion] = None) -> bool:
        """Opportunistically stage the table's [C, cap] stat plane ahead
        of its launch — the serving front-end's double-buffer seam: a
        staging thread prefetches batch N+1's planes while batch N's
        launches run lock-free on device.

        Runs the ordinary ``get`` path (epoch check, delta replay,
        checksum stamp, budget accounting — nothing is bypassed), under
        the same reentrant lock, so a concurrent getter simply finds the
        plane already resident.  Never raises: prefetch is advisory, and
        a staging failure here surfaces on the real launch where the
        degradation ladder handles it.  Returns True when bytes were
        actually staged (counted in ``prefetch_stages``).
        """
        with self._lock:
            before = self.staged_bytes
            try:
                self.get(table, tv)
            except Exception:
                return False
            staged = self.staged_bytes > before
            if staged:
                self.prefetch_stages += 1
            return staged

    def plane_epoch(self, table) -> Optional[PlaneEpoch]:
        """The resident [C, cap] plane's epoch for this table, if staged."""
        with self._lock:
            e = self.entries.get((table.name, table.stats.uid))
            return e.epoch if e is not None else None

    # ---- [C, cap] stat planes ------------------------------------------

    @staticmethod
    def _stat_cols(stats: PartitionStats, lo: int, hi: int):
        """Host f32 plane columns for partitions [lo, hi) (delta slice)."""
        m32, x32, inexact = cast_stats_f32(stats.mins[lo:hi].T,
                                           stats.maxs[lo:hi].T)
        dm = ((stats.null_counts[lo:hi].T > 0) | inexact).astype(np.float32)
        return m32, x32, dm

    def _replay_stats(self, e: DeviceStats, table, deltas) -> bool:
        """Bring a resident [C, cap] entry current by replaying deltas.

        Returns False when a full restage is required (rewrite delta,
        capacity overflow, unknown kind); on success only the changed
        partition columns were staged.
        """
        stats = table.stats
        if stats.num_partitions > e.capacity:
            return False
        mins, maxs, dem = e.planes
        nbytes = 0
        for d in deltas:
            if d.kind == "append":
                m32, x32, dm = self._stat_cols(stats, d.part_lo, d.part_hi)
                sl = slice(d.part_lo, d.part_hi)
                mins = mins.at[:, sl].set(jnp.asarray(m32))
                maxs = maxs.at[:, sl].set(jnp.asarray(x32))
                dem = dem.at[:, sl].set(jnp.asarray(dm))
                nbytes += int(m32.nbytes + x32.nbytes + dm.nbytes)
            elif d.kind == "drop":
                ids = jnp.asarray(np.asarray(d.part_ids, dtype=np.int32))
                mins = mins.at[:, ids].set(_F32_MAX)
                maxs = maxs.at[:, ids].set(-_F32_MAX)
                dem = dem.at[:, ids].set(np.float32(1.0))
                nbytes += 3 * e.num_columns * len(d.part_ids) * 4
            elif d.kind == "update":
                try:
                    ci = stats.col_id(d.column)
                except KeyError:
                    return False
                P = stats.num_partitions
                m32, x32, inexact = cast_stats_f32(
                    stats.mins[:, ci][None, :], stats.maxs[:, ci][None, :])
                dm = ((stats.null_counts[:, ci][None, :] > 0)
                      | inexact).astype(np.float32)
                mins = mins.at[ci, :P].set(jnp.asarray(m32[0]))
                maxs = maxs.at[ci, :P].set(jnp.asarray(x32[0]))
                dem = dem.at[ci, :P].set(jnp.asarray(dm[0]))
                nbytes += 3 * P * 4
            else:                      # rewrite (or unknown): full restage
                return False
        # re-stamp from the clean replayed arrays, then let the chaos
        # seam tear bytes *after* the stamp (exactly the corruption the
        # verifier must catch); one atomic tuple store: an in-flight
        # launch that already read e.planes_state keeps a consistent
        # pre-replay (planes, P) pair, and a later read sees the full
        # post-replay pair — never a mix
        e.checksum = plane_checksum((mins, maxs, dem))
        e.planes_state = (self._corrupt("stage.stat", (mins, maxs, dem)),
                          stats.num_partitions)
        e.live_count = self._live_count(table)
        self.staged_bytes += nbytes
        self.delta_stages += 1
        return True

    def get(self, table, tv: Optional[TableVersion] = None) -> DeviceStats:
        """The table's resident DeviceStats: staged on first touch,
        delta-synced on table DML, fully restaged only when it must be.

        stats.uid guards against a rebuilt table (same name, same shape,
        new data) silently hitting the stale staged plane — stale stats
        would break NO_MATCH safety, the one direction that loses rows.
        A service ``TableVersion`` bump without a covering table delta
        log (legacy invalidation flow) also forces a restage.
        """
        with self._lock:
            self._fire("get.stat")
            key = (table.name, table.stats.uid)
            tvv = tv.version if tv is not None else None
            tver = self._table_version(table)
            e = self.entries.get(key)
            if e is not None:
                served = False
                if e.version == tver and (tvv is None or e.tv_version in
                                          (None, tvv)):
                    self.hits += 1
                    if tvv is not None:
                        e.tv_version = tvv
                    served = True
                elif e.version < tver:
                    deltas = self._deltas_since(table, e.version)
                    if deltas is not None and self._replay_stats(e, table,
                                                                 deltas):
                        e.version = tver
                        e.tv_version = tvv
                        self.hits += 1
                        served = True
                if served:
                    self.entries.move_to_end(key)
                    self._touch("stat", key)
                    if not self._verify_due() or self._verify(e.planes,
                                                              e.checksum):
                        return e
                    # sampled verify caught a torn resident plane:
                    # quarantine it and restage fresh below (verified)
                    self._quarantine("stat", key)
                else:
                    # stale and not replayable: rebuild below
                    self.full_restages += 1
                    self.memory.release("stat", key)
            self.misses += 1
            retried = False
            while True:
                self._fire("stage.stat")
                e = DeviceStats.stage(
                    table.stats, table.name, tver,
                    capacity=plane_capacity(table.stats.num_partitions),
                    live=getattr(table, "live", None))
                e.tv_version = tvv
                planes, logical_p = e.planes_state
                e.planes_state = (self._corrupt("stage.stat", planes),
                                  logical_p)
                self.staged_bytes += e.nbytes
                self._admit("stat", key, e.nbytes)
                self.entries[key] = e
                self.entries.move_to_end(key)
                if self.memory.budget_bytes is None:
                    while len(self.entries) > self.max_entries:
                        k, _ = self.entries.popitem(last=False)
                        self.memory.release("stat", k)
                # a restage of a quarantined or previously-evicted key is
                # ALWAYS verified, whatever the sampling schedule says;
                # other fresh stages join the sampled schedule so a torn
                # stage can't serve its first read unchecked
                force = ("stat", key) in self._quarantined \
                    or self.memory.was_evicted("stat", key) \
                    or self._verify_due()
                if not force or self._verify(e.planes, e.checksum):
                    self._quarantined.discard(("stat", key))
                    return e
                if retried:
                    self._quarantined.discard(("stat", key))
                    self.entries.pop(key, None)
                    self.memory.release("stat", key)
                    raise PlaneIntegrityError(
                        f"stat plane {key} failed checksum verification "
                        f"after quarantine restage")
                self._quarantine("stat", key)
                retried = True

    # ---- runtime-technique planes --------------------------------------

    def _plane_current(self, family: str, store: "OrderedDict", key: Tuple,
                       table, column: str, append_fn, drop_fn):
        """Return the resident plane entry brought current, or None.

        Replays the table's delta log against the entry: appends stage
        only the new partitions (``append_fn``), drops scatter the
        family's sentinel (``drop_fn``), updates of *other* columns are
        free version advances.  An update of ``column`` itself, a
        rewrite, a log gap, or capacity overflow drops the entry (the
        caller stages fresh, counted as a plane miss + full restage).
        """
        e = store.get(key)
        if e is None:
            return None
        tver = self._table_version(table)
        served = False
        if e.version == tver:
            served = True
        elif e.version < tver:
            deltas = self._deltas_since(table, e.version)
            if deltas is not None and \
                    table.stats.num_partitions <= e.capacity:
                ok = True
                staged = False
                nbytes = 0
                for d in deltas:
                    if d.kind == "append":
                        nbytes += append_fn(e, table, d.part_lo, d.part_hi)
                        staged = True
                    elif d.kind == "drop":
                        nbytes += drop_fn(e, table, d.part_ids)
                        staged = True
                    elif d.kind == "update" and d.column != column:
                        continue
                    else:
                        ok = False
                        break
                if ok:
                    e.version = tver
                    e.logical_p = table.stats.num_partitions
                    self.staged_bytes += nbytes
                    if staged:
                        self.delta_stages += 1
                        # re-stamp from the clean replayed arrays, then
                        # the chaos seam may tear bytes post-stamp
                        e.meta["checksum"] = plane_checksum(e.arrays)
                        e.arrays = self._corrupt(f"stage.{family}",
                                                 e.arrays)
                    served = True
        if served:
            self.plane_hits += 1
            store.move_to_end(key)
            self._touch(family, key)
            if not self._verify_due() or self._verify(e.arrays,
                                                      e.meta.get("checksum")):
                return e
            # torn resident plane: quarantine; the caller stages fresh
            # (and _plane_fresh force-verifies that restage)
            self._quarantine(family, key)
            return None
        del store[key]
        self.memory.release(family, key)
        self.full_restages += 1
        return None

    def _plane_fresh(self, family: str, store: "OrderedDict", key: Tuple,
                     build_fn) -> _PlaneEntry:
        """Stage a fresh per-column plane with the integrity protocol:
        stamp from the built arrays, admit, and force-verify whenever the
        key was just quarantined or was ever budget-evicted; a verify
        failure quarantines and rebuilds once, a second failure raises
        ``PlaneIntegrityError`` (the serving ladder demotes past it)."""
        retried = False
        while True:
            self._fire(f"stage.{family}")
            e = build_fn()
            e.meta["checksum"] = plane_checksum(e.arrays)
            e.arrays = self._corrupt(f"stage.{family}", e.arrays)
            e = self._plane_put(family, store, key, e)
            fk = (family, key)
            force = fk in self._quarantined \
                or self.memory.was_evicted(family, key) \
                or self._verify_due()
            if not force or self._verify(e.arrays, e.meta["checksum"]):
                self._quarantined.discard(fk)
                return e
            if retried:
                self._quarantined.discard(fk)
                store.pop(key, None)
                self.memory.release(family, key)
                raise PlaneIntegrityError(
                    f"{family} plane {key} failed checksum verification "
                    f"after quarantine restage")
            self._quarantine(family, key)
            retried = True

    def _plane_put(self, family: str, store: "OrderedDict", key: Tuple,
                   entry: _PlaneEntry) -> _PlaneEntry:
        self.plane_misses += 1
        self.staged_bytes += entry.nbytes
        self._admit(family, key, entry.nbytes)
        store[key] = entry
        if self.memory.budget_bytes is None:
            while len(store) > self.max_planes:
                k, _ = store.popitem(last=False)
                self.memory.release(family, k)
        return entry

    # -- join-key planes --

    def _key_rows(self, table, key_col: str, lo: int, hi: int):
        """Widened f32 (pmin, pmax) host rows for partitions [lo, hi)."""
        pmin = np.clip(round_down_f32(table.stats.col_min(key_col)[lo:hi]),
                       -_F32_MAX, _F32_MAX).astype(np.float32)
        pmax = np.clip(round_up_f32(table.stats.col_max(key_col)[lo:hi]),
                       -_F32_MAX, _F32_MAX).astype(np.float32)
        return pmin, pmax

    def _key_append(self, e: _PlaneEntry, table, lo: int, hi: int) -> int:
        pmin, pmax = self._key_rows(table, e.meta["col"], lo, hi)
        a, b = e.arrays
        e.arrays = (a.at[lo:hi].set(jnp.asarray(pmin)),
                    b.at[lo:hi].set(jnp.asarray(pmax)))
        return int(pmin.nbytes + pmax.nbytes)

    def _key_drop(self, e: _PlaneEntry, table, part_ids) -> int:
        ids = jnp.asarray(np.asarray(part_ids, dtype=np.int32))
        a, b = e.arrays
        e.arrays = (a.at[ids].set(_F32_MAX), b.at[ids].set(-_F32_MAX))
        return 2 * len(part_ids) * 4

    def join_key_plane(self, table, key_col: str) -> Tuple:
        """The key column's resident (pmin, pmax) [cap] f32 rows (widened).

        Staged once per (table identity, column) and delta-synced on
        table DML; consumed by the batched join-overlap kernel.  Clamped
        to finite f32 like the [C, cap] planes, so +inf distinct-key
        padding can never produce a hit; dropped/capacity slots hold the
        empty-interval sentinel (+f32max, -f32max) — never a hit either.
        """
        with self._lock:
            self._fire("get.join_key")
            key = (table.name, table.stats.uid, key_col)
            e = self._plane_current("join_key", self.key_planes, key, table,
                                    key_col, self._key_append, self._key_drop)
            if e is not None:
                return e.arrays

            def build():
                P = table.stats.num_partitions
                cap = plane_capacity(P)
                pmin = np.full(cap, _F32_MAX, dtype=np.float32)
                pmax = np.full(cap, -_F32_MAX, dtype=np.float32)
                pmin[:P], pmax[:P] = self._key_rows(table, key_col, 0, P)
                return _PlaneEntry(self._table_version(table), P,
                                   (jnp.asarray(pmin), jnp.asarray(pmax)),
                                   meta=dict(col=key_col))

            return self._plane_fresh("join_key", self.key_planes, key,
                                     build).arrays

    def enum_plane(self, table, key_col: str) -> Tuple:
        """The key column's resident enumeration rows:
        (pmin, width, wmax, domain_ok).

        pmin/width are [P] int32 device rows feeding the Bloom probe
        kernel's narrow-range enumeration: integer-snapped partition
        minima (``ceil(col_min)``) and candidate counts
        (``floor(col_max) - ceil(col_min) + 1``, compared in float64
        before any integer cast so extreme ranges can't overflow).
        width 0 marks partitions that must never be enumerated — empty
        interval, non-finite bounds, or outside int32 (the kernel hashes
        int32 candidates) — and means *keep*: skipping enumeration can
        only miss prunable partitions, never prune joinable ones.  wmax
        (host int) is the plane's max width, used to bucket the kernel's
        enumeration lane dim without a device round-trip.  domain_ok
        (host bool) records whether every non-empty partition's bounds
        sit inside int32 — the device-vs-host parity gate
        (``PruningService.join_device_eligible``), computed once here so
        eligibility never rescans [P] stats per query.

        Same (table identity, column) keying, delta-sync, and
        column-granular invalidation as ``join_key_plane``; width-0 is
        also the drop/capacity sentinel (a dropped partition is never
        enumerated, i.e. kept — which its absence from every scan set
        then makes irrelevant).
        """
        with self._lock:
            self._fire("get.enum")
            key = (table.name, table.stats.uid, key_col)
            e = self._plane_current("enum", self.enum_planes, key, table,
                                    key_col, self._enum_append,
                                    self._enum_drop)
            if e is not None:
                return e.arrays + (e.meta["wmax"], e.meta["domain_ok"])

            def build():
                P = table.stats.num_partitions
                cap = plane_capacity(P)
                pmin_h, width_h, wmax, domain_ok = self._enum_rows(table,
                                                                   key_col)
                pmin = np.zeros(cap, dtype=np.int32)
                width = np.zeros(cap, dtype=np.int32)
                pmin[:P], width[:P] = pmin_h, width_h
                return _PlaneEntry(self._table_version(table), P,
                                   (jnp.asarray(pmin), jnp.asarray(width)),
                                   meta=dict(col=key_col, wmax=wmax,
                                             domain_ok=domain_ok))

            e = self._plane_fresh("enum", self.enum_planes, key, build)
            return e.arrays + (e.meta["wmax"], e.meta["domain_ok"])

    @staticmethod
    def _enum_rows(table, key_col: str):
        """Host enumeration rows over all partitions:
        (pmin i32 [P], width i32 [P], wmax, domain_ok) — exact recompute,
        shared by fresh staging and delta replay (the replay stages only
        the changed slices but refreshes wmax/domain_ok exactly, so the
        delta path choses the same kernel-vs-host route as a fresh one).
        """
        lo = np.ceil(np.asarray(table.stats.col_min(key_col), np.float64))
        hi = np.floor(np.asarray(table.stats.col_max(key_col), np.float64))
        with np.errstate(invalid="ignore", over="ignore"):
            wf = hi - lo + 1.0
            in32 = (lo >= -2.0 ** 31) & (hi < 2.0 ** 31)
            live = np.isfinite(lo) & np.isfinite(hi) & (lo <= hi)
            ok = live & in32 & (wf > 0) & (wf < 2.0 ** 31)
        domain_ok = not bool(np.any(live & ~in32))
        pmin = np.where(ok, lo, 0.0).astype(np.int32)
        width = np.where(ok, wf, 0.0).astype(np.int32)
        wmax = int(width.max()) if width.size else 0
        return pmin, width, wmax, domain_ok

    def _enum_append(self, e: _PlaneEntry, table, lo: int, hi: int) -> int:
        pmin_h, width_h, wmax, domain_ok = self._enum_rows(table,
                                                           e.meta["col"])
        a, b = e.arrays
        e.arrays = (a.at[lo:hi].set(jnp.asarray(pmin_h[lo:hi])),
                    b.at[lo:hi].set(jnp.asarray(width_h[lo:hi])))
        e.meta.update(wmax=wmax, domain_ok=domain_ok)
        return 2 * (hi - lo) * 4

    def _enum_drop(self, e: _PlaneEntry, table, part_ids) -> int:
        ids = jnp.asarray(np.asarray(part_ids, dtype=np.int32))
        a, b = e.arrays
        e.arrays = (a.at[ids].set(np.int32(0)), b.at[ids].set(np.int32(0)))
        _pmin, _width, wmax, domain_ok = self._enum_rows(table,
                                                         e.meta["col"])
        e.meta.update(wmax=wmax, domain_ok=domain_ok)
        return 2 * len(part_ids) * 4

    def block_topk_plane(self, table, order_col: str, desc: bool,
                         k_plane: int = KPLANE) -> jnp.ndarray:
        """The column's resident [P, k_plane] signed block-top-k rows.

        Row p holds partition p's k_plane largest ``sign * value`` entries
        (desc per row, -inf padded, nulls excluded).  Values are rounded
        toward -inf in the signed domain, so every stored entry is <= the
        true value of an actual non-null row — any boundary taken from
        these rows is a *witnessed* Sec. 5.4 boundary.
        """
        with self._lock:
            self._fire("get.block_topk")
            key = (table.name, table.stats.uid, order_col, bool(desc),
                   int(k_plane))
            e = self._plane_current("block_topk", self.topk_planes, key,
                                    table, order_col, self._topk_append,
                                    self._topk_drop)
            if e is not None:
                return e.arrays[0]

            def build():
                P = table.stats.num_partitions
                cap = plane_capacity(P)
                rows = np.full((cap, int(k_plane)), -np.inf,
                               dtype=np.float32)
                rows[:P] = self._topk_rows(table, order_col, bool(desc),
                                           int(k_plane), 0, P)
                return _PlaneEntry(self._table_version(table), P,
                                   (jnp.asarray(rows),),
                                   meta=dict(col=order_col,
                                             desc=bool(desc)))

            return self._plane_fresh("block_topk", self.topk_planes, key,
                                     build).arrays[0]

    @staticmethod
    def _topk_rows(table, order_col: str, desc: bool, k_plane: int,
                   lo: int, hi: int) -> np.ndarray:
        """Signed block-top-k host rows for partitions [lo, hi).

        Rows of dropped partitions are all -inf (the no-contribution
        sentinel): their tombstoned data rows must never witness a
        boundary, and a fresh restage produces the same rows as the
        delta path's sentinel scatter.
        """
        from ..kernels.ops import build_block_topk  # lazy: ops imports us
        sign = 1.0 if desc else -1.0
        sv = round_down_f32(sign * np.asarray(table.data[order_col],
                                              dtype=np.float64))
        nm = table.nulls.get(order_col)
        mask = None if nm is None else ~np.asarray(nm, dtype=bool)
        live = getattr(table, "live", None)
        if live is not None:
            live_rows = np.repeat(np.asarray(live, dtype=bool),
                                  np.diff(table.part_bounds))
            mask = live_rows if mask is None else (mask & live_rows)
        return build_block_topk(sv.astype(np.float32),
                                table.part_bounds[lo:hi + 1],
                                int(k_plane), mask=mask)

    def _topk_append(self, e: _PlaneEntry, table, lo: int, hi: int) -> int:
        (rows,) = e.arrays
        k_plane = int(rows.shape[1])
        new = self._topk_rows(table, e.meta["col"],
                              e.meta["desc"], k_plane, lo, hi)
        e.arrays = (rows.at[lo:hi].set(jnp.asarray(new)),)
        return int(new.nbytes)

    def _topk_drop(self, e: _PlaneEntry, table, part_ids) -> int:
        ids = jnp.asarray(np.asarray(part_ids, dtype=np.int32))
        (rows,) = e.arrays
        e.arrays = (rows.at[ids].set(-jnp.inf),)
        return len(part_ids) * int(rows.shape[1]) * 4

# -- hierarchical (tree) planes --

    def _tree_replay(self, e: _PlaneEntry, table, dstats: DeviceStats,
                     deltas) -> Optional[int]:
        """Re-aggregate only the dirtied groups from the current flat
        planes; returns staged bytes, or None when a full rebuild is
        required (rewrite, unknown delta, unknown column).

        The flat entry ``dstats`` is already current (the caller syncs it
        first), so group hulls re-derive on device with no extra H2D:
        appends dirty only the touched tail groups, drops only the
        dropped ids' groups, and a column update re-aggregates that
        column's group row.  The host coarse level re-derives from the
        group arrays afterwards (one small D2H).
        """
        fanout = e.meta["fanout"]
        gm, gx, gd = e.arrays[:3]
        C, G = int(gm.shape[0]), int(gm.shape[1])
        mins, maxs, dem = dstats.planes
        dirty: set = set()
        rows: set = set()
        for d in deltas:
            if d.kind == "append":
                dirty.update(range(d.part_lo // fanout,
                                   (max(d.part_hi, d.part_lo + 1) - 1)
                                   // fanout + 1))
            elif d.kind == "drop":
                dirty.update(int(p) // fanout
                             for p in np.asarray(d.part_ids).tolist())
            elif d.kind == "update":
                try:
                    rows.add(table.stats.col_id(d.column))
                except KeyError:
                    return None
            else:                  # rewrite (or unknown): full rebuild
                return None
        nbytes = 0
        if dirty:
            gids = np.fromiter(sorted(dirty), dtype=np.int32)
            idx = (gids[:, None].astype(np.int64) * fanout
                   + np.arange(fanout)[None, :]).reshape(-1)
            idx_d = jnp.asarray(idx.astype(np.int32))
            jg = jnp.asarray(gids)
            sm = jnp.take(mins, idx_d, axis=1).reshape(C, len(gids), fanout)
            sx = jnp.take(maxs, idx_d, axis=1).reshape(C, len(gids), fanout)
            sd = jnp.take(dem, idx_d, axis=1).reshape(C, len(gids), fanout)
            gm = gm.at[:, jg].set(sm.min(axis=2))
            gx = gx.at[:, jg].set(sx.max(axis=2))
            gd = gd.at[:, jg].set(sd.max(axis=2))
            nbytes += 3 * C * len(gids) * 4
        for ci in sorted(rows):
            row = slice(0, G * fanout)
            gm = gm.at[ci].set(mins[ci, row].reshape(G, fanout).min(axis=1))
            gx = gx.at[ci].set(maxs[ci, row].reshape(G, fanout).max(axis=1))
            gd = gd.at[ci].set(dem[ci, row].reshape(G, fanout).max(axis=1))
            nbytes += 3 * G * 4
        cmins, cmaxs = coarse_from_groups(gm, gx)
        e.arrays = (gm, gx, gd, cmins, cmaxs)
        return nbytes

    def tree_plane(self, table, dstats: DeviceStats) -> _PlaneEntry:
        """The table's resident hierarchical plane entry, brought current.

        ``dstats`` must be the table's *current* flat entry (from
        ``get``): the tree arrays are pure aggregations of it, so delta
        maintenance re-aggregates dirtied groups from the resident flat
        planes instead of restaging from host truth.  Full member of the
        integrity protocol: CRC-stamped at build and after every replay
        (the stamp covers the host coarse level too — it participates in
        pruning decisions), sampled-verified on read, force-verified
        after quarantine/eviction restage, ``PlaneIntegrityError`` on a
        second failure (the serving ladder demotes to the flat rungs).
        A geometry change (capacity growth, fanout reconfig) rebuilds.
        """
        with self._lock:
            self._fire("get.tree_stat")
            key = (table.name, table.stats.uid)
            fanout = self.tree_fanout
            tver = self._table_version(table)
            e = self.tree_planes.get(key)
            if e is not None:
                served = False
                geometry_ok = (e.meta["fanout"] == fanout
                               and e.meta["cap"] == dstats.capacity)
                if geometry_ok and e.version == tver:
                    served = True
                elif geometry_ok and e.version < tver:
                    deltas = self._deltas_since(table, e.version)
                    if deltas is not None:
                        nbytes = self._tree_replay(e, table, dstats, deltas)
                        if nbytes is not None:
                            e.version = tver
                            e.logical_p = table.stats.num_partitions
                            self.staged_bytes += nbytes
                            self.delta_stages += 1
                            e.meta["checksum"] = plane_checksum(e.arrays)
                            e.arrays = self._corrupt("stage.tree_stat",
                                                     e.arrays)
                            served = True
                if served:
                    self.plane_hits += 1
                    self.tree_planes.move_to_end(key)
                    self._touch("tree_stat", key)
                    if not self._verify_due() or self._verify(
                            e.arrays, e.meta.get("checksum")):
                        return e
                    self._quarantine("tree_stat", key)
                else:
                    self.tree_planes.pop(key, None)
                    self.memory.release("tree_stat", key)
                    self.full_restages += 1

            def build():
                return tree_entry_for(dstats, fanout=fanout, version=tver,
                                      logical_p=table.stats.num_partitions)

            return self._plane_fresh("tree_stat", self.tree_planes, key,
                                     build)

    # -- verdict planes (Sec. 8.2 predicate cache, device-resident) ------

    def verdict_plane(self, table, pred, ckey: str) -> Optional[np.ndarray]:
        """The cached int8 ``[P]`` verdict row for ``(table, predicate)``,
        brought current — or None on miss (the caller launches the
        ordinary kernel chain and ``verdict_record``s the result).

        ``ckey`` is the canonical predicate key (``expr.canonical_key``),
        so syntactic variants of one predicate share a row.  Full member
        of the integrity protocol: CRC-stamped at record and after every
        delta replay, sampled-verified on read (a torn row quarantines
        and misses — never serves), force-verified on the restage,
        ``PlaneIntegrityError`` on a second failure (the serving ladder
        demotes to the kernel chain: cache-off is a demotion rung, not a
        wrong answer).

        Delta repair from the ``TableDelta`` log: appended partitions are
        the only unknown slots — their verdicts are evaluated host-side
        (f64 ``eval_tv`` over just the ``[part_lo, part_hi)`` stats
        slice, exact, and bit-identical to the device kernels on the
        int/dict exact-f32 domains the parity harness pins) and patched
        in place, counted in ``integrity["verdict_repairs"]``; drops
        scatter the NO_MATCH tombstone sentinel; an UPDATE touching any
        column the predicate reads, a rewrite, a log gap, or capacity
        overflow drops the entry (full miss).
        """
        from .prune_filter import eval_tv  # lazy: avoid import cycles
        with self._lock:
            self._fire("get.verdict")
            key = (table.name, table.stats.uid, ckey)
            e = self.verdict_planes.get(key)
            if e is None:
                return None
            tver = self._table_version(table)
            P = table.stats.num_partitions
            served = False
            if e.version == tver:
                served = True
            elif e.version < tver:
                deltas = self._deltas_since(table, e.version)
                if deltas is not None and P <= e.capacity:
                    row = e.arrays[0]
                    ok = True
                    staged = False
                    nbytes = 0
                    for d in deltas:
                        if d.kind == "append":
                            sub = table.stats.select(
                                np.arange(d.part_lo, d.part_hi))
                            patch = eval_tv(pred, sub).astype(np.int8)
                            row = row.at[d.part_lo:d.part_hi].set(
                                jnp.asarray(patch))
                            self.integrity["verdict_repairs"] += 1
                            nbytes += d.part_hi - d.part_lo
                            staged = True
                        elif d.kind == "drop":
                            ids = jnp.asarray(
                                np.asarray(d.part_ids, dtype=np.int32))
                            row = row.at[ids].set(np.int8(NO_MATCH))
                            nbytes += len(d.part_ids)
                            staged = True
                        elif d.kind == "update" and \
                                d.column not in e.meta["cols"]:
                            continue
                        else:       # rewrite / predicate-column update
                            ok = False
                            break
                    if ok:
                        e.arrays = (row,)
                        e.version = tver
                        e.logical_p = P
                        self.staged_bytes += nbytes
                        if staged:
                            self.delta_stages += 1
                            e.meta["checksum"] = plane_checksum(e.arrays)
                            e.arrays = self._corrupt("stage.verdict",
                                                     e.arrays)
                        served = True
            if served:
                self.plane_hits += 1
                self.verdict_planes.move_to_end(key)
                self._touch("verdict", key)
                if not self._verify_due() or self._verify(
                        e.arrays, e.meta.get("checksum")):
                    return np.asarray(e.arrays[0][:P], dtype=np.int8)
                # torn verdict row: quarantine and miss — the relaunch's
                # verdict_record force-verifies the restage
                self._quarantine("verdict", key)
                return None
            del self.verdict_planes[key]
            self.memory.release("verdict", key)
            self.full_restages += 1
            return None

    def verdict_record(self, table, pred, ckey: str,
                       tv_row: np.ndarray) -> None:
        """Stage a freshly-computed verdict row as a resident plane.

        ``tv_row`` is the int8 ``[P]`` three-valued result of a ladder
        rung at or above ``host_oracle`` (exact rungs only — passthrough
        verdicts are uncertified and never recorded).  Capacity-padded
        with the NO_MATCH sentinel like every delta-staged family, so
        appended partitions patch in place.
        """
        with self._lock:
            key = (table.name, table.stats.uid, ckey)
            P = table.stats.num_partitions
            cap = plane_capacity(P)
            row = np.full(cap, NO_MATCH, dtype=np.int8)
            row[:P] = np.asarray(tv_row, dtype=np.int8)
            cols = tuple(pred.columns()) if pred is not None else ()

            def build():
                return _PlaneEntry(self._table_version(table), P,
                                   (jnp.asarray(row),),
                                   meta=dict(cols=cols))

            self._plane_fresh("verdict", self.verdict_planes, key, build)

    def invalidate(self, table_name: str, column: Optional[str] = None
                   ) -> None:
        """Drop staged planes for a table.

        ``column=None`` drops everything (insert/delete semantics); a
        column drops the [C, P] planes (they carry every column's stats)
        plus only that column's join-key / enumeration / block-top-k
        planes.
        """
        with self._lock:
            stale = [k for k in self.entries if k[0] == table_name]
            for k in stale:
                del self.entries[k]
                self.memory.release("stat", k)
            # tree planes aggregate every column, exactly like the [C, P]
            # stat planes they derive from: any invalidation drops them
            stale = [k for k in self.tree_planes if k[0] == table_name]
            for k in stale:
                del self.tree_planes[k]
                self.memory.release("tree_stat", k)
            for family, store in (("join_key", self.key_planes),
                                  ("enum", self.enum_planes),
                                  ("block_topk", self.topk_planes)):
                stale = [k for k in store
                         if k[0] == table_name
                         and (column is None or k[2] == column)]
                for k in stale:
                    del store[k]
                    self.memory.release(family, k)
            # verdict keys carry a canonical predicate, not a column:
            # match on the columns the cached predicate actually reads
            stale = [k for k, e in self.verdict_planes.items()
                     if k[0] == table_name
                     and (column is None or column in e.meta.get("cols", ()))]
            for k in stale:
                del self.verdict_planes[k]
                self.memory.release("verdict", k)

    # ---- DML hooks (mirror predicate_cache's safety analysis; staging a
    # stale stats plane is never *unsafe* for NO_MATCH only if stats were
    # still valid, which DML breaks — so every mutation invalidates) ------

    def on_insert(self, table_name: str) -> None:
        self.invalidate(table_name)

    def on_delete(self, table_name: str) -> None:
        self.invalidate(table_name)

    def on_update(self, table_name: str, column: str) -> None:
        # Updates are column-scoped: the [C, P] stat planes must re-stage
        # (they include the updated column), while the other columns'
        # join-key / enumeration / block-top-k planes remain valid and
        # stay resident.
        self.invalidate(table_name, column=column)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def resident_bytes(self) -> int:
        # (the enum store used to be summed with a stale 3-tuple unpack
        # that raised once any enum plane was resident; the generic
        # _PlaneEntry walk fixes that)
        with self._lock:
            total = sum(e.nbytes for e in self.entries.values())
            for store in (self.key_planes, self.enum_planes,
                          self.topk_planes, self.tree_planes,
                          self.verdict_planes):
                total += sum(e.nbytes for e in store.values())
            return total
