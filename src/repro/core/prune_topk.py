"""Top-k (ORDER BY x LIMIT k) runtime pruning (paper Sec. 5).

Block-max-WAND adapted to the relational setting: while scanning, the k-th
best value seen so far — the *boundary value* — is passed sideways to the
table scan, and a partition whose metadata max (DESC ordering) cannot beat
the boundary is skipped without being fetched.

Three pieces, mirroring the paper:
  * the scan loop with boundary pruning (`run_topk`),
  * partition processing-order strategies (Sec. 5.3): 'none' | 'random' |
    'sort' (by block max),
  * upfront boundary initialization from fully-matching partitions'
    metadata (Sec. 5.4).

Everything works in the *signed domain*: ``sv = sign * value`` with
sign=+1 for DESC and -1 for ASC, so the core logic is DESC-only.  The
per-partition "block max" is ``max(sign * values) = sign * (max if desc
else min)``.

Skip rules (proved safe; hypothesis-tested against a full-scan oracle):
  with B = upfront boundary, H = heap k-th value (when the heap is full):
  * skip if block_max <  max(B, H): no row can enter the final top-k
    (rows < B are below the true k-th value; rows < H cannot improve the
    current heap);
  * skip if the heap is full and block_max <= H: a tie with the current
    k-th value cannot change the top-k *value multiset*.
  Note block_max == B with a non-full heap must NOT be skipped: the rows
  guaranteeing B may live in exactly that partition.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import expr as E
from .metadata import FULL_MATCH, PartitionStats, ScanSet
from .rowval import matches


@dataclasses.dataclass
class TopKResult:
    values: np.ndarray          # the top-k order-column values (best first)
    scanned: np.ndarray         # partition ids fetched
    skipped: np.ndarray         # partition ids pruned by the boundary
    pruning_ratio: float
    rows_scanned: int
    boundary_final: float       # signed-domain boundary at completion
    sources: np.ndarray = None  # partition id contributing each heap value
                                # (Sec. 8.2: recorded "alongside each tuple
                                # in the top-k heap" for predicate caching)

    @property
    def contributing(self) -> np.ndarray:
        """Distinct partitions whose rows form the final top-k."""
        if self.sources is None or self.sources.size == 0:
            return np.zeros(0, dtype=np.int64)
        return np.unique(self.sources)


def _signed_block_max(stats: PartitionStats, order_col: str, sign: float,
                      part_ids: Optional[np.ndarray] = None) -> np.ndarray:
    """Per-partition ``max(sign * value)``; ``part_ids`` restricts the
    gather to a scan subset (O(|scan|), not O(P) — the engine only ever
    consults the partitions it may fetch)."""
    ci = stats.col_id(order_col)
    if part_ids is None:
        return np.where(sign > 0, stats.maxs[:, ci], -stats.mins[:, ci])
    if sign > 0:
        return stats.maxs[part_ids, ci]
    return -stats.mins[part_ids, ci]


def order_partitions(
    scan: ScanSet,
    stats: PartitionStats,
    order_col: str,
    strategy: str = "sort",
    sign: float = 1.0,
    rng: Optional[np.random.Generator] = None,
) -> ScanSet:
    """Sec. 5.3 processing-order strategies."""
    if strategy == "none":
        return scan
    if strategy == "random":
        rng = rng or np.random.default_rng(0)
        return scan.reorder(rng.permutation(len(scan)))
    if strategy == "sort":
        bmax = _signed_block_max(stats, order_col, sign, scan.part_ids)
        return scan.reorder(np.argsort(-bmax, kind="stable"))
    raise ValueError(f"unknown strategy {strategy!r}")


def upfront_boundary(
    scan: ScanSet, stats: PartitionStats, order_col: str, k: int, sign: float = 1.0
) -> float:
    """Sec. 5.4: initialize the boundary from fully-matching partitions.

    Signed-domain candidates: (a) the k-th largest signed block max over
    fully-matching partitions — each such partition contains a row equal to
    its block max, so >= k fully-matching partitions guarantee k rows at or
    above the k-th largest; (b) sort fully-matching partitions by signed
    block *min* descending and take the block min where the cumulative
    non-null row count first reaches k — all rows of the partitions up to
    that point are >= it.  Returns the stricter (larger).
    """
    if scan.match is None:
        return -np.inf
    full_ids = scan.part_ids[scan.match == FULL_MATCH]
    if full_ids.size == 0:
        return -np.inf
    ci = stats.col_id(order_col)
    bmax = (stats.maxs[full_ids, ci] if sign > 0 else -stats.mins[full_ids, ci])
    bmin = (stats.mins[full_ids, ci] if sign > 0 else -stats.maxs[full_ids, ci])
    rows = stats.row_counts[full_ids] - stats.null_counts[full_ids, ci]
    valid = rows > 0
    bmax, bmin, rows = bmax[valid], bmin[valid], rows[valid]
    if bmax.size == 0:
        return -np.inf

    cand_a = float(np.sort(bmax)[-k]) if bmax.size >= k else -np.inf

    order = np.argsort(-bmin, kind="stable")
    cum = np.cumsum(rows[order])
    pos = int(np.searchsorted(cum, k))
    cand_b = float(bmin[order][pos]) if pos < bmin.size else -np.inf

    return max(cand_a, cand_b)


def run_topk(
    table,
    scan: ScanSet,
    order_col: str,
    k: int,
    pred: Optional[E.Pred] = None,
    desc: bool = True,
    strategy: str = "sort",
    use_upfront_init: bool = False,
    rng: Optional[np.random.Generator] = None,
    extra_mask_fn=None,
    b_init_floor: float = -np.inf,
) -> TopKResult:
    """Execute a top-k scan with boundary-value partition pruning.

    ``extra_mask_fn(ctx) -> bool[n]`` models operators between the scan and
    the TopK node (Fig. 7b: a join probe — only rows that survive it feed
    the heap).  Note: when an extra mask is present, Sec. 5.4 upfront
    initialization is disabled — fully-matching only certifies the scan's
    own predicate, not the join's survival.

    ``b_init_floor`` lets a caller strengthen the upfront boundary with an
    externally computed one (signed domain).  The caller must guarantee it
    is a *witnessed* Sec. 5.4 boundary — k matching rows >= the floor must
    exist — e.g. the device plane's boundary init, which takes the k-th
    largest value over fully-matching partitions' resident block-top-k
    rows.  Like the built-in init, it is ignored when an extra mask is
    present (fully-matching does not certify the mask's survival).
    """
    stats = table.stats
    sign = 1.0 if desc else -1.0
    scan = order_partitions(scan, stats, order_col, strategy, sign, rng)

    b_init = (
        upfront_boundary(scan, stats, order_col, k, sign)
        if use_upfront_init and extra_mask_fn is None
        else -np.inf
    )
    if extra_mask_fn is None:
        b_init = max(b_init, float(b_init_floor))

    heap = np.empty(0)  # signed values, sorted descending
    heap_src = np.empty(0, dtype=np.int64)
    rows_scanned = 0
    block_max = _signed_block_max(stats, order_col, sign, scan.part_ids)

    # Vectorized pre-skip: eff = max(b_init, h_kth) >= b_init throughout the
    # loop, so a partition with block_max < b_init is skipped no matter how
    # the heap evolves — drop them from the Python loop in one shot (same
    # skip set, same heap; skip order is reconstructed positionally).
    skip_flag = np.asarray(block_max < b_init)
    scanned: list = []
    for pos in np.where(~skip_flag)[0]:
        pid = scan.part_ids[pos]
        bm = block_max[pos]
        heap_full = len(heap) >= k
        h_kth = heap[k - 1] if heap_full else -np.inf
        eff = max(b_init, h_kth)
        if bm < eff or (heap_full and bm <= h_kth):
            skip_flag[pos] = True
            continue
        ctx = table.partition_ctx(int(pid))
        mask = matches(pred, ctx) if pred is not None else np.ones(ctx.n, dtype=bool)
        if extra_mask_fn is not None:
            mask &= extra_mask_fn(ctx)
        vals, nm = ctx.col(order_col)
        mask &= ~nm  # NULLS LAST: nulls never enter the heap
        rows_scanned += ctx.n
        scanned.append(pid)
        if mask.any():
            newv = sign * vals[mask]
            merged = np.concatenate([heap, newv])
            srcs = np.concatenate(
                [heap_src, np.full(len(newv), pid, dtype=np.int64)])
            order_ix = np.argsort(-merged, kind="stable")[:k]
            heap = merged[order_ix]
            heap_src = srcs[order_ix]

    total = len(scan)
    skipped = scan.part_ids[skip_flag]
    ratio = len(skipped) / total if total else 0.0
    return TopKResult(
        values=sign * heap,
        scanned=np.asarray(scanned, dtype=np.int64),
        skipped=np.asarray(skipped, dtype=np.int64),
        pruning_ratio=ratio,
        rows_scanned=rows_scanned,
        boundary_final=float(heap[k - 1]) if len(heap) >= k else -np.inf,
        sources=heap_src,
    )


def topk_oracle(table, order_col: str, k: int, pred=None, desc: bool = True) -> np.ndarray:
    """Full-scan reference: the true top-k value multiset."""
    ctx = table.global_ctx()
    mask = matches(pred, ctx) if pred is not None else np.ones(ctx.n, dtype=bool)
    vals, nm = ctx.col(order_col)
    vals = vals[mask & ~nm]
    vals = np.sort(vals)
    return vals[::-1][:k] if desc else vals[:k]
