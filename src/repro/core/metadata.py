"""Partition-level metadata: the substrate every pruning technique reads.

Mirrors Snowflake's metadata service (Sec. 2): per micro-partition and per
column we keep min / max / null_count, plus per-partition row counts.  The
stats are stored as *packed dense arrays* (``[P, C]``) rather than
per-partition objects so that a pruning pass is a branch-free vectorized
evaluation — the TPU-native adaptation described in DESIGN.md §2.

All value columns are widened to float64:  int64 values and dictionary
codes are exact in float64 up to 2**53, far beyond any dictionary or
realistic integer-key domain used here; genuinely large int64 key spaces
would use a dedicated int path (not needed for the paper's workloads).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# Three-valued match lattice (DESIGN.md §2): AND=min, OR=max, NOT=2-x.
NO_MATCH = 0        # no row in the partition can satisfy the predicate
PARTIAL_MATCH = 1   # some row may satisfy it (must scan)
FULL_MATCH = 2      # every row is guaranteed to satisfy it (Sec. 4.2)


@dataclasses.dataclass(frozen=True)
class ColumnMeta:
    """Static, table-level column description."""

    name: str
    kind: str                                  # 'int' | 'float' | 'str'
    dictionary: Optional[np.ndarray] = None    # sorted str array (kind='str')

    def __post_init__(self):
        if self.kind not in ("int", "float", "str"):
            raise ValueError(f"unknown column kind {self.kind!r}")
        if self.kind == "str" and self.dictionary is None:
            raise ValueError(f"str column {self.name!r} needs a dictionary")

    def encode(self, values) -> np.ndarray:
        """Encode raw values to the numeric domain used by the metadata."""
        if self.kind != "str":
            return np.asarray(values, dtype=np.float64)
        idx = np.searchsorted(self.dictionary, np.asarray(values, dtype=self.dictionary.dtype))
        idx = np.clip(idx, 0, len(self.dictionary) - 1)
        ok = self.dictionary[idx] == np.asarray(values)
        if not np.all(ok):
            missing = np.asarray(values)[~ok][:3]
            raise KeyError(f"values not in dictionary for {self.name!r}: {missing}")
        return idx.astype(np.float64)

    def prefix_code_range(self, prefix: str):
        """Dictionary-code interval covering every string with ``prefix``.

        Exact because the dictionary is sorted: lexicographic order equals
        code order, and v startswith p  <=>  p <= v < p + chr(maxchar).
        Returns (lo, hi) inclusive, or None if no dictionary entry matches.
        """
        if self.kind != "str":
            raise TypeError("prefix_code_range only valid for str columns")
        d = self.dictionary
        lo = int(np.searchsorted(d, prefix, side="left"))
        hi = int(np.searchsorted(d, prefix + "￿", side="right")) - 1
        if lo > hi:
            return None
        return float(lo), float(hi)


@dataclasses.dataclass(frozen=True)
class TableDelta:
    """One logged DML step, replayable by resident metadata planes.

    The device cache (``core.device_stats.DeviceStatsCache``) consumes
    these to bring staged planes up to the table's current version by
    staging only the changed partitions (appends write ``[C, ΔP]``
    columns, drops scatter no-op sentinels) instead of restaging the
    whole ``[C, P]`` plane.  A ``rewrite`` is the one kind that always
    forces a full restage (arbitrary in-place row changes).
    """

    version: int                       # table version AFTER this step
    kind: str                          # 'append' | 'drop' | 'rewrite' | 'update'
    part_lo: int = 0                   # append: [part_lo, part_hi) new ids
    part_hi: int = 0
    part_ids: Tuple[int, ...] = ()     # drop / rewrite targets
    column: str = ""                   # update: the rewritten column


@dataclasses.dataclass
class PartitionStats:
    """Packed per-partition metadata arrays; the pruning engine's input.

    mins/maxs/null_counts are ``[P, C]``; row_counts is ``[P]``.
    A fully-null column within a partition is encoded with min=+inf,
    max=-inf (an empty interval), which makes every range test evaluate
    to NO_MATCH for that partition — the correct SQL semantics, because
    a NULL never satisfies a comparison.  Dropped partitions reuse the
    same sentinel (plus null/row counts of 0), so every range test and
    the LIMIT cutter see them as empty.
    """

    columns: List[ColumnMeta]
    mins: np.ndarray
    maxs: np.ndarray
    null_counts: np.ndarray
    row_counts: np.ndarray

    _uid_counter = itertools.count()

    def __post_init__(self):
        P, C = self.mins.shape
        assert self.maxs.shape == (P, C) and self.null_counts.shape == (P, C)
        assert self.row_counts.shape == (P,)
        assert len(self.columns) == C
        self._col_index = {c.name: i for i, c in enumerate(self.columns)}
        # Process-unique identity: lets caches (device_stats) distinguish a
        # rebuilt table from the one they staged, even at equal name/shape.
        self.uid = next(PartitionStats._uid_counter)

    @property
    def num_partitions(self) -> int:
        return self.mins.shape[0]

    @property
    def num_columns(self) -> int:
        return self.mins.shape[1]

    def col_id(self, name: str) -> int:
        try:
            return self._col_index[name]
        except KeyError:
            raise KeyError(f"unknown column {name!r}; have {list(self._col_index)}")

    def column(self, name: str) -> ColumnMeta:
        return self.columns[self.col_id(name)]

    def col_min(self, name: str) -> np.ndarray:
        return self.mins[:, self.col_id(name)]

    def col_max(self, name: str) -> np.ndarray:
        return self.maxs[:, self.col_id(name)]

    def col_has_nulls(self, name: str) -> np.ndarray:
        return self.null_counts[:, self.col_id(name)] > 0

    def select(self, part_ids: np.ndarray) -> "PartitionStats":
        """Stats restricted to a subset of partitions (scan-set refinement)."""
        return PartitionStats(
            columns=self.columns,
            mins=self.mins[part_ids],
            maxs=self.maxs[part_ids],
            null_counts=self.null_counts[part_ids],
            row_counts=self.row_counts[part_ids],
        )

    # ---- incremental DML (streaming micro-partition ingest) ---------------
    # These mutate the arrays IN PLACE, preserving ``uid``: the table stays
    # the same identity and resident device planes sync via the delta log
    # (``TableDelta``) instead of restaging from scratch.

    def append_rows(self, other: "PartitionStats") -> None:
        """Append another stats block's partitions (same column schema)."""
        assert [c.name for c in other.columns] == [c.name for c in self.columns]
        self.mins = np.concatenate([self.mins, other.mins], axis=0)
        self.maxs = np.concatenate([self.maxs, other.maxs], axis=0)
        self.null_counts = np.concatenate(
            [self.null_counts, other.null_counts], axis=0)
        self.row_counts = np.concatenate(
            [self.row_counts, other.row_counts], axis=0)

    def drop_rows(self, part_ids: np.ndarray) -> None:
        """Mark partitions dropped: empty-interval sentinel, zero counts.

        The sentinel makes every range test NO_MATCH and contributes no
        rows to LIMIT arithmetic; resident device planes replay the same
        sentinel without reshaping (no partition renumbering)."""
        ids = np.asarray(part_ids, dtype=np.int64)
        self.mins[ids] = np.inf
        self.maxs[ids] = -np.inf
        self.null_counts[ids] = 0
        self.row_counts[ids] = 0

    def rewrite_rows(self, part_ids: np.ndarray,
                     other: "PartitionStats") -> None:
        """Replace the stat rows of ``part_ids`` with ``other``'s rows."""
        ids = np.asarray(part_ids, dtype=np.int64)
        self.mins[ids] = other.mins
        self.maxs[ids] = other.maxs
        self.null_counts[ids] = other.null_counts
        self.row_counts[ids] = other.row_counts

    @staticmethod
    def from_columns(
        columns: Sequence[ColumnMeta],
        encoded: Dict[str, np.ndarray],
        null_masks: Dict[str, np.ndarray],
        part_bounds: np.ndarray,
    ) -> "PartitionStats":
        """Build stats from encoded column data.

        part_bounds: ``[P+1]`` row offsets delimiting each partition.
        """
        P = len(part_bounds) - 1
        C = len(columns)
        mins = np.full((P, C), np.inf)
        maxs = np.full((P, C), -np.inf)
        nulls = np.zeros((P, C), dtype=np.int64)
        rows = np.diff(part_bounds).astype(np.int64)
        for ci, col in enumerate(columns):
            vals = encoded[col.name]
            nmask = null_masks.get(col.name)
            for p in range(P):
                s, e = part_bounds[p], part_bounds[p + 1]
                v = vals[s:e]
                if nmask is not None:
                    m = nmask[s:e]
                    nulls[p, ci] = int(m.sum())
                    v = v[~m]
                if v.size:
                    mins[p, ci] = v.min()
                    maxs[p, ci] = v.max()
        return PartitionStats(list(columns), mins, maxs, nulls, rows)


@dataclasses.dataclass
class ScanSet:
    """The set of partitions a table scan must process (Sec. 2).

    ``part_ids`` is ordered — runtime techniques (top-k) are sensitive to
    processing order, and LIMIT pruning reorders fully-matching partitions
    to the front.  ``match`` carries the three-valued result per partition
    (aligned with part_ids) so later stages can reuse it.
    """

    part_ids: np.ndarray
    match: Optional[np.ndarray] = None

    def __post_init__(self):
        self.part_ids = np.asarray(self.part_ids, dtype=np.int64)
        if self.match is not None:
            self.match = np.asarray(self.match, dtype=np.int8)
            assert self.match.shape == self.part_ids.shape

    def __len__(self) -> int:
        return int(self.part_ids.size)

    @staticmethod
    def full(num_partitions: int) -> "ScanSet":
        return ScanSet(
            np.arange(num_partitions, dtype=np.int64),
            np.full(num_partitions, FULL_MATCH, dtype=np.int8),
        )

    def keep(self, mask: np.ndarray) -> "ScanSet":
        return ScanSet(
            self.part_ids[mask],
            None if self.match is None else self.match[mask],
        )

    def reorder(self, order: np.ndarray) -> "ScanSet":
        return ScanSet(
            self.part_ids[order],
            None if self.match is None else self.match[order],
        )


def live_full_scan(table) -> ScanSet:
    """Every *live* partition of a table, FULL-matching.

    The TruePred result under streaming DML: dropped partitions are
    tombstoned in place (partition ids never shift), so a full scan is
    the live mask, not ``range(P)``.  Tables without DML support (no
    ``live`` mask, or one never materialized) are fully live and get the
    classic ``ScanSet.full``.
    """
    live = getattr(table, "live", None)
    if live is None:
        return ScanSet.full(table.num_partitions)
    ids = np.where(np.asarray(live, dtype=bool))[0].astype(np.int64)
    return ScanSet(ids, np.full(ids.size, FULL_MATCH, dtype=np.int8))


def mask_dead_partitions(tv: np.ndarray, table) -> np.ndarray:
    """Force NO_MATCH on dropped partitions of a ``[P]`` match vector.

    Metadata sentinels make most predicates NO_MATCH on dropped
    partitions already, but not all (``NOT (x > 5)`` is FULL on an empty
    interval under the three-valued lattice), so the filter stage masks
    explicitly — identically on the host and device paths, keeping them
    bit-identical.
    """
    live = getattr(table, "live", None)
    if live is None:
        return tv
    return np.where(np.asarray(live, dtype=bool), tv,
                    NO_MATCH).astype(np.int8)


def pruning_ratio(before: int, after: int) -> float:
    """Fraction of partitions removed (the paper's headline metric)."""
    if before == 0:
        return 0.0
    return 1.0 - after / before
