"""Partition-level metadata: the substrate every pruning technique reads.

Mirrors Snowflake's metadata service (Sec. 2): per micro-partition and per
column we keep min / max / null_count, plus per-partition row counts.  The
stats are stored as *packed dense arrays* (``[P, C]``) rather than
per-partition objects so that a pruning pass is a branch-free vectorized
evaluation — the TPU-native adaptation described in DESIGN.md §2.

All value columns are widened to float64:  int64 values and dictionary
codes are exact in float64 up to 2**53, far beyond any dictionary or
realistic integer-key domain used here; genuinely large int64 key spaces
would use a dedicated int path (not needed for the paper's workloads).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence

import numpy as np

# Three-valued match lattice (DESIGN.md §2): AND=min, OR=max, NOT=2-x.
NO_MATCH = 0        # no row in the partition can satisfy the predicate
PARTIAL_MATCH = 1   # some row may satisfy it (must scan)
FULL_MATCH = 2      # every row is guaranteed to satisfy it (Sec. 4.2)


@dataclasses.dataclass(frozen=True)
class ColumnMeta:
    """Static, table-level column description."""

    name: str
    kind: str                                  # 'int' | 'float' | 'str'
    dictionary: Optional[np.ndarray] = None    # sorted str array (kind='str')

    def __post_init__(self):
        if self.kind not in ("int", "float", "str"):
            raise ValueError(f"unknown column kind {self.kind!r}")
        if self.kind == "str" and self.dictionary is None:
            raise ValueError(f"str column {self.name!r} needs a dictionary")

    def encode(self, values) -> np.ndarray:
        """Encode raw values to the numeric domain used by the metadata."""
        if self.kind != "str":
            return np.asarray(values, dtype=np.float64)
        idx = np.searchsorted(self.dictionary, np.asarray(values, dtype=self.dictionary.dtype))
        idx = np.clip(idx, 0, len(self.dictionary) - 1)
        ok = self.dictionary[idx] == np.asarray(values)
        if not np.all(ok):
            missing = np.asarray(values)[~ok][:3]
            raise KeyError(f"values not in dictionary for {self.name!r}: {missing}")
        return idx.astype(np.float64)

    def prefix_code_range(self, prefix: str):
        """Dictionary-code interval covering every string with ``prefix``.

        Exact because the dictionary is sorted: lexicographic order equals
        code order, and v startswith p  <=>  p <= v < p + chr(maxchar).
        Returns (lo, hi) inclusive, or None if no dictionary entry matches.
        """
        if self.kind != "str":
            raise TypeError("prefix_code_range only valid for str columns")
        d = self.dictionary
        lo = int(np.searchsorted(d, prefix, side="left"))
        hi = int(np.searchsorted(d, prefix + "￿", side="right")) - 1
        if lo > hi:
            return None
        return float(lo), float(hi)


@dataclasses.dataclass
class PartitionStats:
    """Packed per-partition metadata arrays; the pruning engine's input.

    mins/maxs/null_counts are ``[P, C]``; row_counts is ``[P]``.
    A fully-null column within a partition is encoded with min=+inf,
    max=-inf (an empty interval), which makes every range test evaluate
    to NO_MATCH for that partition — the correct SQL semantics, because
    a NULL never satisfies a comparison.
    """

    columns: List[ColumnMeta]
    mins: np.ndarray
    maxs: np.ndarray
    null_counts: np.ndarray
    row_counts: np.ndarray

    _uid_counter = itertools.count()

    def __post_init__(self):
        P, C = self.mins.shape
        assert self.maxs.shape == (P, C) and self.null_counts.shape == (P, C)
        assert self.row_counts.shape == (P,)
        assert len(self.columns) == C
        self._col_index = {c.name: i for i, c in enumerate(self.columns)}
        # Process-unique identity: lets caches (device_stats) distinguish a
        # rebuilt table from the one they staged, even at equal name/shape.
        self.uid = next(PartitionStats._uid_counter)

    @property
    def num_partitions(self) -> int:
        return self.mins.shape[0]

    @property
    def num_columns(self) -> int:
        return self.mins.shape[1]

    def col_id(self, name: str) -> int:
        try:
            return self._col_index[name]
        except KeyError:
            raise KeyError(f"unknown column {name!r}; have {list(self._col_index)}")

    def column(self, name: str) -> ColumnMeta:
        return self.columns[self.col_id(name)]

    def col_min(self, name: str) -> np.ndarray:
        return self.mins[:, self.col_id(name)]

    def col_max(self, name: str) -> np.ndarray:
        return self.maxs[:, self.col_id(name)]

    def col_has_nulls(self, name: str) -> np.ndarray:
        return self.null_counts[:, self.col_id(name)] > 0

    def select(self, part_ids: np.ndarray) -> "PartitionStats":
        """Stats restricted to a subset of partitions (scan-set refinement)."""
        return PartitionStats(
            columns=self.columns,
            mins=self.mins[part_ids],
            maxs=self.maxs[part_ids],
            null_counts=self.null_counts[part_ids],
            row_counts=self.row_counts[part_ids],
        )

    @staticmethod
    def from_columns(
        columns: Sequence[ColumnMeta],
        encoded: Dict[str, np.ndarray],
        null_masks: Dict[str, np.ndarray],
        part_bounds: np.ndarray,
    ) -> "PartitionStats":
        """Build stats from encoded column data.

        part_bounds: ``[P+1]`` row offsets delimiting each partition.
        """
        P = len(part_bounds) - 1
        C = len(columns)
        mins = np.full((P, C), np.inf)
        maxs = np.full((P, C), -np.inf)
        nulls = np.zeros((P, C), dtype=np.int64)
        rows = np.diff(part_bounds).astype(np.int64)
        for ci, col in enumerate(columns):
            vals = encoded[col.name]
            nmask = null_masks.get(col.name)
            for p in range(P):
                s, e = part_bounds[p], part_bounds[p + 1]
                v = vals[s:e]
                if nmask is not None:
                    m = nmask[s:e]
                    nulls[p, ci] = int(m.sum())
                    v = v[~m]
                if v.size:
                    mins[p, ci] = v.min()
                    maxs[p, ci] = v.max()
        return PartitionStats(list(columns), mins, maxs, nulls, rows)


@dataclasses.dataclass
class ScanSet:
    """The set of partitions a table scan must process (Sec. 2).

    ``part_ids`` is ordered — runtime techniques (top-k) are sensitive to
    processing order, and LIMIT pruning reorders fully-matching partitions
    to the front.  ``match`` carries the three-valued result per partition
    (aligned with part_ids) so later stages can reuse it.
    """

    part_ids: np.ndarray
    match: Optional[np.ndarray] = None

    def __post_init__(self):
        self.part_ids = np.asarray(self.part_ids, dtype=np.int64)
        if self.match is not None:
            self.match = np.asarray(self.match, dtype=np.int8)
            assert self.match.shape == self.part_ids.shape

    def __len__(self) -> int:
        return int(self.part_ids.size)

    @staticmethod
    def full(num_partitions: int) -> "ScanSet":
        return ScanSet(
            np.arange(num_partitions, dtype=np.int64),
            np.full(num_partitions, FULL_MATCH, dtype=np.int8),
        )

    def keep(self, mask: np.ndarray) -> "ScanSet":
        return ScanSet(
            self.part_ids[mask],
            None if self.match is None else self.match[mask],
        )

    def reorder(self, order: np.ndarray) -> "ScanSet":
        return ScanSet(
            self.part_ids[order],
            None if self.match is None else self.match[order],
        )


def pruning_ratio(before: int, after: int) -> float:
    """Fraction of partitions removed (the paper's headline metric)."""
    if before == 0:
        return 0.0
    return 1.0 - after / before
