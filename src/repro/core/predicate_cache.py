"""Predicate caching for top-k queries (paper Sec. 8.2 — implemented).

The paper *proposes* extending Schmidt et al.'s predicate caching to top-k:
record the micro-partitions contributing tuples to the final top-k heap;
on a repeat of the same plan shape, scan only those partitions.  We build
it, including the paper's DML semantics:

  * INSERT            — safe: new partitions (appended after the cached
                        version) are added to the cached scan set;
  * UPDATE (non-order
    column)           — safe: row membership in the top-k is unchanged;
  * UPDATE (order col)— unsafe: invalidate (reordering may promote rows
                        outside the cached partitions);
  * DELETE            — unsafe: invalidate (the k+1-th row may live
                        elsewhere — the paper's exact argument).

Capacity-bounded LRU: evicting is always safe (a miss falls back to
boundary pruning).  The benchmark (Sec. 8.2 module) shows the paper's
conclusion quantitatively: caching beats pruning on *repetitive* queries
over badly-clustered data, loses on ad-hoc plans (Fig. 12: most top-k
plan shapes appear once), and the two compose.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from . import expr as E


def plan_key(table_name: str, pred: Optional[E.Pred], order_col: str,
             desc: bool, k: int) -> Tuple:
    """The paper keys the cache by query-plan shape (its Fig. 12 metric)."""
    return (table_name, repr(pred), order_col, desc, k)


@dataclasses.dataclass
class CacheEntry:
    part_ids: np.ndarray        # contributing partitions at record time
    version: int                # table version when recorded
    num_partitions: int         # partition count at record time


class TableVersion:
    """Minimal DML bookkeeping a table exposes to the cache."""

    def __init__(self, num_partitions: int):
        self.version = 0
        self.num_partitions = num_partitions

    def insert_partitions(self, n: int) -> None:
        self.version += 1
        self.num_partitions += n


class PredicateCache:
    def __init__(self, max_entries: int = 128):
        self.entries: "OrderedDict[Tuple, CacheEntry]" = OrderedDict()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0

    def lookup(self, key: Tuple, tv: TableVersion) -> Optional[np.ndarray]:
        """Partitions sufficient for this plan, or None on miss.

        INSERT-safety: partitions appended after the entry was recorded
        are unioned in (they may hold better rows).
        """
        e = self.entries.get(key)
        if e is None:
            self.misses += 1
            return None
        self.entries.move_to_end(key)
        self.hits += 1
        fresh = np.arange(e.num_partitions, tv.num_partitions, dtype=np.int64)
        return np.concatenate([e.part_ids, fresh])

    def record(self, key: Tuple, contributing: np.ndarray,
               tv: TableVersion) -> None:
        self.entries[key] = CacheEntry(
            np.asarray(contributing, dtype=np.int64), tv.version,
            tv.num_partitions)
        self.entries.move_to_end(key)
        while len(self.entries) > self.max_entries:
            self.entries.popitem(last=False)

    # ---- DML hooks (the paper's safety analysis) -------------------------

    def on_insert(self, table_name: str) -> None:
        """Safe — handled incrementally in lookup()."""

    def on_delete(self, table_name: str) -> None:
        self._invalidate_table(table_name)

    def on_update(self, table_name: str, column: str) -> None:
        stale = [k for k in self.entries
                 if k[0] == table_name and k[2] == column]
        for k in stale:
            del self.entries[k]

    def _invalidate_table(self, table_name: str) -> None:
        stale = [k for k in self.entries if k[0] == table_name]
        for k in stale:
            del self.entries[k]

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
