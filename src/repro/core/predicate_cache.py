"""Predicate caching for top-k queries (paper Sec. 8.2 — implemented).

The paper *proposes* extending Schmidt et al.'s predicate caching to top-k:
record the micro-partitions contributing tuples to the final top-k heap;
on a repeat of the same plan shape, scan only those partitions.  We build
it, including the paper's DML semantics:

  * INSERT            — safe: new partitions (appended after the cached
                        version) are added to the cached scan set;
  * UPDATE (non-order
    column)           — safe: row membership in the top-k is unchanged;
  * UPDATE (order col)— unsafe: invalidate (reordering may promote rows
                        outside the cached partitions);
  * DELETE            — unsafe: invalidate (the k+1-th row may live
                        elsewhere — the paper's exact argument).

Capacity-bounded LRU: evicting is always safe (a miss falls back to
boundary pruning).  The benchmark (Sec. 8.2 module) shows the paper's
conclusion quantitatively: caching beats pruning on *repetitive* queries
over badly-clustered data, loses on ad-hoc plans (Fig. 12: most top-k
plan shapes appear once), and the two compose.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from . import expr as E


def plan_key(table_name: str, pred: Optional[E.Pred], order_col: str,
             desc: bool, k: int) -> Tuple:
    """The paper keys the cache by query-plan shape (its Fig. 12 metric).

    Predicates are canonicalized (``expr.canonical_key``) so commutative
    conjunct orderings and ``1`` vs ``1.0`` literals of one predicate
    share a key instead of always missing.
    """
    return (table_name, E.canonical_key(pred), order_col, desc, k)


@dataclasses.dataclass
class CacheEntry:
    part_ids: np.ndarray        # contributing partitions at record time
    version: int                # table version when recorded
    num_partitions: int         # partition count at record time
    pred_cols: Tuple[str, ...] = ()   # columns the cached predicate reads
    has_delta_log: bool = False       # recorded against a Table delta log


class TableVersion:
    """Minimal DML bookkeeping a table exposes to the cache."""

    def __init__(self, num_partitions: int):
        self.version = 0
        self.num_partitions = num_partitions

    def insert_partitions(self, n: int) -> None:
        self.version += 1
        self.num_partitions += n


class PredicateCache:
    def __init__(self, max_entries: int = 128):
        self.entries: "OrderedDict[Tuple, CacheEntry]" = OrderedDict()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0

    def lookup(self, key: Tuple, tv: TableVersion,
               table=None) -> Optional[np.ndarray]:
        """Partitions sufficient for this plan, or None on miss.

        INSERT-safety: partitions appended after the entry was recorded
        are unioned in (they may hold better rows).  When the entry was
        recorded against a ``data.table.Table`` (``record(..., table=)``)
        freshness is keyed on its ``TableDelta`` log and live mask:
        appends contribute exactly the logged ``[part_lo, part_hi)``
        slots, drops are masked out (tombstoned ids never resurrect),
        and an unsafe step since record time (rewrite, update of the
        order or a predicate column, compacted-away log) is a miss.  The
        raw-count arange is only the legacy ``TableVersion`` path, and
        even there a shrunken count (drop-then-append overlap) misses
        instead of resurrecting dropped ids.
        """
        e = self.entries.get(key)
        if e is None:
            self.misses += 1
            return None
        if e.has_delta_log and table is not None:
            ids = self._replay_deltas(key, e, table)
            if ids is None:
                self.misses += 1
                return None
            self.entries.move_to_end(key)
            self.hits += 1
            return ids
        if tv.num_partitions < e.num_partitions:
            # The table shrank below the recorded count: the dense-growth
            # assumption is broken, so the arange union would be wrong.
            del self.entries[key]
            self.misses += 1
            return None
        self.entries.move_to_end(key)
        self.hits += 1
        fresh = np.arange(e.num_partitions, tv.num_partitions, dtype=np.int64)
        return np.concatenate([e.part_ids, fresh])

    def _replay_deltas(self, key: Tuple, e: CacheEntry,
                       table) -> Optional[np.ndarray]:
        """Delta-log freshness: cached ids + logged appends, live-masked."""
        if e.version < getattr(table, "delta_floor", 0):
            del self.entries[key]   # log compacted past the entry
            return None
        fresh: list = []
        for d in table.deltas:
            if d.version <= e.version:
                continue
            if d.kind == "append":
                fresh.append(np.arange(d.part_lo, d.part_hi, dtype=np.int64))
            elif d.kind == "drop":
                continue            # live mask handles tombstones below
            elif d.kind == "update" and d.column != key[2] \
                    and d.column not in e.pred_cols:
                continue            # touches neither order nor predicate
            else:                   # rewrite / unsafe update / unknown
                del self.entries[key]
                return None
        ids = np.concatenate([e.part_ids] + fresh) if fresh else e.part_ids
        live = np.asarray(table.live_mask, dtype=bool)
        ids = np.unique(ids)
        return ids[live[ids]]

    def record(self, key: Tuple, contributing: np.ndarray,
               tv: TableVersion, pred: Optional[E.Pred] = None,
               table=None) -> None:
        cols = pred.columns() if isinstance(pred, (E.Pred, E.Expr)) else ()
        version = int(table.version) if table is not None else tv.version
        self.entries[key] = CacheEntry(
            np.asarray(contributing, dtype=np.int64), version,
            tv.num_partitions, pred_cols=tuple(cols),
            has_delta_log=table is not None and hasattr(table, "deltas"))
        self.entries.move_to_end(key)
        while len(self.entries) > self.max_entries:
            self.entries.popitem(last=False)

    # ---- DML hooks (the paper's safety analysis) -------------------------

    def on_insert(self, table_name: str) -> None:
        """Safe — handled incrementally in lookup()."""

    def on_update(self, table_name: str, column: str) -> None:
        """Invalidate entries whose *order column* or *predicate* reads
        the updated column — a predicate-only update still changes which
        partitions contribute (the stale set can return a wrong top-k)."""
        stale = [k for k, e in self.entries.items()
                 if k[0] == table_name
                 and (k[2] == column or column in e.pred_cols)]
        for k in stale:
            del self.entries[k]

    def on_delete(self, table_name: str) -> None:
        self._invalidate_table(table_name)

    def _invalidate_table(self, table_name: str) -> None:
        stale = [k for k in self.entries if k[0] == table_name]
        for k in stale:
            del self.entries[k]

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
