"""Adaptive pruning-tree execution: filter reordering + cutoff (Sec. 3.2).

Compile-time pruning is modeled as an incremental, batched process over the
partition population (Snowflake refines pruning "as new filters are
identified"; here batches of partitions stand in for that incremental
refinement).  Per pruning-tree node we track
  - examined: partitions this node was evaluated on,
  - pruned:   partitions this node newly decided NO_MATCH,
  - cost:     simulated evaluation cost units (deterministic — operation
              counts, not wall clock, so tests are reproducible; see
              DESIGN.md §2 "what did not transfer").

After every batch the tree is *locally* re-optimized:
  - AND children reordered by descending pruned/cost (fast, selective
    filters first); OR children by descending full/cost (fast,
    low-selectivity filters first — they saturate the OR early).
  - Cutoff: a child of an AND whose projected benefit (partitions it would
    prune on the remaining population x per-partition scan cost) is below
    its projected evaluation cost is disabled; a disabled node contributes
    PARTIAL_MATCH (conservative: "assume every partition passes").  Per the
    paper, children of an OR are never cut off — removing one poisons the
    whole OR branch.

Invariant (tested): the adaptive result never prunes a partition that exact
evaluation would keep, and with cutoff disabled it is bit-identical to
``prune_filter.eval_tv``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from . import expr as E
from .metadata import FULL_MATCH, NO_MATCH, PARTIAL_MATCH, PartitionStats
from .prune_filter import eval_tv
from .rewrite import Widened, rewrite_for_pruning


def _expr_cost(node) -> float:
    """Deterministic per-partition evaluation cost: expression node count."""
    if isinstance(node, (E.Col, E.Lit, E.TruePred)):
        return 1.0
    if isinstance(node, E.Arith):
        return 1.0 + _expr_cost(node.lhs) + _expr_cost(node.rhs)
    if isinstance(node, E.Cmp):
        return 1.0 + _expr_cost(node.lhs) + _expr_cost(node.rhs)
    if isinstance(node, E.If):
        return 1.0 + _expr_cost(node.cond) + _expr_cost(node.then) + _expr_cost(node.other)
    if isinstance(node, (E.And, E.Or)):
        return 1.0 + sum(_expr_cost(c) for c in node.children)
    if isinstance(node, E.Not):
        return 1.0 + _expr_cost(node.child)
    if isinstance(node, Widened):
        return 1.0 + _expr_cost(node.child)
    if isinstance(node, (E.Like, E.StartsWith, E.InSet, E.IsNull)):
        return 2.0
    return 2.0


@dataclasses.dataclass
class NodeStats:
    examined: int = 0
    pruned: int = 0
    full: int = 0
    cost_units: float = 0.0
    disabled: bool = False

    @property
    def prune_ratio(self) -> float:
        return self.pruned / self.examined if self.examined else 0.0

    @property
    def full_ratio(self) -> float:
        return self.full / self.examined if self.examined else 0.0


class _Node:
    def __init__(self):
        self.stats = NodeStats()


class _Leaf(_Node):
    def __init__(self, pred: E.Pred):
        super().__init__()
        self.pred = pred
        self.cost = _expr_cost(pred)

    def describe(self) -> str:
        return repr(self.pred)


class _Bool(_Node):
    def __init__(self, op: str, children: List[_Node]):
        super().__init__()
        self.op = op  # 'and' | 'or'
        self.children = children
        self.cost = sum(c.cost for c in children)

    def describe(self) -> str:
        sep = " & " if self.op == "and" else " | "
        return "(" + sep.join(c.describe() for c in self.children) + ")"


def _build(pred: E.Pred) -> _Node:
    if isinstance(pred, E.And):
        return _Bool("and", [_build(c) for c in pred.children])
    if isinstance(pred, E.Or):
        return _Bool("or", [_build(c) for c in pred.children])
    return _Leaf(pred)


@dataclasses.dataclass
class PruneRunResult:
    tv: np.ndarray                 # [P] three-valued result
    work_units: float              # total simulated evaluation cost
    leaf_report: List[dict]        # per-leaf stats snapshots


class AdaptivePruner:
    """Batched, self-reordering, self-cutting pruning-tree executor."""

    def __init__(
        self,
        pred: E.Pred,
        scan_cost: float = 1000.0,
        reorder: bool = True,
        cutoff: bool = True,
    ):
        self.pred = rewrite_for_pruning(pred)
        self.root = _build(self.pred)
        self.scan_cost = scan_cost
        self.reorder = reorder
        self.cutoff = cutoff
        self.work_units = 0.0

    # -- evaluation -------------------------------------------------------

    def _eval(self, node: _Node, stats: PartitionStats, active: np.ndarray) -> np.ndarray:
        P = stats.num_partitions
        if node.stats.disabled:
            return np.full(P, PARTIAL_MATCH, dtype=np.int8)
        if isinstance(node, _Leaf):
            n_active = int(active.sum())
            tv = eval_tv(node.pred, stats, _rewrite=False)
            node.stats.examined += n_active
            node.stats.pruned += int(((tv == NO_MATCH) & active).sum())
            node.stats.full += int(((tv == FULL_MATCH) & active).sum())
            cost = n_active * node.cost
            node.stats.cost_units += cost
            self.work_units += cost
            return tv
        assert isinstance(node, _Bool)
        if node.op == "and":
            tv = np.full(P, FULL_MATCH, dtype=np.int8)
            for child in node.children:
                # short-circuit: partitions already NO skip further children
                ctv = self._eval(child, stats, active & (tv > NO_MATCH))
                tv = np.minimum(tv, ctv)
        else:
            tv = np.full(P, NO_MATCH, dtype=np.int8)
            for child in node.children:
                # saturation: partitions already FULL skip further children
                ctv = self._eval(child, stats, active & (tv < FULL_MATCH))
                tv = np.maximum(tv, ctv)
        return tv

    # -- adaptation -------------------------------------------------------

    def _reorder(self, node: _Node) -> None:
        if not isinstance(node, _Bool):
            return
        for c in node.children:
            self._reorder(c)
        if not self.reorder:
            return
        if node.op == "and":
            key = lambda c: -(c.stats.prune_ratio / max(c.cost, 1e-9))
        else:
            key = lambda c: -(c.stats.full_ratio / max(c.cost, 1e-9))
        node.children.sort(key=key)

    def _apply_cutoff(self, node: _Node, remaining: int) -> None:
        """Disable AND children whose projected cost exceeds their benefit.

        Benefit of keeping child c: remaining * prune_ratio * scan_cost
        (partitions it would remove never get scanned).  Cost of keeping:
        remaining * c.cost.  This is the paper's "two scenarios" model.
        """
        if not isinstance(node, _Bool):
            return
        if node.op == "and" and self.cutoff:
            for c in node.children:
                if c.stats.disabled or c.stats.examined == 0:
                    continue
                benefit = remaining * c.stats.prune_ratio * self.scan_cost
                cost = remaining * c.cost
                if cost > benefit:
                    c.stats.disabled = True
        # Never cut off below an OR (paper Sec. 3.2).  Recurse either way:
        # an AND nested inside an OR may still cut its own children.
        for c in node.children:
            self._apply_cutoff(c, remaining)

    # -- driver -----------------------------------------------------------

    def run(self, stats: PartitionStats, batch_size: Optional[int] = None) -> PruneRunResult:
        P = stats.num_partitions
        if batch_size is None or batch_size >= P:
            tv = self._eval(self.root, stats, np.ones(P, dtype=bool))
            return PruneRunResult(tv, self.work_units, self.leaf_report())
        tvs = []
        done = 0
        while done < P:
            batch = stats.select(np.arange(done, min(done + batch_size, P)))
            tvs.append(self._eval(self.root, batch, np.ones(batch.num_partitions, dtype=bool)))
            done += batch.num_partitions
            self._reorder(self.root)
            self._apply_cutoff(self.root, remaining=P - done)
        return PruneRunResult(np.concatenate(tvs), self.work_units, self.leaf_report())

    def leaf_report(self) -> List[dict]:
        out: List[dict] = []

        def walk(node: _Node):
            if isinstance(node, _Leaf):
                out.append(
                    dict(
                        pred=node.describe(),
                        cost=node.cost,
                        examined=node.stats.examined,
                        pruned=node.stats.pruned,
                        full=node.stats.full,
                        cost_units=node.stats.cost_units,
                        disabled=node.stats.disabled,
                    )
                )
            else:
                for c in node.children:
                    walk(c)

        walk(self.root)
        return out
