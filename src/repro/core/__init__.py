"""The paper's primary contribution: partition pruning for analytical scans.

Four techniques (paper sections in parentheses), composed by ``flow``:
  * filter pruning        — prune_filter (Sec. 3), prune_tree (Sec. 3.2)
  * LIMIT pruning         — prune_limit (Sec. 4)
  * top-k pruning         — prune_topk  (Sec. 5)
  * JOIN pruning          — prune_join  (Sec. 6)
"""

from . import expr
from .device_stats import DeviceStats, DeviceStatsCache
from .expr import (and_, col, if_, in_, invert, is_not_null, is_null, like, lit,
                   or_, startswith, true)
from .flow import JoinSpec, PruningPipeline, PruningReport, Query, TableScanSpec
from .metadata import (FULL_MATCH, NO_MATCH, PARTIAL_MATCH, ColumnMeta,
                       PartitionStats, ScanSet, pruning_ratio)
from .prune_filter import eval_tv, extract_ranges, fully_matching_two_pass
from .prune_join import BlockedBloom, BuildSummary, prune_probe, summarize_build
from .prune_limit import limit_prune
from .prune_topk import run_topk, topk_oracle, upfront_boundary
from .prune_tree import AdaptivePruner

__all__ = [
    "expr", "col", "lit", "if_", "like", "startswith", "in_", "is_null",
    "is_not_null", "true", "and_", "or_", "invert",
    "Query", "TableScanSpec", "JoinSpec", "PruningPipeline", "PruningReport",
    "ColumnMeta", "PartitionStats", "ScanSet", "pruning_ratio",
    "DeviceStats", "DeviceStatsCache",
    "NO_MATCH", "PARTIAL_MATCH", "FULL_MATCH",
    "eval_tv", "extract_ranges", "fully_matching_two_pass",
    "BlockedBloom", "BuildSummary", "summarize_build", "prune_probe",
    "limit_prune", "run_topk", "topk_oracle", "upfront_boundary",
    "AdaptivePruner",
]
