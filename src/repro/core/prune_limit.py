"""LIMIT pruning via fully-matching partitions (paper Sec. 4).

If the rows of fully-matching partitions alone can satisfy ``LIMIT k``,
the scan set is cut to the *minimal* number of fully-matching partitions —
globally IO-optimal for supported query shapes.  Otherwise the scan set is
merely reordered to put fully-matching partitions first ("starting the
table scan with fully-matching partitions promises faster query execution
times").

Row counting uses non-null row counts when a projection column is given;
the default counts partition rows (SELECT * semantics, as in the paper).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .metadata import FULL_MATCH, PartitionStats, ScanSet

# Table 2 categories.
ALREADY_MINIMAL = "already_minimal"
UNSUPPORTED_SHAPE = "unsupported_shape"
NO_FULLY_MATCHING = "no_fully_matching"   # prerequisites unmet -> reorder only
PRUNED_TO_0 = "pruned_to_=0"              # LIMIT 0: scan wiped entirely
PRUNED_TO_1 = "pruned_to_=1"
PRUNED_TO_N = "pruned_to_>1"


@dataclasses.dataclass
class LimitPruneResult:
    scan: ScanSet
    applied: bool
    category: str
    partitions_before: int
    partitions_after: int


def limit_prune(
    scan: ScanSet,
    stats: PartitionStats,
    k: int,
    supported_shape: bool = True,
) -> LimitPruneResult:
    """Prune/reorder ``scan`` for ``LIMIT k`` (k includes any OFFSET).

    ``scan.match`` must carry the three-valued filter-pruning result
    (Sec. 4.2: fully-matching detection is an extension of filter pruning).
    """
    before = len(scan)
    if not supported_shape:
        return LimitPruneResult(scan, False, UNSUPPORTED_SHAPE, before, before)
    if k == 0:
        # LIMIT 0 (BI tools fetching schemas): the scan is wiped — checked
        # BEFORE the already-minimal early return (a single-partition scan
        # must be emptied too) and reported under its own category, so the
        # Table 2 accounting never claims "pruned to 1" for 0 partitions.
        empty = ScanSet(np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int8))
        return LimitPruneResult(empty, True, PRUNED_TO_0, before, 0)
    if before <= 1:
        return LimitPruneResult(scan, False, ALREADY_MINIMAL, before, before)
    assert scan.match is not None, "run filter pruning first"

    rows = stats.row_counts[scan.part_ids]
    full = scan.match == FULL_MATCH
    total_full_rows = int(rows[full].sum())

    if total_full_rows < k or not full.any():
        # Cannot prune; reorder fully-matching partitions to the front.
        order = np.argsort(~full, kind="stable")
        return LimitPruneResult(scan.reorder(order), False, NO_FULLY_MATCHING, before, before)

    # Greedy: biggest fully-matching partitions first -> minimal count.
    full_idx = np.where(full)[0]
    by_rows = full_idx[np.argsort(-rows[full_idx], kind="stable")]
    cum = np.cumsum(rows[by_rows])
    need = int(np.searchsorted(cum, k) + 1)
    chosen = np.sort(by_rows[:need])
    pruned = scan.keep(np.isin(np.arange(before), chosen))
    cat = PRUNED_TO_1 if need == 1 else PRUNED_TO_N
    return LimitPruneResult(pruned, True, cat, before, need)
