"""Mamba2 (SSD — state-space duality) mixer, chunked scan formulation.

Implements the SSD block algorithm [arXiv:2405.21060]: within a chunk the
quadratic dual form (attention-like einsums, MXU-friendly); across chunks
a linear recurrence over the [H, P, N] state carried by lax.scan.  A is
scalar-per-head (Mamba2's simplification); B/C are shared across heads
(one group).  Includes the depthwise causal conv frontend and the
single-token decode step used by serving (constant-size state cache —
this is what lets the ssm/hybrid archs run the long_500k shape).

Heads shard over the `model` axis; B/C (state-dim) replicate.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .sharding import ParamSpec, constrain


def mamba_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h, K = cfg.ssm_heads, cfg.conv_kernel
    return {
        "wz": ParamSpec((d, di), ("embed", "ssm_inner")),
        "wx": ParamSpec((d, di), ("embed", "ssm_inner")),
        "wB": ParamSpec((d, n), ("embed", "ssm_state")),
        "wC": ParamSpec((d, n), ("embed", "ssm_state")),
        "wdt": ParamSpec((d, h), ("embed", "ssm_heads")),
        "conv_x": ParamSpec((K, di), ("conv", "ssm_inner"), scale=0.1),
        "conv_B": ParamSpec((K, n), ("conv", "ssm_state"), scale=0.1),
        "conv_C": ParamSpec((K, n), ("conv", "ssm_state"), scale=0.1),
        "A_log": ParamSpec((h,), ("ssm_heads",), init="zeros"),  # A = -exp(.)
        "D": ParamSpec((h,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((h,), ("ssm_heads",), init="zeros"),
        "norm": ParamSpec((di,), ("ssm_inner",), init="ones"),
        "wo": ParamSpec((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, kernel K unrolled: y_t = sum_j w_j x_{t-K+1+j}."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    out = jnp.zeros_like(x)
    for j in range(K):
        out = out + pad[:, j : j + S, :] * w[j]
    return out


class SSMState(NamedTuple):
    """Decode-time cache: recurrent state + conv tail (constant size)."""

    s: jax.Array       # [B, H, P, N] recurrent state
    conv: jax.Array    # [B, K-1, di + 2n] conv input tail


def ssd_scan(
    x: jax.Array,      # [B, S, H, P]
    dt: jax.Array,     # [B, S, H]  (post-softplus)
    A: jax.Array,      # [H]        (negative reals)
    B: jax.Array,      # [B, S, N]
    C: jax.Array,      # [B, S, N]
    chunk: int,
    s0: jax.Array = None,  # [B, H, P, N] initial state
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD: returns (y [B,S,H,P], final state [B,H,P,N])."""
    b, s, h, p_ = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    S_ = s + pad
    nc = S_ // q
    xc = x.reshape(b, nc, q, h, p_)
    dtc = dt.reshape(b, nc, q, h)
    Bc = B.reshape(b, nc, q, n)
    Cc = C.reshape(b, nc, q, n)

    da = dtc * A[None, None, None, :]                      # [b,c,q,h] (<= 0)
    cum = jnp.cumsum(da, axis=2)                           # [b,c,q,h]

    # intra-chunk (dual quadratic form): y_i += C_i.B_j dt_j decay(i,j) x_j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # [b,c,i,j,h]
    causal = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.exp(jnp.where(causal[None, None, :, :, None], seg, -jnp.inf))
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)         # [b,c,i,j]
    y_intra = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp", scores, L, dtc, xc)

    # per-chunk states: S_c = sum_j B_j dt_j decay(end, j) x_j
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)           # [b,c,q,h]
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, dtc * decay_end, xc)

    # inter-chunk recurrence (linear scan over chunks)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # [b,c,h]
    s_init = (jnp.zeros((b, h, p_, n), x.dtype)
              if s0 is None else s0.astype(x.dtype))

    def step(s_prev, inp):
        st, dec = inp                                      # [b,h,p,n], [b,h]
        s_next = s_prev * dec[:, :, None, None] + st
        return s_next, s_prev

    states_t = jnp.moveaxis(states, 1, 0)                  # [c,b,h,p,n]
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)              # [c,b,h]
    s_final, s_prefix = jax.lax.scan(step, s_init, (states_t, decay_t))
    s_prefix = jnp.moveaxis(s_prefix, 0, 1)                # [b,c,h,p,n]

    y_inter = jnp.einsum(
        "bcin,bchpn,bcih->bcihp", Cc, s_prefix, jnp.exp(cum)
    )
    y = (y_intra + y_inter).reshape(b, S_, h, p_)[:, :s]
    return y.astype(x.dtype), s_final


def mamba_block(
    p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
    return_state: bool = False,
):
    """Full Mamba2 mixer over a sequence (training/prefill path).

    With ``return_state`` also returns the decode-ready SSMState: the
    final recurrent state from the chunked scan plus the conv tail (the
    last K-1 *pre-conv* projected inputs) — what ``mamba_decode_step``
    continues from.
    """
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hd = cfg.ssm_head_dim
    K = cfg.conv_kernel
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xi0 = jnp.einsum("bsd,de->bse", x, p["wx"])
    Bv0 = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cv0 = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["wdt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )
    xi = jax.nn.silu(_causal_conv(xi0, p["conv_x"]))
    Bv = jax.nn.silu(_causal_conv(Bv0, p["conv_B"]))
    Cv = jax.nn.silu(_causal_conv(Cv0, p["conv_C"]))
    xi = constrain(xi, "batch", "seq", "ssm_inner")

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xi.reshape(*xi.shape[:2], h, hd)
    y, s_final = ssd_scan(
        xh.astype(jnp.float32), dt, A,
        Bv.astype(jnp.float32), Cv.astype(jnp.float32), cfg.ssm_chunk,
    )
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(*xi.shape[:2], di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    from .layers import rmsnorm
    y = rmsnorm(y, p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["wo"])
    out = constrain(out, "batch", "seq", "embed")
    if not return_state:
        return out
    # conv tail: last K-1 raw (pre-conv) projected inputs, left-padded
    # with zeros when the prompt is shorter than the kernel
    cat = jnp.concatenate([xi0, Bv0, Cv0], axis=-1)       # [B, S, di+2n]
    cat = jnp.pad(cat, ((0, 0), (K - 1, 0), (0, 0)))[:, -(K - 1):, :]
    state = SSMState(s=s_final.astype(jnp.float32), conv=cat.astype(jnp.float32))
    return out, state


def mamba_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSMState:
    di, n = cfg.d_inner, cfg.ssm_state
    return SSMState(
        s=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, n), dtype),
        conv=jnp.zeros((batch, cfg.conv_kernel - 1, di + 2 * n), dtype),
    )


def mamba_decode_step(
    p: Dict[str, jax.Array], x: jax.Array, state: SSMState, cfg: ModelConfig
) -> Tuple[jax.Array, SSMState]:
    """Single-token decode: O(1) state update (x: [B, 1, d])."""
    di, n, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    K = cfg.conv_kernel
    z = jnp.einsum("bsd,de->bse", x, p["wz"])[:, 0]
    xi = jnp.einsum("bsd,de->bse", x, p["wx"])[:, 0]
    Bv = jnp.einsum("bsd,dn->bsn", x, p["wB"])[:, 0]
    Cv = jnp.einsum("bsd,dn->bsn", x, p["wC"])[:, 0]
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["wdt"])[:, 0].astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )                                                       # [B, H]

    # conv over the cached tail + this step
    cat = jnp.concatenate([xi, Bv, Cv], axis=-1)            # [B, di+2n]
    window = jnp.concatenate([state.conv, cat[:, None, :]], axis=1)  # [B,K,*]
    wfull = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=1)
    conv_out = jnp.einsum("bkf,kf->bf", window, wfull)
    conv_out = jax.nn.silu(conv_out)
    xi, Bv, Cv = jnp.split(conv_out, [di, di + n], axis=-1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # [H]
    xh = xi.reshape(-1, h, hd).astype(jnp.float32)
    decay = jnp.exp(dt * A)                                 # [B, H]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bv.astype(jnp.float32))
    s_new = state.s * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", s_new, Cv.astype(jnp.float32))
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(-1, di).astype(x.dtype) * jax.nn.silu(z)
    from .layers import rmsnorm
    y = rmsnorm(y, p["norm"])
    out = jnp.einsum("be,ed->bd", y, p["wo"])[:, None, :]
    return out, SSMState(s=s_new, conv=window[:, 1:, :])
