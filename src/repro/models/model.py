"""Model assembly: param specs + train/decode apply fns for all families.

``build_model(cfg)`` returns a ``Model`` bundle:
  * ``specs``        — pytree of ParamSpec (shapes + logical sharding axes)
  * ``loss_fn``      — (params, batch) -> (loss, metrics); batch provides
                       tokens/labels (+ ``prefix`` embeddings for vlm/audio)
  * ``prefill_fn``   — (params, batch) -> (logits_last, cache)
  * ``decode_fn``    — (params, cache, tokens, position) -> (logits, cache)
  * ``init_cache``   — abstract cache spec for a (batch, max_seq) shape

Layers run under jax.lax.scan over stacked parameters (compile-time O(1)
in depth) with jax.checkpoint (remat) per layer — required for the 61-layer
trillion-parameter dry-run to both compile quickly and fit HBM.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from .mamba import (SSMState, mamba_block, mamba_decode_step,
                    mamba_specs)
from .moe import moe_block, moe_specs
from .sharding import ParamSpec, constrain


class Model(NamedTuple):
    cfg: ModelConfig
    specs: Any
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    init_cache: Callable


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _embed_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    V = cfg.padded_vocab  # §Perf H3: pad so the vocab dim TP-shards
    out = {
        "embed": ParamSpec((V, cfg.d_model), ("vocab", "embed"), scale=1.0),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        out["unembed"] = ParamSpec((V, cfg.d_model), ("vocab", "embed"))
    return out


def _embed(params, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    return constrain(x, "batch", "seq", "embed")


def _unembed_matrix(params) -> jax.Array:
    return params.get("unembed", params["embed"])


def _lm_loss(params, hidden: jax.Array, labels: jax.Array, cfg: ModelConfig):
    """Chunked cross-entropy: never materializes [B, S, V] for the full S.

    labels < 0 are masked (the VLM prefix, padding).  Vocab stays sharded
    over `model`; the logsumexp reduction becomes a psum under GSPMD.
    """
    B, S, d = hidden.shape
    W = _unembed_matrix(params)
    c = min(cfg.logits_chunk, S)
    pad = (-S) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (S + pad) // c
    hs = hidden.reshape(B, nc, c, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, c).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(h, lab):
        logits = jnp.einsum("bcd,vd->bcv", h, W).astype(jnp.float32)
        logits = constrain(logits, "batch", "seq", "vocab")
        if W.shape[0] > cfg.vocab:  # mask padded vocab rows out of the CE
            pad_mask = jnp.arange(W.shape[0]) >= cfg.vocab
            logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1
        )[..., 0]
        valid = (lab >= 0).astype(jnp.float32)
        return ((logz - ll) * valid).sum(), valid.sum()

    def step(carry, inp):
        tot, cnt = carry
        h, lab = inp
        t, n = chunk_loss(h, lab)
        return (tot + t, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(step, (0.0, 0.0), (hs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def _last_logits(params, hidden: jax.Array, cfg: Optional[ModelConfig] = None
                 ) -> jax.Array:
    W = _unembed_matrix(params)
    logits = jnp.einsum("bd,vd->bv", hidden[:, -1, :], W).astype(jnp.float32)
    if cfg is not None and W.shape[0] > cfg.vocab:
        pad_mask = jnp.arange(W.shape[0]) >= cfg.vocab
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    return constrain(logits, "batch", "vocab")


# ---------------------------------------------------------------------------
# decoder-only transformer (dense / moe / vlm)
# ---------------------------------------------------------------------------

def _layer_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    specs = {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": L.attn_specs(cfg),
    }
    specs["ffn"] = moe_specs(cfg) if cfg.family == "moe" else L.mlp_specs(cfg)
    return specs


def _decoder_specs(cfg: ModelConfig):
    return {
        **_embed_specs(cfg),
        "layers": _stack_specs_tree(_layer_specs(cfg), cfg.n_layers),
    }


def _stack_specs_tree(tree, n: int):
    return jax.tree.map(
        lambda s: dataclasses.replace(
            s, shape=(n, *s.shape), logical=("layers", *s.logical)
        ),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _decoder_layer(lp, x, cfg: ModelConfig, positions):
    h = L.attention(lp["attn"], L.rmsnorm(x, lp["ln1"]), cfg, positions)
    x = x + h
    if cfg.family == "moe":
        f, aux = moe_block(lp["ffn"], L.rmsnorm(x, lp["ln2"]), cfg)
    else:
        f, aux = L.mlp(lp["ffn"], L.rmsnorm(x, lp["ln2"]), cfg), 0.0
    return x + f, aux


def _decoder_hidden(params, x, cfg: ModelConfig, positions):
    layer = _decoder_layer
    if cfg.remat:
        layer = jax.checkpoint(layer, static_argnums=(2,))

    if cfg.scan_layers:
        def body(carry, lp):
            x, aux = carry
            x2, a = layer(lp, x, cfg, positions)
            return (x2, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, 0.0), params["layers"])
    else:
        aux = 0.0
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            x, a = layer(lp, x, cfg, positions)
            aux = aux + a
    return L.rmsnorm(x, params["final_norm"]), aux


def _tokens_to_hidden(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    x = _embed(params, tokens)
    if cfg.frontend != "none" and "prefix" in batch:
        prefix = batch["prefix"].astype(x.dtype)
        prefix = constrain(prefix, "batch", "prefix", "embed")
        x = jnp.concatenate([prefix, x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    return _decoder_hidden(params, x, cfg, positions)


def _decoder_loss(params, batch, cfg: ModelConfig):
    hidden, aux = _tokens_to_hidden(params, batch, cfg)
    labels = batch["labels"]
    if cfg.frontend != "none" and "prefix" in batch:
        npf = batch["prefix"].shape[1]
        labels = jnp.concatenate(
            [jnp.full((labels.shape[0], npf), -1, labels.dtype), labels], axis=1
        )
    ce = _lm_loss(params, hidden, labels, cfg)
    metrics = {"ce": ce, "aux": aux}
    return ce + 0.01 * aux, metrics


# -- caches -----------------------------------------------------------------

def _decoder_cache_shapes(cfg: ModelConfig, batch: int, max_seq: int):
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    kv = jax.ShapeDtypeStruct((cfg.n_layers, batch, max_seq, KV, Dh), jnp.bfloat16)
    return {"k": kv, "v": kv}


def _decoder_prefill(params, batch, cfg: ModelConfig, max_seq: int):
    """Run the prompt through the stack, returning (last_logits, cache)."""
    tokens = batch["tokens"]
    x = _embed(params, tokens)
    if cfg.frontend != "none" and "prefix" in batch:
        prefix = batch["prefix"].astype(x.dtype)
        x = jnp.concatenate([prefix, x], axis=1)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        xn = L.rmsnorm(x, lp["ln1"])
        q, k, v = L.qkv_project(lp["attn"], xn, cfg, positions)
        ke = L._expand_kv(k, cfg.n_heads)
        ve = L._expand_kv(v, cfg.n_heads)
        o = L.chunked_attention(q, ke, ve, causal=True, chunk=cfg.attn_chunk)
        o = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        x = x + o
        if cfg.family == "moe":
            f, _ = moe_block(lp["ffn"], L.rmsnorm(x, lp["ln2"]), cfg)
        else:
            f = L.mlp(lp["ffn"], L.rmsnorm(x, lp["ln2"]), cfg)
        x = x + f
        pad = max_seq - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        kc = constrain(kc, "batch", "kv_seq", "kv_heads", None)
        vc = constrain(vc, "batch", "kv_seq", "kv_heads", None)
        return x, {"k": kc, "v": vc}

    if cfg.remat:
        body = jax.checkpoint(body)
    x, cache = jax.lax.scan(body, x, params["layers"])
    hidden = L.rmsnorm(x, params["final_norm"])
    return _last_logits(params, hidden, cfg), cache


def _decoder_decode(params, cache, tokens, position, cfg: ModelConfig):
    """One decode step for the whole batch (tokens: [B, 1])."""
    x = _embed(params, tokens)

    def body(x, inp):
        lp, ck, cv = inp
        xn = L.rmsnorm(x, lp["ln1"])
        o, ck, cv = L.decode_attention(lp["attn"], xn, cfg, ck, cv, position)
        x = x + o
        if cfg.family == "moe":
            f, _ = moe_block(lp["ffn"], L.rmsnorm(x, lp["ln2"]), cfg)
        else:
            f = L.mlp(lp["ffn"], L.rmsnorm(x, lp["ln2"]), cfg)
        return x + f, {"k": ck, "v": cv}

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    hidden = L.rmsnorm(x, params["final_norm"])
    return _last_logits(params, hidden, cfg), new_cache


# ---------------------------------------------------------------------------
# SSM (mamba2) and hybrid (zamba2)
# ---------------------------------------------------------------------------

def _ssm_specs(cfg: ModelConfig):
    block = {
        "ln": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "mixer": mamba_specs(cfg),
    }
    return {**_embed_specs(cfg), "layers": _stack_specs_tree(block, cfg.n_layers)}


def _ssm_hidden(params, x, cfg: ModelConfig):
    def body(x, lp):
        x = x + mamba_block(lp["mixer"], L.rmsnorm(x, lp["ln"]), cfg)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.rmsnorm(x, params["final_norm"]), 0.0


def _ssm_loss(params, batch, cfg: ModelConfig):
    x = _embed(params, batch["tokens"])
    hidden, _ = _ssm_hidden(params, x, cfg)
    ce = _lm_loss(params, hidden, batch["labels"], cfg)
    return ce, {"ce": ce}


def _ssm_cache_shapes(cfg: ModelConfig, batch: int, max_seq: int):
    del max_seq  # constant-size state: the point of SSMs
    di, n = cfg.d_inner, cfg.ssm_state
    return {
        "s": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, n), jnp.float32),
        "conv": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, cfg.conv_kernel - 1, di + 2 * n), jnp.float32),
    }


def _ssm_prefill(params, batch, cfg: ModelConfig, max_seq: int):
    # Prefill = full forward, carrying out each layer's final SSM state
    # (the ssd chunk scan produces it for free) + conv tail for decode.
    x = _embed(params, batch["tokens"])

    def body(carry, lp):
        x = carry
        xn = L.rmsnorm(x, lp["ln"])
        y, st = mamba_block(lp["mixer"], xn, cfg, return_state=True)
        return x + y, {"s": st.s, "conv": st.conv}

    bodyf = jax.checkpoint(body) if cfg.remat else body
    x, cache = jax.lax.scan(bodyf, x, params["layers"])
    hidden = L.rmsnorm(x, params["final_norm"])
    return _last_logits(params, hidden, cfg), cache


def _ssm_decode(params, cache, tokens, position, cfg: ModelConfig):
    x = _embed(params, tokens)

    def body(x, inp):
        lp, s, conv = inp
        xn = L.rmsnorm(x, lp["ln"])
        y, st = mamba_decode_step(lp["mixer"], xn, SSMState(s, conv), cfg)
        return x + y, {"s": st.s, "conv": st.conv}

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache["s"], cache["conv"]))
    hidden = L.rmsnorm(x, params["final_norm"])
    return _last_logits(params, hidden, cfg), new_cache


# -- hybrid (zamba2): groups of SSM layers + one SHARED attention block ------

def _hybrid_specs(cfg: ModelConfig):
    assert cfg.n_layers % cfg.attn_every == 0
    groups = cfg.n_layers // cfg.attn_every
    ssm_block = {
        "ln": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "mixer": mamba_specs(cfg),
    }
    stacked = _stack_specs_tree(_stack_specs_tree(ssm_block, cfg.attn_every), groups)
    return {
        **_embed_specs(cfg),
        "layers": stacked,                       # [groups, attn_every, ...]
        "shared_attn": {
            "ln1": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
            "ln2": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
            "attn": L.attn_specs(cfg),
            "ffn": L.mlp_specs(cfg),
        },
    }


def _hybrid_hidden(params, x, cfg: ModelConfig, positions):
    shared = params["shared_attn"]

    def group(x, gp):
        for i in range(cfg.attn_every):
            lp = jax.tree.map(lambda p: p[i], gp)
            x = x + mamba_block(lp["mixer"], L.rmsnorm(x, lp["ln"]), cfg)
        # shared attention block closes the group
        h = L.attention(shared["attn"], L.rmsnorm(x, shared["ln1"]), cfg, positions)
        x = x + h
        x = x + L.mlp(shared["ffn"], L.rmsnorm(x, shared["ln2"]), cfg)
        return x, None

    groupf = jax.checkpoint(group) if cfg.remat else group
    x, _ = jax.lax.scan(groupf, x, params["layers"])
    return L.rmsnorm(x, params["final_norm"]), 0.0


def _hybrid_loss(params, batch, cfg: ModelConfig):
    x = _embed(params, batch["tokens"])
    S = x.shape[1]
    hidden, _ = _hybrid_hidden(params, x, cfg, jnp.arange(S)[None, :])
    ce = _lm_loss(params, hidden, batch["labels"], cfg)
    return ce, {"ce": ce}


def _hybrid_cache_shapes(cfg: ModelConfig, batch: int, max_seq: int):
    groups = cfg.n_layers // cfg.attn_every
    di, n = cfg.d_inner, cfg.ssm_state
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "s": jax.ShapeDtypeStruct(
            (groups, cfg.attn_every, batch, cfg.ssm_heads, cfg.ssm_head_dim, n),
            jnp.float32),
        "conv": jax.ShapeDtypeStruct(
            (groups, cfg.attn_every, batch, cfg.conv_kernel - 1, di + 2 * n),
            jnp.float32),
        "k": jax.ShapeDtypeStruct((groups, batch, max_seq, KV, Dh), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((groups, batch, max_seq, KV, Dh), jnp.bfloat16),
    }


def _hybrid_prefill(params, batch, cfg: ModelConfig, max_seq: int):
    x = _embed(params, batch["tokens"])
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]
    shared = params["shared_attn"]

    def group(x, gp):
        ss, convs = [], []
        for i in range(cfg.attn_every):
            lp = jax.tree.map(lambda p: p[i], gp)
            y, st = mamba_block(lp["mixer"], L.rmsnorm(x, lp["ln"]), cfg,
                                return_state=True)
            x = x + y
            ss.append(st.s)
            convs.append(st.conv)
        xn = L.rmsnorm(x, shared["ln1"])
        q, k, v = L.qkv_project(shared["attn"], xn, cfg, positions)
        ke = L._expand_kv(k, cfg.n_heads)
        ve = L._expand_kv(v, cfg.n_heads)
        o = L.chunked_attention(q, ke, ve, causal=True, chunk=cfg.attn_chunk)
        x = x + jnp.einsum("bshk,hkd->bsd", o, shared["attn"]["wo"])
        x = x + L.mlp(shared["ffn"], L.rmsnorm(x, shared["ln2"]), cfg)
        pad = max_seq - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        kc = constrain(kc, "batch", "kv_seq", "kv_heads", None)
        vc = constrain(vc, "batch", "kv_seq", "kv_heads", None)
        return x, {"k": kc, "v": vc, "s": jnp.stack(ss),
                   "conv": jnp.stack(convs)}

    groupf = jax.checkpoint(group) if cfg.remat else group
    x, cache = jax.lax.scan(groupf, x, params["layers"])
    hidden = L.rmsnorm(x, params["final_norm"])
    return _last_logits(params, hidden, cfg), cache


def _hybrid_decode(params, cache, tokens, position, cfg: ModelConfig):
    x = _embed(params, tokens)
    shared = params["shared_attn"]

    def group(x, inp):
        gp, s, conv, ck, cv = inp
        new_s, new_conv = [], []
        for i in range(cfg.attn_every):
            lp = jax.tree.map(lambda p: p[i], gp)
            xn = L.rmsnorm(x, lp["ln"])
            y, st = mamba_decode_step(lp["mixer"], xn, SSMState(s[i], conv[i]), cfg)
            x = x + y
            new_s.append(st.s)
            new_conv.append(st.conv)
        xn = L.rmsnorm(x, shared["ln1"])
        o, ck, cv = L.decode_attention(shared["attn"], xn, cfg, ck, cv, position)
        x = x + o
        x = x + L.mlp(shared["ffn"], L.rmsnorm(x, shared["ln2"]), cfg)
        return x, {"s": jnp.stack(new_s), "conv": jnp.stack(new_conv),
                   "k": ck, "v": cv}

    x, new_cache = jax.lax.scan(
        group, x,
        (params["layers"], cache["s"], cache["conv"], cache["k"], cache["v"]),
    )
    hidden = L.rmsnorm(x, params["final_norm"])
    return _last_logits(params, hidden, cfg), new_cache


# ---------------------------------------------------------------------------
# encoder-decoder (whisper)
# ---------------------------------------------------------------------------

def _encdec_specs(cfg: ModelConfig):
    enc_layer = {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": L.attn_specs(cfg),
        "ffn": L.mlp_specs(cfg),
    }
    dec_layer = {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "ln_x": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": L.attn_specs(cfg),
        "xattn": L.attn_specs(cfg),
        "ffn": L.mlp_specs(cfg),
    }
    return {
        **_embed_specs(cfg),
        "enc_layers": _stack_specs_tree(enc_layer, cfg.n_enc_layers),
        "enc_norm": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "dec_layers": _stack_specs_tree(dec_layer, cfg.n_layers),
    }


def _encode(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = frames
    T = x.shape[1]
    positions = jnp.arange(T)[None, :]

    def body(x, lp):
        h = L.attention(lp["attn"], L.rmsnorm(x, lp["ln1"]), cfg, positions,
                        causal=False, use_rope=True)
        x = x + h
        x = x + L.mlp(lp["ffn"], L.rmsnorm(x, lp["ln2"]), cfg)
        return x, None

    bodyf = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(bodyf, x, params["enc_layers"])
    return L.rmsnorm(x, params["enc_norm"])


def _cross_attention(lp, x, memory, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"])
    k = jnp.einsum("btd,dhk->bthk", memory, lp["wk"])
    v = jnp.einsum("btd,dhk->bthk", memory, lp["wv"])
    k = L._expand_kv(k, cfg.n_heads)
    v = L._expand_kv(v, cfg.n_heads)
    o = L.chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    return jnp.einsum("bshk,hkd->bsd", o, lp["wo"])


def _encdec_loss(params, batch, cfg: ModelConfig):
    memory = _encode(params, batch["prefix"].astype(jnp.bfloat16), cfg)
    x = _embed(params, batch["tokens"])
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        h = L.attention(lp["attn"], L.rmsnorm(x, lp["ln1"]), cfg, positions)
        x = x + h
        x = x + _cross_attention(lp["xattn"], L.rmsnorm(x, lp["ln_x"]), memory, cfg)
        x = x + L.mlp(lp["ffn"], L.rmsnorm(x, lp["ln2"]), cfg)
        return x, None

    bodyf = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(bodyf, x, params["dec_layers"])
    hidden = L.rmsnorm(x, params["final_norm"])
    ce = _lm_loss(params, hidden, batch["labels"], cfg)
    return ce, {"ce": ce}


def _encdec_cache_shapes(cfg: ModelConfig, batch: int, max_seq: int):
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    Ld = cfg.n_layers
    return {
        "k": jax.ShapeDtypeStruct((Ld, batch, max_seq, KV, Dh), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((Ld, batch, max_seq, KV, Dh), jnp.bfloat16),
        # cross-attention K/V precomputed from the encoder memory
        "xk": jax.ShapeDtypeStruct((Ld, batch, cfg.n_prefix, KV, Dh), jnp.bfloat16),
        "xv": jax.ShapeDtypeStruct((Ld, batch, cfg.n_prefix, KV, Dh), jnp.bfloat16),
    }


def _encdec_prefill(params, batch, cfg: ModelConfig, max_seq: int):
    memory = _encode(params, batch["prefix"].astype(jnp.bfloat16), cfg)
    x = _embed(params, batch["tokens"])
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        xn = L.rmsnorm(x, lp["ln1"])
        q, k, v = L.qkv_project(lp["attn"], xn, cfg, positions)
        o = L.chunked_attention(
            q, L._expand_kv(k, cfg.n_heads), L._expand_kv(v, cfg.n_heads),
            causal=True, chunk=cfg.attn_chunk)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        x = x + _cross_attention(lp["xattn"], L.rmsnorm(x, lp["ln_x"]), memory, cfg)
        x = x + L.mlp(lp["ffn"], L.rmsnorm(x, lp["ln2"]), cfg)
        pad = max_seq - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        xk = jnp.einsum("btd,dhk->bthk", memory, lp["xattn"]["wk"]).astype(jnp.bfloat16)
        xv = jnp.einsum("btd,dhk->bthk", memory, lp["xattn"]["wv"]).astype(jnp.bfloat16)
        return x, {"k": kc, "v": vc, "xk": xk, "xv": xv}

    bodyf = jax.checkpoint(body) if cfg.remat else body
    x, cache = jax.lax.scan(bodyf, x, params["dec_layers"])
    hidden = L.rmsnorm(x, params["final_norm"])
    return _last_logits(params, hidden, cfg), cache


def _encdec_decode(params, cache, tokens, position, cfg: ModelConfig):
    x = _embed(params, tokens)

    def body(x, inp):
        lp, ck, cv, xk, xv = inp
        xn = L.rmsnorm(x, lp["ln1"])
        o, ck, cv = L.decode_attention(lp["attn"], xn, cfg, ck, cv, position)
        x = x + o
        # cross-attention over the (static) encoder memory
        xq = jnp.einsum("bsd,dhk->bshk", L.rmsnorm(x, lp["ln_x"]), lp["xattn"]["wq"])
        keys = L._expand_kv(xk, cfg.n_heads)
        vals = L._expand_kv(xv, cfg.n_heads)
        s = jnp.einsum("bohk,bthk->bhot", xq, keys) * (cfg.resolved_head_dim ** -0.5)
        w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(vals.dtype)
        xo = jnp.einsum("bhot,bthk->bohk", w, vals)
        x = x + jnp.einsum("bohk,hkd->bod", xo, lp["xattn"]["wo"])
        x = x + L.mlp(lp["ffn"], L.rmsnorm(x, lp["ln2"]), cfg)
        return x, {"k": ck, "v": cv, "xk": xk, "xv": xv}

    x, new_cache = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    hidden = L.rmsnorm(x, params["final_norm"])
    return _last_logits(params, hidden, cfg), new_cache


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        specs = _decoder_specs(cfg)
        return Model(
            cfg, specs,
            loss_fn=functools.partial(_decoder_loss, cfg=cfg),
            prefill_fn=lambda p, b, max_seq: _decoder_prefill(p, b, cfg, max_seq),
            decode_fn=lambda p, c, t, pos: _decoder_decode(p, c, t, pos, cfg),
            init_cache=lambda batch, max_seq: _decoder_cache_shapes(cfg, batch, max_seq),
        )
    if fam == "ssm":
        return Model(
            cfg, _ssm_specs(cfg),
            loss_fn=functools.partial(_ssm_loss, cfg=cfg),
            prefill_fn=lambda p, b, max_seq: _ssm_prefill(p, b, cfg, max_seq),
            decode_fn=lambda p, c, t, pos: _ssm_decode(p, c, t, pos, cfg),
            init_cache=lambda batch, max_seq: _ssm_cache_shapes(cfg, batch, max_seq),
        )
    if fam == "hybrid":
        return Model(
            cfg, _hybrid_specs(cfg),
            loss_fn=functools.partial(_hybrid_loss, cfg=cfg),
            prefill_fn=lambda p, b, max_seq: _hybrid_prefill(p, b, cfg, max_seq),
            decode_fn=lambda p, c, t, pos: _hybrid_decode(p, c, t, pos, cfg),
            init_cache=lambda batch, max_seq: _hybrid_cache_shapes(cfg, batch, max_seq),
        )
    if fam == "encdec":
        return Model(
            cfg, _encdec_specs(cfg),
            loss_fn=functools.partial(_encdec_loss, cfg=cfg),
            prefill_fn=lambda p, b, max_seq: _encdec_prefill(p, b, cfg, max_seq),
            decode_fn=lambda p, c, t, pos: _encdec_decode(p, c, t, pos, cfg),
            init_cache=lambda batch, max_seq: _encdec_cache_shapes(cfg, batch, max_seq),
        )
    raise ValueError(f"unknown family {fam!r}")
