"""Mixture-of-Experts block: top-k routing with capacity-based dispatch.

Sort-based dispatch (no [T, E] one-hot cumsum): slots are ranked within
their expert via argsort + searchsorted, mapped to an [E, C, d] expert
buffer with a scatter, and combined back with the routing weights.  This
keeps HLO FLOPs ~= active-expert FLOPs (dense all-expert einsums would
inflate compiled FLOPs ~E/k-fold — visible in the roofline's
MODEL_FLOPS/HLO_FLOPs ratio).

Experts shard over the `model` mesh axis (EP); the dispatch scatter
becomes an all-to-all under GSPMD.  Tokens beyond an expert's capacity
C = ceil(T*k*cf/E) are dropped (standard dropping MoE) — the router's
residual stream passes through unchanged for them.

Beyond-paper tie-in (DESIGN.md §6.4): the router can apply the paper's
top-k *boundary* trick — experts whose block-max routing logit across the
batch cannot reach the running k-th logit are skipped during analysis;
here it surfaces as the `router_boundary_stats` diagnostic.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .sharding import ParamSpec, constrain


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    if cfg.moe_sharding == "resident":
        # §Perf H1 iter 1: no FSDP dim on expert weights — they shard over
        # (experts x d_ff) = (pod*data x model) and never move.
        e_ax, d_ax, f_ax = "experts_resident", None, "moe_ff"
    elif cfg.moe_sharding == "expert_only":
        # §Perf H1 iter 3: experts over `model` ONLY.  No d-sharding means
        # the grouped-dispatch einsums contract locally — GSPMD neither
        # gathers weights nor all-reduces activation partials.  Per-device
        # expert params = total/TP (kimi: 2.1 GB bf16 — resident is fine).
        e_ax, d_ax, f_ax = "experts", None, None
    else:
        e_ax, d_ax, f_ax = "experts", "embed", None
    return {
        "router": ParamSpec((d, E), ("embed", "experts"), scale=0.01),
        "wg": ParamSpec((E, d, f), (e_ax, d_ax, f_ax)),
        "wu": ParamSpec((E, d, f), (e_ax, d_ax, f_ax)),
        "wd": ParamSpec((E, f, d), (e_ax, f_ax, d_ax)),
    }


def moe_block(
    p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,d], aux load-balancing loss scalar).

    Long sequences are dispatched in chunks along S (scan): the gather/
    scatter working set is O(B * moe_seq_chunk * d) instead of O(B * S * d)
    — at (B=256, S=4096, d=7168) the unchunked buffers are terabytes.
    The expert-weight all-gather is loop-invariant and hoisted by XLA.
    """
    B, S, d = x.shape
    c = cfg.moe_seq_chunk
    if S > c and S % c == 0:
        nc = S // c
        xs = x.reshape(B, nc, c, d).transpose(1, 0, 2, 3)  # [nc, B, c, d]

        def body(aux, xc):
            y, a = _moe_dispatch(p, xc, cfg)
            return aux + a, y

        aux, ys = jax.lax.scan(body, 0.0, xs)
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, d)
        return y, aux / nc
    return _moe_dispatch(p, x, cfg)


def _moe_grouped(
    p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """§Perf H1 (iteration 2): batch-local dispatch.

    Routing, ranking and the dispatch scatter all happen PER BATCH ROW
    (vmapped), so every scatter touches only data resident on the row's
    shard — no cross-device scatter, hence no dense all-reduce.  The
    [B, E, C_b, d] buffer is sharded (batch x experts) and expert FFNs run
    on local (B-shard x E-shard) tiles.  Capacity is per row:
    C_b = ceil(S*k*cf/E).
    """
    B, S, d = x.shape
    k, E = cfg.experts_per_tok, cfg.n_experts
    C = max(int(math.ceil(S * k * cfg.capacity_factor / E)), 1)

    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)                      # [B, S, k]
    w = (w / w.sum(-1, keepdims=True)).astype(x.dtype)

    flat_e = constrain(idx.reshape(B, S * k), "batch", None)
    order = constrain(jnp.argsort(flat_e, axis=1), "batch", None)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)

    # rank within (row, expert) via a cumsum over a one-hot-free compare:
    # rank[i] = i - first-position-of(sorted_e[i]) — searchsorted per row
    def row_rank(se):
        return jnp.arange(S * k) - jnp.searchsorted(se, se, side="left")

    rank = jax.vmap(row_rank)(sorted_e)
    keep = rank < C
    dest = jnp.where(keep, sorted_e * C + rank, E * C)    # [B, S*k]
    dest = constrain(dest, "batch", None)
    tok = order // k

    # every [B, S*k, d] intermediate must stay batch-sharded: without the
    # explicit constraints GSPMD gives up on the vmapped gather/scatter
    # and ALL-GATHERS the full batch (measured: 4 GiB x2 per layer, §Perf
    # H1 iter 4)
    gathered = jnp.take_along_axis(x, tok[..., None], axis=1)
    gathered = gathered * keep[..., None].astype(x.dtype)
    gathered = constrain(gathered, "batch", None, None)

    def row_scatter(g, dst):
        return jnp.zeros((E * C + 1, d), x.dtype).at[dst].set(g)

    buf = jax.vmap(row_scatter)(gathered, dest)           # [B, E*C+1, d]
    buf = constrain(buf, "batch", None, None)
    xe = constrain(buf[:, :-1].reshape(B, E, C, d), "batch", "experts",
                   None, None)

    act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
    h = act(jnp.einsum("becd,edf->becf", xe, p["wg"])) * jnp.einsum(
        "becd,edf->becf", xe, p["wu"])
    h = constrain(h, "batch", "experts", None, None)
    ye = jnp.einsum("becf,efd->becd", h, p["wd"]).reshape(B, E * C, d)
    ye = constrain(ye, "batch", None, None)
    ye = jnp.concatenate([ye, jnp.zeros((B, 1, d), ye.dtype)], axis=1)

    y_sorted = jnp.take_along_axis(ye, dest[..., None], axis=1)
    y_sorted = constrain(y_sorted, "batch", None, None)

    def row_unscatter(ys, o):
        return jnp.zeros((S * k, d), ys.dtype).at[o].set(ys)

    y_slots = constrain(jax.vmap(row_unscatter)(y_sorted, order),
                        "batch", None, None)
    y = (y_slots.reshape(B, S, k, d) * w[..., None]).sum(axis=2)

    me = probs.reshape(B * S, E).mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[flat_e.reshape(-1)].add(1.0) / (B * S * k)
    aux = E * jnp.sum(me * ce)
    return y, aux


def _moe_dispatch(
    p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    if cfg.moe_dispatch == "grouped":
        return _moe_grouped(p, x, cfg)
    B, S, d = x.shape
    T = B * S
    k, E = cfg.experts_per_tok, cfg.n_experts
    xt = x.reshape(T, d)

    logits = jnp.einsum(
        "td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)                      # [T, k]
    w = (w / w.sum(-1, keepdims=True)).astype(x.dtype)

    # --- capacity-based dispatch (sort + rank) ---
    C = max(int(math.ceil(T * k * cfg.capacity_factor / E)), 1)
    flat_e = idx.reshape(-1)                              # [T*k]
    order = jnp.argsort(flat_e)                           # stable
    sorted_e = flat_e[order]
    rank = jnp.arange(T * k) - jnp.searchsorted(sorted_e, sorted_e, side="left")
    keep = rank < C
    dest = jnp.where(keep, sorted_e * C + rank, E * C)    # E*C = trash slot
    tok = order // k

    e_ax = "experts_resident" if cfg.moe_sharding == "resident" else "experts"
    f_ax = "moe_ff" if cfg.moe_sharding == "resident" else None
    gathered = xt[tok] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[dest].set(gathered)
    xe = constrain(buf[:-1].reshape(E, C, d), e_ax, None, None)

    # --- expert FFNs (EP; resident mode adds TP over d_ff) ---
    act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["wu"]
    )
    h = constrain(h, e_ax, None, f_ax)
    ye = jnp.einsum("ecf,efd->ecd", h, p["wd"]).reshape(E * C, d)

    # --- combine ---
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], axis=0)
    y_sorted = ye[dest]                                   # [T*k, d]
    y_slots = jnp.zeros((T * k, d), ye.dtype).at[order].set(y_sorted)
    y = (y_slots.reshape(T, k, d) * w[..., None]).sum(axis=1)

    # Switch-style load-balance aux: E * sum_e mean_prob_e * frac_routed_e
    me = probs.mean(axis=0)                               # [E]
    ce = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux


def router_boundary_stats(logits: jax.Array, k: int, block: int = 256) -> jax.Array:
    """Diagnostic: fraction of router-logit blocks skippable by the paper's
    top-k boundary rule (block max <= running k-th).  Used by benchmarks
    to quantify the Sec. 5 -> MoE transfer; not on the training path."""
    T, E = logits.shape
    nb = T // block
    lb = logits[: nb * block].reshape(nb, block, E)
    bmax = lb.max(axis=1)                                 # [nb, E]
    kth = jax.lax.top_k(logits, k)[0][:, -1]              # [T]
    kth_blocks = kth[: nb * block].reshape(nb, block).max(axis=1)
    return (bmax <= kth_blocks[:, None]).mean()
