"""Model substrate: the 10 assigned architectures as pure-JAX modules."""

from .model import build_model

__all__ = ["build_model"]
