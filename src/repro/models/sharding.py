"""Logical-axis sharding rules (MaxText-style) + the ParamSpec system.

Every parameter is declared as a ``ParamSpec(shape, logical_axes)``;
logical axes are resolved to mesh axes through a rule table, with
*divisibility resolution*: a logical axis whose dimension does not divide
the mesh axis size falls back to replication (e.g. GLM-4's 2 KV heads on
16-way TP).  This keeps every (arch x mesh) combination lowerable without
per-arch special-casing — the property the multi-pod dry-run checks.

Parallelism mapping (DESIGN.md §4):
  batch   -> (pod, data)   data parallelism, hierarchical across pods
  fsdp    -> data           parameter/optimizer sharding (ZeRO-3 style)
  model   -> model          tensor parallelism: heads / mlp / experts / vocab
  kv_seq  -> model           context parallelism for decode KV caches when
                             kv_heads cannot use the model axis
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis name(s) (None = replicated)
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "fsdp": "data",          # weight sharding along the data axis
    "embed": None,           # d_model
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    # 'resident' MoE sharding (§Perf H1): experts over the DP axes, expert
    # d_ff over model — weights stay put, tokens all-to-all to them.
    "experts_resident": ("pod", "data"),
    "moe_ff": "model",
    "ssm_heads": "model",
    "ssm_inner": "model",
    "ssm_state": None,
    "conv": None,
    "seq": None,
    "kv_seq": None,          # flipped to 'model' for context-parallel decode
    "layers": None,          # stacked scan-over-layers axis
    "head_dim": None,
    "prefix": None,
}


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[str, ...]
    init: str = "normal"       # normal | zeros | ones
    scale: float = 0.02
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis if a in mesh.shape]))
    return int(mesh.shape.get(axis, 1))


def resolve_axis(dim: int, axis, mesh: Mesh):
    """Divisibility resolution: replicate when the dim doesn't divide."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        axis = tuple(a for a in axis if a in mesh.shape)
        if not axis:
            return None
        size = mesh_axis_size(mesh, axis)
        if size > 1 and dim % size == 0:
            return axis if len(axis) > 1 else axis[0]
        # try the largest prefix that divides
        for end in range(len(axis) - 1, 0, -1):
            sub = axis[:end]
            if dim % mesh_axis_size(mesh, sub) == 0:
                return sub if len(sub) > 1 else sub[0]
        return None
    if axis not in mesh.shape:
        return None
    size = mesh.shape[axis]
    return axis if (size > 1 and dim % size == 0) else None


def logical_to_pspec(
    logical: Tuple[str, ...],
    shape: Tuple[int, ...],
    mesh: Mesh,
    rules: Optional[Dict[str, Any]] = None,
) -> P:
    rules = {**DEFAULT_RULES, **(rules or {})}
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        axis = resolve_axis(dim, rules.get(name), mesh)
        # a mesh axis may appear only once in a PartitionSpec
        flat = axis if isinstance(axis, tuple) else (axis,) if axis else ()
        if any(a in used for a in flat):
            axis = None
        for a in flat:
            used.add(a)
        out.append(axis)
    return P(*out)


def spec_sharding(spec: ParamSpec, mesh: Mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_pspec(spec.logical, spec.shape, mesh, rules))


def tree_shardings(specs, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda s: spec_sharding(s, mesh, rules),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def tree_abstract(specs):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def init_params(specs, key: jax.Array):
    """Materialize parameters on the current device(s)."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))

    def mk(spec: ParamSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.dtype)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        scale = spec.scale if spec.init == "normal" else 1.0 / math.sqrt(fan_in)
        return (scale * jax.random.normal(k, spec.shape, jnp.float32)).astype(spec.dtype)

    return jax.tree.unflatten(treedef, [mk(s, k) for s, k in zip(leaves, keys)])


_CURRENT_MESH: Optional[Mesh] = None
_CURRENT_RULES: Optional[Dict[str, Any]] = None


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Activation sharding constraint by logical axes.

    No-op when no mesh is active (single-device smoke tests) so model code
    can sprinkle constraints unconditionally.
    """
    mesh = _CURRENT_MESH
    if mesh is None or mesh.size == 1:
        return x
    pspec = logical_to_pspec(
        tuple(l if l is not None else "_replicated" for l in logical),
        x.shape, mesh, _CURRENT_RULES,
    )
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))


class use_mesh:
    """Activate a mesh (+ optional rule overrides) for `constrain`."""

    def __init__(self, mesh: Mesh, rules: Optional[Dict[str, Any]] = None):
        self.mesh = mesh
        self.rules = rules

    def __enter__(self):
        global _CURRENT_MESH, _CURRENT_RULES
        self._prev = (_CURRENT_MESH, _CURRENT_RULES)
        _CURRENT_MESH = self.mesh
        _CURRENT_RULES = self.rules
        return self.mesh

    def __exit__(self, *exc):
        global _CURRENT_MESH, _CURRENT_RULES
        _CURRENT_MESH, _CURRENT_RULES = self._prev
        return False
