"""Transformer building blocks: norms, RoPE, GQA attention, gated MLPs.

Pure functions over parameter dicts (ParamSpec-declared).  Attention is
*chunked* with an online softmax (flash-style, O(S·chunk) memory) so the
32k prefill shapes lower without materializing S x S score tensors; XLA
fuses the inner loop into a streaming reduction on TPU.

Activation sharding uses logical axes via sharding.constrain; batch is
(pod, data)-sharded, heads/mlp over the model axis.  GQA K/V heads that
do not divide the TP degree replicate automatically (sharding.py).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .sharding import ParamSpec, constrain

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms & RoPE
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: [..., S] int32."""
    D = x.shape[-1]
    half = D // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)
    return out


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    specs = {
        "wq": ParamSpec((d, H, Dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, KV, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, KV, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, Dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((H, Dh), ("heads", "head_dim"), init="zeros")
        specs["bk"] = ParamSpec((KV, Dh), ("kv_heads", "head_dim"), init="zeros")
        specs["bv"] = ParamSpec((KV, Dh), ("kv_heads", "head_dim"), init="zeros")
    return specs


def qkv_project(p, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
                use_rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """GQA: repeat KV heads to match query heads."""
    B, S, KV, Dh = k.shape
    rep = n_heads // KV
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def chunked_attention(
    q: jax.Array,           # [B, Sq, H, D]
    k: jax.Array,           # [B, Sk, H, D] (already GQA-expanded)
    v: jax.Array,
    causal: bool,
    chunk: int,
    q_offset: int = 0,
    kv_valid: Optional[jax.Array] = None,   # [B] valid cache length
) -> jax.Array:
    """Flash-style online-softmax attention, scanning KV chunks per Q chunk."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = D ** -0.5
    qc = min(chunk, Sq)
    kc = min(chunk, Sk)
    nq = -(-Sq // qc)
    nk = -(-Sk // kc)
    pad_q = nq * qc - Sq
    pad_k = nk * kc - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    qs = q.reshape(B, nq, qc, H, D).transpose(1, 0, 3, 2, 4)  # [nq, B, H, qc, D]
    ks = k.reshape(B, nk, kc, H, D).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(B, nk, kc, H, D).transpose(1, 0, 3, 2, 4)

    q_pos = q_offset + jnp.arange(nq * qc).reshape(nq, qc)
    k_pos = jnp.arange(nk * kc).reshape(nk, kc)
    k_valid_limit = Sk if kv_valid is None else None

    def q_block(qi_and_block):
        qi, qb, qp = qi_and_block  # qb: [B, H, qc, D]

        def kv_step(carry, inputs):
            m, l, acc = carry
            kb, vb, kp = inputs
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb) * scale
            mask = jnp.ones((qc, kc), dtype=bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            mask &= (kp < Sk)[None, :]          # strip K padding
            if kv_valid is not None:
                mask = mask[None] & (kp[None, None, :] < kv_valid[:, None, None])
                s = jnp.where(mask[:, None], s, NEG_INF)
            else:
                s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        a0 = jnp.zeros((B, H, qc, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, k_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)

    outs = jax.lax.map(q_block, (jnp.arange(nq), qs, q_pos))  # [nq, B, H, qc, D]
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, nq * qc, H, D)
    return out[:, :Sq]


def attention(
    p: Dict[str, jax.Array],
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    causal: bool = True,
    use_rope: bool = True,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> jax.Array:
    """Full attention block (projection + chunked attention + output)."""
    q, k, v = qkv_project(p, x, cfg, positions, use_rope)
    if kv_override is not None:
        k, v = kv_override
    k = _expand_kv(k, cfg.n_heads)
    v = _expand_kv(v, cfg.n_heads)
    o = chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    o = constrain(o, "batch", "seq", "heads", None)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return constrain(out, "batch", "seq", "embed")


def decode_attention(
    p: Dict[str, jax.Array],
    x: jax.Array,                      # [B, 1, d]
    cfg: ModelConfig,
    cache_k: jax.Array,                # [B, S, KV, D]
    cache_v: jax.Array,
    position: jax.Array,               # [B] PER-REQUEST positions
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode against a KV cache.

    Inserts this step's K/V at each request's own ``position`` (a batched
    scatter — continuous batching runs every slot at its own depth; the
    dry-run showed the scatter costs ~10 MB of extra index all-gather vs
    a same-position dynamic-update-slice) and attends over each prefix.
    Returns (out, cache_k, cache_v); callers donate the cache buffers.
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = rope(q, position[:, None], cfg.rope_theta)
    k = rope(k, position[:, None], cfg.rope_theta)
    b_idx = jnp.arange(x.shape[0])
    cache_k = cache_k.at[b_idx, position].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[b_idx, position].set(v[:, 0].astype(cache_v.dtype))
    # GQA-grouped attention WITHOUT expanding K/V to the query heads:
    # expanding a kv_seq-sharded cache forces GSPMD to all-gather it
    # (measured 2 x 896 MiB per layer on kimi decode — §Perf H2 iter 2).
    # Grouped einsums contract against the cache in place; with the seq
    # dim context-parallel over `model`, the softmax and the value
    # contraction reduce over the shards with small psums instead.
    B = x.shape[0]
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    rep = cfg.n_heads // KV
    qg = q[:, 0].reshape(B, KV, rep, Dh)
    S = cache_k.shape[1]
    scale = Dh ** -0.5
    s = jnp.einsum("bgrk,bsgk->bgrs", qg, cache_k) * scale
    mask = jnp.arange(S)[None, None, None, :] <= position[:, None, None, None]
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(cache_v.dtype)
    o = jnp.einsum("bgrs,bsgk->bgrk", w, cache_v)
    wo = p["wo"].reshape(KV, rep, Dh, p["wo"].shape[-1])
    out = jnp.einsum("bgrk,grkd->bd", o, wo)[:, None, :]
    return constrain(out, "batch", None, "embed"), cache_k, cache_v


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "wg": ParamSpec((d, f), ("embed", "mlp")),
        "wu": ParamSpec((d, f), ("embed", "mlp")),
        "wd": ParamSpec((f, d), ("mlp", "embed")),
    }


def mlp(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
    h = act(jnp.einsum("bsd,df->bsf", x, p["wg"])) * jnp.einsum(
        "bsd,df->bsf", x, p["wu"]
    )
    h = constrain(h, "batch", "seq", "mlp")
    return constrain(jnp.einsum("bsf,fd->bsd", h, p["wd"]),
                     "batch", "seq", "embed")
