"""Batched serving: prefill + decode loop with greedy/temperature sampling.

``Generator`` jit-compiles the model's prefill and decode steps once and
drives them from the host: prefill the prompt batch, then step the decode
function with donated caches.  This is the ``serve_step`` the decode_* dry
-run shapes lower, exercised for real by the CPU-scale examples and tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model


@dataclasses.dataclass
class Generator:
    model: Model
    params: object
    max_seq: int = 256

    def __post_init__(self):
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill_fn(p, b, self.max_seq))
        self._decode = jax.jit(
            self.model.decode_fn, donate_argnums=(1,))

    def generate(
        self,
        tokens: np.ndarray,                 # [B, S] prompt
        steps: int,
        temperature: float = 0.0,
        key: Optional[jax.Array] = None,
        prefix: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        batch = {"tokens": jnp.asarray(tokens)}
        if prefix is not None:
            batch["prefix"] = jnp.asarray(prefix)
        logits, cache = self._prefill(self.params, batch)
        B, S = tokens.shape
        pos0 = S + (prefix.shape[1] if prefix is not None
                    and self.model.cfg.family == "vlm" else 0)
        out = []
        tok = self._sample(logits, temperature, key, 0)
        for i in range(steps):
            out.append(np.asarray(tok))
            position = jnp.full((B,), pos0 + i, jnp.int32)
            logits, cache = self._decode(self.params, cache, tok, position)
            tok = self._sample(logits, temperature, key, i + 1)
        return np.concatenate(out, axis=1)

    def _sample(self, logits, temperature, key, i):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1)[:, None]
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(k, logits / temperature)[:, None]
