"""Async serving front-end: admission, micro-batching, latency SLOs.

The batched engine (``PruningService.run_batch``) answers a *batch* of
queries per call; production traffic arrives one query at a time.  This
module is the admission layer between the two — the continuous-batching
shape of LLM serving systems applied to the pruning service:

  * ``submit(query) -> Future`` enqueues one query and returns
    immediately; the caller blocks on the future only when it needs the
    answer.
  * A micro-batcher accumulates pending submissions until **either** a
    deadline fires (``deadline_s`` since the oldest pending submission —
    the latency bound) **or** a size cap fills (``max_batch`` — the
    throughput bound), then dispatches the batch through the existing
    ``run_batch`` degradation ladder on a worker.  Results are therefore
    bit-identical to calling ``run_batch`` directly on the same queries:
    the front-end adds scheduling, never semantics.
  * **Double-buffer plane staging:** while the worker drives batch N's
    launches (which run lock-free on device once their getters return),
    the batcher thread prestages batch N+1's host→device plane deltas
    through ``PruningService.prestage`` — ``pin_scope`` threaded around
    the prefetches so the ``PlaneMemoryManager`` can never evict a plane
    an in-flight launch is consuming (pins are global refcounts; the
    launch scope's own pins are taken on the worker thread).
  * Every response carries queue/stage/launch timestamps, and a
    ``counters["latency"]`` block (keys registered in
    ``COUNTER_REGISTRY`` — CL006) accumulates per-batch p50/p99/max and
    saturation (queue-depth peak, deadline- vs size-fired dispatches),
    surfaced service-lifetime through ``fleet_summary()["latency"]``.

Clock injection (the PR 6 resilience pattern): pass ``clock`` and
``threaded=False`` and the front-end becomes a deterministic state
machine — ``submit`` dispatches inline when the size cap fills,
``poll()`` dispatches when the injected clock passes the deadline,
``flush()`` forces the rest — so tests never sleep and never race.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence

import numpy as np

# Latency sample window for the running p50/p99 (lifetime max is exact).
# Bounded so a long-lived service never grows host memory with traffic.
LATENCY_WINDOW = 4096


@dataclasses.dataclass
class FrontendResponse:
    """One query's answer plus its life-cycle timing.

    ``timestamps`` (clock units, usually ``time.monotonic`` seconds):
      queued      submit() admitted the query
      staged      its planes were prestaged (None: no prefetch overlap)
      dispatched  the micro-batch closed (deadline/size/flush fired)
      launched    the worker entered run_batch
      done        run_batch returned
    """

    rid: int
    report: object                 # core.flow.PruningReport
    cause: str                     # "deadline" | "size" | "flush"
    timestamps: Dict[str, Optional[float]]
    queue_ms: float                # queued -> dispatched
    latency_ms: float              # queued -> done (end to end)
    queue_depth: int               # pending depth observed at submit


@dataclasses.dataclass
class _Submission:
    query: object
    future: Future
    rid: int
    t_submit: float
    queue_depth: int
    staged: bool = False
    t_staged: Optional[float] = None


@dataclasses.dataclass
class _Batch:
    subs: List[_Submission]
    cause: str
    t_close: float


class ServingFrontend:
    """Async admission layer over a ``PruningService``.

    Parameters:
      service     the PruningService every batch dispatches through
      pipeline    forwarded to ``run_batch`` (None: the service builds
                  its own device pipeline — the synchronous default)
      max_batch   size cap Q: a batch dispatches the moment Q queries
                  are pending
      deadline_s  micro-batch deadline T: a batch dispatches at most T
                  after its oldest query was admitted
      clock       injectable monotonic clock (tests pin it; production
                  uses ``time.monotonic``)
      threaded    True: a batcher thread (deadline timing + prestaging)
                  and a worker thread (dispatch) run the loop; False:
                  deterministic inline mode driven by ``submit`` /
                  ``poll`` / ``flush`` under the injected clock
      prefetch    overlap batch N+1's plane staging with batch N's
                  launches (inline mode prestages right before dispatch,
                  which still warms the planes but without overlap)
    """

    def __init__(self, service, pipeline=None, max_batch: int = 8,
                 deadline_s: float = 0.005, clock=None,
                 threaded: bool = True, prefetch: bool = True):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if deadline_s < 0:
            raise ValueError(f"deadline_s must be >= 0, got {deadline_s}")
        self.service = service
        self.pipeline = pipeline
        self.max_batch = int(max_batch)
        self.deadline_s = float(deadline_s)
        self.clock = clock if clock is not None else time.monotonic
        self.threaded = bool(threaded)
        self.prefetch = bool(prefetch)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending: List[_Submission] = []       # guarded by _cv
        self._batches: "collections.deque[_Batch]" = collections.deque()
        self._inflight = 0                          # batches in _execute
        self._closed = False
        self._flush_requested = False
        self._batcher_done = not self.threaded
        self._rid = 0
        self._samples: "collections.deque[float]" = collections.deque(
            maxlen=LATENCY_WINDOW)
        self._threads: List[threading.Thread] = []
        if self.threaded:
            for name, target in (("frontend-batcher", self._batch_loop),
                                 ("frontend-worker", self._work_loop)):
                t = threading.Thread(target=target, name=name, daemon=True)
                t.start()
                self._threads.append(t)

    # -- API ----------------------------------------------------------------

    def submit(self, query) -> Future:
        """Admit one query; resolves to a ``FrontendResponse``."""
        inline: Optional[_Batch] = None
        with self._cv:
            if self._closed:
                raise RuntimeError("frontend is closed")
            sub = _Submission(query, Future(), self._rid, self.clock(),
                              len(self._pending) + 1)
            self._rid += 1
            self._pending.append(sub)
            lat = self.service.latency
            lat["requests"] += 1
            lat["queue_depth_peak"] = max(lat["queue_depth_peak"],
                                          len(self._pending))
            if len(self._pending) >= self.max_batch:
                if self.threaded:
                    self._cv.notify_all()   # batcher closes + dispatches
                else:
                    inline = self._close_locked("size")
            else:
                self._cv.notify_all()       # (re)arm the deadline wait
        if inline is not None:
            self._execute(inline)
        return sub.future

    def poll(self) -> Optional[str]:
        """Inline mode's clock edge: dispatch if the deadline (per the
        injected clock) has passed; returns the firing cause or None.
        Threaded mode never needs it (the batcher thread owns timing)."""
        if self.threaded:
            return None
        with self._cv:
            cause = self._due_locked()
            batch = self._close_locked(cause) if cause else None
        if batch is None:
            return None
        self._execute(batch)
        return batch.cause

    def flush(self) -> int:
        """Force-dispatch everything pending; returns how many queries
        were flushed (0 when nothing was pending)."""
        if not self.threaded:
            with self._cv:
                batches = []
                while self._pending:
                    batches.append(self._close_locked("flush"))
            for b in batches:
                self._execute(b)
            return sum(len(b.subs) for b in batches)
        with self._cv:
            n = len(self._pending)
            self._flush_requested = True
            self._cv.notify_all()
        return n

    def drain(self) -> None:
        """Block until every admitted query has resolved (flushes any
        partial batch rather than waiting out its deadline)."""
        self.flush()
        if not self.threaded:
            return
        with self._cv:
            self._cv.wait_for(lambda: not self._pending
                              and not self._batches and self._inflight == 0)

    def close(self) -> None:
        """Flush, drain, and stop the threads.  Idempotent."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self.threaded:
            for t in self._threads:
                t.join()
            self._threads = []
        else:
            with self._cv:
                batches = []
                while self._pending:
                    batches.append(self._close_locked("flush"))
            for b in batches:
                self._execute(b)

    def __enter__(self) -> "ServingFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- scheduling ---------------------------------------------------------

    def _due_locked(self) -> Optional[str]:
        """What (if anything) should close the current micro-batch now.
        Size beats flush beats deadline: a full batch is dispatched as
        such even when a flush/close raced with the last submit."""
        if not self._pending:
            return None
        if len(self._pending) >= self.max_batch:
            return "size"
        if self._closed or self._flush_requested:
            return "flush"
        if self.clock() - self._pending[0].t_submit >= self.deadline_s:
            return "deadline"
        return None

    def _close_locked(self, cause: str) -> _Batch:
        subs, self._pending = (self._pending[:self.max_batch],
                               self._pending[self.max_batch:])
        if not self._pending:
            self._flush_requested = False
        return _Batch(subs, cause, self.clock())

    def _batch_loop(self) -> None:
        """Batcher thread: owns deadline timing, closes batches, and —
        while the worker runs batch N — prestages the pending (batch
        N+1) submissions' planes outside the condition lock.  This is
        the double-buffer overlap: staging happens on this thread while
        the worker's launches are in flight, and the launch-side
        ``pin_scope`` refcounts keep in-flight planes unevictable."""
        try:
            while True:
                unstaged: List[_Submission] = []
                with self._cv:
                    while True:
                        cause = self._due_locked()
                        if cause is not None:
                            self._batches.append(self._close_locked(cause))
                            self._cv.notify_all()
                            continue
                        if self._closed and not self._pending:
                            return
                        if self.prefetch:
                            unstaged = [s for s in self._pending
                                        if not s.staged]
                            if unstaged:
                                break       # go stage outside the lock
                        timeout = None
                        if self._pending:
                            timeout = max(
                                0.0, self._pending[0].t_submit
                                + self.deadline_s - self.clock())
                        self._cv.wait(timeout)
                # Off-lock staging: getters inside prestage take the
                # cache's own lock; holding our condition lock here
                # would serialize staging against submit/dispatch.
                self.service.prestage([s.query for s in unstaged])
                now = self.clock()
                with self._cv:
                    for s in unstaged:
                        s.staged = True
                        s.t_staged = now
        finally:
            with self._cv:
                self._batcher_done = True
                self._cv.notify_all()

    def _work_loop(self) -> None:
        """Worker thread: dispatch closed batches through run_batch."""
        while True:
            with self._cv:
                self._cv.wait_for(
                    lambda: self._batches or self._batcher_done)
                if not self._batches:
                    if self._batcher_done:
                        return
                    continue
                batch = self._batches.popleft()
                self._inflight += 1
            try:
                self._execute(batch)
            finally:
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()

    # -- dispatch -----------------------------------------------------------

    def _execute(self, batch: _Batch) -> None:
        """Dispatch one micro-batch through the service's ladder.

        Registered in ``LADDER_LAUNCH_SITES`` (CL001): every kernel
        launch below this frame goes through ``run_batch``, whose stages
        execute exclusively via the service's registered rung builders.
        Resolves every submission's future — with a ``FrontendResponse``
        on success, with the exception if the dispatch itself failed
        (run_batch's own contract makes that an engine bug, not a
        query-shaped problem).
        """
        if self.prefetch and not self.threaded:
            # Inline mode has no staging thread: prestage right before
            # the launch so the getters still hit resident planes.
            self.service.prestage(
                [s.query for s in batch.subs if not s.staged])
            now = self.clock()
            for s in batch.subs:
                if not s.staged:
                    s.staged = True
                    s.t_staged = now
        t_launch = self.clock()
        try:
            reports = self.service.run_batch(
                [s.query for s in batch.subs], self.pipeline)
        except BaseException as exc:  # noqa: BLE001 — futures must resolve
            for s in batch.subs:
                s.future.set_exception(exc)
            raise
        t_done = self.clock()
        lat_ms: List[float] = []
        responses: List[FrontendResponse] = []
        for s, rep in zip(batch.subs, reports):
            ms = (t_done - s.t_submit) * 1e3
            lat_ms.append(ms)
            responses.append(FrontendResponse(
                rid=s.rid, report=rep, cause=batch.cause,
                timestamps=dict(queued=s.t_submit, staged=s.t_staged,
                                dispatched=batch.t_close, launched=t_launch,
                                done=t_done),
                queue_ms=(batch.t_close - s.t_submit) * 1e3,
                latency_ms=ms, queue_depth=s.queue_depth))
        block = self._account(batch, lat_ms)
        for rep in reports:
            # run_batch gave each report its own counters copy; the
            # batch's latency block joins the other per-batch sections
            rep.counters["latency"] = dict(block)
        for s, resp in zip(batch.subs, responses):
            s.future.set_result(resp)

    def _account(self, batch: _Batch, lat_ms: Sequence[float]) -> dict:
        """Fold one batch into the service-lifetime latency block and
        return the per-batch ``counters["latency"]`` section (every key
        declared in ``COUNTER_REGISTRY`` — CL006)."""
        p50, p99 = np.percentile(np.asarray(lat_ms), (50.0, 99.0))
        staged = sum(1 for s in batch.subs if s.t_staged is not None)
        block = dict(requests=len(batch.subs), batches=1,
                     deadline_fired=0, size_fired=0, flush_fired=0,
                     queue_depth_peak=max(s.queue_depth for s in batch.subs),
                     prefetches=staged,
                     p50_ms=float(p50), p99_ms=float(p99),
                     max_ms=float(max(lat_ms)))
        block[batch.cause + "_fired"] = 1
        with self._lock:
            lat = self.service.latency
            lat["batches"] += 1
            lat[batch.cause + "_fired"] += 1
            lat["prefetches"] += staged
            self._samples.extend(lat_ms)
            window = np.asarray(self._samples)
            w50, w99 = np.percentile(window, (50.0, 99.0))
            lat["p50_ms"] = float(w50)
            lat["p99_ms"] = float(w99)
            lat["max_ms"] = max(lat["max_ms"], float(max(lat_ms)))
        return block
