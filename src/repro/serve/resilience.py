"""Resilience layer: fail prune-less, never wrong, never crash the caller.

Pruning has a property the rest of the stack lacks: a *safe degraded
answer always exists*.  Keeping a partition is always correct (the scan
just reads more), and every cheaper prover — unsharded launch, host
kernel, f64 host oracle, finally "keep everything" — only ever
over-approximates the kept set (the same safety argument Extensible Data
Skipping makes for its indexes: skipping metadata may only
over-approximate).  This module turns that property into machinery:

  * ``DegradationLadder`` executes a per-table batched launch through an
    ordered fallback chain (``RUNGS``): sharded tree kernel (group
    pre-pass over the hierarchical plane) -> tree kernel -> sharded flat
    device kernel -> unsharded device kernel -> host kernel fallback
    (``kernels/ops.py``) -> host oracle technique -> no-prune
    passthrough.  Each rung gets a
    bounded number of retries with deterministic exponential backoff
    (injectable clock/sleep so tests never really sleep) and a per-stage
    deadline; every demotion is recorded in the service's
    ``counters["resilience"]`` block.
  * ``BackoffPolicy`` is the retry-delay schedule: exponential with a
    cap and seeded deterministic jitter.
  * ``FaultInjector`` is the chaos seam threaded through staging,
    eviction, getter, and kernel-launch call sites (``fire``/``corrupt``).
    It is **off by default**: every call site guards with
    ``if injector is not None``, so the disabled path costs one attribute
    load — no schedule lookups, no rng draws.

Counters contract (attached per batch as ``counters["resilience"]``):

    retries         failed attempts that were retried on the same rung
    deadline_hits   rung abandonments forced by the per-stage deadline
    passthroughs    launches that degraded all the way to no-prune
    errors          malformed query specs isolated to a passthrough
    salvaged_batches  whole-batch guard trips (per-query host salvage)
    demotions       {rung: times the ladder demoted INTO that rung}
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from ..core.device_stats import PlaneIntegrityError  # noqa: F401  re-export

# The ordered fallback chain.  A launch enters at the highest rung its
# configuration supports (the verdict rung only when the service's
# verdict cache is enabled, tree rungs only when the table is large
# enough to carry a resident group plane, sharded only when the service
# has a mesh) and only ever moves down; the bottom rung keeps every live
# partition as PARTIAL — a superset of any correct answer, never FULL
# (so LIMIT / the top-k boundary initializers cannot trust uncertified
# rows).  The ``verdict`` top rung serves device-resident cached verdict
# rows (batch hits launch nothing); a verdict-plane fault (integrity
# error) demotes to the ordinary kernel chain — cache-off is a demotion,
# never a wrong answer.  The tree rungs run the hierarchical group
# pre-pass over the ``[C, G]`` tree plane before touching leaves; a
# tree-plane fault (integrity error, staging failure) demotes to the
# flat device rungs, which never consult the tree family.
RUNGS = ("verdict", "sharded_tree", "tree", "sharded", "device",
         "host_kernel", "host_oracle", "passthrough")

# Single registry of every counter key the serving layer may write —
# dict keys of the resilience / integrity counter stores, report-section
# names assembled by PruningService.run_batch, and the per-technique
# attribution families passed to ServiceCounters.bump().  The contract
# linter (tools/contract_lint, rule CL006) rejects any counter write
# whose key is not declared here, so a new counter cannot ship in a
# shape fleet_summary() silently drops.
COUNTER_REGISTRY = frozenset({
    # resilience counters (new_resilience_counters / DegradationLadder)
    "retries", "deadline_hits", "passthroughs", "errors",
    "salvaged_batches", "demotions",
    # verdict-cache counters: batch hit/miss per unique canonical
    # predicate (new_resilience_counters), within-batch duplicate
    # launches saved (verdict_deduped), append-repair patches applied by
    # the plane getter (core.device_stats integrity store)
    "verdict_hits", "verdict_misses", "verdict_deduped", "verdict_repairs",
    # plane-integrity counters (core.device_stats.DeviceStatsCache)
    "verifications", "checksum_failures", "quarantines",
    # per-technique attribution (ServiceCounters.bump / .technique)
    "filter", "join", "join_bloom", "topk", "launches", "fallbacks",
    # report sections attached to each batch (PruningService.run_batch)
    "technique", "staging", "memory", "resilience", "integrity", "planes",
    # latency/SLO counters (new_latency_counters; serve.frontend attaches
    # the per-batch block as counters["latency"] and the service exposes
    # the lifetime block through fleet_summary()["latency"])
    "latency", "requests", "batches", "deadline_fired", "size_fired",
    "flush_fired", "queue_depth_peak", "prefetches",
    "p50_ms", "p99_ms", "max_ms",
})


def new_resilience_counters() -> dict:
    return dict(retries=0, deadline_hits=0, passthroughs=0, errors=0,
                salvaged_batches=0, verdict_hits=0, verdict_misses=0,
                verdict_deduped=0,
                demotions={r: 0 for r in RUNGS[1:]})


def new_latency_counters() -> dict:
    """The serving front-end's latency/saturation family (CL006: every
    key here is declared in COUNTER_REGISTRY).

    requests / batches      admitted submissions and dispatched batches
    deadline_fired /        what closed each batch: the deadline timer,
    size_fired /            the size cap, or an explicit flush/drain
    flush_fired
    queue_depth_peak        deepest pending queue observed at any submit
    prefetches              staging prefetches overlapped with launches
    p50_ms / p99_ms /       end-to-end latency percentiles over the
    max_ms                  retained sample window (max is lifetime-true)
    """
    return dict(requests=0, batches=0, deadline_fired=0, size_fired=0,
                flush_fired=0, queue_depth_peak=0, prefetches=0,
                p50_ms=0.0, p99_ms=0.0, max_ms=0.0)


def resilience_snapshot(c: dict) -> dict:
    out = {k: v for k, v in c.items() if k != "demotions"}
    out["demotions"] = dict(c["demotions"])
    return out


def resilience_delta(before: dict, after: dict) -> dict:
    out = {k: after[k] - before[k] for k in after if k != "demotions"}
    out["demotions"] = {r: after["demotions"][r] - before["demotions"].get(r, 0)
                        for r in after["demotions"]}
    return out


@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """Deterministic exponential backoff: delay(i) = base * mult**i,
    capped at ``max_delay``; ``jitter`` adds a seeded-rng fraction of the
    delay (deterministic under a fixed ladder seed).  ``retries`` is the
    number of *re*-attempts per rung (0 = one attempt, no retry)."""

    retries: int = 1
    base_delay: float = 0.001
    multiplier: float = 2.0
    max_delay: float = 0.25
    jitter: float = 0.0

    def delay(self, attempt: int, rng: random.Random) -> float:
        d = min(self.base_delay * self.multiplier ** attempt, self.max_delay)
        if self.jitter:
            d *= 1.0 + self.jitter * rng.random()
        return min(d, self.max_delay)


class FaultInjector:
    """Seeded, scheduled fault injection at named call sites.

    Rules are registered with ``add(site, ...)`` and match a fired site
    by exact name or prefix (``"launch.filter"`` matches
    ``"launch.filter:sharded"``).  Sites follow the convention
    ``stage.<family>`` / ``get.<family>`` / ``evict`` /
    ``launch.<technique>:<rung>``.

    Kinds:
      * ``error``   — ``fire(site)`` raises ``exc`` (default
        ``InjectedFault``);
      * ``delay``   — ``fire(site)`` calls the injector's ``sleep``
        (injectable; pair with a fake clock so suites never really
        sleep);
      * ``corrupt`` — ``corrupt(site, arrays)`` flips one element per
        array (a torn plane), leaving the stamped checksum stale so the
        integrity verifier must catch it.

    Scheduling per rule: skip the first ``after`` matching firings, then
    fire for ``times`` firings (None = forever), each gated by ``prob``
    under the injector's seeded rng — a fixed seed replays the same
    schedule.  ``log`` records every firing as ``(site, kind)``.
    """

    def __init__(self, seed: int = 0, sleep: Callable[[float], None] = None):
        self._rules: list = []
        self._rng = random.Random(seed)
        self._sleep = sleep if sleep is not None else time.sleep
        self.log: list = []

    def add(self, site: str, kind: str = "error", prob: float = 1.0,
            times: Optional[int] = None, after: int = 0,
            delay: float = 0.0, exc: Optional[BaseException] = None
            ) -> "FaultInjector":
        if kind not in ("error", "delay", "corrupt"):
            raise ValueError(f"unknown fault kind {kind!r}")
        self._rules.append(dict(site=site, kind=kind, prob=prob, times=times,
                                after=after, delay=delay, exc=exc, seen=0,
                                fired=0))
        return self

    def clear(self) -> "FaultInjector":
        """Drop every rule (the log survives) — wave-style chaos runs."""
        self._rules.clear()
        return self

    def _match(self, site: str, kinds: Tuple[str, ...]):
        for r in self._rules:
            if r["kind"] not in kinds:
                continue
            if not (site == r["site"] or site.startswith(r["site"])):
                continue
            r["seen"] += 1
            if r["seen"] <= r["after"]:
                continue
            if r["times"] is not None and r["fired"] >= r["times"]:
                continue
            if r["prob"] < 1.0 and self._rng.random() >= r["prob"]:
                continue
            r["fired"] += 1
            return r
        return None

    def fire(self, site: str) -> None:
        """Raise / delay if a rule matches this site (error+delay kinds)."""
        r = self._match(site, ("error", "delay"))
        if r is None:
            return
        self.log.append((site, r["kind"]))
        if r["kind"] == "delay":
            self._sleep(r["delay"])
            return
        exc = r["exc"]
        raise exc if exc is not None else InjectedFault(site)

    def corrupt(self, site: str, arrays: Sequence) -> Tuple:
        """Return ``arrays`` with one element flipped per array when a
        corrupt rule matches; the unmodified tuple otherwise.  Works on
        host numpy or device arrays (round-trips through numpy)."""
        r = self._match(site, ("corrupt",))
        if r is None:
            return tuple(arrays)
        self.log.append((site, "corrupt"))
        out = []
        for a in arrays:
            h = np.array(np.asarray(a), copy=True)
            if h.size:
                flat = h.reshape(-1)
                idx = self._rng.randrange(flat.shape[0])
                v = flat[idx]
                # flip to a value that changes the bytes for any dtype
                flat[idx] = (v + 1) if np.isfinite(v) else 0
            out.append(_like(a, h))
        return tuple(out)


def _like(orig, host: np.ndarray):
    """Rebuild ``host`` in the array flavor of ``orig`` (jax vs numpy)."""
    if isinstance(orig, np.ndarray):
        return host
    import jax.numpy as jnp
    return jnp.asarray(host)


class InjectedFault(RuntimeError):
    """The FaultInjector's default raised fault."""


class DegradationLadder:
    """Execute a launch through the ordered rung chain with bounded
    retry, deterministic backoff, and a per-stage deadline.

    ``execute(rungs)`` takes ``[(rung_name, thunk), ...]`` ordered
    highest first and returns ``(result, rung_name)`` from the first
    thunk that succeeds.  A thunk that raises is retried on the same
    rung up to ``policy.retries`` times (sleeping ``policy.delay``
    between attempts) unless the rung's deadline has expired; then the
    ladder demotes to the next rung, recording the demotion.  The caller
    makes the final rung infallible (host passthrough); if every rung
    raises anyway the last exception propagates — that is a bug in the
    rung list, not a degradation.
    """

    def __init__(self, policy: Optional[BackoffPolicy] = None,
                 deadline_s: Optional[float] = None,
                 clock: Callable[[], float] = None,
                 sleep: Callable[[float], None] = None,
                 seed: int = 0, counters: Optional[dict] = None):
        self.policy = policy if policy is not None else BackoffPolicy()
        self.deadline_s = deadline_s
        self.clock = clock if clock is not None else time.monotonic
        self.sleep = sleep if sleep is not None else time.sleep
        self._rng = random.Random(seed)
        self.counters = (counters if counters is not None
                         else new_resilience_counters())

    def _expired(self, start: float) -> bool:
        return (self.deadline_s is not None
                and self.clock() - start >= self.deadline_s)

    def execute(self, rungs: Sequence[Tuple[str, Callable]]):
        c = self.counters
        last_exc: Optional[BaseException] = None
        for ri, (name, thunk) in enumerate(rungs):
            start = self.clock()
            attempt = 0
            while True:
                try:
                    result = thunk()
                except Exception as exc:      # noqa: BLE001 — the whole point
                    last_exc = exc
                    if attempt >= self.policy.retries or self._expired(start):
                        if self._expired(start):
                            c["deadline_hits"] += 1
                        break                 # demote to the next rung
                    delay = self.policy.delay(attempt, self._rng)
                    if self.deadline_s is not None and \
                            self.clock() - start + delay >= self.deadline_s:
                        # sleeping would blow the stage deadline: demote
                        # now instead of sleeping into it
                        c["deadline_hits"] += 1
                        break
                    c["retries"] += 1
                    self.sleep(delay)
                    attempt += 1
                else:
                    if name == "passthrough":
                        c["passthroughs"] += 1
                    return result, name
            if ri + 1 < len(rungs):
                c["demotions"][rungs[ri + 1][0]] = \
                    c["demotions"].get(rungs[ri + 1][0], 0) + 1
        raise last_exc  # every rung failed: rung list had no safe bottom
