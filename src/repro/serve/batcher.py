"""Continuous batching: slot-based serving with per-request decode depth.

Production serving never waits for a whole batch of equal-length prompts:
requests are admitted into SLOTS as they arrive, every decode step
advances all active slots (each at its own position — the per-request
scatter in layers.decode_attention), and finished slots are recycled
immediately.  This is the vLLM-style scheduling loop at the granularity
this framework models (slot = contiguous KV region; paging within a slot
is an orthogonal extension noted in DESIGN.md).

Host-side control, device-side state: the slot caches live as one batched
pytree (donated through the jitted decode step); prefill inserts a single
request's K/V into its slot with a jitted writer.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray            # prompt
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, model: Model, params, n_slots: int = 4,
                 max_seq: int = 128, eos_id: Optional[int] = None):
        if model.cfg.family in ("ssm", "hybrid", "encdec", "vlm"):
            raise NotImplementedError(
                "slot-insert prefill is implemented for decoder-only LMs")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        cfg = model.cfg
        shapes = model.init_cache(n_slots, max_seq)
        self.cache = {k: jnp.zeros(v.shape, v.dtype) for k, v in shapes.items()}
        self.positions = np.zeros(n_slots, dtype=np.int32)
        self.last_tok = np.zeros(n_slots, dtype=np.int32)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._rid = 0

        self._decode = jax.jit(model.decode_fn, donate_argnums=(1,))
        self._prefill1 = jax.jit(
            lambda p, b: model.prefill_fn(p, b, max_seq))

        def write_slot(cache, kv, slot):
            # kv: per-layer [L, 1, S, KV, D] from a single-request prefill
            return jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_slice_in_dim(
                    c, n.astype(c.dtype), slot, axis=1),
                cache, kv)

        self._write_slot = jax.jit(write_slot, donate_argnums=(0,))

    # -- API ----------------------------------------------------------------

    def submit(self, tokens: np.ndarray, max_new: int = 16) -> int:
        tokens = np.asarray(tokens, np.int32)
        if len(tokens) >= self.max_seq:
            # A slot's KV region holds max_seq positions and decode
            # scatters at positions[slot] onward: admitting a longer
            # prompt would write past the slot's region (and start
            # positions[slot] beyond max_seq).  Rejecting at submit keeps
            # _admit unconditional and the failure visible to the caller.
            raise ValueError(
                f"prompt of {len(tokens)} tokens exceeds slot capacity "
                f"{self.max_seq - 1} (max_seq={self.max_seq}, and decoding "
                f"needs at least one free position)")
        req = Request(self._rid, tokens, max_new)
        self._rid += 1
        self.queue.append(req)
        return req.rid

    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def run(self) -> Dict[int, List[int]]:
        """Drive until queue + slots drain; returns rid -> generated ids."""
        while self.queue or self.active():
            self._admit()
            self._step()
        return {rid: r.out for rid, r in self.finished.items()}

    # -- internals ----------------------------------------------------------

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            # A request can finish at admit time (max_new=1 satisfied by
            # the prefill token, or eos as the first token), leaving this
            # slot free — keep admitting from the queue until the slot is
            # actually occupied or the queue drains.
            while self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                logits, kv = self._prefill1(
                    self.params, {"tokens": jnp.asarray(req.tokens[None, :])})
                tok = int(jnp.argmax(logits[0]))
                req.out.append(tok)
                if len(req.out) >= req.max_new or tok == self.eos_id:
                    # Done conditions hold before any decode step: finish
                    # now, never occupy the slot (an eos-first request
                    # must not keep decoding, and max_new=1 must emit
                    # exactly one token).  The prefilled KV is dropped —
                    # the slot's cache region stays whatever it was.
                    req.done = True
                    self.finished[req.rid] = req
                    continue
                self.cache = self._write_slot(self.cache, kv, slot)
                self.slot_req[slot] = req
                self.positions[slot] = len(req.tokens)
                self.last_tok[slot] = tok

    def _step(self) -> None:
        # Snapshot the occupied slots up front: the decode launch always
        # runs the full [n_slots] batch (fixed device shape), but only
        # slots in this snapshot may be read back — freed slots carry
        # zeroed last_tok/positions and their logits are discarded.
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        toks = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.positions)
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for slot in active:
            req = self.slot_req[slot]
            self.positions[slot] += 1
            tok = int(nxt[slot])
            self.last_tok[slot] = tok
            req.out.append(tok)
            full = self.positions[slot] + 1 >= self.max_seq
            if len(req.out) >= req.max_new or tok == self.eos_id or full:
                req.done = True
                self.finished[req.rid] = req
                self.slot_req[slot] = None
                self.positions[slot] = 0
                # Zero on release: a recycled slot must never observe its
                # predecessor's token (the next occupant overwrites both
                # fields at admit, but stale state should not survive to
                # be read by accident either).
                self.last_tok[slot] = 0
