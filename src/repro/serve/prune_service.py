"""PruningService: the workload-facing engine of the device plane.

A production metadata service (paper Sec. 2) answers pruning questions for
*every* query of a heavy workload, not one query at a time.  This service
accepts a batch of ``core.flow.Query`` objects and drives the pipeline's
full **technique sequence** (filter -> LIMIT -> JOIN -> top-k) over them,
batching every device-eligible stage per table group:

  * **filter** (``prune_batch``): each scan's predicate is lowered to
    conjunctive ranges; lowered scans are grouped by table and evaluated
    by one ``minmax_prune_batched`` launch per group against the resident
    [C, P] planes (non-lowerable predicates fall back to the host
    evaluator, counted, never wrong);
  * **join** (``join_hit_batch`` / ``bloom_hit_batch``): build-side
    summaries stay host-side (they are runtime values), but the probe-side
    matching runs on the resident planes — the distinct-key overlap as one
    ``join_overlap_batched`` launch per (table, key column) group against
    the join-key plane, and the Bloom narrow-range enumeration as one
    ``bloom_probe_batched`` launch per group against the enumeration
    plane (non-integer key domains keep the host matcher, counted per
    technique under ``join_bloom``);
  * **top-k** (``topk_init_batch``): the Sec. 5.4 upfront boundary is
    initialized as the k-th largest value over each query's
    fully-matching partitions' resident block-top-k rows — one
    ``topk_init_batched`` launch per (table, order column, direction)
    group.

Kernel launches per stage are therefore bounded by the number of distinct
tables (groups), not by the number of queries, and ``run_batch`` produces
``PruningReport``s bit-identical to per-query ``PruningPipeline.run`` in
the same mode (the batched launches evaluate exactly the same per-query
math, packed).

``PruningPipeline(filter_mode="device")`` delegates each stage here for
single queries (Q=1 batches share the same resident planes).

Counters: ``ServiceCounters`` tracks launches and host fallbacks both in
aggregate and per technique (``counters.technique``), and ``run_batch``
attaches a snapshot to every report (``PruningReport.counters``) so
benchmarks can attribute speedups per stage.

Fleet scale (PR 5): ``budget_bytes`` puts every resident plane family
under one HBM budget (``core.device_stats.PlaneMemoryManager`` — LRU
eviction, in-flight pinning around each batched launch, hit / miss /
eviction / restage-storm counters in ``counters["memory"]``), and
``shard_mesh`` partition-shards every batched launch over a 1-D device
mesh (``launch.mesh.make_plane_mesh``) so a table's planes can outgrow
one device.  ``run_fleet`` drives a many-table workload — thousands of
tables churning through the budget — and ``fleet_summary`` reports the
budget-sizing view.

DML: mutations made through the Table's own streaming methods
(``append_partitions`` / ``drop_partitions`` / ``rewrite_partitions`` /
``update_column``) log ``TableDelta``s, and the resident planes
*delta-sync* on the next batch — appends stage O(ΔP), drops scatter
sentinels, nothing is invalidated (``notify_append/drop/rewrite`` keep
the ``TableVersion`` bookkeeping aligned).  The legacy ``notify_insert /
notify_delete / notify_update`` path still bumps the version and
invalidates outright, forcing a full restage — never wrong, just the
pre-ingest cost.  Updates are column-scoped either way: the join-key /
enum / block-top-k planes of *other* columns stay resident (see
``DeviceStatsCache``).  Per-batch staging work and the ``PlaneEpoch``
each table's launches ran against are attached to every report
(``counters["staging"]`` / ``counters["planes"]``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import expr as E
from ..core.device_stats import (TREE_MIN_GROUPS, DeviceStatsCache,
                                 PlaneEpoch, PlaneMemoryManager)
from ..core.metadata import (FULL_MATCH, NO_MATCH, PARTIAL_MATCH, ScanSet,
                             live_full_scan, mask_dead_partitions)
from ..core.predicate_cache import TableVersion
from ..core.prune_filter import eval_tv, extract_ranges
from ..core.prune_join import DEFAULT_ENUM_LIMIT, BuildSummary
from ..kernels import ops as kops
from .resilience import (DegradationLadder, new_latency_counters,
                         new_resilience_counters, resilience_delta,
                         resilience_snapshot)

# Registered DegradationLadder launch sites: the only methods allowed to
# call ``kops.*_batched_*`` entrypoints.  Each builds a rung list that is
# executed exclusively through ``self.ladder.execute`` — that is the PR 6
# degradation contract, and the contract linter (tools/contract_lint,
# rule CL001) flags any batched-kernel call outside these methods.  Add
# a method here ONLY if its launches go through the ladder.
LADDER_LAUNCH_SITES = frozenset({
    "PruningService._filter_rungs",
    "PruningService._verdict_group",
    "PruningService.join_hit_batch",
    "PruningService.bloom_hit_batch",
    "PruningService.topk_init_batch",
    # The async front-end's dispatch path (serve/frontend.py): every
    # launch it triggers goes through run_batch, whose stages execute
    # exclusively via the registered rung builders above — registering
    # the dispatch method keeps the reviewed launch-path list complete.
    "ServingFrontend._execute",
})

# Boundary-init k cap: the kernel's rank-selection merge is quadratic in
# (k bucket + KPLANE), so the per-step comparison tensor must stay well
# inside VMEM — at 128 it is [8, 192, 192] (~1.2MB).  Larger k also gains
# little from the plane (each partition contributes at most KPLANE=64
# witnessed rows); such queries keep the host-only init.
TOPK_INIT_MAX_K = 128


@dataclasses.dataclass
class ServiceCounters:
    queries: int = 0
    scans: int = 0
    launches: int = 0          # batched kernel launches, all techniques
    host_fallbacks: int = 0    # host fallbacks, all techniques
    sharded_launches: int = 0  # launches that ran partition-sharded
    tree_launches: int = 0     # launches that ran the hierarchical path
    # per-technique attribution: {'filter': {'launches': n, 'fallbacks': m}}
    technique: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)

    def bump(self, tech: str, launches: int = 0, fallbacks: int = 0,
             sharded: int = 0, tree: int = 0) -> None:
        t = self.technique.setdefault(tech, dict(launches=0, fallbacks=0))
        t["launches"] += launches
        t["fallbacks"] += fallbacks
        self.launches += launches
        self.host_fallbacks += fallbacks
        self.sharded_launches += sharded
        self.tree_launches += tree

    def snapshot(self) -> dict:
        return dict(queries=self.queries, scans=self.scans,
                    launches=self.launches,
                    host_fallbacks=self.host_fallbacks,
                    sharded_launches=self.sharded_launches,
                    tree_launches=self.tree_launches,
                    technique={k: dict(v) for k, v in self.technique.items()})

    @staticmethod
    def delta(before: dict, after: dict) -> dict:
        """after - before of two snapshots: the activity in between."""
        out = {k: after[k] - before[k]
               for k in ("queries", "scans", "launches", "host_fallbacks",
                         "sharded_launches", "tree_launches")}
        zero = dict(launches=0, fallbacks=0)
        out["technique"] = {
            t: {f: v - before["technique"].get(t, zero)[f]
                for f, v in fields.items()}
            for t, fields in after["technique"].items()}
        return out


class PruningService:
    # doorkeeper bound: past this many distinct (table, predicate) keys
    # the seen-set resets rather than grow without bound
    VERDICT_SEEN_CAP = 1 << 17

    def __init__(
        self,
        mode: str = "auto",            # kernel mode: auto|pallas|interpret|ref
        cache: Optional[DeviceStatsCache] = None,
        budget_bytes: Optional[int] = None,  # HBM budget across all resident
                                             # plane families (None: unbounded)
        shard_mesh=None,               # 1-D 'parts' mesh (True: build the
                                       # host plane mesh) — partition-shards
                                       # every batched launch
        fault_injector=None,           # serve.resilience.FaultInjector chaos
                                       # seam (None: zero-overhead disabled)
        backoff=None,                  # resilience.BackoffPolicy for the
                                       # degradation ladder's retries
        deadline_s: Optional[float] = None,  # per-rung deadline (seconds)
        clock=None,                    # injectable monotonic clock (tests)
        sleep=None,                    # injectable sleep (tests: no real
                                       # sleeps under the fake clock)
        integrity_sample: Optional[int] = None,  # cache checksum-verify
                                       # schedule: every n-th read (1 =
                                       # every read; None keeps the
                                       # cache's default)
        tree_fanout: Optional[int] = None,  # hierarchical-plane group size
                                       # (None keeps the cache's default;
                                       # tests shrink it so small tables
                                       # exercise the tree rungs)
        verdict_cache: bool = True,    # device-resident verdict plane:
                                       # dedupe canonical predicates per
                                       # batch and serve repeats without
                                       # a launch (False: PR 8 behavior)
    ):
        self.mode = mode
        if cache is None:
            cache = DeviceStatsCache(
                budget_bytes=budget_bytes, fault_injector=fault_injector,
                **({} if integrity_sample is None
                   else dict(integrity_sample=integrity_sample)),
                **({} if tree_fanout is None
                   else dict(tree_fanout=tree_fanout)))
        elif tree_fanout is not None and cache.tree_fanout != tree_fanout:
            # safe on a shared cache: the tree getter's geometry check
            # rebuilds any entry staged under the old fanout
            cache.tree_fanout = int(tree_fanout)
        else:
            # adopt the chaos/integrity configuration onto a shared cache
            # only where it has none of its own (mirrors the budget rule)
            if fault_injector is not None and cache.fault_injector is None:
                cache.fault_injector = fault_injector
            if integrity_sample is not None:
                cache.integrity_sample = int(integrity_sample)
            if budget_bytes is not None:
                # A shared cache's budget belongs to whoever set it: only
                # adopt ours when none is configured — silently
                # re-budgeting a cache other services share would evict
                # planes they sized their budget for.
                if cache.memory.budget_bytes is None:
                    cache.memory.budget_bytes = budget_bytes
                elif cache.memory.budget_bytes != budget_bytes:
                    raise ValueError(
                        f"cache already budgeted at "
                        f"{cache.memory.budget_bytes} bytes; refusing to "
                        f"re-budget to {budget_bytes}")
        self.cache = cache
        if shard_mesh is True:
            from ..launch.mesh import make_plane_mesh
            shard_mesh = make_plane_mesh()
        self.shard_mesh = shard_mesh
        self.versions: Dict[str, TableVersion] = {}
        self.counters = ServiceCounters()
        # The resilience layer: every batched launch executes through the
        # degradation ladder (verdict -> sharded tree -> tree -> sharded
        # -> device -> host kernel -> host oracle -> passthrough; the
        # verdict rung only with the verdict cache enabled, tree rungs
        # only for tables large enough to carry a resident group plane),
        # so a kernel failure, a torn plane, or a
        # deadline costs pruning quality, never correctness and never an
        # exception out of run_batch.  The counters dict is shared with
        # the ladder so demotions/retries surface per batch under
        # ``PruningReport.counters["resilience"]``.
        self.fault_injector = (fault_injector if fault_injector is not None
                               else cache.fault_injector)
        self.verdict_cache = bool(verdict_cache)
        # doorkeeper for seen-once verdict admission (_verdict_group)
        self._verdict_seen: set = set()
        # (stats uid, pred repr) pairs that validated clean (_validate_query)
        self._validated: set = set()
        self.resilience = new_resilience_counters()
        # Service-lifetime latency/SLO block, written by the async
        # front-end (serve.frontend.ServingFrontend) and surfaced through
        # fleet_summary()["latency"]; stays all-zero for synchronous use.
        self.latency = new_latency_counters()
        self.ladder = DegradationLadder(
            policy=backoff, deadline_s=deadline_s, clock=clock, sleep=sleep,
            counters=self.resilience)

    def _fire(self, site: str) -> None:
        if self.fault_injector is not None:
            self.fault_injector.fire(site)

    @staticmethod
    def _sharded() -> int:
        """1 when the launch that just returned actually ran sharded
        (the kernel wrappers can demote a mesh-eligible launch back to
        unsharded when the jnp-oracle footprint exceeds the slab
        bound — the counter reports what ran, not eligibility)."""
        return 1 if kops.last_launch_shards() > 1 else 0

    # -- DML bookkeeping ----------------------------------------------------

    def register(self, table) -> TableVersion:
        tv = self.versions.get(table.name)
        if tv is None:
            tv = TableVersion(table.num_partitions)
            self.versions[table.name] = tv
        return tv

    def notify_insert(self, table_name: str, n_partitions: int) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.insert_partitions(n_partitions)
        self.cache.on_insert(table_name)

    def notify_delete(self, table_name: str) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.version += 1
        self.cache.on_delete(table_name)

    def notify_update(self, table_name: str, column: str) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.version += 1
        self.cache.on_update(table_name, column)

    # -- streaming DML (delta-staged; planes stay resident) ----------------
    # Use these when the mutation went through the Table's own DML methods
    # (append_partitions / drop_partitions / rewrite_partitions /
    # update_column): the table's delta log lets the cache sync resident
    # planes in place, so unlike notify_insert/delete/update nothing is
    # invalidated here — only the TableVersion bookkeeping advances.

    def notify_append(self, table_name: str, n_partitions: int) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.insert_partitions(n_partitions)

    def notify_drop(self, table_name: str) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.version += 1

    def notify_rewrite(self, table_name: str) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.version += 1

    def plane_epoch(self, table) -> Optional[PlaneEpoch]:
        """(version, live count, capacity) of the table's resident plane."""
        return self.cache.plane_epoch(table)

    def prestage(self, queries: Sequence) -> int:
        """Prefetch the stat planes a batch of queries will consume —
        the front-end's double-buffer seam: while batch N's launches run
        on the worker, the batcher thread prestages batch N+1's deltas
        so its getters hit resident planes.

        Threads ``pin_scope`` around the prefetches so the
        ``PlaneMemoryManager`` cannot evict a plane this very call just
        staged while admitting the next table under the budget (launch
        scopes re-pin at launch time; pins are refcounts, so a
        concurrent in-flight launch is never evicted either).  Advisory
        and never raises; returns the number of planes that actually
        staged bytes (also counted in ``staging_snapshot()``'s
        ``prefetch_stages``).
        """
        staged = 0
        seen: set = set()
        with self.cache.pin_scope():
            for q in queries:
                for spec in q.scans.values():
                    tkey = id(spec.table)
                    if tkey in seen:
                        continue
                    seen.add(tkey)
                    if self.cache.prefetch(spec.table,
                                           self.versions.get(spec.table.name)):
                        staged += 1
        return staged

    # -- filter stage -------------------------------------------------------

    @staticmethod
    def _scan_set(tv: np.ndarray, table=None) -> ScanSet:
        if table is not None:
            tv = mask_dead_partitions(tv, table)
        keep = tv > NO_MATCH
        return ScanSet(np.where(keep)[0], tv[keep])

    @staticmethod
    def _passthrough_set(table) -> ScanSet:
        """The ladder's bottom rung: keep every live partition, PARTIAL.

        Never FULL — an uncertified partition declared FULL would let the
        LIMIT cutter and the top-k boundary initializers trust rows the
        predicate was never checked against (the same demotion
        ``flow._prune_scan`` applies with the filter stage disabled)."""
        ss = live_full_scan(table)
        return ScanSet(ss.part_ids,
                       np.full(len(ss), PARTIAL_MATCH, dtype=np.int8))

    def _tree_eligible(self, table) -> bool:
        """Should this table's launches enter at the tree rungs?

        Below ``tree_fanout * TREE_MIN_GROUPS`` partitions the flat
        launch always wins (and small-table suites keep their byte-exact
        staging accounting: no tree plane is ever staged for them)."""
        return (table.stats.num_partitions
                >= self.cache.tree_fanout * TREE_MIN_GROUPS)

    def _device_rungs(self, tech: str, launch_fn, table=None) -> list:
        """The device rungs of a ladder chain: tree rungs first when the
        table is large enough to carry a resident group plane (sharded
        tree only with a mesh), then the flat sharded/unsharded rungs.
        ``launch_fn(mesh, rung_site, tree=False)`` builds the thunk; a
        tree-plane fault (staging failure, torn plane) demotes to the
        flat rungs, which never consult the tree family."""
        rungs = []
        if table is not None and self._tree_eligible(table):
            if self.shard_mesh is not None:
                rungs.append(("sharded_tree", launch_fn(
                    self.shard_mesh, f"launch.{tech}:sharded_tree", True)))
            rungs.append(("tree",
                          launch_fn(None, f"launch.{tech}:tree", True)))
        if self.shard_mesh is not None:
            rungs.append(("sharded",
                          launch_fn(self.shard_mesh, f"launch.{tech}:sharded")))
        rungs.append(("device", launch_fn(None, f"launch.{tech}:device")))
        return rungs

    def _filter_rungs(self, table, range_lists, preds) -> list:
        """The filter stage's full rung chain for one table group.

        Every rung returns the same contract: tv ``[Q, P]`` int8 rows
        (None from the passthrough rung — the caller keeps every live
        partition as PARTIAL).  The tree rungs run the hierarchical
        group pre-pass (bit-identical by the hull argument in
        ``kops.prune_ranges_batched_tree``); the host kernel is exact
        f64 over the same lowered ranges; the host oracle re-evaluates
        each predicate tree — both bit-identical to ``eval_tv`` for
        lowerable predicates, so stopping at any rung costs latency,
        not pruning quality.
        """
        def launch(mesh, site, tree=False):
            def thunk():
                self._fire(site)
                # Pin scope: the planes this launch gathers from must not
                # be evicted (by another table's staging under the
                # budget) while the launch is in flight.
                with self.cache.pin_scope():
                    dstats = self.cache.get(table,
                                            self.versions.get(table.name))
                    if tree:
                        te = self.cache.tree_plane(table, dstats)
                        tv = kops.prune_ranges_batched_tree(
                            range_lists, dstats, te, self.mode, mesh=mesh)
                    else:
                        tv = kops.prune_ranges_batched_device(
                            range_lists, dstats, self.mode, mesh=mesh)
                    self.counters.bump("filter", launches=1,
                                       sharded=self._sharded(),
                                       tree=1 if tree else 0)
                return tv
            return thunk

        def host_kernel():
            self._fire("launch.filter:host_kernel")
            tv = kops.prune_ranges_batched_host(range_lists, table.stats)
            self.counters.bump("filter", fallbacks=1)
            return tv

        def host_oracle():
            self._fire("launch.filter:host_oracle")
            tv = np.stack([np.asarray(eval_tv(pred, table.stats),
                                      dtype=np.int8) for pred in preds])
            self.counters.bump("filter", fallbacks=1)
            return tv

        return self._device_rungs("filter", launch, table=table) + [
            ("host_kernel", host_kernel),
            ("host_oracle", host_oracle),
            ("passthrough", lambda: None),
        ]

    def scan_tv(self, spec) -> Optional[np.ndarray]:
        """Device tv [P] for one scan, or None when it doesn't lower (or
        when the ladder degraded past the host kernel — the caller's own
        host evaluator takes over either way).

        The single-query fast path of the batched plane: resident stats,
        Q padded to one sublane tile.  ``PruningPipeline`` calls this for
        ``filter_mode="device"``.  Counts scans/launches/fallbacks like
        prune_batch (``queries`` is only tracked by the batch API, which
        knows query boundaries).
        """
        self.counters.scans += 1
        ranges = extract_ranges(spec.pred, spec.table.stats)
        if ranges is None:
            self.counters.bump("filter", fallbacks=1)
            return None
        # device rungs + host kernel; the terminal rung hands back None
        # so flow's _prune_scan runs its own eval_tv host path
        rungs = self._filter_rungs(spec.table, [ranges], [spec.pred])[:-2]
        rungs.append(("host_oracle", lambda: None))
        tv_rows, _rung = self.ladder.execute(rungs)
        if tv_rows is None:
            self.counters.bump("filter", fallbacks=1)
            return None
        return tv_rows[0]

    def _verdict_group(self, table, jobs) -> list:
        """One table group's filter verdicts through the verdict cache.

        Jobs are deduped by canonical predicate key *before any launch*
        (``verdict_deduped`` counts the saved duplicates), then the
        unique predicates execute through the ladder with the ``verdict``
        rung on top: serve resident verdict rows (a full-hit batch never
        touches a kernel), launch only the missing predicates through
        the ordinary ``_filter_rungs`` chain, and record the fresh
        verdicts.  A verdict-plane integrity failure fails the rung and
        the ladder demotes to the flat chain — cache-off is a demotion,
        never a wrong answer.  Returns one ``[P]`` int8 row (or None for
        passthrough) per job, duplicates fanned back out.

        Admission is seen-once (a doorkeeper, as in TinyLFU): a
        predicate earns a resident verdict row only on its *second*
        sighting — in-batch repetition counts, so zipf/dashboard traffic
        is admitted on its first batch, while one-shot exploratory
        predicates never pay the record cost (HBM + checksum stamp) on
        top of their launch.
        """
        ckeys = [E.canonical_key(pred) for _, _, _, pred in jobs]
        uniq: Dict[str, int] = {}
        counts: Dict[str, int] = {}
        u_ranges: list = []
        u_preds: list = []
        for (_, _, ranges, pred), ck in zip(jobs, ckeys):
            counts[ck] = counts.get(ck, 0) + 1
            if ck not in uniq:
                uniq[ck] = len(u_preds)
                u_ranges.append(ranges)
                u_preds.append(pred)
        self.resilience["verdict_deduped"] += len(jobs) - len(u_preds)
        u_keys = list(uniq)
        admit = [counts[ck] > 1 or (table.name, ck) in self._verdict_seen
                 for ck in u_keys]
        if len(self._verdict_seen) > self.VERDICT_SEEN_CAP:
            self._verdict_seen.clear()      # doorkeeper reset, TinyLFU-style
        self._verdict_seen.update((table.name, ck) for ck in u_keys)

        def verdict_rung():
            rows: list = [None] * len(u_keys)
            miss: list = []
            # Pin scope: served verdict rows stay resident while the
            # misses' launch consumes the stat planes.
            with self.cache.pin_scope():
                for i, (ck, pred) in enumerate(zip(u_keys, u_preds)):
                    row = self.cache.verdict_plane(table, pred, ck)
                    if row is None:
                        miss.append(i)
                    else:
                        rows[i] = row
                self.resilience["verdict_hits"] += len(u_keys) - len(miss)
                self.resilience["verdict_misses"] += len(miss)
                if miss:
                    tv_rows, rung = self.ladder.execute(self._filter_rungs(
                        table, [u_ranges[i] for i in miss],
                        [u_preds[i] for i in miss]))
                    if tv_rows is not None:
                        for mi, tv in zip(miss, tv_rows):
                            row = np.asarray(tv, dtype=np.int8)
                            rows[mi] = row
                            if rung != "passthrough" and admit[mi]:
                                self.cache.verdict_record(
                                    table, u_preds[mi], u_keys[mi], row)
            return rows

        u_rows, _rung = self.ladder.execute(
            [("verdict", verdict_rung)]
            + self._filter_rungs(table, u_ranges, u_preds))
        u_rows = ([None] * len(u_keys) if u_rows is None else list(u_rows))
        return [u_rows[uniq[ck]] for ck in ckeys]

    def prune_batch(self, queries: Sequence) -> List[Dict[str, ScanSet]]:
        """Filter-prune a batch of queries; per-query scan_name -> ScanSet.

        One batched kernel launch per distinct table (not per query),
        executed through the degradation ladder; queries whose predicates
        don't lower are evaluated on the host, and a scan whose every
        prover failed (malformed spec slipping past validation) degrades
        to a keep-everything PARTIAL set — counted, never raised.
        """
        self.counters.queries += len(queries)
        results: List[Dict[str, ScanSet]] = [dict() for _ in queries]
        # id(table) -> (table, [(query idx, scan name, ranges, pred), ...])
        groups: Dict[int, Tuple[object, list]] = {}
        fallbacks: List[Tuple[int, str, object]] = []
        for qi, q in enumerate(queries):
            for name, spec in q.scans.items():
                self.counters.scans += 1
                if isinstance(spec.pred, E.TruePred):
                    results[qi][name] = live_full_scan(spec.table)
                    continue
                try:
                    ranges = extract_ranges(spec.pred, spec.table.stats)
                except Exception:
                    # malformed spec (unknown column / bad literal):
                    # isolate to this scan, keep the batch on course
                    self.resilience["errors"] += 1
                    results[qi][name] = self._passthrough_set(spec.table)
                    continue
                if ranges is None:
                    fallbacks.append((qi, name, spec))
                    continue
                groups.setdefault(id(spec.table), (spec.table, []))[1].append(
                    (qi, name, ranges, spec.pred))
        for table, jobs in groups.values():
            if self.verdict_cache:
                rows = self._verdict_group(table, jobs)
            else:
                tv_rows, _rung = self.ladder.execute(self._filter_rungs(
                    table, [ranges for _, _, ranges, _ in jobs],
                    [pred for _, _, _, pred in jobs]))
                rows = ([None] * len(jobs) if tv_rows is None
                        else list(tv_rows))
            # deduped jobs share one tv row OBJECT: materialize the O(P)
            # scan set once per unique row, give each query its own
            # ScanSet over the shared (read-only) arrays
            memo: Dict[int, ScanSet] = {}
            for (qi, name, _ranges, _pred), tv in zip(jobs, rows):
                if tv is None:
                    results[qi][name] = self._passthrough_set(table)
                    continue
                ss = memo.get(id(tv))
                if ss is None:
                    memo[id(tv)] = ss = self._scan_set(tv, table)
                results[qi][name] = ScanSet(ss.part_ids, ss.match)
        for qi, name, spec in fallbacks:
            self.counters.bump("filter", fallbacks=1)
            try:
                tv = eval_tv(spec.pred, spec.table.stats)
            except Exception:
                self.resilience["errors"] += 1
                results[qi][name] = self._passthrough_set(spec.table)
                continue
            results[qi][name] = self._scan_set(tv, spec.table)
        return results

    # -- join stage ---------------------------------------------------------

    def join_device_eligible(self, summary: BuildSummary, table=None,
                             key_col: Optional[str] = None) -> bool:
        """Can this summary's probe-side matching run on the device plane?

        Distinct summaries need their keys finite in f32 (join-key plane
        overlap).  Bloom summaries need the probe table/key column: the
        kernel's narrow-range enumeration hashes *int32* candidates with
        the shared murmur mixer, so the key column must be an
        integer/dictionary domain wholly inside int32 — fractional or
        out-of-range keys keep the host matcher so batched output stays
        bit-identical to it — and the filter must fit the kernel's block
        cap (``kops.BLOOM_MAX_BLOCKS``).  The int32-domain check is the
        cached ``domain_ok`` of the enumeration plane — table-version
        invariant, so eligibility never rescans [P] stats per query.
        Empty summaries are host short-circuits, not kernel work.
        """
        if summary.empty:
            return False
        if summary.distinct is not None:
            d32 = np.asarray(summary.distinct,
                             dtype=np.float64).astype(np.float32)
            return bool(np.isfinite(d32).all())
        if summary.bloom is None or table is None or key_col is None:
            return False
        if summary.bloom.n_blocks > kops.BLOOM_MAX_BLOCKS:
            return False
        if table.stats.column(key_col).kind == "float":
            return False
        return self.cache.enum_plane(table, key_col)[3]

    def join_hit_batch(self, table, key_col: str,
                       summaries: Sequence[BuildSummary],
                       part_ids: Optional[Sequence[np.ndarray]] = None
                       ) -> Optional[np.ndarray]:
        """hit [G, P] for a (table, key column) group — one launch.

        ``part_ids`` optionally restricts the no-Pallas fallback to each
        query's scan set (entries outside it are 0 and must not be read);
        the kernel path always evaluates the resident plane dense.
        Returns None when the ladder degraded past the device rungs —
        the caller's host matcher is this stage's exact terminal rung
        (``prune_probe`` recomputes the overlap from host truth, so a
        degraded join loses latency, never pruning quality).
        """
        def launch(mesh, site, tree=False):
            def thunk():
                self._fire(site)
                with self.cache.pin_scope():
                    pmin, pmax = self.cache.join_key_plane(table, key_col)
                    dist = [s.distinct for s in summaries]
                    if tree:
                        dstats = self.cache.get(table,
                                                self.versions.get(table.name))
                        te = self.cache.tree_plane(table, dstats)
                        hit = kops.join_overlap_batched_tree(
                            dist, pmin, pmax, te,
                            table.stats.col_id(key_col), self.mode,
                            part_ids_lists=part_ids, mesh=mesh)
                    else:
                        hit = kops.join_overlap_batched_device(
                            dist, pmin, pmax, self.mode,
                            part_ids_lists=part_ids, mesh=mesh)
                    self.counters.bump("join", launches=1,
                                       sharded=self._sharded(),
                                       tree=1 if tree else 0)
                return hit
            return thunk

        def host_oracle():
            self.counters.bump("join", fallbacks=len(summaries))
            return None

        hit, _rung = self.ladder.execute(
            self._device_rungs("join", launch, table=table)
            + [("host_oracle", host_oracle)])
        return hit

    def bloom_hit_batch(self, table, key_col: str,
                        summaries: Sequence[BuildSummary],
                        part_ids: Optional[Sequence[np.ndarray]] = None,
                        enum_limit: int = DEFAULT_ENUM_LIMIT
                        ) -> Optional[np.ndarray]:
        """hit [G, P] for a (table, key column) group of Bloom summaries —
        one batched narrow-range enumeration launch over the resident
        enumeration plane (``part_ids`` restricts the no-Pallas fallback
        to each query's scan set, like ``join_hit_batch``).  None when
        the ladder degraded to the exact host matcher."""
        def launch(mesh, site, tree=False):
            def thunk():
                self._fire(site)
                with self.cache.pin_scope():
                    pmin, width, wmax, _domain_ok = self.cache.enum_plane(
                        table, key_col)
                    blooms = [s.bloom for s in summaries]
                    if tree:
                        dstats = self.cache.get(table,
                                                self.versions.get(table.name))
                        te = self.cache.tree_plane(table, dstats)
                        hit = kops.bloom_probe_batched_tree(
                            blooms, pmin, width, wmax, enum_limit, te,
                            self.mode, part_ids_lists=part_ids, mesh=mesh)
                    else:
                        hit = kops.bloom_probe_batched_device(
                            blooms, pmin, width, wmax, enum_limit,
                            self.mode, part_ids_lists=part_ids, mesh=mesh)
                    self.counters.bump("join_bloom", launches=1,
                                       sharded=self._sharded(),
                                       tree=1 if tree else 0)
                return hit
            return thunk

        def host_oracle():
            self.counters.bump("join_bloom", fallbacks=len(summaries))
            return None

        hit, _rung = self.ladder.execute(
            self._device_rungs("join_bloom", launch, table=table)
            + [("host_oracle", host_oracle)])
        return hit

    def join_hit(self, table, key_col: str, summary: BuildSummary,
                 part_ids: Optional[np.ndarray] = None
                 ) -> Optional[np.ndarray]:
        """hit [P] for one query, or None -> host path (counted per
        technique — ``join`` for distinct, ``join_bloom`` for Bloom —
        unless the summary is empty, which the host handles as a trivial
        wipe)."""
        if not self.join_device_eligible(summary, table, key_col):
            if not summary.empty:
                self.counters.bump(
                    "join_bloom" if summary.bloom is not None else "join",
                    fallbacks=1)
            return None
        pid = None if part_ids is None else [part_ids]
        if summary.distinct is not None:
            hit = self.join_hit_batch(table, key_col, [summary],
                                      part_ids=pid)
        else:
            hit = self.bloom_hit_batch(table, key_col, [summary],
                                       part_ids=pid)
        # None: the ladder degraded to the host matcher terminal rung
        return None if hit is None else hit[0]

    # -- top-k stage --------------------------------------------------------

    def topk_init_batch(self, table, order_col: str, desc: bool,
                        jobs: Sequence[Tuple[ScanSet, int]]) -> List[float]:
        """Per-query upfront boundaries for a (table, column, direction)
        group — one ``topk_init_batched`` launch.

        Each job is ``(scan_set, effective_k)``; the boundary is the k-th
        largest resident block-top-k value over the scan set's
        fully-matching partitions (signed domain), or -inf when fewer
        than k candidates exist.  Launch heaps are sized to the group's
        k bucket; a prefix of a larger heap is the exact smaller-k
        answer, so mixed-k groups share one launch.
        """
        # Jobs whose k is out of the useful range never consult the heap —
        # exclude them up front so they neither widen the group's k bucket
        # (merge cost grows with kb^2) nor force a launch alone.
        live: List[Tuple[int, ScanSet, int]] = []
        any_candidates = False
        for i, (scan, k) in enumerate(jobs):
            if scan.match is None or not (0 < int(k) <= TOPK_INIT_MAX_K):
                continue
            live.append((i, scan, int(k)))
        out = [-np.inf] * len(jobs)
        if not live:
            return out
        P = table.num_partitions
        masks = np.zeros((len(live), P), dtype=np.float32)
        for row, (_i, scan, _k) in enumerate(live):
            full_ids = scan.part_ids[scan.match == FULL_MATCH]
            masks[row, full_ids] = 1.0
            any_candidates |= full_ids.size > 0
        if not any_candidates:
            return out                     # nothing to bound; skip the launch
        kb = kops.k_bucket(max(k for _, _, k in live))

        def launch(mesh, site, tree=False):
            def thunk():
                self._fire(site)
                with self.cache.pin_scope():
                    plane = self.cache.block_topk_plane(table, order_col,
                                                        desc)
                    if tree:
                        dstats = self.cache.get(table,
                                                self.versions.get(table.name))
                        te = self.cache.tree_plane(table, dstats)
                        heap = kops.topk_init_batched_tree(
                            plane, masks, kb, te, self.mode, mesh=mesh)
                    else:
                        heap = kops.topk_init_batched_device(
                            plane, masks, kb, self.mode, mesh=mesh)
                    self.counters.bump("topk", launches=1,
                                       sharded=self._sharded(),
                                       tree=1 if tree else 0)
                return heap
            return thunk

        def host_oracle():
            # -inf floors: run_topk's own boundary discovery takes over —
            # a weaker starting boundary, never a wrong result
            self.counters.bump("topk", fallbacks=1)
            return None

        heap, _rung = self.ladder.execute(
            self._device_rungs("topk", launch, table=table)
            + [("host_oracle", host_oracle)])
        if heap is None:
            return out
        for row, (i, _scan, k) in enumerate(live):
            out[i] = float(heap[row, k - 1])
        return out

    def topk_init(self, table, scan: ScanSet, order_col: str, desc: bool,
                  k: int) -> float:
        """One query's upfront boundary from the resident plane (signed)."""
        if (scan.match is None or k <= 0 or k > TOPK_INIT_MAX_K
                or not (scan.match == FULL_MATCH).any()):
            return -np.inf
        return self.topk_init_batch(table, order_col, desc, [(scan, k)])[0]

    # -- workload driver ----------------------------------------------------

    def _validate_query(self, q) -> None:
        """Raise the spec's own error for a malformed query spec.

        Probes each scan's predicate against a one-partition stats slice
        (O(1) per scan, not O(P)) so unknown columns and bad literal
        dtypes surface *here*, at validation time — ``run_batch``
        isolates the raise to this query instead of letting it abort the
        batch mid-launch.  Join/order-by column names are checked the
        same way.

        Validity is a pure function of (stats identity, predicate) — a
        table's schema and dtypes are fixed for its lifetime — so clean
        probes are memoized: repeated traffic re-validates by a set
        lookup instead of a per-query probe walk.  Failed probes are
        never cached (a malformed spec raises every time).
        """
        for spec in q.scans.values():
            stats = spec.table.stats
            vkey = (stats.uid, repr(spec.pred))
            if vkey in self._validated:
                continue
            probe = (stats.select(np.zeros(1, dtype=np.int64))
                     if stats.num_partitions > 1 else stats)
            eval_tv(spec.pred, probe)
            if len(self._validated) > self.VERDICT_SEEN_CAP:
                self._validated.clear()
            self._validated.add(vkey)
        if q.join is not None:
            for scan_name, col in ((q.join.build, q.join.build_key),
                                   (q.join.probe, q.join.probe_key)):
                q.scans[scan_name].table.stats.col_id(col)
        if q.order_by is not None:
            scan_name, col, _desc = q.order_by
            q.scans[scan_name].table.stats.col_id(col)

    def _passthrough_report(self, pipeline, q):
        """A no-prune report for a query the engine refused to run
        (malformed spec / unsalvageable failure): every scan keeps all
        live partitions as PARTIAL, no technique applied."""
        from ..core.flow import TechniqueReport
        st = pipeline.make_state(q)
        for name, spec in q.scans.items():
            ss = self._passthrough_set(spec.table)
            st.scan_sets[name] = ss
            st.per_scan[name]["filter"] = TechniqueReport(
                spec.table.num_partitions, len(ss), applied=False,
                detail=dict(path="passthrough"))
        return pipeline.finish(st)

    def run_batch(self, queries: Sequence, pipeline=None) -> List:
        """Full pruning pipelines over a workload, every device-eligible
        stage batched per table group.

        Returns one ``PruningReport`` per query, identical to running
        ``pipeline.run(q)`` per query in the same mode.  Each report
        carries its own copy of THIS batch's counter delta (not the
        service-lifetime totals) for per-stage attribution, including the
        resilience block (``counters["resilience"]``: retries, demotions
        per rung, passthroughs, deadline hits, isolated errors) and the
        plane-integrity block (``counters["integrity"]``).

        Failure contract: ``run_batch`` never raises for a query-shaped
        problem.  Malformed specs are caught at validation time and
        returned as no-prune passthrough reports (``errors`` counter);
        launch/staging/plane failures degrade through the ladder inside
        each stage; an unexpected batch-level failure falls back to
        per-query execution, and a query that still fails gets a
        passthrough report.
        """
        from ..core.flow import PruningPipeline
        if pipeline is None:
            pipeline = PruningPipeline(filter_mode="device", service=self)
        # Only batch device stages when the pipeline itself declares the
        # device path — a host/adaptive pipeline keeps its own semantics.
        device = not pipeline.adaptive and pipeline.filter_mode == "device"
        before = self.counters.snapshot()
        before_staging = self.cache.staging_snapshot()
        before_memory = self.cache.memory.snapshot()
        before_res = resilience_snapshot(self.resilience)
        before_integrity = self.cache.integrity_snapshot()
        # satellite: per-query spec validation — one malformed query
        # becomes one passthrough report, the rest stay on the fast path
        invalid: Dict[int, object] = {}
        valid: List[Tuple[int, object]] = []
        for i, q in enumerate(queries):
            try:
                self._validate_query(q)
            except Exception:
                self.resilience["errors"] += 1
                invalid[i] = q
            else:
                valid.append((i, q))
        states = [pipeline.make_state(q) for _, q in valid]
        try:
            for tech in pipeline.techniques:
                tech.run_batch(pipeline, states,
                               service=self if device else None)
            good = [pipeline.finish(s) for s in states]
        except Exception:
            # Last-resort guard: something outside the ladder's reach
            # broke the batched drive (a host-stage bug, a summary raise).
            # Salvage per query; a query that still fails degrades to a
            # passthrough report instead of taking the batch down.
            self.resilience["salvaged_batches"] += 1
            good = []
            for _i, q in valid:
                try:
                    good.append(pipeline.run(q))
                except Exception:
                    self.resilience["errors"] += 1
                    good.append(self._passthrough_report(pipeline, q))
        reports: List = [None] * len(queries)
        for (i, _q), rep in zip(valid, good):
            reports[i] = rep
        for i, q in invalid.items():
            reports[i] = self._passthrough_report(pipeline, q)
        delta = ServiceCounters.delta(before, self.counters.snapshot())
        after_staging = self.cache.staging_snapshot()
        staging = {k: after_staging[k] - before_staging[k]
                   for k in after_staging}
        memory = PlaneMemoryManager.delta(before_memory,
                                          self.cache.memory.snapshot())
        res = resilience_delta(before_res,
                               resilience_snapshot(self.resilience))
        after_integrity = self.cache.integrity_snapshot()
        integrity = {k: after_integrity[k] - before_integrity[k]
                     for k in after_integrity}
        # PlaneEpoch per table touched by the batch: what the launches
        # actually ran against (version, live count, capacity) — the
        # check that a delta-staged batch served the same table state a
        # fresh restage would.
        planes: Dict[str, dict] = {}
        for q in queries:
            for spec in q.scans.values():
                epoch = self.cache.plane_epoch(spec.table)
                if epoch is not None:
                    planes[spec.table.name] = dataclasses.asdict(epoch)
        for r in reports:
            # each report owns its copy — mutating one never leaks
            r.counters = {**delta,
                          "technique": {k: dict(v)
                                        for k, v in delta["technique"].items()},
                          "staging": dict(staging),
                          "memory": dict(memory),
                          "resilience": {**res,
                                         "demotions": dict(res["demotions"])},
                          "integrity": dict(integrity),
                          "planes": {k: dict(v) for k, v in planes.items()}}
        return reports

    def run_fleet(self, batches: Sequence[Sequence], pipeline=None) -> List:
        """The fleet-scale entry point: a *many-table* workload — a
        sequence of query batches (e.g. rounds of skewed table
        popularity) — driven through ``run_batch`` under the configured
        memory budget and shard mesh.

        Returns one report list per batch.  Each batch's reports carry
        that batch's counter deltas (``counters["memory"]`` shows the
        hits / misses / evictions / restage storms the LRU plane manager
        paid for it); ``fleet_summary()`` aggregates the service-lifetime
        view for budget sizing.
        """
        return [self.run_batch(b, pipeline) for b in batches]

    def fleet_summary(self) -> dict:
        """Service-lifetime memory + staging + launch counters: the
        budget-sizing view (is the budget thrashing? what fraction of
        getter traffic hit resident planes?)."""
        mem = self.cache.memory.snapshot()
        total = mem["hits"] + mem["misses"]
        return dict(memory=mem,
                    staging=self.cache.staging_snapshot(),
                    counters=self.counters.snapshot(),
                    resilience=resilience_snapshot(self.resilience),
                    integrity=self.cache.integrity_snapshot(),
                    latency=dict(self.latency),
                    plane_hit_rate=(mem["hits"] / total) if total else 0.0)
