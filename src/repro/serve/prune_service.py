"""PruningService: the workload-facing entry point of the device plane.

A production metadata service (paper Sec. 2) answers pruning questions for
*every* query of a heavy workload, not one query at a time.  This service
accepts a batch of ``core.flow.Query`` objects and runs their filter
pruning as a handful of batched kernel launches:

  1. each scan's predicate is lowered to conjunctive ranges
     (``extract_ranges``); non-lowerable predicates fall back to the host
     evaluator per scan (counted, never wrong);
  2. lowered scans are **grouped by table**; each table's metadata plane is
     fetched from the ``DeviceStatsCache`` (staged once per table version,
     an on-device gather afterwards);
  3. one ``minmax_prune_batched`` launch per table group evaluates all of
     its queries' constraints — Q on the sublane dim, constraints padded
     into power-of-two K-buckets — and the resulting ``[Q, P]`` tv rows
     are scattered back into per-query ``ScanSet``s.

``PruningPipeline(filter_mode="device")`` delegates its filter stage here
(single-query batches share the same resident planes), and ``run_batch``
drives whole pipelines over a workload with the filter stage batched.

DML: route mutations through ``notify_insert / notify_delete /
notify_update`` — they bump the table's ``TableVersion`` and invalidate
the staged planes, so the next batch re-stages fresh metadata.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import expr as E
from ..core.device_stats import DeviceStatsCache
from ..core.metadata import NO_MATCH, ScanSet
from ..core.predicate_cache import TableVersion
from ..core.prune_filter import eval_tv, extract_ranges
from ..kernels import ops as kops


@dataclasses.dataclass
class ServiceCounters:
    queries: int = 0
    scans: int = 0
    launches: int = 0          # batched kernel launches (per table group)
    host_fallbacks: int = 0    # scans whose predicate didn't lower


class PruningService:
    def __init__(
        self,
        mode: str = "auto",            # kernel mode: auto|pallas|interpret|ref
        cache: Optional[DeviceStatsCache] = None,
    ):
        self.mode = mode
        self.cache = cache if cache is not None else DeviceStatsCache()
        self.versions: Dict[str, TableVersion] = {}
        self.counters = ServiceCounters()

    # -- DML bookkeeping ----------------------------------------------------

    def register(self, table) -> TableVersion:
        tv = self.versions.get(table.name)
        if tv is None:
            tv = TableVersion(table.num_partitions)
            self.versions[table.name] = tv
        return tv

    def notify_insert(self, table_name: str, n_partitions: int) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.insert_partitions(n_partitions)
        self.cache.on_insert(table_name)

    def notify_delete(self, table_name: str) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.version += 1
        self.cache.on_delete(table_name)

    def notify_update(self, table_name: str, column: str) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.version += 1
        self.cache.on_update(table_name, column)

    # -- pruning ------------------------------------------------------------

    @staticmethod
    def _scan_set(tv: np.ndarray) -> ScanSet:
        keep = tv > NO_MATCH
        return ScanSet(np.where(keep)[0], tv[keep])

    def scan_tv(self, spec) -> Optional[np.ndarray]:
        """Device tv [P] for one scan, or None when it doesn't lower.

        The single-query fast path of the batched plane: resident stats,
        Q padded to one sublane tile.  ``PruningPipeline`` calls this for
        ``filter_mode="device"``.  Counts scans/launches/fallbacks like
        prune_batch (``queries`` is only tracked by the batch API, which
        knows query boundaries).
        """
        self.counters.scans += 1
        ranges = extract_ranges(spec.pred, spec.table.stats)
        if ranges is None:
            self.counters.host_fallbacks += 1
            return None
        dstats = self.cache.get(spec.table, self.versions.get(spec.table.name))
        self.counters.launches += 1
        return kops.prune_ranges_batched_device([ranges], dstats, self.mode)[0]

    def prune_batch(self, queries: Sequence) -> List[Dict[str, ScanSet]]:
        """Filter-prune a batch of queries; per-query scan_name -> ScanSet.

        One batched kernel launch per distinct table (not per query);
        queries whose predicates don't lower are evaluated on the host.
        """
        self.counters.queries += len(queries)
        results: List[Dict[str, ScanSet]] = [dict() for _ in queries]
        # id(table) -> (table, [(query idx, scan name, ranges), ...])
        groups: Dict[int, Tuple[object, list]] = {}
        fallbacks: List[Tuple[int, str, object]] = []
        for qi, q in enumerate(queries):
            for name, spec in q.scans.items():
                self.counters.scans += 1
                if isinstance(spec.pred, E.TruePred):
                    results[qi][name] = ScanSet.full(spec.table.num_partitions)
                    continue
                ranges = extract_ranges(spec.pred, spec.table.stats)
                if ranges is None:
                    fallbacks.append((qi, name, spec))
                    continue
                groups.setdefault(id(spec.table), (spec.table, []))[1].append(
                    (qi, name, ranges))
        for table, jobs in groups.values():
            dstats = self.cache.get(table, self.versions.get(table.name))
            tv_rows = kops.prune_ranges_batched_device(
                [ranges for _, _, ranges in jobs], dstats, self.mode)
            self.counters.launches += 1
            for (qi, name, _), tv in zip(jobs, tv_rows):
                results[qi][name] = self._scan_set(tv)
        for qi, name, spec in fallbacks:
            self.counters.host_fallbacks += 1
            results[qi][name] = self._scan_set(eval_tv(spec.pred, spec.table.stats))
        return results

    def run_batch(self, queries: Sequence, pipeline=None) -> List:
        """Full pruning pipelines over a workload, filter stage batched.

        Returns one ``PruningReport`` per query, identical to running
        ``pipeline.run(q)`` per query with ``filter_mode="device"``.
        """
        from ..core.flow import PruningPipeline
        if pipeline is None:
            pipeline = PruningPipeline(filter_mode="device", service=self)
        # Only batch the filter stage when the pipeline itself declares the
        # device path — a host/adaptive pipeline keeps its own semantics.
        if (pipeline.enable_filter and not pipeline.adaptive
                and pipeline.filter_mode == "device"):
            filter_sets = self.prune_batch(queries)
        else:
            filter_sets = [None] * len(queries)
        return [pipeline.run(q, filter_sets=filter_sets[i])
                for i, q in enumerate(queries)]
