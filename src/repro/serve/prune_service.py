"""PruningService: the workload-facing engine of the device plane.

A production metadata service (paper Sec. 2) answers pruning questions for
*every* query of a heavy workload, not one query at a time.  This service
accepts a batch of ``core.flow.Query`` objects and drives the pipeline's
full **technique sequence** (filter -> LIMIT -> JOIN -> top-k) over them,
batching every device-eligible stage per table group:

  * **filter** (``prune_batch``): each scan's predicate is lowered to
    conjunctive ranges; lowered scans are grouped by table and evaluated
    by one ``minmax_prune_batched`` launch per group against the resident
    [C, P] planes (non-lowerable predicates fall back to the host
    evaluator, counted, never wrong);
  * **join** (``join_hit_batch`` / ``bloom_hit_batch``): build-side
    summaries stay host-side (they are runtime values), but the probe-side
    matching runs on the resident planes — the distinct-key overlap as one
    ``join_overlap_batched`` launch per (table, key column) group against
    the join-key plane, and the Bloom narrow-range enumeration as one
    ``bloom_probe_batched`` launch per group against the enumeration
    plane (non-integer key domains keep the host matcher, counted per
    technique under ``join_bloom``);
  * **top-k** (``topk_init_batch``): the Sec. 5.4 upfront boundary is
    initialized as the k-th largest value over each query's
    fully-matching partitions' resident block-top-k rows — one
    ``topk_init_batched`` launch per (table, order column, direction)
    group.

Kernel launches per stage are therefore bounded by the number of distinct
tables (groups), not by the number of queries, and ``run_batch`` produces
``PruningReport``s bit-identical to per-query ``PruningPipeline.run`` in
the same mode (the batched launches evaluate exactly the same per-query
math, packed).

``PruningPipeline(filter_mode="device")`` delegates each stage here for
single queries (Q=1 batches share the same resident planes).

Counters: ``ServiceCounters`` tracks launches and host fallbacks both in
aggregate and per technique (``counters.technique``), and ``run_batch``
attaches a snapshot to every report (``PruningReport.counters``) so
benchmarks can attribute speedups per stage.

Fleet scale (PR 5): ``budget_bytes`` puts every resident plane family
under one HBM budget (``core.device_stats.PlaneMemoryManager`` — LRU
eviction, in-flight pinning around each batched launch, hit / miss /
eviction / restage-storm counters in ``counters["memory"]``), and
``shard_mesh`` partition-shards every batched launch over a 1-D device
mesh (``launch.mesh.make_plane_mesh``) so a table's planes can outgrow
one device.  ``run_fleet`` drives a many-table workload — thousands of
tables churning through the budget — and ``fleet_summary`` reports the
budget-sizing view.

DML: mutations made through the Table's own streaming methods
(``append_partitions`` / ``drop_partitions`` / ``rewrite_partitions`` /
``update_column``) log ``TableDelta``s, and the resident planes
*delta-sync* on the next batch — appends stage O(ΔP), drops scatter
sentinels, nothing is invalidated (``notify_append/drop/rewrite`` keep
the ``TableVersion`` bookkeeping aligned).  The legacy ``notify_insert /
notify_delete / notify_update`` path still bumps the version and
invalidates outright, forcing a full restage — never wrong, just the
pre-ingest cost.  Updates are column-scoped either way: the join-key /
enum / block-top-k planes of *other* columns stay resident (see
``DeviceStatsCache``).  Per-batch staging work and the ``PlaneEpoch``
each table's launches ran against are attached to every report
(``counters["staging"]`` / ``counters["planes"]``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import expr as E
from ..core.device_stats import (DeviceStatsCache, PlaneEpoch,
                                 PlaneMemoryManager)
from ..core.metadata import (FULL_MATCH, NO_MATCH, ScanSet, live_full_scan,
                             mask_dead_partitions)
from ..core.predicate_cache import TableVersion
from ..core.prune_filter import eval_tv, extract_ranges
from ..core.prune_join import DEFAULT_ENUM_LIMIT, BuildSummary
from ..kernels import ops as kops

# Boundary-init k cap: the kernel's rank-selection merge is quadratic in
# (k bucket + KPLANE), so the per-step comparison tensor must stay well
# inside VMEM — at 128 it is [8, 192, 192] (~1.2MB).  Larger k also gains
# little from the plane (each partition contributes at most KPLANE=64
# witnessed rows); such queries keep the host-only init.
TOPK_INIT_MAX_K = 128


@dataclasses.dataclass
class ServiceCounters:
    queries: int = 0
    scans: int = 0
    launches: int = 0          # batched kernel launches, all techniques
    host_fallbacks: int = 0    # host fallbacks, all techniques
    sharded_launches: int = 0  # launches that ran partition-sharded
    # per-technique attribution: {'filter': {'launches': n, 'fallbacks': m}}
    technique: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)

    def bump(self, tech: str, launches: int = 0, fallbacks: int = 0,
             sharded: int = 0) -> None:
        t = self.technique.setdefault(tech, dict(launches=0, fallbacks=0))
        t["launches"] += launches
        t["fallbacks"] += fallbacks
        self.launches += launches
        self.host_fallbacks += fallbacks
        self.sharded_launches += sharded

    def snapshot(self) -> dict:
        return dict(queries=self.queries, scans=self.scans,
                    launches=self.launches,
                    host_fallbacks=self.host_fallbacks,
                    sharded_launches=self.sharded_launches,
                    technique={k: dict(v) for k, v in self.technique.items()})

    @staticmethod
    def delta(before: dict, after: dict) -> dict:
        """after - before of two snapshots: the activity in between."""
        out = {k: after[k] - before[k]
               for k in ("queries", "scans", "launches", "host_fallbacks",
                         "sharded_launches")}
        zero = dict(launches=0, fallbacks=0)
        out["technique"] = {
            t: {f: v - before["technique"].get(t, zero)[f]
                for f, v in fields.items()}
            for t, fields in after["technique"].items()}
        return out


class PruningService:
    def __init__(
        self,
        mode: str = "auto",            # kernel mode: auto|pallas|interpret|ref
        cache: Optional[DeviceStatsCache] = None,
        budget_bytes: Optional[int] = None,  # HBM budget across all resident
                                             # plane families (None: unbounded)
        shard_mesh=None,               # 1-D 'parts' mesh (True: build the
                                       # host plane mesh) — partition-shards
                                       # every batched launch
    ):
        self.mode = mode
        if cache is None:
            cache = DeviceStatsCache(budget_bytes=budget_bytes)
        elif budget_bytes is not None:
            # A shared cache's budget belongs to whoever set it: only
            # adopt ours when none is configured — silently re-budgeting
            # a cache other services share would evict planes they
            # sized their budget for.
            if cache.memory.budget_bytes is None:
                cache.memory.budget_bytes = budget_bytes
            elif cache.memory.budget_bytes != budget_bytes:
                raise ValueError(
                    f"cache already budgeted at "
                    f"{cache.memory.budget_bytes} bytes; refusing to "
                    f"re-budget to {budget_bytes}")
        self.cache = cache
        if shard_mesh is True:
            from ..launch.mesh import make_plane_mesh
            shard_mesh = make_plane_mesh()
        self.shard_mesh = shard_mesh
        self.versions: Dict[str, TableVersion] = {}
        self.counters = ServiceCounters()

    @staticmethod
    def _sharded() -> int:
        """1 when the launch that just returned actually ran sharded
        (the kernel wrappers can demote a mesh-eligible launch back to
        unsharded when the jnp-oracle footprint exceeds the slab
        bound — the counter reports what ran, not eligibility)."""
        return 1 if kops.last_launch_shards() > 1 else 0

    # -- DML bookkeeping ----------------------------------------------------

    def register(self, table) -> TableVersion:
        tv = self.versions.get(table.name)
        if tv is None:
            tv = TableVersion(table.num_partitions)
            self.versions[table.name] = tv
        return tv

    def notify_insert(self, table_name: str, n_partitions: int) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.insert_partitions(n_partitions)
        self.cache.on_insert(table_name)

    def notify_delete(self, table_name: str) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.version += 1
        self.cache.on_delete(table_name)

    def notify_update(self, table_name: str, column: str) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.version += 1
        self.cache.on_update(table_name, column)

    # -- streaming DML (delta-staged; planes stay resident) ----------------
    # Use these when the mutation went through the Table's own DML methods
    # (append_partitions / drop_partitions / rewrite_partitions /
    # update_column): the table's delta log lets the cache sync resident
    # planes in place, so unlike notify_insert/delete/update nothing is
    # invalidated here — only the TableVersion bookkeeping advances.

    def notify_append(self, table_name: str, n_partitions: int) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.insert_partitions(n_partitions)

    def notify_drop(self, table_name: str) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.version += 1

    def notify_rewrite(self, table_name: str) -> None:
        tv = self.versions.get(table_name)
        if tv is not None:
            tv.version += 1

    def plane_epoch(self, table) -> Optional[PlaneEpoch]:
        """(version, live count, capacity) of the table's resident plane."""
        return self.cache.plane_epoch(table)

    # -- filter stage -------------------------------------------------------

    @staticmethod
    def _scan_set(tv: np.ndarray, table=None) -> ScanSet:
        if table is not None:
            tv = mask_dead_partitions(tv, table)
        keep = tv > NO_MATCH
        return ScanSet(np.where(keep)[0], tv[keep])

    def scan_tv(self, spec) -> Optional[np.ndarray]:
        """Device tv [P] for one scan, or None when it doesn't lower.

        The single-query fast path of the batched plane: resident stats,
        Q padded to one sublane tile.  ``PruningPipeline`` calls this for
        ``filter_mode="device"``.  Counts scans/launches/fallbacks like
        prune_batch (``queries`` is only tracked by the batch API, which
        knows query boundaries).
        """
        self.counters.scans += 1
        ranges = extract_ranges(spec.pred, spec.table.stats)
        if ranges is None:
            self.counters.bump("filter", fallbacks=1)
            return None
        with self.cache.pin_scope():
            dstats = self.cache.get(spec.table,
                                    self.versions.get(spec.table.name))
            tv = kops.prune_ranges_batched_device(
                [ranges], dstats, self.mode, mesh=self.shard_mesh)[0]
            self.counters.bump("filter", launches=1,
                               sharded=self._sharded())
            return tv

    def prune_batch(self, queries: Sequence) -> List[Dict[str, ScanSet]]:
        """Filter-prune a batch of queries; per-query scan_name -> ScanSet.

        One batched kernel launch per distinct table (not per query);
        queries whose predicates don't lower are evaluated on the host.
        """
        self.counters.queries += len(queries)
        results: List[Dict[str, ScanSet]] = [dict() for _ in queries]
        # id(table) -> (table, [(query idx, scan name, ranges), ...])
        groups: Dict[int, Tuple[object, list]] = {}
        fallbacks: List[Tuple[int, str, object]] = []
        for qi, q in enumerate(queries):
            for name, spec in q.scans.items():
                self.counters.scans += 1
                if isinstance(spec.pred, E.TruePred):
                    results[qi][name] = live_full_scan(spec.table)
                    continue
                ranges = extract_ranges(spec.pred, spec.table.stats)
                if ranges is None:
                    fallbacks.append((qi, name, spec))
                    continue
                groups.setdefault(id(spec.table), (spec.table, []))[1].append(
                    (qi, name, ranges))
        for table, jobs in groups.values():
            # Pin scope: the planes this launch gathered from must not be
            # evicted (by another table's staging under the budget) while
            # the launch is in flight.
            with self.cache.pin_scope():
                dstats = self.cache.get(table, self.versions.get(table.name))
                tv_rows = kops.prune_ranges_batched_device(
                    [ranges for _, _, ranges in jobs], dstats, self.mode,
                    mesh=self.shard_mesh)
                self.counters.bump("filter", launches=1,
                                   sharded=self._sharded())
            for (qi, name, _), tv in zip(jobs, tv_rows):
                results[qi][name] = self._scan_set(tv, table)
        for qi, name, spec in fallbacks:
            self.counters.bump("filter", fallbacks=1)
            results[qi][name] = self._scan_set(
                eval_tv(spec.pred, spec.table.stats), spec.table)
        return results

    # -- join stage ---------------------------------------------------------

    def join_device_eligible(self, summary: BuildSummary, table=None,
                             key_col: Optional[str] = None) -> bool:
        """Can this summary's probe-side matching run on the device plane?

        Distinct summaries need their keys finite in f32 (join-key plane
        overlap).  Bloom summaries need the probe table/key column: the
        kernel's narrow-range enumeration hashes *int32* candidates with
        the shared murmur mixer, so the key column must be an
        integer/dictionary domain wholly inside int32 — fractional or
        out-of-range keys keep the host matcher so batched output stays
        bit-identical to it — and the filter must fit the kernel's block
        cap (``kops.BLOOM_MAX_BLOCKS``).  The int32-domain check is the
        cached ``domain_ok`` of the enumeration plane — table-version
        invariant, so eligibility never rescans [P] stats per query.
        Empty summaries are host short-circuits, not kernel work.
        """
        if summary.empty:
            return False
        if summary.distinct is not None:
            d32 = np.asarray(summary.distinct,
                             dtype=np.float64).astype(np.float32)
            return bool(np.isfinite(d32).all())
        if summary.bloom is None or table is None or key_col is None:
            return False
        if summary.bloom.n_blocks > kops.BLOOM_MAX_BLOCKS:
            return False
        if table.stats.column(key_col).kind == "float":
            return False
        return self.cache.enum_plane(table, key_col)[3]

    def join_hit_batch(self, table, key_col: str,
                       summaries: Sequence[BuildSummary],
                       part_ids: Optional[Sequence[np.ndarray]] = None
                       ) -> np.ndarray:
        """hit [G, P] for a (table, key column) group — one launch.

        ``part_ids`` optionally restricts the no-Pallas fallback to each
        query's scan set (entries outside it are 0 and must not be read);
        the kernel path always evaluates the resident plane dense.
        """
        with self.cache.pin_scope():
            pmin, pmax = self.cache.join_key_plane(table, key_col)
            hit = kops.join_overlap_batched_device(
                [s.distinct for s in summaries], pmin, pmax, self.mode,
                part_ids_lists=part_ids, mesh=self.shard_mesh)
            self.counters.bump("join", launches=1,
                               sharded=self._sharded())
        return hit

    def bloom_hit_batch(self, table, key_col: str,
                        summaries: Sequence[BuildSummary],
                        part_ids: Optional[Sequence[np.ndarray]] = None,
                        enum_limit: int = DEFAULT_ENUM_LIMIT) -> np.ndarray:
        """hit [G, P] for a (table, key column) group of Bloom summaries —
        one batched narrow-range enumeration launch over the resident
        enumeration plane (``part_ids`` restricts the no-Pallas fallback
        to each query's scan set, like ``join_hit_batch``)."""
        with self.cache.pin_scope():
            pmin, width, wmax, _domain_ok = self.cache.enum_plane(table,
                                                                  key_col)
            hit = kops.bloom_probe_batched_device(
                [s.bloom for s in summaries], pmin, width, wmax, enum_limit,
                self.mode, part_ids_lists=part_ids, mesh=self.shard_mesh)
            self.counters.bump("join_bloom", launches=1,
                               sharded=self._sharded())
        return hit

    def join_hit(self, table, key_col: str, summary: BuildSummary,
                 part_ids: Optional[np.ndarray] = None
                 ) -> Optional[np.ndarray]:
        """hit [P] for one query, or None -> host path (counted per
        technique — ``join`` for distinct, ``join_bloom`` for Bloom —
        unless the summary is empty, which the host handles as a trivial
        wipe)."""
        if not self.join_device_eligible(summary, table, key_col):
            if not summary.empty:
                self.counters.bump(
                    "join_bloom" if summary.bloom is not None else "join",
                    fallbacks=1)
            return None
        pid = None if part_ids is None else [part_ids]
        if summary.distinct is not None:
            return self.join_hit_batch(table, key_col, [summary],
                                       part_ids=pid)[0]
        return self.bloom_hit_batch(table, key_col, [summary],
                                    part_ids=pid)[0]

    # -- top-k stage --------------------------------------------------------

    def topk_init_batch(self, table, order_col: str, desc: bool,
                        jobs: Sequence[Tuple[ScanSet, int]]) -> List[float]:
        """Per-query upfront boundaries for a (table, column, direction)
        group — one ``topk_init_batched`` launch.

        Each job is ``(scan_set, effective_k)``; the boundary is the k-th
        largest resident block-top-k value over the scan set's
        fully-matching partitions (signed domain), or -inf when fewer
        than k candidates exist.  Launch heaps are sized to the group's
        k bucket; a prefix of a larger heap is the exact smaller-k
        answer, so mixed-k groups share one launch.
        """
        # Jobs whose k is out of the useful range never consult the heap —
        # exclude them up front so they neither widen the group's k bucket
        # (merge cost grows with kb^2) nor force a launch alone.
        live: List[Tuple[int, ScanSet, int]] = []
        any_candidates = False
        for i, (scan, k) in enumerate(jobs):
            if scan.match is None or not (0 < int(k) <= TOPK_INIT_MAX_K):
                continue
            live.append((i, scan, int(k)))
        out = [-np.inf] * len(jobs)
        if not live:
            return out
        P = table.num_partitions
        masks = np.zeros((len(live), P), dtype=np.float32)
        for row, (_i, scan, _k) in enumerate(live):
            full_ids = scan.part_ids[scan.match == FULL_MATCH]
            masks[row, full_ids] = 1.0
            any_candidates |= full_ids.size > 0
        if not any_candidates:
            return out                     # nothing to bound; skip the launch
        kb = kops.k_bucket(max(k for _, _, k in live))
        with self.cache.pin_scope():
            plane = self.cache.block_topk_plane(table, order_col, desc)
            heap = kops.topk_init_batched_device(plane, masks, kb, self.mode,
                                                 mesh=self.shard_mesh)
            self.counters.bump("topk", launches=1,
                               sharded=self._sharded())
        for row, (i, _scan, k) in enumerate(live):
            out[i] = float(heap[row, k - 1])
        return out

    def topk_init(self, table, scan: ScanSet, order_col: str, desc: bool,
                  k: int) -> float:
        """One query's upfront boundary from the resident plane (signed)."""
        if (scan.match is None or k <= 0 or k > TOPK_INIT_MAX_K
                or not (scan.match == FULL_MATCH).any()):
            return -np.inf
        return self.topk_init_batch(table, order_col, desc, [(scan, k)])[0]

    # -- workload driver ----------------------------------------------------

    def run_batch(self, queries: Sequence, pipeline=None) -> List:
        """Full pruning pipelines over a workload, every device-eligible
        stage batched per table group.

        Returns one ``PruningReport`` per query, identical to running
        ``pipeline.run(q)`` per query in the same mode.  Each report
        carries its own copy of THIS batch's counter delta (not the
        service-lifetime totals) for per-stage attribution.
        """
        from ..core.flow import PruningPipeline
        if pipeline is None:
            pipeline = PruningPipeline(filter_mode="device", service=self)
        # Only batch device stages when the pipeline itself declares the
        # device path — a host/adaptive pipeline keeps its own semantics.
        device = not pipeline.adaptive and pipeline.filter_mode == "device"
        before = self.counters.snapshot()
        before_staging = self.cache.staging_snapshot()
        before_memory = self.cache.memory.snapshot()
        states = [pipeline.make_state(q) for q in queries]
        for tech in pipeline.techniques:
            tech.run_batch(pipeline, states, service=self if device else None)
        reports = [pipeline.finish(s) for s in states]
        delta = ServiceCounters.delta(before, self.counters.snapshot())
        after_staging = self.cache.staging_snapshot()
        staging = {k: after_staging[k] - before_staging[k]
                   for k in after_staging}
        memory = PlaneMemoryManager.delta(before_memory,
                                          self.cache.memory.snapshot())
        # PlaneEpoch per table touched by the batch: what the launches
        # actually ran against (version, live count, capacity) — the
        # check that a delta-staged batch served the same table state a
        # fresh restage would.
        planes: Dict[str, dict] = {}
        for q in queries:
            for spec in q.scans.values():
                epoch = self.cache.plane_epoch(spec.table)
                if epoch is not None:
                    planes[spec.table.name] = dataclasses.asdict(epoch)
        for r in reports:
            # each report owns its copy — mutating one never leaks
            r.counters = {**delta,
                          "technique": {k: dict(v)
                                        for k, v in delta["technique"].items()},
                          "staging": dict(staging),
                          "memory": dict(memory),
                          "planes": {k: dict(v) for k, v in planes.items()}}
        return reports

    def run_fleet(self, batches: Sequence[Sequence], pipeline=None) -> List:
        """The fleet-scale entry point: a *many-table* workload — a
        sequence of query batches (e.g. rounds of skewed table
        popularity) — driven through ``run_batch`` under the configured
        memory budget and shard mesh.

        Returns one report list per batch.  Each batch's reports carry
        that batch's counter deltas (``counters["memory"]`` shows the
        hits / misses / evictions / restage storms the LRU plane manager
        paid for it); ``fleet_summary()`` aggregates the service-lifetime
        view for budget sizing.
        """
        return [self.run_batch(b, pipeline) for b in batches]

    def fleet_summary(self) -> dict:
        """Service-lifetime memory + staging + launch counters: the
        budget-sizing view (is the budget thrashing? what fraction of
        getter traffic hit resident planes?)."""
        mem = self.cache.memory.snapshot()
        total = mem["hits"] + mem["misses"]
        return dict(memory=mem,
                    staging=self.cache.staging_snapshot(),
                    counters=self.counters.snapshot(),
                    plane_hit_rate=(mem["hits"] / total) if total else 0.0)
