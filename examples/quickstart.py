"""Quickstart: the paper's four pruning techniques in ~60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import expr as E
from repro.core.flow import JoinSpec, PruningPipeline, Query, TableScanSpec
from repro.data.generator import make_events_table, make_users_table
from repro.data.scan import execute_query

rng = np.random.default_rng(0)

# A production-shaped fact table: 200 micro-partitions, clustered by time.
events = make_events_table(rng, n_rows=200_000, rows_per_partition=1000,
                           user_clustering=0.995)
users = make_users_table(rng, n_rows=20_000)

# -- 1. filter pruning (Sec. 3): a tight recent-time window ---------------
q = Query(scans={"events": TableScanSpec(events, E.col("ts") >= 9_950_000)})
report = PruningPipeline().run(q)
f = report.per_scan["events"]["filter"]
print(f"filter pruning : {f.before} -> {f.after} partitions "
      f"({f.ratio:.1%} pruned)")

# -- 2. LIMIT pruning (Sec. 4): fully-matching partitions ------------------
q = Query(scans={"events": TableScanSpec(events, E.col("ts") >= 5_000_000)},
          limit=100)
report = PruningPipeline().run(q)
l = report.per_scan["events"]["limit"]
print(f"LIMIT pruning  : {l.before} -> {l.after} partitions "
      f"(category: {l.detail['category']})")
res = execute_query(q, report)
print(f"                 {res.num_rows} rows returned, "
      f"{res.total_bytes()/1e6:.2f} MB scanned")

# -- 3. top-k pruning (Sec. 5): boundary values -----------------------------
q = Query(scans={"events": TableScanSpec(events, E.col("score") >= 0.5)},
          limit=10, order_by=("events", "num_sightings", True))
report = PruningPipeline().run(q)
t = report.per_scan["events"]["topk"]
print(f"top-k pruning  : {t.before} -> {t.after} partitions "
      f"({t.ratio:.1%} skipped by the boundary value)")

# -- 4. join pruning (Sec. 6): build-side summaries -------------------------
q = Query(
    scans={
        "users": TableScanSpec(users, E.col("age") >= 80),
        "events": TableScanSpec(events),
    },
    join=JoinSpec("users", "events", "id", "user_id"),
)
report = PruningPipeline().run(q)
j = report.per_scan["events"]["join"]
print(f"join pruning   : {j.before} -> {j.after} partitions "
      f"({j.ratio:.1%} pruned, summary={j.detail['summary_kind']}, "
      f"{j.detail['summary_bytes']} bytes shipped)")

# -- everything together (the paper's guiding example shape) ----------------
q = Query(
    scans={
        "users": TableScanSpec(users, E.col("age") >= 80),
        "events": TableScanSpec(events, E.col("score") >= 0.25),
    },
    join=JoinSpec("users", "events", "id", "user_id"),
    limit=3, order_by=("events", "num_sightings", True),
)
report = PruningPipeline().run(q)
print(f"combined       : overall pruning ratio {report.overall_ratio:.1%}")
